//! The L1/L2 ↔ L3 bridge: load the AOT-compiled JAX/Pallas layer step
//! from `artifacts/` and cross-check it against the native rust engine's
//! math on the same dense model.
//!
//! ```text
//! make artifacts && cargo run --release --example xla_layer
//! ```

use mscm_xmr::inference::sigmoid;
use mscm_xmr::runtime::{Tensor, XlaRuntime};
use mscm_xmr::util::{Json, Rng};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let meta_raw = std::fs::read_to_string(format!("{dir}/meta.json"))
        .map_err(|e| anyhow::anyhow!("{e}; run `make artifacts` first"))?;
    let meta = Json::parse(&meta_raw).map_err(anyhow::Error::msg)?;
    let geti = |k: &str| meta.get(k).and_then(|v| v.as_f64()).unwrap() as usize;
    let (n, d, b1, b2) = (geti("n"), geti("d"), geti("b1"), geti("b2"));
    println!("artifact shapes: n={n} d={d} b1={b1} b2={b2}");

    let rt = XlaRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // Random dense inputs.
    let mut rng = Rng::seed_from_u64(42);
    let x = Tensor::new(
        (0..n * d).map(|_| rng.gen_normal() * 0.2).collect(),
        vec![n, d],
    );
    let w1 = Tensor::new(
        (0..d * b1).map(|_| rng.gen_normal() * 0.05).collect(),
        vec![1, d, b1],
    );
    let mask = Tensor::new(vec![1.0; n], vec![n, 1]);
    let ps = Tensor::new(vec![1.0; n], vec![n, 1]);

    // 1. matmul_only: the bare Pallas MSCM kernel.
    let matmul = rt.load_hlo_text(format!("{dir}/matmul_only.hlo.txt"))?;
    let out = matmul.run(&[x.clone(), w1.clone(), mask.clone(), ps.clone()])?;
    let scores = &out[0];
    assert_eq!(scores.dims, vec![n, b1]);

    // Cross-check against rust math: sigmoid(x_i · w_col).
    let mut max_err = 0f32;
    for i in 0..n {
        for c in 0..b1 {
            let mut a = 0f32;
            for k in 0..d {
                a += x.data[i * d + k] * w1.data[k * b1 + c];
            }
            let want = sigmoid(a);
            let got = scores.data[i * b1 + c];
            max_err = max_err.max((want - got).abs());
        }
    }
    println!("matmul_only: max |rust - xla| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-4, "kernel mismatch");

    // 2. layer_step: kernel + top-b beam.
    let beam = geti("beam");
    let layer = rt.load_hlo_text(format!("{dir}/layer_step.hlo.txt"))?;
    let out = layer.run(&[x.clone(), w1.clone(), mask, ps])?;
    let (top_s, top_i) = (&out[0], &out[1]);
    assert_eq!(top_s.dims, vec![n, beam]);
    for i in 0..n {
        // top scores must be the beam largest of row i of the kernel output
        let mut row: Vec<f32> = (0..b1).map(|c| scores.data[i * b1 + c]).collect();
        row.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for (k, &s) in top_s.data[i * beam..(i + 1) * beam].iter().enumerate() {
            anyhow::ensure!((s - row[k]).abs() < 1e-5, "beam mismatch at ({i},{k})");
        }
    }
    println!("layer_step: top-{beam} beam matches rust selection");
    let _ = top_i;

    // 3. full_inference: the two-layer tree end to end.
    let w2 = Tensor::new(
        (0..b1 * d * b2).map(|_| rng.gen_normal() * 0.05).collect(),
        vec![b1, d, b2],
    );
    let full = rt.load_hlo_text(format!("{dir}/full_inference.hlo.txt"))?;
    let out = full.run(&[x.clone(), w1.clone(), w2.clone()])?;
    let topk = geti("topk");
    assert_eq!(out[0].dims, vec![n, topk]);
    // rust reference: exhaustive two-layer beam with the same widths
    for i in 0..n {
        let mut l1: Vec<(usize, f32)> = (0..b1)
            .map(|c| (c, scores.data[i * b1 + c]))
            .collect();
        l1.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        l1.truncate(beam);
        let mut cands: Vec<f32> = Vec::new();
        for &(p, ps) in &l1 {
            for c in 0..b2 {
                let mut a = 0f32;
                for k in 0..d {
                    a += x.data[i * d + k] * w2.data[(p * d + k) * b2 + c];
                }
                cands.push(ps * sigmoid(a));
            }
        }
        cands.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for k in 0..topk {
            let got = out[0].data[i * topk + k];
            anyhow::ensure!(
                (got - cands[k]).abs() < 1e-4,
                "full_inference mismatch at ({i},{k}): {got} vs {}",
                cands[k]
            );
        }
    }
    println!("full_inference: end-to-end scores match rust reference");
    println!("\nxla_layer OK — the AOT Pallas/JAX stack and the rust engine agree");
    Ok(())
}
