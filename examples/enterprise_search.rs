//! **End-to-end driver** (DESIGN.md §7): enterprise-scale semantic
//! product search served through the full L3 stack.
//!
//! Synthesizes a §6-shaped model (default 1M products, d=400K, B=32 — a
//! 1/100-scale stand-in for the paper's proprietary 100M-product model),
//! starts the coordinator (router → dynamic batcher → worker pool over
//! the MSCM engine), drives an open-loop query load, and reports
//! throughput plus avg/P95/P99 latency; then repeats with the non-MSCM
//! baseline engine to measure the paper's headline speedup end to end.
//!
//! ```text
//! cargo run --release --example enterprise_search            # full (~1M labels)
//! cargo run --release --example enterprise_search -- --quick # CI-sized
//! ```
//!
//! Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;
use std::time::{Duration, Instant};

use mscm_xmr::coordinator::{Coordinator, CoordinatorConfig};
use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};

fn run_load(
    label: &str,
    engine: Arc<InferenceEngine>,
    queries: &mscm_xmr::sparse::CsrMatrix,
    rps: u64,
    workers: usize,
) -> (f64, f64, f64, f64, f64) {
    // Warm the engine (page in the model, build caches) outside the
    // measured window so the first configuration is not penalized, and
    // measure the direct service time to pick a non-saturating arrival
    // rate (open-loop at >~60% utilization on this box just measures the
    // queue, not the engine).
    let service_ms = {
        let mut ws = engine.workspace();
        let warm = queries.rows.min(64);
        for i in 0..warm {
            std::hint::black_box(engine.predict_with(&queries.row_owned(i), 10, 10, &mut ws));
        }
        let t = Instant::now();
        for i in 0..warm {
            std::hint::black_box(engine.predict_with(&queries.row_owned(i), 10, 10, &mut ws));
        }
        t.elapsed().as_secs_f64() * 1e3 / warm as f64
    };
    let rps = rps.min((600.0 / service_ms) as u64).max(50);
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            workers,
            max_batch: 32,
            // Sub-ms engines want minimal coalescing delay; batches still
            // form naturally under queueing.
            max_batch_delay: Duration::from_micros(50),
            beam: 10,
            topk: 10,
            queue_capacity: 100_000,
        },
    );
    let n = queries.rows;
    let interval = Duration::from_nanos(1_000_000_000 / rps);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let target = t0 + interval * i as u32;
        if let Some(sleep) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(sleep);
        }
        match coord.submit(queries.row_owned(i)) {
            Ok((_, rx)) => rxs.push(rx),
            Err(e) => panic!("submit failed: {e}"),
        }
    }
    let mut got = 0usize;
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(60)).expect("reply");
        assert_eq!(r.predictions.len(), 10);
        got += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = coord.stats();
    let qps = got as f64 / wall;
    let (avg, p95, p99) = (
        stats.latency.mean_ms(),
        stats.latency.quantile_ms(0.95),
        stats.latency.quantile_ms(0.99),
    );
    println!(
        "{label:<24} {got} ok  {qps:>8.0} qps (offered {rps})  avg {avg:>7.3} ms  p95 {p95:>7.3} ms  p99 {p99:>7.3} ms  (service {service_ms:.3} ms, mean batch {:.1})",
        stats.mean_batch()
    );
    coord.shutdown();
    (qps, avg, p95, p99, service_ms)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let spec = if quick {
        EnterpriseSpec {
            num_labels: 100_000,
            dim: 50_000,
            ..Default::default()
        }
    } else {
        EnterpriseSpec::default() // 1M labels, d = 400K, B = 32
    };
    println!(
        "synthesizing enterprise model: L={} d={} B={} (1/{:.0} of the paper's 100M)",
        spec.num_labels,
        spec.dim,
        spec.branching,
        spec.scale_factor()
    );
    let t = Instant::now();
    let model = Arc::new(spec.build_model());
    println!(
        "built in {:.1}s — {}",
        t.elapsed().as_secs_f64(),
        model.stats()
    );

    let n_queries = if quick { 2_000 } else { 6_000 };
    let rps = if quick { 2_000 } else { 3_000 };
    let queries = spec.build_queries(n_queries);
    let workers = std::thread::available_parallelism()?.get().min(8);
    // Single-core substrate note (EXPERIMENTS.md): with one core the
    // coordinator pipeline (client, batcher, worker) time-shares; absolute
    // latency includes scheduling noise, but the MSCM-vs-baseline ratio —
    // the paper's claim — is preserved.
    println!("\nserving {n_queries} queries open-loop at {rps} rps with {workers} workers\n");

    let mscm = Arc::new(InferenceEngine::from_arc(
        Arc::clone(&model),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
    ));
    let (_, mscm_avg, _, mscm_p99, mscm_svc) = run_load("hash MSCM", mscm, &queries, rps, workers);

    let bin_mscm = Arc::new(InferenceEngine::from_arc(
        Arc::clone(&model),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
    ));
    run_load("binary-search MSCM", bin_mscm, &queries, rps, workers);

    let baseline = Arc::new(InferenceEngine::from_arc(
        Arc::clone(&model),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::BinarySearch),
    ));
    let (_, base_avg, _, base_p99, base_svc) =
        run_load("binary-search baseline", baseline, &queries, rps, workers);

    println!(
        "\nengine service-time MSCM gain: {:.1}x  (paper §6 headline: 8x avg, single-thread)",
        base_svc / mscm_svc
    );
    println!(
        "end-to-end (incl. router/batcher overhead): avg {:.1}x, p99 {:.1}x",
        base_avg / mscm_avg,
        base_p99 / mscm_p99
    );
    Ok(())
}
