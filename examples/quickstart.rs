//! Quickstart: generate a labeled corpus, train an XMR tree, run
//! inference under every engine configuration, and verify the paper's
//! exactness claim (MSCM ⇔ baseline, bit for bit).
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use mscm_xmr::data::corpus::{Corpus, CorpusSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine};
use mscm_xmr::train::{train_model, RankerParams, Tfidf};
use mscm_xmr::tree::{load_model, save_model};

fn main() -> anyhow::Result<()> {
    // 1. A synthetic product corpus: 64 "product categories" (labels).
    let spec = CorpusSpec {
        vocab: 4_000,
        topics: 64,
        docs: 3_000,
        seed: 7,
        ..Default::default()
    };
    println!("generating corpus: {} docs, {} labels", spec.docs, spec.topics);
    let corpus = Corpus::generate(spec.clone());

    // 2. TFIDF features (the paper's word embedding).
    let tfidf = Tfidf::fit(&corpus.docs, spec.vocab);
    let x = tfidf.transform(&corpus.docs);
    println!("features: {} x {} ({} nnz)", x.rows, x.cols, x.nnz());

    // 3. Train the tree: PIFA -> balanced k-means -> logistic rankers.
    let trained = train_model(
        &x,
        &corpus.labels,
        spec.topics,
        8,
        &RankerParams::default(),
        1,
    );
    println!("model: {}", trained.model.stats());

    // 4. Round-trip through the binary model format.
    let dir = mscm_xmr::util::temp_dir("quickstart");
    let path = dir.join("model.bin");
    save_model(&trained.model, &path)?;
    let model = load_model(&path, true)?;
    println!("saved + reloaded {}", path.display());

    // 5. Run one held-out query through all 8 engine configurations.
    let query = tfidf.transform_doc(&corpus.docs[0]);
    let mut reference = None;
    for config in EngineConfig::all() {
        let engine = InferenceEngine::new(model.clone(), config);
        let preds = engine.predict(&query, 4, 3);
        let line: Vec<String> = preds
            .iter()
            .map(|p| format!("{}:{:.4}", trained.label_perm[p.label as usize], p.score))
            .collect();
        println!("{:<28} -> {}", config.label(), line.join(" "));
        // The paper's exactness claim: every configuration returns the
        // *identical* ranking and scores.
        match &reference {
            None => reference = Some(preds),
            Some(r) => assert_eq!(&preds, r, "{} diverged!", config.label()),
        }
    }
    println!("\nall 8 configurations bitwise identical — MSCM is exact (paper §4)");
    println!("true label of the probe document: {:?}", corpus.labels[0]);
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}
