//! Semantic product search (the paper's §1/§6 motivating workload):
//! train a search model over a product-title corpus, then serve
//! free-text queries and retrieve the top-k matching products.
//!
//! ```text
//! cargo run --release --example semantic_search
//! ```

use mscm_xmr::data::corpus::{Corpus, CorpusSpec};
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::train::{train_model, RankerParams, Tfidf};
use mscm_xmr::util::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // Products are topics; documents are "titles/descriptions" of them.
    let spec = CorpusSpec {
        vocab: 8_000,
        topics: 512, // 512 products
        docs: 6_000,
        doc_len: 24,
        max_labels: 1,
        seed: 13,
        ..Default::default()
    };
    println!(
        "catalog: {} products, {} training descriptions",
        spec.topics, spec.docs
    );
    let corpus = Corpus::generate(spec.clone());
    let tfidf = Tfidf::fit(&corpus.docs, spec.vocab);
    let x = tfidf.transform(&corpus.docs);

    let t = Instant::now();
    let trained = train_model(
        &x,
        &corpus.labels,
        spec.topics,
        16,
        &RankerParams {
            epochs: 4,
            ..Default::default()
        },
        3,
    );
    println!(
        "trained in {:.1}s: {}",
        t.elapsed().as_secs_f64(),
        trained.model.stats()
    );

    // Production config per the paper's guidance (App. A.1): hash MSCM
    // for the online setting.
    let engine = InferenceEngine::new(
        trained.model.clone(),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
    );

    // "User queries": short keyword fragments of held-out descriptions.
    let mut rng = Rng::seed_from_u64(99);
    let mut ws = engine.workspace();
    let mut hits = 0;
    let n_queries = 200;
    let t = Instant::now();
    for qi in 0..n_queries {
        let doc_id = rng.gen_range(0..corpus.docs.len());
        let doc = &corpus.docs[doc_id];
        // a 6-token search query sampled from the description
        let q_tokens: Vec<u32> = (0..6.min(doc.len()))
            .map(|_| doc[rng.gen_range(0..doc.len())])
            .collect();
        let q = tfidf.transform_doc(&q_tokens);
        let preds = engine.predict_with(&q, 10, 5, &mut ws);
        let truth = corpus.labels[doc_id][0];
        if preds
            .iter()
            .any(|p| trained.label_perm[p.label as usize] == truth)
        {
            hits += 1;
        }
        if qi < 3 {
            let top: Vec<String> = preds
                .iter()
                .take(3)
                .map(|p| {
                    format!(
                        "product{}:{:.3}",
                        trained.label_perm[p.label as usize], p.score
                    )
                })
                .collect();
            println!("query {qi} (truth product{truth}): {}", top.join(" "));
        }
    }
    let ms = t.elapsed().as_secs_f64() * 1e3 / n_queries as f64;
    println!("\nrecall@5: {:.1}% over {n_queries} queries", 100.0 * hits as f64 / n_queries as f64);
    println!("online latency: {ms:.3} ms/query (hash MSCM, beam 10)");
    assert!(hits * 2 > n_queries, "search quality collapsed");
    Ok(())
}
