//! Cross-process serving walkthrough: partition an enterprise-scale
//! model, host every shard **twice** (two replicas each) on loopback TCP,
//! serve queries through the [`RemoteShardedCoordinator`] — and kill one
//! replica mid-stream to show that replica failover absorbs the loss with
//! zero failed queries and bit-identical rankings.
//!
//! `cargo run --release --example remote_search`

use std::sync::atomic::Ordering;
use std::time::Duration;

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::shard::{
    partition, RemoteConfig, RemoteCoordinatorConfig, RemoteShardedCoordinator, ShardHost,
    ShardHostConfig,
};

fn main() -> anyhow::Result<()> {
    // 1. A scaled-down §6 enterprise model.
    let spec = EnterpriseSpec {
        num_labels: 30_000,
        dim: 30_000,
        branching: 32,
        col_nnz: 16,
        query_nnz: 10,
        seed: 7,
    };
    println!("synthesizing model (L={}, d={}) ...", spec.num_labels, spec.dim);
    let model = spec.build_model();
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);

    // 2. Host the partition: every shard gets TWO replica hosts, each a
    //    separate TCP server with its own engine — in production these
    //    are separate machines; here they are loopback listeners.
    let host_cfg = ShardHostConfig {
        engine: cfg,
        ..Default::default()
    };
    let mut primaries = Vec::new();
    let mut backups = Vec::new();
    let mut groups = Vec::new();
    for shard in partition(&model, 2) {
        let a = ShardHost::spawn(shard.clone(), host_cfg.clone(), "127.0.0.1:0")?;
        let b = ShardHost::spawn(shard, host_cfg.clone(), "127.0.0.1:0")?;
        println!(
            "  shard {} replicas: {} (primary), {} (backup)",
            groups.len(),
            a.local_addr(),
            b.local_addr()
        );
        groups.push(vec![a.local_addr(), b.local_addr()]);
        primaries.push(a);
        backups.push(b);
    }

    // 3. Serve through the remote coordinator: dynamic batcher in front,
    //    gather workers driving the hosts layer by layer over TCP, with
    //    speculative expansion halving the network rounds per query.
    let coord = RemoteShardedCoordinator::start_groups(
        &groups,
        RemoteCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 2,
                max_batch: 32,
                max_batch_delay: Duration::from_micros(300),
                beam: 10,
                topk: 5,
                ..Default::default()
            },
            remote: RemoteConfig {
                round_timeout: Duration::from_secs(2),
                ..Default::default()
            },
        },
    )?;
    println!(
        "serving {} remote shards (L={}, d={})",
        coord.num_shards(),
        coord.num_labels(),
        coord.dim()
    );

    // The unsharded resident engine as ground truth.
    let reference = InferenceEngine::new(model, cfg);
    let queries = spec.build_queries(300);

    let mut pending = Vec::new();
    let mut killed = false;
    for i in 0..queries.rows {
        // 4. Mid-stream, kill shard 0's primary replica — connections
        //    sever immediately; in-flight rounds fail over to the backup
        //    and re-issue (rounds are stateless), so no query fails.
        if i == queries.rows / 3 && !killed {
            println!("killing shard 0's primary replica mid-stream ...");
            primaries[0].kill();
            killed = true;
        }
        pending.push((i, coord.submit(queries.row_owned(i))?.1));
    }
    let mut checked = 0usize;
    for (i, rx) in pending {
        let resp = rx.recv()?;
        let direct = reference.predict(&queries.row_owned(i), 10, 5);
        anyhow::ensure!(
            resp.predictions == direct,
            "query {i}: remote result diverged from the resident engine"
        );
        checked += 1;
    }

    let stats = coord.stats();
    let rs = coord.remote_stats();
    println!(
        "served {checked}/{} queries with zero failures across the replica kill \
         (mean batch {:.1}, p50 {:.3} ms)",
        queries.rows,
        stats.mean_batch(),
        stats.latency.quantile_ms(0.5)
    );
    println!(
        "transport: {} network rounds, {} answered from speculation, {} failovers",
        rs.rounds.load(Ordering::Relaxed),
        rs.spec_rounds_saved.load(Ordering::Relaxed),
        rs.failovers.load(Ordering::Relaxed)
    );
    println!("per-shard rounds:\n{}", rs.scatter.summary());
    anyhow::ensure!(
        rs.failovers.load(Ordering::Relaxed) >= 1,
        "the replica kill should have forced at least one failover"
    );
    coord.shutdown();
    for h in primaries.into_iter().chain(backups) {
        h.shutdown();
    }
    println!("remote_search OK");
    Ok(())
}
