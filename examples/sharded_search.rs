//! Sharded serving walkthrough: partition an enterprise-scale model into
//! label-space shards, persist and reload them, and serve queries through
//! the exact scatter-gather coordinator — verifying along the way that
//! every answer is bit-identical to a single resident engine.
//!
//! `cargo run --release --example sharded_search`

use std::sync::Arc;
use std::time::Duration;

use mscm_xmr::coordinator::CoordinatorConfig;
use mscm_xmr::data::enterprise::EnterpriseSpec;
use mscm_xmr::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use mscm_xmr::shard::{
    load_shards, partition, save_shards, ShardedCoordinator, ShardedCoordinatorConfig,
    ShardedEngine,
};

fn main() -> anyhow::Result<()> {
    // 1. A scaled-down §6 enterprise model (same shape, fewer labels).
    let spec = EnterpriseSpec {
        num_labels: 40_000,
        dim: 40_000,
        branching: 32,
        col_nnz: 16,
        query_nnz: 10,
        seed: 7,
    };
    println!("synthesizing model (L={}, d={}) ...", spec.num_labels, spec.dim);
    let model = spec.build_model();
    println!("model: {}", model.stats());

    // 2. Partition the label space: the root's children are split into
    //    contiguous subtree groups, each a standalone model.
    let shards = partition(&model, 4);
    for s in &shards {
        println!(
            "  shard {}/{}: root children [{}, {}), labels [{}, {}), {} bytes chunked",
            s.spec.shard_id,
            s.spec.num_shards,
            s.spec.root_lo,
            s.spec.root_hi,
            s.spec.label_offset,
            s.spec.label_offset + s.spec.num_labels,
            s.model.stats().chunked_bytes
        );
    }

    // 3. Persist and reload through the versioned shard format — this is
    //    what a fleet deployment ships to each machine.
    let dir = mscm_xmr::util::temp_dir("sharded-search-example");
    let paths = save_shards(&shards, &dir)?;
    println!("wrote {} shard files under {}", paths.len(), dir.display());
    let loaded = load_shards(&dir, false)?;

    // 4. Serve: dynamic batcher in front, a worker pool per shard, and a
    //    gather stage that owns the global beam, driving every shard
    //    layer by layer — exact by construction.
    let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
    let engine = Arc::new(ShardedEngine::new(loaded, cfg));
    let coord = ShardedCoordinator::start(
        Arc::clone(&engine),
        ShardedCoordinatorConfig {
            base: CoordinatorConfig {
                workers: 2,
                max_batch: 32,
                max_batch_delay: Duration::from_micros(300),
                beam: 10,
                topk: 5,
                ..Default::default()
            },
            shard_workers: 2,
        },
    );

    // A single unsharded engine as the ground truth.
    let reference = InferenceEngine::new(model, cfg);

    let queries = spec.build_queries(256);
    let mut rxs = Vec::new();
    for i in 0..queries.rows {
        rxs.push((i, coord.submit(queries.row_owned(i))?.1));
    }
    let mut checked = 0usize;
    for (i, rx) in rxs {
        let resp = rx.recv()?;
        let direct = reference.predict(&queries.row_owned(i), 10, 5);
        anyhow::ensure!(
            resp.predictions == direct,
            "query {i}: sharded result diverged from the unsharded engine"
        );
        checked += 1;
    }
    let stats = coord.stats();
    println!(
        "served {checked} queries — all bit-identical to the unsharded engine \
         (mean batch {:.1}, p50 {:.3} ms)",
        stats.mean_batch(),
        stats.latency.quantile_ms(0.5)
    );
    coord.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    println!("top-5 for query 0: {:?}", engine.predict(&queries.row_owned(0), 10, 5));
    Ok(())
}
