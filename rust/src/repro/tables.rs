//! Tables 1–3 (per-query latency, batch + online, 4 iterators ×
//! {MSCM, baseline}, branching 2/8/32, six datasets), the speedup series
//! behind Figures 3–4, and Tables 5–6.

use std::sync::Arc;
use std::time::Instant;

use crate::data::synthetic::{paper_suite, synth_model, synth_queries, DatasetSpec};
use crate::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo, Prediction};
use crate::sparse::CsrMatrix;
use crate::tree::XmrModel;
use crate::util::Json;

/// Knobs shared by the table/figure benchmarks.
#[derive(Clone, Debug)]
pub struct BenchOptions {
    /// Queries in the batch-mode measurement.
    pub batch_queries: usize,
    /// Queries in the online (one-at-a-time) measurement.
    pub online_queries: usize,
    /// Beam width (paper's enterprise runs use 10).
    pub beam: usize,
    /// Labels returned.
    pub topk: usize,
    /// Scale divisor applied to the three large datasets (DESIGN.md §5).
    pub scale: usize,
    /// Restrict to these dataset names (empty = all six).
    pub only: Vec<String>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for BenchOptions {
    fn default() -> Self {
        Self {
            batch_queries: 512,
            online_queries: 128,
            beam: 10,
            topk: 10,
            scale: 10,
            only: Vec::new(),
            seed: 2022,
        }
    }
}

/// One measured cell pair of Tables 1–3.
#[derive(Clone, Debug)]
pub struct TableRow {
    /// Dataset name.
    pub dataset: String,
    /// `(algo, iter)` pair measured.
    pub config: EngineConfig,
    /// Batch-mode ms per query.
    pub batch_ms: f64,
    /// Online-mode ms per query.
    pub online_ms: f64,
}

fn datasets_for(opts: &BenchOptions) -> Vec<DatasetSpec> {
    paper_suite(opts.scale)
        .into_iter()
        .filter(|s| opts.only.is_empty() || opts.only.iter().any(|n| n == s.name))
        .collect()
}

/// Measures batch ms/query for one engine.
fn measure_batch(engine: &InferenceEngine, x: &CsrMatrix, opts: &BenchOptions) -> f64 {
    // one warmup pass over a prefix
    let warm = x.rows.min(32);
    let xw = x.select_rows(&(0..warm).collect::<Vec<_>>());
    std::hint::black_box(engine.predict_batch(&xw, opts.beam, opts.topk));
    let t = Instant::now();
    std::hint::black_box(engine.predict_batch(x, opts.beam, opts.topk));
    t.elapsed().as_secs_f64() * 1e3 / x.rows as f64
}

/// Measures online ms/query for one engine (one query at a time, reusing
/// the workspace as a server would).
fn measure_online(engine: &InferenceEngine, x: &CsrMatrix, opts: &BenchOptions) -> f64 {
    let n = x.rows.min(opts.online_queries);
    let mut ws = engine.workspace();
    // warmup
    for i in 0..n.min(8) {
        std::hint::black_box(engine.predict_with(&x.row_owned(i), opts.beam, opts.topk, &mut ws));
    }
    let rows: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();
    let t = Instant::now();
    for q in &rows {
        std::hint::black_box(engine.predict_with(q, opts.beam, opts.topk, &mut ws));
    }
    t.elapsed().as_secs_f64() * 1e3 / n as f64
}

/// Mean top-`k` label overlap between an approximate run and its exact
/// (f32) oracle — the regression gate for the planner's `--approx`
/// quantized layouts: per query, `|approx ∩ exact| / k` over the two
/// top-`k` label sets, averaged across queries. `1.0` means identical
/// retrieved sets (scores may still differ in low bits); the quant
/// property suite (`rust/tests/quant.rs`) pins a floor on this value.
pub fn precision_overlap_at_k(
    exact: &[Vec<Prediction>],
    approx: &[Vec<Prediction>],
    k: usize,
) -> f64 {
    assert_eq!(exact.len(), approx.len(), "query counts differ");
    assert!(k > 0, "k must be positive");
    if exact.is_empty() {
        return 1.0;
    }
    let mut total = 0.0f64;
    for (e, a) in exact.iter().zip(approx) {
        let truth: std::collections::HashSet<u32> = e.iter().take(k).map(|p| p.label).collect();
        let hits = a.iter().take(k).filter(|p| truth.contains(&p.label)).count();
        // an oracle list shorter than k gates on the labels that exist
        total += hits as f64 / truth.len().min(k).max(1) as f64;
    }
    total / exact.len() as f64
}

/// Runs the Table-1/2/3 grid for one branching factor.
pub fn bench_table(branching: usize, opts: &BenchOptions) -> Vec<TableRow> {
    let mut rows = Vec::new();
    for spec in datasets_for(opts) {
        eprintln!("[table B={branching}] building {} ...", spec.name);
        let model = Arc::new(synth_model(&spec, branching, opts.seed));
        let xb = synth_queries(&spec, opts.batch_queries, opts.seed);
        let xo = synth_queries(&spec, opts.online_queries, opts.seed + 1);
        for config in EngineConfig::all() {
            let engine = InferenceEngine::from_arc(Arc::clone(&model), config);
            let batch_ms = measure_batch(&engine, &xb, opts);
            let online_ms = measure_online(&engine, &xo, opts);
            eprintln!(
                "[table B={branching}] {:<28} {:<14} batch {:.3} ms/q  online {:.3} ms/q",
                spec.name,
                config.label(),
                batch_ms,
                online_ms
            );
            rows.push(TableRow {
                dataset: spec.name.to_string(),
                config,
                batch_ms,
                online_ms,
            });
        }
    }
    rows
}

/// Prints a Table-1/2/3-shaped table (datasets as columns).
pub fn print_table(branching: usize, rows: &[TableRow]) {
    let datasets: Vec<String> = {
        let mut d: Vec<String> = rows.iter().map(|r| r.dataset.clone()).collect();
        d.dedup();
        d
    };
    println!("\nBranching Factor: {branching}");
    print!("{:<26}", "");
    for d in &datasets {
        print!("{d:>16}");
    }
    println!();
    for setting in ["Batch", "Online"] {
        println!("{setting}");
        // paper row order: per iterator, MSCM then baseline
        for iter in IterationMethod::ALL {
            for algo in [MatmulAlgo::Mscm, MatmulAlgo::Baseline] {
                let label = format!("{}{}", iter.label(), algo.label());
                print!("{label:<26}");
                for d in &datasets {
                    let r = rows
                        .iter()
                        .find(|r| &r.dataset == d && r.config.iter == iter && r.config.algo == algo)
                        .expect("cell");
                    let v = if setting == "Batch" {
                        r.batch_ms
                    } else {
                        r.online_ms
                    };
                    print!("{:>13.2} ms", v);
                }
                println!();
            }
        }
    }
}

/// Prints the Figure-3 (batch) or Figure-4 (online) speedup series:
/// baseline time / MSCM time per iterator per dataset.
pub fn print_figure34(branching: usize, rows: &[TableRow], online: bool) {
    let figure = if online { "Figure 4 (online)" } else { "Figure 3 (batch)" };
    println!("\n{figure} — MSCM speedup over non-MSCM baseline, branching {branching}");
    let datasets: Vec<String> = {
        let mut d: Vec<String> = rows.iter().map(|r| r.dataset.clone()).collect();
        d.dedup();
        d
    };
    print!("{:<22}", "iterator");
    for d in &datasets {
        print!("{d:>16}");
    }
    println!();
    for iter in IterationMethod::ALL {
        print!("{:<22}", iter.label());
        for d in &datasets {
            let get = |algo| {
                let r = rows
                    .iter()
                    .find(|r| &r.dataset == d && r.config.iter == iter && r.config.algo == algo)
                    .expect("cell");
                if online {
                    r.online_ms
                } else {
                    r.batch_ms
                }
            };
            let speedup = get(MatmulAlgo::Baseline) / get(MatmulAlgo::Mscm);
            print!("{speedup:>15.2}x");
        }
        println!();
    }
}

/// Serializes table rows for the JSON report.
pub fn rows_to_json(branching: usize, rows: &[TableRow]) -> Json {
    Json::obj(vec![
        ("branching", Json::Num(branching as f64)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("dataset", Json::Str(r.dataset.clone())),
                            ("config", Json::Str(r.config.label())),
                            ("batch_ms", Json::Num(r.batch_ms)),
                            ("online_ms", Json::Num(r.online_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Table 5: dataset statistics — paper scale vs generated scale, plus
/// measured stats of the actually-generated models.
pub fn table5(opts: &BenchOptions) {
    println!(
        "\nTable 5 — dataset statistics (scale divisor {} on large sets)",
        opts.scale
    );
    println!(
        "{:<16}{:>12}{:>12}{:>12}{:>12}{:>14}{:>12}",
        "dataset", "paper d", "paper L", "our d", "our L", "query nnz", "col nnz"
    );
    for spec in datasets_for(opts) {
        println!(
            "{:<16}{:>12}{:>12}{:>12}{:>12}{:>14}{:>12}",
            spec.name,
            spec.paper_dim,
            spec.paper_labels,
            spec.dim,
            spec.num_labels,
            spec.query_nnz,
            spec.col_nnz
        );
    }
}

/// Table 6: measured per-iterator time complexity inputs and memory
/// overhead on one mid-size model.
pub fn table6(opts: &BenchOptions) {
    let spec = datasets_for(opts)
        .into_iter()
        .find(|s| s.name == "amazoncat-13k")
        .unwrap_or_else(|| paper_suite(opts.scale)[1].clone());
    eprintln!("[table6] building {} ...", spec.name);
    let mut model = synth_model(&spec, 32, opts.seed);
    let with_maps = model.stats().chunked_bytes;
    model.drop_row_maps();
    let plain_chunked = model.stats().chunked_bytes;
    let csc = model.stats().csc_bytes;
    model.build_row_maps();
    let model = Arc::new(model);

    println!("\nTable 6 — per-query complexity and measured memory overhead ({})", spec.name);
    println!(
        "{:<20}{:<44}{:>18}",
        "iterator", "time complexity (paper)", "extra memory"
    );
    let rows: Vec<(IterationMethod, &str)> = vec![
        (
            IterationMethod::MarchingPointers,
            "O(nnz_x + nnz_K)",
        ),
        (
            IterationMethod::BinarySearch,
            "O(min(nnz) * log(max(nnz)))",
        ),
        (IterationMethod::Hash, "O(h * nnz_x)"),
        (IterationMethod::DenseLookup, "O(nnz_x + nnz_K / n)"),
    ];
    for (iter, complexity) in rows {
        let overhead = match iter {
            IterationMethod::MarchingPointers | IterationMethod::BinarySearch => 0usize,
            IterationMethod::Hash => with_maps - plain_chunked,
            IterationMethod::DenseLookup => {
                let engine = InferenceEngine::from_arc(
                    Arc::clone(&model),
                    EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup),
                );
                engine.workspace().memory_bytes()
            }
            // Auto's overhead is plan-dependent (the whole point of the
            // planner); Table 6 only tabulates the fixed methods.
            IterationMethod::Auto => unreachable!("Table 6 rows are fixed methods"),
        };
        println!("{:<20}{:<44}{:>14} KiB", iter.label(), complexity, overhead / 1024);
    }
    // The per-column baseline-hash overhead MSCM amortizes away:
    let engine = InferenceEngine::from_arc(
        Arc::clone(&model),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::Hash),
    );
    println!(
        "\n(for contrast) per-column hash side index (NapkinXC scheme): {} KiB",
        engine.side_index_bytes() / 1024
    );
    println!(
        "model storage: CSC {} KiB, chunked {} KiB (+{:.1}% hash row maps)",
        csc / 1024,
        plain_chunked / 1024,
        100.0 * (with_maps - plain_chunked) as f64 / plain_chunked as f64
    );
}

/// Re-exported for the harness consumers that need the raw model/query
/// builders (bench binaries).
pub fn build_dataset(
    name: &str,
    branching: usize,
    opts: &BenchOptions,
) -> Option<(Arc<XmrModel>, CsrMatrix)> {
    let spec = paper_suite(opts.scale).into_iter().find(|s| s.name == name)?;
    let model = Arc::new(synth_model(&spec, branching, opts.seed));
    let x = synth_queries(&spec, opts.batch_queries, opts.seed);
    Some((model, x))
}
