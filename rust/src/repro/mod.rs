//! The paper-reproduction harness: one entry point per table and figure
//! of the evaluation section (Tables 1–6, Figures 3–6), each printing the
//! same rows/series the paper reports and returning structured results
//! for the JSON reports referenced by EXPERIMENTS.md.

mod enterprise;
mod figures;
mod tables;

pub use enterprise::{bench_table4, print_table4, table4_to_json, Table4Row};
pub use figures::{
    bench_figure5, bench_figure6, figure5_to_json, figure6_to_json, print_figure5,
    print_figure6, Figure5Row, Figure6Row,
};
pub use tables::{
    bench_table, build_dataset, precision_overlap_at_k, print_figure34, print_table, rows_to_json,
    table5, table6, BenchOptions, TableRow,
};

use crate::util::Json;

/// Writes a JSON report next to the printed output.
pub fn write_report(path: &str, payload: Json) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, payload.to_string())
}
