//! Figure 5 (hash-MSCM vs NapkinXC, ~10×) and Figure 6 (multi-threaded
//! scaling of binary/hash × {MSCM, baseline}).

use std::sync::Arc;
use std::time::Instant;

use super::tables::BenchOptions;
use crate::data::synthetic::{paper_suite, synth_model, synth_queries};
use crate::inference::napkinxc::NapkinXcEngine;
use crate::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use crate::util::Json;

/// One Figure-5 bar pair.
#[derive(Clone, Debug)]
pub struct Figure5Row {
    /// Dataset name.
    pub dataset: String,
    /// Our hash-MSCM online ms/query.
    pub ours_ms: f64,
    /// NapkinXC-style online ms/query.
    pub napkinxc_ms: f64,
}

/// Figure 5: our hash-MSCM engine vs the NapkinXC reimplementation
/// (both hash-based, online setting, same beam) on every dataset.
pub fn bench_figure5(opts: &BenchOptions) -> Vec<Figure5Row> {
    let mut out = Vec::new();
    for spec in paper_suite(opts.scale)
        .into_iter()
        .filter(|s| opts.only.is_empty() || opts.only.iter().any(|n| n == s.name))
    {
        eprintln!("[figure5] building {} ...", spec.name);
        let model = Arc::new(synth_model(&spec, 32, opts.seed));
        let x = synth_queries(&spec, opts.online_queries, opts.seed);
        let n = x.rows;
        let queries: Vec<_> = (0..n).map(|i| x.row_owned(i)).collect();

        let ours = InferenceEngine::from_arc(
            Arc::clone(&model),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        );
        let mut ws = ours.workspace();
        for q in queries.iter().take(8) {
            std::hint::black_box(ours.predict_with(q, opts.beam, opts.topk, &mut ws));
        }
        let t = Instant::now();
        for q in &queries {
            std::hint::black_box(ours.predict_with(q, opts.beam, opts.topk, &mut ws));
        }
        let ours_ms = t.elapsed().as_secs_f64() * 1e3 / n as f64;

        let napkin = NapkinXcEngine::new(Arc::clone(&model));
        for q in queries.iter().take(8) {
            std::hint::black_box(napkin.predict_beam(q, opts.beam, opts.topk));
        }
        let t = Instant::now();
        for q in &queries {
            std::hint::black_box(napkin.predict_beam(q, opts.beam, opts.topk));
        }
        let napkinxc_ms = t.elapsed().as_secs_f64() * 1e3 / n as f64;

        eprintln!(
            "[figure5] {:<16} ours {:.3} ms/q  napkinxc {:.3} ms/q  ({:.1}x)",
            spec.name,
            ours_ms,
            napkinxc_ms,
            napkinxc_ms / ours_ms
        );
        out.push(Figure5Row {
            dataset: spec.name.to_string(),
            ours_ms,
            napkinxc_ms,
        });
    }
    out
}

/// Prints the Figure-5 series.
pub fn print_figure5(rows: &[Figure5Row]) {
    println!("\nFigure 5 — hash-MSCM (ours) vs NapkinXC reimplementation, online");
    println!(
        "{:<16}{:>14}{:>16}{:>10}",
        "dataset", "ours ms/q", "napkinxc ms/q", "gain"
    );
    for r in rows {
        println!(
            "{:<16}{:>14.3}{:>16.3}{:>9.1}x",
            r.dataset,
            r.ours_ms,
            r.napkinxc_ms,
            r.napkinxc_ms / r.ours_ms
        );
    }
}

/// One Figure-6 measurement.
#[derive(Clone, Debug)]
pub struct Figure6Row {
    /// Dataset name.
    pub dataset: String,
    /// Engine configuration measured.
    pub config: EngineConfig,
    /// Thread count.
    pub threads: usize,
    /// Batch ms per query.
    pub batch_ms: f64,
}

/// Figure 6: thread-scaling of batch inference for binary-search and
/// hash, MSCM and baseline, on the paper's three largest datasets.
pub fn bench_figure6(opts: &BenchOptions, thread_counts: &[usize]) -> Vec<Figure6Row> {
    let mut out = Vec::new();
    let wanted = ["wiki-500k", "amazon-670k", "amazon-3m"];
    for spec in paper_suite(opts.scale).into_iter().filter(|s| {
        wanted.contains(&s.name) && (opts.only.is_empty() || opts.only.iter().any(|n| n == s.name))
    }) {
        eprintln!("[figure6] building {} ...", spec.name);
        let model = Arc::new(synth_model(&spec, 32, opts.seed));
        let x = synth_queries(&spec, opts.batch_queries, opts.seed);
        for iter in [IterationMethod::BinarySearch, IterationMethod::Hash] {
            for algo in MatmulAlgo::ALL {
                let config = EngineConfig::new(algo, iter);
                let engine = InferenceEngine::from_arc(Arc::clone(&model), config);
                for &threads in thread_counts {
                    // warmup + measure
                    std::hint::black_box(engine.predict_batch_parallel(
                        &x,
                        opts.beam,
                        opts.topk,
                        threads,
                    ));
                    let t = Instant::now();
                    std::hint::black_box(engine.predict_batch_parallel(
                        &x,
                        opts.beam,
                        opts.topk,
                        threads,
                    ));
                    let batch_ms = t.elapsed().as_secs_f64() * 1e3 / x.rows as f64;
                    eprintln!(
                        "[figure6] {:<14} {:<22} t={:<2} {:.3} ms/q",
                        spec.name,
                        config.label(),
                        threads,
                        batch_ms
                    );
                    out.push(Figure6Row {
                        dataset: spec.name.to_string(),
                        config,
                        threads,
                        batch_ms,
                    });
                }
            }
        }
    }
    out
}

/// Prints the Figure-6 series.
pub fn print_figure6(rows: &[Figure6Row]) {
    println!("\nFigure 6 — multi-threaded batch inference (ms/query)");
    let mut datasets: Vec<String> = rows.iter().map(|r| r.dataset.clone()).collect();
    datasets.dedup();
    for d in datasets {
        println!("\n{d}");
        let mut threads: Vec<usize> = rows
            .iter()
            .filter(|r| r.dataset == d)
            .map(|r| r.threads)
            .collect();
        threads.sort_unstable();
        threads.dedup();
        print!("{:<26}", "config");
        for t in &threads {
            print!("{:>10}", format!("t={t}"));
        }
        println!();
        for iter in [IterationMethod::BinarySearch, IterationMethod::Hash] {
            for algo in [MatmulAlgo::Mscm, MatmulAlgo::Baseline] {
                print!("{:<26}", format!("{}{}", iter.label(), algo.label()));
                for &t in &threads {
                    if let Some(r) = rows.iter().find(|r| {
                        r.dataset == d
                            && r.config.iter == iter
                            && r.config.algo == algo
                            && r.threads == t
                    }) {
                        print!("{:>10.3}", r.batch_ms);
                    } else {
                        print!("{:>10}", "-");
                    }
                }
                println!();
            }
        }
    }
}

/// JSON report payloads.
pub fn figure5_to_json(rows: &[Figure5Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("dataset", Json::Str(r.dataset.clone())),
                    ("ours_ms", Json::Num(r.ours_ms)),
                    ("napkinxc_ms", Json::Num(r.napkinxc_ms)),
                ])
            })
            .collect(),
    )
}

/// JSON report payloads.
pub fn figure6_to_json(rows: &[Figure6Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("dataset", Json::Str(r.dataset.clone())),
                    ("config", Json::Str(r.config.label())),
                    ("threads", Json::Num(r.threads as f64)),
                    ("batch_ms", Json::Num(r.batch_ms)),
                ])
            })
            .collect(),
    )
}
