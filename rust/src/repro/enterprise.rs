//! Table 4: enterprise-scale semantic product search (paper §6) —
//! average / P95 / P99 per-query latency at beam 10 and 20 for
//! binary-search MSCM, hash-map MSCM and the binary-search baseline,
//! single-threaded. (Dense lookup is excluded in the paper for OOM;
//! we match its table rows.)

use std::sync::Arc;
use std::time::Instant;

use super::tables::BenchOptions;
use crate::data::enterprise::EnterpriseSpec;
use crate::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
use crate::metrics::ExactLatencies;
use crate::util::Json;

/// One Table-4 row.
#[derive(Clone, Debug)]
pub struct Table4Row {
    /// Beam width (10 / 20).
    pub beam: usize,
    /// Engine configuration.
    pub config: EngineConfig,
    /// Mean ms/query.
    pub avg_ms: f64,
    /// 95th percentile ms/query.
    pub p95_ms: f64,
    /// 99th percentile ms/query.
    pub p99_ms: f64,
}

/// Runs Table 4 on a synthesized enterprise model.
pub fn bench_table4(spec: &EnterpriseSpec, opts: &BenchOptions) -> Vec<Table4Row> {
    eprintln!(
        "[table4] synthesizing enterprise model: L={} d={} B={} (paper scale / {:.0})",
        spec.num_labels,
        spec.dim,
        spec.branching,
        spec.scale_factor()
    );
    let t = Instant::now();
    let model = Arc::new(spec.build_model());
    eprintln!(
        "[table4] model built in {:.1}s: {}",
        t.elapsed().as_secs_f64(),
        model.stats()
    );
    let x = spec.build_queries(opts.online_queries.max(256));
    let queries: Vec<_> = (0..x.rows).map(|i| x.row_owned(i)).collect();

    let configs = [
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::BinarySearch),
    ];
    let mut rows = Vec::new();
    for beam in [10usize, 20] {
        for config in configs {
            let engine = InferenceEngine::from_arc(Arc::clone(&model), config);
            let mut ws = engine.workspace();
            for q in queries.iter().take(8) {
                std::hint::black_box(engine.predict_with(q, beam, opts.topk, &mut ws));
            }
            let lat = ExactLatencies::new();
            for q in &queries {
                let t = Instant::now();
                std::hint::black_box(engine.predict_with(q, beam, opts.topk, &mut ws));
                lat.record(t.elapsed());
            }
            let (avg, _, p95, p99) = lat.stats_ms();
            eprintln!(
                "[table4] beam {:<3} {:<22} avg {:.3} p95 {:.3} p99 {:.3} ms/q",
                beam,
                config.label(),
                avg,
                p95,
                p99
            );
            rows.push(Table4Row {
                beam,
                config,
                avg_ms: avg,
                p95_ms: p95,
                p99_ms: p99,
            });
        }
    }
    rows
}

/// Prints Table 4 in the paper's layout.
pub fn print_table4(spec: &EnterpriseSpec, rows: &[Table4Row]) {
    println!(
        "\nTable 4 — enterprise-scale search, single thread (L={}, d={}, B={}, scale 1/{:.0} of paper)",
        spec.num_labels,
        spec.dim,
        spec.branching,
        spec.scale_factor()
    );
    println!(
        "{:<26}{:>16}{:>16}{:>16}",
        "Iteration Method", "Average (ms/q)", "P95 (ms/q)", "P99 (ms/q)"
    );
    for beam in [10usize, 20] {
        println!("Beam Size: {beam}");
        for r in rows.iter().filter(|r| r.beam == beam) {
            println!(
                "{:<26}{:>16.3}{:>16.3}{:>16.3}",
                r.config.label(),
                r.avg_ms,
                r.p95_ms,
                r.p99_ms
            );
        }
    }
    // Headline ratio (paper: 8x+ avg, ~9x P99 at beam 10)
    let get = |beam, algo, iter| {
        rows.iter()
            .find(|r| r.beam == beam && r.config.algo == algo && r.config.iter == iter)
            .map(|r| (r.avg_ms, r.p99_ms))
    };
    if let (Some((ma, mp)), Some((ba, bp))) = (
        get(10, MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        get(10, MatmulAlgo::Baseline, IterationMethod::BinarySearch),
    ) {
        println!(
            "\nheadline: binary-search MSCM vs baseline at beam 10 — avg {:.1}x, P99 {:.1}x (paper: 8.2x avg, 9.0x P99)",
            ba / ma,
            bp / mp
        );
    }
}

/// JSON payload.
pub fn table4_to_json(spec: &EnterpriseSpec, rows: &[Table4Row]) -> Json {
    Json::obj(vec![
        ("num_labels", Json::Num(spec.num_labels as f64)),
        ("dim", Json::Num(spec.dim as f64)),
        ("branching", Json::Num(spec.branching as f64)),
        ("scale_factor", Json::Num(spec.scale_factor())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("beam", Json::Num(r.beam as f64)),
                            ("config", Json::Str(r.config.label())),
                            ("avg_ms", Json::Num(r.avg_ms)),
                            ("p95_ms", Json::Num(r.p95_ms)),
                            ("p99_ms", Json::Num(r.p99_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
