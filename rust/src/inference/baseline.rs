//! Vanilla per-column evaluation of the masked product — the baseline the
//! paper measures MSCM against (§4 intro, Alg. 4).
//!
//! For every mask nonzero `(i, j)` the activation is an independent sparse
//! dot product `A_ij = x_i · w_j`, under the same four iteration methods
//! as MSCM: marching pointers / binary search (Alg. 4) walk the two sorted
//! supports, hash keeps a **per-column** row→position map (NapkinXC's
//! scheme), and dense lookup scatters the *query* into an `O(d)` dense
//! array once per query (Parabel/Bonsai's scheme).

use super::engine::Workspace;
use super::{sigmoid, IterationMethod};
use crate::sparse::{CscMatrix, CsrMatrix, SparseVecView, U32Map};
use crate::tree::Layer;

/// Builds the per-column row→position hash maps for one layer's CSC weight
/// matrix (the baseline hash method's side index; its `O(c · nnz)` memory
/// is what chunking amortizes). Each map is pre-sized from its column's
/// support length (the pair iterator is exact-size off the CSC slices).
pub(crate) fn build_col_hash(csc: &CscMatrix) -> Vec<U32Map> {
    (0..csc.cols)
        .map(|j| {
            let col = csc.col(j);
            U32Map::from_pairs(col.indices.iter().enumerate().map(|(p, &r)| (r, p as u32)))
        })
        .collect()
}

/// Dot product via a per-column hash map: iterate the query support,
/// look each feature up in the column's map.
#[inline]
fn dot_hash(x: SparseVecView<'_>, col: SparseVecView<'_>, map: &U32Map) -> f32 {
    let mut z = 0.0f32;
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        if let Some(pos) = map.get(i) {
            z += xv * col.values[pos as usize];
        }
    }
    z
}

/// Dot product against a densely-scattered query: iterate the column
/// support, read the query from the dense array.
#[inline]
fn dot_dense(col: SparseVecView<'_>, dense_x: &[f32]) -> f32 {
    let mut z = 0.0f32;
    for (&r, &wv) in col.indices.iter().zip(col.values) {
        z += dense_x[r as usize] * wv;
    }
    z
}

/// Computes all layer candidates `(child node, path score)` for local
/// queries `0..n` (rows `qlo..qlo+n` of `x`), writing each query's
/// candidates into its pre-laid-out slice of the workspace candidate
/// arena (the caller ran [`Workspace::begin_layer`]).
pub(crate) fn baseline_layer(
    layer: &Layer,
    x: &CsrMatrix,
    qlo: usize,
    n: usize,
    iter: IterationMethod,
    col_hash: Option<&Vec<U32Map>>,
    ws: &mut Workspace,
) {
    let csc = &layer.csc;
    let chunked = &layer.chunked; // only for the children ranges (tree topology)
    for q in 0..n {
        let xq = x.row(qlo + q);
        // Baseline dense lookup: scatter the query once per query
        // (amortized over every masked column it touches), clear after.
        if iter == IterationMethod::DenseLookup {
            let dense_x = ws.dense_x.as_mut().expect("dense query scatter");
            for (&i, &v) in xq.indices.iter().zip(xq.values) {
                dense_x[i as usize] = v;
            }
        }
        {
            // Disjoint field borrows: the beam arena is read while the
            // candidate arena is written through the query's cursor.
            let Workspace {
                beam_entries,
                beam_offsets,
                cand_entries,
                cand_cursor,
                dense_x,
                ..
            } = ws;
            let mut dst = cand_cursor[q];
            for &(p, ps) in &beam_entries[beam_offsets[q]..beam_offsets[q + 1]] {
                let start = chunked.chunk_start(p as usize);
                let width = chunked.chunk_width(p as usize);
                for j in start..start + width {
                    let col = csc.col(j);
                    let a = match iter {
                        IterationMethod::MarchingPointers => xq.dot_marching(col),
                        IterationMethod::BinarySearch => xq.dot_binary_search(col),
                        IterationMethod::Hash => {
                            dot_hash(xq, col, &col_hash.expect("per-column hash index")[j])
                        }
                        IterationMethod::DenseLookup => {
                            dot_dense(col, dense_x.as_ref().unwrap())
                        }
                    };
                    cand_entries[dst] = (j as u32, ps * sigmoid(a));
                    dst += 1;
                }
            }
            cand_cursor[q] = dst;
        }
        if iter == IterationMethod::DenseLookup {
            let dense_x = ws.dense_x.as_mut().unwrap();
            for &i in xq.indices {
                dense_x[i as usize] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{EngineConfig, Workspace};
    use super::super::MatmulAlgo;
    use super::*;
    use crate::sparse::SparseVec;
    use crate::tree::{Layer, XmrModel};

    fn layer() -> Layer {
        Layer::new(
            CscMatrix::from_cols(
                vec![
                    SparseVec::from_pairs(vec![(0, 1.0), (2, 2.0)]),
                    SparseVec::from_pairs(vec![(0, -1.0)]),
                    SparseVec::from_pairs(vec![(1, 3.0)]),
                    SparseVec::from_pairs(vec![(1, 0.5), (3, 0.5)]),
                ],
                4,
            ),
            &[0, 2, 4],
            false,
        )
    }

    #[test]
    fn col_hash_resolves_every_entry() {
        let l = layer();
        let maps = build_col_hash(&l.csc);
        for j in 0..l.csc.cols {
            let col = l.csc.col(j);
            for (p, &r) in col.indices.iter().enumerate() {
                assert_eq!(maps[j].get(r), Some(p as u32));
            }
        }
    }

    #[test]
    fn all_baseline_iterators_agree() {
        let l = layer();
        let model = XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], false)]);
        let x = CsrMatrix::from_rows(
            vec![SparseVec::from_pairs(vec![(0, 2.0), (1, -1.0), (3, 4.0)])],
            4,
        );
        let beam = vec![(0u32, 1.0f32), (1u32, 0.5f32)];
        let maps = build_col_hash(&l.csc);
        let mut results = Vec::new();
        for iter in IterationMethod::ALL {
            let mut ws = Workspace::new(
                &model,
                EngineConfig {
                    algo: MatmulAlgo::Baseline,
                    iter,
                },
            );
            ws.begin_beams(1);
            ws.push_beam(&beam);
            ws.begin_layer(&l.chunked, 1);
            baseline_layer(&l, &x, 0, 1, iter, Some(&maps), &mut ws);
            results.push(ws.cand(0).to_vec());
        }
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
        assert_eq!(results[0].len(), 4);
    }
}
