//! Vanilla per-column evaluation of the masked product — the baseline the
//! paper measures MSCM against (§4 intro, Alg. 4).
//!
//! For every mask nonzero `(i, j)` the activation is an independent sparse
//! dot product `A_ij = x_i · w_j`, under the same four iteration methods
//! as MSCM: marching pointers / binary search (Alg. 4) walk the two sorted
//! supports, hash keeps a **per-column** row→position map (NapkinXC's
//! scheme), and dense lookup scatters the *query* into an `O(d)` dense
//! array once per query (Parabel/Bonsai's scheme).
//!
//! Like the MSCM kernels, this module carries no timing hooks of its
//! own: [`crate::metrics::EngineMetrics`] measures the whole layer
//! expansion around the engine's dispatch, so baseline and MSCM timings
//! are directly comparable and the per-column loops stay clock-free.
//!
//! # Why the baseline has no SIMD tier
//!
//! The MSCM kernels vectorize across *independent output rows* (see
//! [`crate::sparse::simd`]), which keeps every output's accumulation
//! order untouched. The per-column dot products here have the opposite
//! shape: one serial `f32` accumulator per column, so the only thing a
//! vector unit could speed up is the reduction itself — and any lane-wise
//! partial-summing reorders the additions and breaks the bitwise
//! equivalence between configurations. The planner therefore pins every
//! baseline block to [`crate::inference::KernelTier::Scalar`], and this
//! module stays tier-free by construction.

use super::engine::Workspace;
use super::{sigmoid, IterationMethod};
use crate::sparse::{ChunkedMatrix, CscMatrix, CsrMatrix, SparseVecView, U32Map};
use crate::tree::Layer;

/// One column's row→position hash map (the baseline hash method's
/// side-index unit; its `O(c · nnz)` total memory is what chunking
/// amortizes). Pre-sized from the column's support length (the pair
/// iterator is exact-size off the CSC slices).
fn col_map(csc: &CscMatrix, j: usize) -> U32Map {
    let col = csc.col(j);
    U32Map::from_pairs(col.indices.iter().enumerate().map(|(p, &r)| (r, p as u32)))
}

/// Builds one layer's per-column hash index, plan-driven: live maps only
/// for columns of hash-planned chunks, 8-byte [`U32Map::empty`]
/// placeholders elsewhere — the memory the planner saves over the fixed
/// NapkinXC scheme (a uniform hash plan reproduces it exactly).
pub(crate) fn build_col_hash_planned(
    csc: &CscMatrix,
    chunked: &ChunkedMatrix,
    methods: &[IterationMethod],
) -> Vec<U32Map> {
    debug_assert_eq!(methods.len(), chunked.num_chunks());
    let mut maps = Vec::with_capacity(csc.cols);
    for (c, &m) in methods.iter().enumerate() {
        let (c0, w) = (chunked.chunk_start(c), chunked.chunk_width(c));
        for j in c0..c0 + w {
            maps.push(if m == IterationMethod::Hash {
                col_map(csc, j)
            } else {
                U32Map::empty()
            });
        }
    }
    debug_assert_eq!(maps.len(), csc.cols);
    maps
}

/// Dot product via a per-column hash map: iterate the query support,
/// look each feature up in the column's map.
#[inline]
fn dot_hash(x: SparseVecView<'_>, col: SparseVecView<'_>, map: &U32Map) -> f32 {
    let mut z = 0.0f32;
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        if let Some(pos) = map.get(i) {
            z += xv * col.values[pos as usize];
        }
    }
    z
}

/// Dot product against a densely-scattered query: iterate the column
/// support, read the query from the dense array.
#[inline]
fn dot_dense(col: SparseVecView<'_>, dense_x: &[f32]) -> f32 {
    let mut z = 0.0f32;
    for (&r, &wv) in col.indices.iter().zip(col.values) {
        z += dense_x[r as usize] * wv;
    }
    z
}

/// Computes all layer candidates `(child node, path score)` for local
/// queries `0..n` (rows `qlo..qlo+n` of `x`), writing each query's
/// candidates into its pre-laid-out slice of the workspace candidate
/// arena (the caller ran [`Workspace::begin_layer`]).
///
/// `methods` is the layer's slice of the resolved
/// [`KernelPlan`](super::plan::KernelPlan), one concrete method per
/// chunk: every column of a beamed chunk is evaluated with its chunk's
/// planned method.
pub(crate) fn baseline_layer(
    layer: &Layer,
    x: &CsrMatrix,
    qlo: usize,
    n: usize,
    methods: &[IterationMethod],
    col_hash: Option<&Vec<U32Map>>,
    ws: &mut Workspace,
) {
    let csc = &layer.csc;
    let chunked = &layer.chunked; // only for the children ranges (tree topology)
    for q in 0..n {
        let xq = x.row(qlo + q);
        // Baseline dense lookup: scatter the query once per query when
        // any beamed chunk plans dense (amortized over every masked
        // column those chunks touch), clear after.
        let needs_dense = {
            let (lo, hi) = (ws.beam_offsets[q], ws.beam_offsets[q + 1]);
            ws.beam_entries[lo..hi]
                .iter()
                .any(|&(p, _)| methods[p as usize] == IterationMethod::DenseLookup)
        };
        if needs_dense {
            let dense_x = ws.dense_x.as_mut().expect("dense query scatter");
            for (&i, &v) in xq.indices.iter().zip(xq.values) {
                dense_x[i as usize] = v;
            }
        }
        {
            // Disjoint field borrows: the beam arena is read while the
            // candidate arena is written through the query's cursor.
            let Workspace {
                beam_entries,
                beam_offsets,
                cand_entries,
                cand_cursor,
                dense_x,
                ..
            } = ws;
            let mut dst = cand_cursor[q];
            for &(p, ps) in &beam_entries[beam_offsets[q]..beam_offsets[q + 1]] {
                let iter = methods[p as usize];
                let start = chunked.chunk_start(p as usize);
                let width = chunked.chunk_width(p as usize);
                for j in start..start + width {
                    let col = csc.col(j);
                    let a = match iter {
                        IterationMethod::MarchingPointers => xq.dot_marching(col),
                        IterationMethod::BinarySearch => xq.dot_binary_search(col),
                        IterationMethod::Hash => {
                            dot_hash(xq, col, &col_hash.expect("per-column hash index")[j])
                        }
                        IterationMethod::DenseLookup => {
                            dot_dense(col, dense_x.as_ref().unwrap())
                        }
                        IterationMethod::Auto => {
                            unreachable!("plans only hold concrete methods")
                        }
                    };
                    cand_entries[dst] = (j as u32, ps * sigmoid(a));
                    dst += 1;
                }
            }
            cand_cursor[q] = dst;
        }
        if needs_dense {
            let dense_x = ws.dense_x.as_mut().unwrap();
            for &i in xq.indices {
                dense_x[i as usize] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{EngineConfig, Workspace};
    use super::super::MatmulAlgo;
    use super::*;
    use crate::sparse::SparseVec;
    use crate::tree::{Layer, XmrModel};

    fn layer() -> Layer {
        Layer::new(
            CscMatrix::from_cols(
                vec![
                    SparseVec::from_pairs(vec![(0, 1.0), (2, 2.0)]),
                    SparseVec::from_pairs(vec![(0, -1.0)]),
                    SparseVec::from_pairs(vec![(1, 3.0)]),
                    SparseVec::from_pairs(vec![(1, 0.5), (3, 0.5)]),
                ],
                4,
            ),
            &[0, 2, 4],
            false,
        )
    }

    /// The fixed NapkinXC-style index: every column live (what a uniform
    /// hash plan materializes).
    fn full_col_hash(l: &Layer) -> Vec<U32Map> {
        build_col_hash_planned(
            &l.csc,
            &l.chunked,
            &vec![IterationMethod::Hash; l.chunked.num_chunks()],
        )
    }

    #[test]
    fn col_hash_resolves_every_entry() {
        let l = layer();
        let maps = full_col_hash(&l);
        for j in 0..l.csc.cols {
            let col = l.csc.col(j);
            for (p, &r) in col.indices.iter().enumerate() {
                assert_eq!(maps[j].get(r), Some(p as u32));
            }
        }
    }

    #[test]
    fn all_baseline_iterators_agree() {
        let l = layer();
        let model = XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], false)]);
        let x = CsrMatrix::from_rows(
            vec![SparseVec::from_pairs(vec![(0, 2.0), (1, -1.0), (3, 4.0)])],
            4,
        );
        let beam = vec![(0u32, 1.0f32), (1u32, 0.5f32)];
        let maps = full_col_hash(&l);
        let mut results = Vec::new();
        for iter in IterationMethod::ALL {
            let mut ws = Workspace::new(&model, EngineConfig::new(MatmulAlgo::Baseline, iter));
            ws.begin_beams(1);
            ws.push_beam(&beam);
            ws.begin_layer(&l.chunked, 1);
            let methods = vec![iter; l.chunked.num_chunks()];
            baseline_layer(&l, &x, 0, 1, &methods, Some(&maps), &mut ws);
            results.push(ws.cand(0).to_vec());
        }
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
        assert_eq!(results[0].len(), 4);
    }

    #[test]
    fn planned_col_hash_builds_only_hash_chunk_columns() {
        let l = layer();
        let methods = vec![IterationMethod::Hash, IterationMethod::BinarySearch];
        let maps = build_col_hash_planned(&l.csc, &l.chunked, &methods);
        assert_eq!(maps.len(), 4);
        // chunk 0 (cols 0-1) live, chunk 1 (cols 2-3) placeholders
        for j in 0..2 {
            let col = l.csc.col(j);
            assert_eq!(maps[j].len(), col.nnz());
        }
        for m in &maps[2..] {
            assert!(m.is_empty());
            assert_eq!(m.memory_bytes(), 8);
        }
        // a uniform hash plan indexes every column like col_map does
        for (j, m) in full_col_hash(&l).iter().enumerate() {
            let direct = col_map(&l.csc, j);
            assert_eq!(m.memory_bytes(), direct.memory_bytes());
            assert_eq!(m.len(), direct.len());
        }
    }

    #[test]
    fn mixed_baseline_methods_match_uniform() {
        let l = layer();
        let model = XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], false)]);
        let x = CsrMatrix::from_rows(
            vec![SparseVec::from_pairs(vec![(0, 2.0), (1, -1.0), (3, 4.0)])],
            4,
        );
        let beam = vec![(0u32, 1.0f32), (1u32, 0.5f32)];
        let maps = full_col_hash(&l);
        let run = |methods: &[IterationMethod]| {
            let mut ws = Workspace::new(
                &model,
                EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::DenseLookup),
            );
            ws.begin_beams(1);
            ws.push_beam(&beam);
            ws.begin_layer(&l.chunked, 1);
            baseline_layer(&l, &x, 0, 1, methods, Some(&maps), &mut ws);
            ws.cand(0).to_vec()
        };
        let uniform = run(&[IterationMethod::MarchingPointers, IterationMethod::MarchingPointers]);
        for mix in [
            [IterationMethod::Hash, IterationMethod::DenseLookup],
            [IterationMethod::DenseLookup, IterationMethod::BinarySearch],
        ] {
            assert_eq!(run(&mix), uniform, "{mix:?}");
        }
    }
}
