//! A NapkinXC-style comparator engine (paper §5.2, Figure 5).
//!
//! NapkinXC's online inference stores every ranker column as its own
//! hash map from feature id to weight and scores a node by looking each
//! query feature up in that per-column map. The paper converts PECOS
//! models to NapkinXC format and measures ~10× in favour of hash-MSCM;
//! this module reimplements NapkinXC's evaluation faithfully — including
//! its use of a general-purpose hash map per column (`std::collections
//! ::HashMap`, the analogue of C++ `std::unordered_map`) and its
//! node-at-a-time priority-queue tree traversal — so Figure 5 can be
//! regenerated without the external C++ code base.

use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

use super::engine::Prediction;
use super::sigmoid;
use crate::sparse::{SparseVec, SparseVecView};
use crate::tree::XmrModel;

/// One ranker column as NapkinXC stores it: feature → weight.
type ColMap = HashMap<u32, f32>;

/// Reimplementation of NapkinXC's probabilistic-label-tree inference.
pub struct NapkinXcEngine {
    model: Arc<XmrModel>,
    /// Per layer, per column: the feature→weight map.
    cols: Vec<Vec<ColMap>>,
}

/// Max-heap entry for the uniform-cost traversal.
struct HeapEntry {
    score: f32,
    layer: usize,
    node: u32,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.layer == other.layer && self.node == other.node
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.score
            .total_cmp(&other.score)
            .then(other.layer.cmp(&self.layer))
            .then(other.node.cmp(&self.node))
    }
}

impl NapkinXcEngine {
    /// Converts a model into NapkinXC's per-column hash-map format (the
    /// paper's PECOS→NapkinXC conversion script analogue).
    pub fn new(model: Arc<XmrModel>) -> Self {
        let cols = model
            .layers
            .iter()
            .map(|layer| {
                (0..layer.csc.cols)
                    .map(|j| {
                        let col = layer.csc.col(j);
                        col.indices
                            .iter()
                            .zip(col.values)
                            .map(|(&r, &v)| (r, v))
                            .collect::<ColMap>()
                    })
                    .collect()
            })
            .collect();
        Self { model, cols }
    }

    /// The wrapped model.
    pub fn model(&self) -> &Arc<XmrModel> {
        &self.model
    }

    /// Per-column map memory overhead in bytes (lower bound: buckets are
    /// at least key+value+control per entry; this is what MSCM's
    /// per-chunk map amortizes away).
    pub fn side_index_bytes(&self) -> usize {
        self.cols
            .iter()
            .flat_map(|layer| layer.iter().map(|m| m.capacity() * 9 + 48))
            .sum()
    }

    fn score_node(&self, layer: usize, node: u32, x: SparseVecView<'_>) -> f32 {
        let map = &self.cols[layer][node as usize];
        let mut a = 0.0f32;
        for (&i, &xv) in x.indices.iter().zip(x.values) {
            if let Some(&wv) = map.get(&i) {
                a += xv * wv;
            }
        }
        sigmoid(a)
    }

    /// Top-k prediction via NapkinXC's uniform-cost search: a max-heap of
    /// frontier nodes ordered by path score; leaves pop in descending
    /// score order, so the first `k` pops are the answer. (With a
    /// monotone score product this is exact — NapkinXC's default
    /// `prediction` mode; the paper's comparison uses the same top-k.)
    pub fn predict(&self, x: &SparseVec, topk: usize) -> Vec<Prediction> {
        let mut heap = BinaryHeap::new();
        let depth = self.model.layers.len();
        // Children of the implicit root = chunk 0 of layer 0.
        for j in self.model.layers[0].children_of(0) {
            heap.push(HeapEntry {
                score: self.score_node(0, j as u32, x.view()),
                layer: 0,
                node: j as u32,
            });
        }
        let mut out = Vec::with_capacity(topk);
        while let Some(e) = heap.pop() {
            if e.layer + 1 == depth {
                out.push(Prediction {
                    label: e.node,
                    score: e.score,
                });
                if out.len() == topk {
                    break;
                }
            } else {
                let next = e.layer + 1;
                for j in self.model.layers[next].children_of(e.node as usize) {
                    heap.push(HeapEntry {
                        score: e.score * self.score_node(next, j as u32, x.view()),
                        layer: next,
                        node: j as u32,
                    });
                }
            }
        }
        out
    }

    /// Beam-limited prediction matching Alg. 1's level-synchronous beam —
    /// used for apples-to-apples latency comparison with our engines.
    pub fn predict_beam(&self, x: &SparseVec, beam: usize, topk: usize) -> Vec<Prediction> {
        let depth = self.model.layers.len();
        let mut frontier: Vec<(u32, f32)> = vec![(0, 1.0)];
        for l in 0..depth {
            let mut cands: Vec<(u32, f32)> = Vec::new();
            for &(p, ps) in &frontier {
                for j in self.model.layers[l].children_of(p as usize) {
                    cands.push((j as u32, ps * self.score_node(l, j as u32, x.view())));
                }
            }
            let cmp =
                |a: &(u32, f32), b: &(u32, f32)| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0));
            if cands.len() > beam {
                cands.select_nth_unstable_by(beam - 1, cmp);
                cands.truncate(beam);
            }
            cands.sort_unstable_by_key(|e| e.0);
            frontier = cands;
        }
        frontier.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        frontier.truncate(topk);
        frontier
            .into_iter()
            .map(|(label, score)| Prediction { label, score })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{EngineConfig, InferenceEngine};
    use super::super::{IterationMethod, MatmulAlgo};
    use super::*;
    use crate::util::Rng;

    fn query(d: usize, seed: u64) -> SparseVec {
        let mut rng = Rng::seed_from_u64(seed);
        SparseVec::from_pairs(
            (0..d / 2)
                .map(|_| (rng.gen_range(0..d) as u32, rng.gen_f32(-1.0, 1.0)))
                .collect(),
        )
    }

    #[test]
    fn beam_prediction_matches_our_engine() {
        let model = crate::tree::test_util::tiny_model(24, 3, 3, 21);
        let ours = InferenceEngine::new(
            model.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        );
        let napkin = NapkinXcEngine::new(Arc::new(model));
        for seed in 0..8 {
            let x = query(24, seed);
            let a = ours.predict(&x, 4, 4);
            let b = napkin.predict_beam(&x, 4, 4);
            assert_eq!(a, b, "seed {seed}");
        }
    }

    #[test]
    fn ucs_prediction_is_exact_topk() {
        // With beam = whole tree, our engine is exhaustive; NapkinXC's
        // uniform-cost search must return the same top-k.
        let model = crate::tree::test_util::tiny_model(16, 3, 2, 5);
        let nlabels = model.num_labels();
        let ours = InferenceEngine::new(
            model.clone(),
            EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::MarchingPointers),
        );
        let napkin = NapkinXcEngine::new(Arc::new(model));
        for seed in 0..8 {
            let x = query(16, 100 + seed);
            let exact = ours.predict(&x, nlabels, 3);
            let ucs = napkin.predict(&x, 3);
            assert_eq!(exact, ucs, "seed {seed}");
        }
    }
}
