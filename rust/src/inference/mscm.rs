//! MSCM evaluation of the masked product (paper Algorithms 2 and 3).
//!
//! One layer step: every `(query, beamed parent)` pair is a nonzero mask
//! *block* (paper §4 item 1) covering the parent's whole sibling chunk.
//! Blocks are evaluated **in chunk order** when the batch has more than
//! one query (Alg. 3 lines 6–8) so a chunk enters cache once; each block
//! is one sparse-vector × chunk product (Alg. 2) under the configured
//! iteration method.

use std::sync::atomic::{AtomicBool, Ordering};

use super::engine::Workspace;
use super::{sigmoid, IterationMethod};
use crate::sparse::iterators::{
    vec_chunk_binary, vec_chunk_dense, vec_chunk_hash, vec_chunk_marching,
};
use crate::sparse::CsrMatrix;
use crate::tree::Layer;

/// Ablation hook (benches/ablation.rs): disables the chunk-order block
/// sort of Alg. 3 lines 6–8 to measure how much of MSCM's batch win
/// comes from cache-resident chunk reuse. Always on in production.
static CHUNK_ORDER: AtomicBool = AtomicBool::new(true);

/// Enables/disables chunk-order evaluation (ablation only; not thread-
/// safe with concurrent predictions using different settings).
pub fn set_chunk_order_enabled(enabled: bool) {
    CHUNK_ORDER.store(enabled, Ordering::Relaxed);
}

/// Computes all layer candidates `(child node, path score)` for local
/// queries `0..n` (rows `qlo..qlo+n` of `x`), appending into `ws.cands`.
pub(crate) fn mscm_layer(
    layer: &Layer,
    x: &CsrMatrix,
    qlo: usize,
    n: usize,
    iter: IterationMethod,
    ws: &mut Workspace,
) {
    // Collect nonzero blocks (Alg. 3 line 5).
    ws.blocks.clear();
    for q in 0..n {
        for &(p, ps) in &ws.beams[q] {
            ws.blocks.push((p, q as u32, ps));
        }
    }
    // Chunk-order evaluation (Alg. 3 lines 6–8); skipped in the online
    // setting where it cannot pay off. Queries tie-break for determinism.
    if n > 1 && CHUNK_ORDER.load(Ordering::Relaxed) {
        ws.blocks.sort_unstable_by_key(|&(c, q, _)| (c, q));
    }

    let chunked = &layer.chunked;
    ws.loaded_chunk = None;
    // Split borrows: the block list is iterated while cands are appended.
    let blocks = std::mem::take(&mut ws.blocks);
    for &(p, q, ps) in &blocks {
        let chunk = &chunked.chunks[p as usize];
        let base = chunked.chunk_start(p as usize) as u32;
        let width = chunk.ncols as usize;
        let out = &mut ws.out_block[..width];
        out.fill(0.0);
        let xq = x.row(qlo + q as usize);
        match iter {
            IterationMethod::MarchingPointers => vec_chunk_marching(xq, chunk, out),
            IterationMethod::BinarySearch => vec_chunk_binary(xq, chunk, out),
            IterationMethod::Hash => vec_chunk_hash(xq, chunk, out),
            IterationMethod::DenseLookup => {
                // Load the chunk's rows into the dense scratch once per
                // chunk — amortized across all queries hitting it.
                if ws.loaded_chunk != Some(p) {
                    let scratch = ws.dense_pos.as_mut().expect("dense scratch");
                    if let Some(prev) = ws.loaded_chunk {
                        scratch.clear(&chunked.chunks[prev as usize]);
                    }
                    scratch.load(chunk);
                    ws.loaded_chunk = Some(p);
                }
                vec_chunk_dense(xq, chunk, ws.dense_pos.as_ref().unwrap(), out);
            }
        }
        // Conditional-probability combine (Alg. 1 lines 7–8): σ then
        // multiply by the parent's path score.
        let cands = &mut ws.cands[q as usize];
        for (c, &a) in out.iter().enumerate() {
            cands.push((base + c as u32, ps * sigmoid(a)));
        }
    }
    ws.blocks = blocks;
    // Leave the scratch clean for the next layer/batch.
    if let Some(prev) = ws.loaded_chunk.take() {
        if let Some(scratch) = ws.dense_pos.as_mut() {
            scratch.clear(&chunked.chunks[prev as usize]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{EngineConfig, Workspace};
    use super::super::{IterationMethod, MatmulAlgo};
    use super::*;
    use crate::sparse::{CscMatrix, SparseVec};

    fn layer() -> Layer {
        Layer::new(
            CscMatrix::from_cols(
                vec![
                    SparseVec::from_pairs(vec![(0, 1.0), (2, 2.0)]),
                    SparseVec::from_pairs(vec![(0, -1.0)]),
                    SparseVec::from_pairs(vec![(1, 3.0)]),
                    SparseVec::from_pairs(vec![(1, 0.5), (3, 0.5)]),
                ],
                4,
            ),
            &[0, 2, 4],
            true,
        )
    }

    fn run(iter: IterationMethod, beams: Vec<Vec<(u32, f32)>>, x: &CsrMatrix) -> Vec<Vec<(u32, f32)>> {
        let l = layer();
        let model = crate::tree::XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], true)]);
        let algo = MatmulAlgo::Mscm;
        let mut ws = Workspace::new(&model, EngineConfig { algo, iter });
        let n = beams.len();
        ws.cands.resize_with(n, Vec::new);
        ws.beams = beams;
        mscm_layer(&l, x, 0, n, iter, &mut ws);
        ws.cands[..n].to_vec()
    }

    #[test]
    fn layer_candidates_match_dense_math() {
        let x = CsrMatrix::from_rows(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (1, 1.0)]),
                SparseVec::from_pairs(vec![(2, 1.0), (3, 2.0)]),
            ],
            4,
        );
        // query 0 beams parent 0; query 1 beams both parents
        let beams = vec![vec![(0u32, 1.0f32)], vec![(0u32, 0.5f32), (1u32, 0.25f32)]];
        for iter in IterationMethod::ALL {
            let cands = run(iter, beams.clone(), &x);
            // q0: children 0,1 with a = [1.0, -1.0]
            assert_eq!(cands[0][0], (0, sigmoid(1.0)));
            assert_eq!(cands[0][1], (1, sigmoid(-1.0)));
            // q1 parent0: a = [2.0, 0.0]; parent1: a = [0.0, 1.0]
            let q1: std::collections::HashMap<u32, f32> = cands[1].iter().copied().collect();
            assert_eq!(q1[&0], 0.5 * sigmoid(2.0));
            assert_eq!(q1[&1], 0.5 * sigmoid(0.0));
            assert_eq!(q1[&2], 0.25 * sigmoid(0.0));
            assert_eq!(q1[&3], 0.25 * sigmoid(1.0));
        }
    }
}
