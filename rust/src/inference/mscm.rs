//! MSCM evaluation of the masked product (paper Algorithms 2 and 3).
//!
//! One layer step: every `(query, beamed parent)` pair is a nonzero mask
//! *block* (paper §4 item 1) covering the parent's whole sibling chunk.
//! Blocks are evaluated **in chunk order** when the batch has more than
//! one query (Alg. 3 lines 6–8) so a chunk enters cache once; each block
//! is one sparse-vector × chunk product (Alg. 2) under the configured
//! iteration method.
//!
//! # Chunk ordering is a counting sort, not a comparison sort
//!
//! The Alg. 3 block order is `(chunk asc, query asc)`. Blocks are
//! collected query-major with each query's parents ascending (beams are
//! kept in ascending node order), and a query beams any parent at most
//! once — so a **stable** distribution by chunk id reproduces the exact
//! `(chunk, query)` order: within one chunk's bucket the surviving
//! relative order is the collection order, which is query order. The
//! sort is therefore `O(blocks)` instead of `O(blocks log blocks)`, and
//! the evaluation order — hence every candidate's position and f32
//! score — is bitwise identical to the previous comparison sort (the
//! `all_configs_bitwise_identical` and sharded property tests pin this).
//!
//! Bucket counts are offset by the smallest chunk id present, so the
//! scratch is sized by the *span* of touched chunks, not the layer's
//! chunk count. In the rare degenerate case where a tiny block list
//! spans a huge chunk range (span > 4·blocks + 64), zeroing the buckets
//! would dominate and the code falls back to the comparison sort —
//! producing the identical order either way.
//!
//! # Merged-span locality pass
//!
//! Chunk order is the right granularity for `Csc`/`DenseRows` chunks —
//! each chunk is its own memory region — but sub-chunks of one
//! [`MergedStore`](crate::sparse::chunked::MergedStore) span share a
//! *single contiguous* region, and `(chunk asc, query asc)` order walks
//! that region once per sub-chunk, re-streaming it from the top for
//! every query each time. [`group_merged_spans`] therefore re-orders
//! each sorted block segment that stays inside one merged span to
//! `(query asc, chunk asc)`: every query then makes one streaming pass
//! over the span's store memory. This is safe for exactness because
//! cross-block evaluation order is free — each block accumulates into
//! its own candidate slice, per-block summation order is untouched — the
//! very invariant the `chunk_order_off_is_bitwise_identical` engine test
//! pins.
//!
//! # Observability boundary
//!
//! This module carries **no** timing hooks: the engine's
//! [`crate::metrics::EngineMetrics`] times each layer expansion as a
//! unit — a single `Instant` pair around the `expand_layer` dispatch —
//! and attributes the elapsed ns to the touched `(method, storage)`
//! chunk classes from the plan. Keeping the kernel inner loops free of
//! per-block clocks preserves both bitwise-identical evaluation order
//! and the zero-allocation hot path (`rust/tests/alloc.rs`).

use super::engine::Workspace;
use super::{sigmoid, IterationMethod, KernelTier};
use crate::sparse::iterators::{
    vec_chunk_binary, vec_chunk_binary_simd, vec_chunk_dense, vec_chunk_dense_rows,
    vec_chunk_dense_rows_simd, vec_chunk_dense_simd, vec_chunk_hash, vec_chunk_hash_simd,
    vec_chunk_marching, vec_chunk_marching_simd,
};
use crate::sparse::{Chunk, ChunkStorage, ChunkView, ChunkedMatrix, CsrMatrix, SimdLevel};
use crate::tree::Layer;

/// Orders `ws.blocks` by `(chunk, query)` via a stable counting sort
/// over the touched chunk-id span (see the module docs for why this is
/// exact and `O(blocks)`).
fn sort_blocks_by_chunk(ws: &mut Workspace) {
    let Workspace {
        blocks,
        blocks_tmp,
        chunk_counts,
        ..
    } = ws;
    let nb = blocks.len();
    if nb <= 1 {
        return;
    }
    debug_assert!(nb <= u32::MAX as usize, "block count exceeds u32 buckets");
    let mut lo = u32::MAX;
    let mut hi = 0u32;
    for &(c, _, _) in blocks.iter() {
        lo = lo.min(c);
        hi = hi.max(c);
    }
    let span = (hi - lo) as usize + 1;
    if span > 4 * nb + 64 {
        // Degenerate span: bucket zeroing would cost more than comparing.
        blocks.sort_unstable_by_key(|&(c, q, _)| (c, q));
        return;
    }
    if chunk_counts.len() < span {
        chunk_counts.resize(span, 0);
    }
    let counts = &mut chunk_counts[..span];
    counts.fill(0);
    for &(c, _, _) in blocks.iter() {
        counts[(c - lo) as usize] += 1;
    }
    // Prefix-sum the counts into bucket start cursors.
    let mut sum = 0u32;
    for c in counts.iter_mut() {
        let k = *c;
        *c = sum;
        sum += k;
    }
    // Stable scatter: collection order survives within each bucket. The
    // scatter writes every slot of [..nb] exactly once (bucket cursors
    // cover 0..nb bijectively), so only grow — never re-zero — the
    // target; truncate is O(1) on Copy entries.
    if blocks_tmp.len() < nb {
        blocks_tmp.resize(nb, (0, 0, 0.0));
    } else {
        blocks_tmp.truncate(nb);
    }
    for &b in blocks.iter() {
        let slot = &mut counts[(b.0 - lo) as usize];
        blocks_tmp[*slot as usize] = b;
        *slot += 1;
    }
    std::mem::swap(blocks, blocks_tmp);
}

/// The merged-span locality pass (module docs): within each maximal
/// segment of chunk-sorted blocks whose chunks all live in **one**
/// `MergedStore` span, re-orders to `(query asc, chunk asc)` so every
/// query streams the span's contiguous store memory once. Segments
/// touching a single sub-chunk are left alone (nothing to group), as is
/// every non-merged chunk.
///
/// A sub-chunk's span is identified without any side table: slots are
/// assigned consecutively within a run by `apply_layout`, so
/// `chunk_id - merged_slot` is the id of the span's first chunk — a
/// per-span fingerprint.
///
/// In-place and allocation-free (`sort_unstable` on the segment slice);
/// the `(q, c)` keys are unique per block, so the unstable sort is
/// deterministic.
fn group_merged_spans(blocks: &mut [(u32, u32, f32)], chunks: &[Chunk]) {
    let nb = blocks.len();
    let mut i = 0;
    while i < nb {
        let c = blocks[i].0 as usize;
        if chunks[c].storage != ChunkStorage::Merged {
            i += 1;
            continue;
        }
        let span = c - chunks[c].merged_slot as usize;
        let mut j = i + 1;
        let mut multi = false;
        while j < nb {
            let cj = blocks[j].0 as usize;
            if chunks[cj].storage != ChunkStorage::Merged
                || cj - chunks[cj].merged_slot as usize != span
            {
                break;
            }
            multi |= cj != c;
            j += 1;
        }
        if multi {
            blocks[i..j].sort_unstable_by_key(|&(c, q, _)| (q, c));
        }
        i = j;
    }
}

/// Computes all layer candidates `(child node, path score)` for local
/// queries `0..n` (rows `qlo..qlo+n` of `x`), writing each query's
/// candidates into its pre-laid-out slice of the workspace candidate
/// arena (the caller ran [`Workspace::begin_layer`]).
///
/// `methods` and `tiers` are the layer's slices of the resolved
/// [`KernelPlan`](super::plan::KernelPlan) — one concrete method and one
/// kernel tier per chunk, indexed by chunk id (uniform slices for fixed
/// configurations); the per-block lookup is a plain slice index, so the
/// hot loop stays allocation-free. `level` is the hardware SIMD level
/// the engine detected at construction: the *effective* tier of a block
/// is `planned ∧ detected`, so SIMD-planned chunks silently run the
/// (bitwise-identical) scalar kernels on plain hardware. `chunk_order`
/// is the per-engine Alg. 3 block-ordering switch (disabled only by the
/// ablation bench).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mscm_layer(
    layer: &Layer,
    x: &CsrMatrix,
    qlo: usize,
    n: usize,
    methods: &[IterationMethod],
    tiers: &[KernelTier],
    chunk_order: bool,
    level: SimdLevel,
    ws: &mut Workspace,
) {
    // Collect nonzero blocks (Alg. 3 line 5), query-major.
    {
        let Workspace {
            blocks,
            beam_entries,
            beam_offsets,
            ..
        } = ws;
        blocks.clear();
        for q in 0..n {
            for &(p, ps) in &beam_entries[beam_offsets[q]..beam_offsets[q + 1]] {
                blocks.push((p, q as u32, ps));
            }
        }
    }
    // Chunk-order evaluation (Alg. 3 lines 6–8); skipped in the online
    // setting where it cannot pay off. Queries tie-break for determinism.
    if n > 1 && chunk_order {
        sort_blocks_by_chunk(ws);
        group_merged_spans(&mut ws.blocks, &layer.chunked.chunks);
    }

    let chunked = &layer.chunked;
    ws.loaded_chunk = None;
    // Split borrows: the block list is iterated while the arena is filled.
    let blocks = std::mem::take(&mut ws.blocks);
    // Quantized chunks have no resident f32 values: they are decoded
    // into this workspace arena one chunk at a time. Chunk-sorted blocks
    // amortize the decode the same way they amortize cache loads, and
    // the arena only grows — the hot path stays allocation-free once
    // warm. Taken out of the workspace so the view borrow below does not
    // conflict with the arena writes.
    let mut dequant = std::mem::take(&mut ws.dequant);
    let mut loaded_quant: Option<u32> = None;
    // Blocks are chunk-sorted (Alg. 3), so the layout-resolved view is
    // reused across every block sharing a chunk — one storage dispatch
    // per chunk run, not per block. Dequantized views are rebuilt per
    // block instead (they borrow the arena, which the next quantized
    // chunk mutates).
    let mut cached: Option<(u32, ChunkView<'_>)> = None;
    for &(p, q, ps) in &blocks {
        let chunk_ref = &chunked.chunks[p as usize];
        let chunk = if chunk_ref.storage.is_quantized() {
            if loaded_quant != Some(p) {
                chunk_ref.dequantize_into(&mut dequant);
                loaded_quant = Some(p);
            }
            // A Csc-shaped view over the chunk's exact structure and the
            // decoded values: every ordinary kernel runs unmodified.
            ChunkView {
                ncols: chunk_ref.ncols,
                storage: ChunkStorage::Csc,
                row_indices: &chunk_ref.row_indices,
                row_ptr: &chunk_ref.row_ptr,
                col_idx: &chunk_ref.col_idx,
                values: &dequant[..],
                row_map: chunk_ref.row_map.as_ref(),
            }
        } else {
            match cached {
                Some((cp, view)) if cp == p => view,
                _ => {
                    let view = chunked.view(p as usize);
                    cached = Some((p, view));
                    view
                }
            }
        };
        let base = chunked.chunk_start(p as usize) as u32;
        let width = chunk.ncols as usize;
        let out = &mut ws.out_block[..width];
        out.fill(0.0);
        let xq = x.row(qlo + q as usize);
        // Effective tier: planned ∧ detected. Both tiers are bitwise
        // identical, so this is purely a speed dispatch.
        let simd = level.is_vector() && tiers[p as usize] == KernelTier::Simd;
        if chunk.storage == ChunkStorage::DenseRows {
            // The layout bakes the row-position array into the chunk's
            // own row_ptr: every method degenerates to the same direct
            // probe (bitwise identical), with no scratch to load.
            if simd {
                vec_chunk_dense_rows_simd(xq, chunk, out, level);
            } else {
                vec_chunk_dense_rows(xq, chunk, out);
            }
        } else {
            let m = methods[p as usize];
            if m == IterationMethod::DenseLookup {
                // Load the chunk's rows into the dense scratch once
                // per chunk — amortized across all queries hitting it.
                if ws.loaded_chunk != Some(p) {
                    let scratch = ws.dense_pos.as_mut().expect("dense scratch");
                    if let Some(prev) = ws.loaded_chunk {
                        scratch.clear(scratch_view(chunked, prev as usize));
                    }
                    scratch.load(chunk);
                    ws.loaded_chunk = Some(p);
                }
            }
            match (m, simd) {
                (IterationMethod::MarchingPointers, false) => vec_chunk_marching(xq, chunk, out),
                (IterationMethod::MarchingPointers, true) => {
                    vec_chunk_marching_simd(xq, chunk, out, level)
                }
                (IterationMethod::BinarySearch, false) => vec_chunk_binary(xq, chunk, out),
                (IterationMethod::BinarySearch, true) => {
                    vec_chunk_binary_simd(xq, chunk, out, level)
                }
                // Merged sub-chunks keep no row map; binary search is
                // their designated (bitwise-identical) stand-in.
                (IterationMethod::Hash, false) if chunk.storage == ChunkStorage::Merged => {
                    vec_chunk_binary(xq, chunk, out)
                }
                (IterationMethod::Hash, true) if chunk.storage == ChunkStorage::Merged => {
                    vec_chunk_binary_simd(xq, chunk, out, level)
                }
                (IterationMethod::Hash, false) => vec_chunk_hash(xq, chunk, out),
                (IterationMethod::Hash, true) => vec_chunk_hash_simd(xq, chunk, out, level),
                (IterationMethod::DenseLookup, false) => {
                    vec_chunk_dense(xq, chunk, ws.dense_pos.as_ref().unwrap(), out)
                }
                (IterationMethod::DenseLookup, true) => {
                    vec_chunk_dense_simd(xq, chunk, ws.dense_pos.as_ref().unwrap(), out, level)
                }
                (IterationMethod::Auto, _) => unreachable!("plans only hold concrete methods"),
            }
        }
        // Conditional-probability combine (Alg. 1 lines 7–8): σ then
        // multiply by the parent's path score, written at the query's
        // arena cursor.
        let dst = ws.cand_cursor[q as usize];
        let cands = &mut ws.cand_entries[dst..dst + width];
        for (c, (&a, slot)) in out.iter().zip(cands.iter_mut()).enumerate() {
            *slot = (base + c as u32, ps * sigmoid(a));
        }
        ws.cand_cursor[q as usize] = dst + width;
    }
    ws.blocks = blocks;
    ws.dequant = dequant;
    // Leave the scratch clean for the next layer/batch.
    if let Some(prev) = ws.loaded_chunk.take() {
        if let Some(scratch) = ws.dense_pos.as_mut() {
            scratch.clear(scratch_view(chunked, prev as usize));
        }
    }
}

/// The view the dense scratch's load/clear walks read (`row_indices`
/// only) for chunk `c`. Quantized chunks have no borrowable f32 payload
/// — their structure arrays are exact, so a values-free `Csc`-shaped
/// view serves the position walks.
fn scratch_view(chunked: &ChunkedMatrix, c: usize) -> ChunkView<'_> {
    let chunk = &chunked.chunks[c];
    if chunk.storage.is_quantized() {
        ChunkView {
            ncols: chunk.ncols,
            storage: ChunkStorage::Csc,
            row_indices: &chunk.row_indices,
            row_ptr: &chunk.row_ptr,
            col_idx: &chunk.col_idx,
            values: &[],
            row_map: chunk.row_map.as_ref(),
        }
    } else {
        chunked.view(c)
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{EngineConfig, Workspace};
    use super::super::{IterationMethod, MatmulAlgo};
    use super::*;
    use crate::sparse::{CscMatrix, SparseVec};

    fn layer() -> Layer {
        Layer::new(
            CscMatrix::from_cols(
                vec![
                    SparseVec::from_pairs(vec![(0, 1.0), (2, 2.0)]),
                    SparseVec::from_pairs(vec![(0, -1.0)]),
                    SparseVec::from_pairs(vec![(1, 3.0)]),
                    SparseVec::from_pairs(vec![(1, 0.5), (3, 0.5)]),
                ],
                4,
            ),
            &[0, 2, 4],
            true,
        )
    }

    fn run(iter: IterationMethod, beams: Vec<Vec<(u32, f32)>>, x: &CsrMatrix) -> Vec<Vec<(u32, f32)>> {
        let l = layer();
        let model = crate::tree::XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], true)]);
        let mut ws = Workspace::new(&model, EngineConfig::new(MatmulAlgo::Mscm, iter));
        let n = beams.len();
        ws.begin_beams(n);
        for b in &beams {
            ws.push_beam(b);
        }
        ws.begin_layer(&l.chunked, n);
        let methods = vec![iter; l.chunked.num_chunks()];
        let tiers = vec![KernelTier::Scalar; l.chunked.num_chunks()];
        mscm_layer(
            &l,
            x,
            0,
            n,
            &methods,
            &tiers,
            true,
            SimdLevel::detect(),
            &mut ws,
        );
        (0..n).map(|q| ws.cand(q).to_vec()).collect()
    }

    #[test]
    fn layer_candidates_match_dense_math() {
        let x = CsrMatrix::from_rows(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (1, 1.0)]),
                SparseVec::from_pairs(vec![(2, 1.0), (3, 2.0)]),
            ],
            4,
        );
        // query 0 beams parent 0; query 1 beams both parents
        let beams = vec![vec![(0u32, 1.0f32)], vec![(0u32, 0.5f32), (1u32, 0.25f32)]];
        for iter in IterationMethod::ALL {
            let cands = run(iter, beams.clone(), &x);
            // q0: children 0,1 with a = [1.0, -1.0]
            assert_eq!(cands[0][0], (0, sigmoid(1.0)));
            assert_eq!(cands[0][1], (1, sigmoid(-1.0)));
            // q1 parent0: a = [2.0, 0.0]; parent1: a = [0.0, 1.0]
            let q1: std::collections::HashMap<u32, f32> = cands[1].iter().copied().collect();
            assert_eq!(q1[&0], 0.5 * sigmoid(2.0));
            assert_eq!(q1[&1], 0.5 * sigmoid(0.0));
            assert_eq!(q1[&2], 0.25 * sigmoid(0.0));
            assert_eq!(q1[&3], 0.25 * sigmoid(1.0));
        }
    }

    #[test]
    fn counting_sort_matches_comparison_sort() {
        // Adversarial block lists: duplicated chunks across queries,
        // unsorted chunk gaps, single-chunk runs — the counting sort must
        // reproduce the exact (chunk asc, query asc) comparison order.
        let cases: Vec<Vec<Vec<u32>>> = vec![
            vec![vec![0, 3], vec![1, 3], vec![0, 1, 3]],
            vec![vec![7], vec![7], vec![7]],
            vec![vec![0], vec![9]],
            vec![vec![2, 5, 8], vec![0, 8], vec![5]],
        ];
        for parents_per_q in cases {
            let mut ws = dummy_workspace();
            ws.blocks.clear();
            let mut expect = Vec::new();
            for (q, parents) in parents_per_q.iter().enumerate() {
                for &p in parents {
                    ws.blocks.push((p, q as u32, (p + q as u32) as f32));
                    expect.push((p, q as u32, (p + q as u32) as f32));
                }
            }
            expect.sort_by_key(|&(c, q, _)| (c, q));
            super::sort_blocks_by_chunk(&mut ws);
            assert_eq!(ws.blocks, expect);
        }
    }

    #[test]
    fn counting_sort_fallback_on_sparse_span() {
        // A span far wider than the block list takes the comparison-sort
        // fallback; the order must be the same (chunk, query) order.
        let mut ws = dummy_workspace();
        ws.blocks = vec![(1_000_000, 1, 0.5), (3, 0, 0.25), (1_000_000, 0, 0.125)];
        super::sort_blocks_by_chunk(&mut ws);
        assert_eq!(
            ws.blocks,
            vec![(3, 0, 0.25), (1_000_000, 0, 0.125), (1_000_000, 1, 0.5)]
        );
    }

    #[test]
    fn merged_spans_group_by_query_csc_untouched() {
        // Four 2-col chunks; the first three coalesce into one merged
        // span, the last stays Csc. After the (chunk, query) counting
        // sort, the locality pass must re-sort the merged span's segment
        // to (query, chunk) — gathering each query's sub-chunk blocks
        // adjacently — while leaving the Csc segment in chunk order.
        use crate::sparse::{ChunkStorage, ChunkedMatrix};
        let cols: Vec<SparseVec> = (0..8)
            .map(|c| SparseVec::from_pairs(vec![(c as u32 % 4, 1.0 + c as f32)]))
            .collect();
        let csc = CscMatrix::from_cols(cols, 4);
        let mut chunked = ChunkedMatrix::from_csc(&csc, &[0, 2, 4, 6, 8], false);
        chunked.apply_layout(&[
            ChunkStorage::Merged,
            ChunkStorage::Merged,
            ChunkStorage::Merged,
            ChunkStorage::Csc,
        ]);
        let mut blocks = vec![
            (0u32, 0u32, 0.5f32),
            (0, 1, 0.25),
            (1, 0, 0.125),
            (1, 2, 0.0625),
            (2, 1, 0.75),
            (3, 0, 0.375),
            (3, 1, 0.1875),
        ];
        super::group_merged_spans(&mut blocks, &chunked.chunks);
        assert_eq!(
            blocks,
            vec![
                (0, 0, 0.5),
                (1, 0, 0.125),
                (0, 1, 0.25),
                (2, 1, 0.75),
                (1, 2, 0.0625),
                (3, 0, 0.375),
                (3, 1, 0.1875),
            ]
        );
    }

    fn dummy_workspace() -> Workspace {
        let l = layer();
        let model = crate::tree::XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], true)]);
        Workspace::new(
            &model,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers),
        )
    }

    #[test]
    fn mixed_methods_within_one_layer_match_uniform() {
        // A per-chunk plan mixing all four kernels across the layer's two
        // chunks must produce the exact candidates of any uniform method.
        let x = CsrMatrix::from_rows(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (1, 1.0)]),
                SparseVec::from_pairs(vec![(2, 1.0), (3, 2.0)]),
            ],
            4,
        );
        let beams = vec![vec![(0u32, 1.0f32), (1u32, 0.25f32)], vec![(0u32, 0.5f32), (1u32, 0.75f32)]];
        let uniform = run(IterationMethod::MarchingPointers, beams.clone(), &x);
        for mix in [
            [IterationMethod::Hash, IterationMethod::DenseLookup],
            [IterationMethod::BinarySearch, IterationMethod::Hash],
            [IterationMethod::DenseLookup, IterationMethod::MarchingPointers],
        ] {
            let l = layer();
            let model =
                crate::tree::XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], true)]);
            // dense scratch + row maps: allocate for the union of needs
            let mut ws = Workspace::new(
                &model,
                EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup),
            );
            let n = beams.len();
            ws.begin_beams(n);
            for b in &beams {
                ws.push_beam(b);
            }
            ws.begin_layer(&l.chunked, n);
            let tiers = vec![KernelTier::Scalar; mix.len()];
            mscm_layer(
                &l,
                &x,
                0,
                n,
                &mix,
                &tiers,
                true,
                SimdLevel::detect(),
                &mut ws,
            );
            let got: Vec<Vec<(u32, f32)>> = (0..n).map(|q| ws.cand(q).to_vec()).collect();
            assert_eq!(got, uniform, "{mix:?}");
        }
    }

    #[test]
    fn mixed_layouts_within_one_layer_match_csc() {
        // DenseRows and Merged chunks interleaved with Csc in one layer
        // must produce the exact candidates of the all-Csc layout, under
        // every method (DenseLookup exercises the scratch on the
        // non-DenseRows chunks).
        use crate::sparse::ChunkStorage;
        let x = CsrMatrix::from_rows(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (1, 1.0)]),
                SparseVec::from_pairs(vec![(2, 1.0), (3, 2.0)]),
            ],
            4,
        );
        let beams = vec![
            vec![(0u32, 1.0f32), (1u32, 0.25f32)],
            vec![(0u32, 0.5f32), (1u32, 0.75f32)],
        ];
        let uniform = run(IterationMethod::MarchingPointers, beams.clone(), &x);
        for layout in [
            [ChunkStorage::DenseRows, ChunkStorage::Csc],
            [ChunkStorage::Merged, ChunkStorage::Merged],
            [ChunkStorage::DenseRows, ChunkStorage::Merged],
        ] {
            for iter in IterationMethod::ALL {
                let mut l = layer();
                l.chunked.apply_layout(&layout);
                let model =
                    crate::tree::XmrModel::new(4, vec![Layer::new(l.csc.clone(), &[0, 4], true)]);
                // dense scratch + row maps: allocate for the union of needs
                let mut ws = Workspace::new(
                    &model,
                    EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup),
                );
                let n = beams.len();
                ws.begin_beams(n);
                for b in &beams {
                    ws.push_beam(b);
                }
                ws.begin_layer(&l.chunked, n);
                let methods = vec![iter; l.chunked.num_chunks()];
                // Force-SIMD tiers: on scalar hardware they degrade to
                // the scalar kernels, on SIMD hardware they must still
                // be bitwise identical — either way `got == uniform`.
                let tiers = vec![KernelTier::Simd; l.chunked.num_chunks()];
                mscm_layer(
                    &l,
                    &x,
                    0,
                    n,
                    &methods,
                    &tiers,
                    true,
                    SimdLevel::detect(),
                    &mut ws,
                );
                let got: Vec<Vec<(u32, f32)>> = (0..n).map(|q| ws.cand(q).to_vec()).collect();
                assert_eq!(got, uniform, "{layout:?}/{iter:?}");
            }
        }
    }
}
