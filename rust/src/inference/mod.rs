//! Beam-search inference (paper Alg. 1) over XMR tree models, with the
//! masked sparse matrix product (eq. 6) evaluated either by the vanilla
//! per-column **baseline** (Alg. 4) or by **MSCM** (Alg. 2–3), each under
//! any of the four support-intersection iteration methods.
//!
//! Every `(algo, iteration)` pair yields *bit-identical* predictions: the
//! per-output-entry summation order (ascending feature id) is the same in
//! all code paths, so the paper's "performance boost … is essentially
//! free" exactness claim holds bitwise here and is enforced by property
//! tests.

mod baseline;
mod engine;
mod mscm;
pub mod napkinxc;
mod parallel;

pub use engine::{EngineConfig, InferenceEngine, Prediction, Workspace};
pub(crate) use engine::{rank_into, select_top};
pub use mscm::set_chunk_order_enabled;

/// How the support intersection `S(x) ∩ S(K)` (or `S(x) ∩ S(w_j)` for the
/// baseline) is iterated — paper §4 items 1–4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IterationMethod {
    /// Two sorted cursors advanced one step at a time.
    MarchingPointers,
    /// Marching pointers with `LowerBound` jumps (Alg. 4).
    BinarySearch,
    /// Prebuilt row-id hash maps (per chunk for MSCM, per column for the
    /// baseline — the latter is NapkinXC's scheme).
    Hash,
    /// `O(d)` dense scratch: chunk rows scattered once per chunk (MSCM) /
    /// the query scattered once per query (baseline, Parabel/Bonsai).
    DenseLookup,
}

impl IterationMethod {
    /// All four methods, in the paper's presentation order.
    pub const ALL: [IterationMethod; 4] = [
        IterationMethod::MarchingPointers,
        IterationMethod::BinarySearch,
        IterationMethod::Hash,
        IterationMethod::DenseLookup,
    ];

    /// Short human-readable name matching the paper's tables.
    pub fn label(&self) -> &'static str {
        match self {
            IterationMethod::MarchingPointers => "Marching Pointers",
            IterationMethod::BinarySearch => "Binary Search",
            IterationMethod::Hash => "Hash",
            IterationMethod::DenseLookup => "Dense Lookup",
        }
    }
}

impl std::str::FromStr for IterationMethod {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "marching" | "marching-pointers" => Ok(IterationMethod::MarchingPointers),
            "binary" | "binary-search" => Ok(IterationMethod::BinarySearch),
            "hash" => Ok(IterationMethod::Hash),
            "dense" | "dense-lookup" => Ok(IterationMethod::DenseLookup),
            other => Err(format!(
                "unknown iteration method '{other}' (expected marching|binary|hash|dense)"
            )),
        }
    }
}

/// Which masked-matmul algorithm evaluates eq. 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatmulAlgo {
    /// Vanilla per-column vector-dot-product evaluation.
    Baseline,
    /// Masked sparse chunk multiplication (the paper's contribution).
    Mscm,
}

impl MatmulAlgo {
    /// Both algorithms.
    pub const ALL: [MatmulAlgo; 2] = [MatmulAlgo::Baseline, MatmulAlgo::Mscm];

    /// Table label ("", " MSCM").
    pub fn label(&self) -> &'static str {
        match self {
            MatmulAlgo::Baseline => "",
            MatmulAlgo::Mscm => " MSCM",
        }
    }
}

impl std::str::FromStr for MatmulAlgo {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" | "vanilla" => Ok(MatmulAlgo::Baseline),
            "mscm" | "chunked" => Ok(MatmulAlgo::Mscm),
            other => Err(format!("unknown algo '{other}' (expected baseline|mscm)")),
        }
    }
}

/// The ranker activation function σ (logistic sigmoid).
#[inline(always)]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
        assert!((sigmoid(1.0) + sigmoid(-1.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn enum_labels() {
        assert_eq!(IterationMethod::Hash.label(), "Hash");
        assert_eq!(MatmulAlgo::Mscm.label(), " MSCM");
        assert_eq!(IterationMethod::ALL.len(), 4);
    }
}
