//! Beam-search inference (paper Alg. 1) over XMR tree models, with the
//! masked sparse matrix product (eq. 6) evaluated either by the vanilla
//! per-column **baseline** (Alg. 4) or by **MSCM** (Alg. 2–3), each under
//! any of the four support-intersection iteration methods — or under a
//! per-chunk **kernel plan** ([`IterationMethod::Auto`]).
//!
//! Every `(algo, iteration)` pair yields *bit-identical* predictions: the
//! per-output-entry summation order (ascending feature id) is the same in
//! all code paths, so the paper's "performance boost … is essentially
//! free" exactness claim holds bitwise here and is enforced by property
//! tests.
//!
//! # The kernel planner (`IterationMethod::Auto`)
//!
//! The paper's benchmarks show no iteration method is uniformly fastest:
//! the winner depends on chunk width, chunk density and query support
//! size, which vary wildly across the layers of one tree. Because all
//! four methods are bitwise identical, [`plan::KernelPlan`] picks the
//! method **per chunk** from an analytical cost model over the chunk's
//! structural statistics ([`crate::sparse::ChunkStats`]) — optionally
//! micro-calibrated against the model's own chunks
//! ([`plan::CostModel::calibrate`]) — with zero accuracy risk: per-chunk
//! selection only permutes *which kernel* computes each block, never the
//! per-entry summation order, so `Auto` output is bit-for-bit the fixed
//! methods' output (property-tested, sharded included).
//!
//! Cost shapes (per block, `q` query nnz, `r` stored chunk rows, `n`
//! blocks amortizing one dense chunk load — Table 6 of the paper):
//! marching `q + r`; binary `min·log2(max)`; hash `q` probes against the
//! chunk row map; dense `1.5q` probes + `2r/n` load. Fixed methods are
//! degenerate uniform plans, so the layer hot loop has exactly one
//! dispatch path — a slice index into the plan, no allocation
//! (`rust/tests/alloc.rs` covers `Auto`).
//!
//! The plan also picks each chunk's **weight storage layout**
//! ([`crate::sparse::ChunkStorage`]): dense-planned chunks whose rows
//! cover most of `d` re-lay as `DenseRows` (direct row-id-indexed
//! pointers — no `row_indices`, no row map, no scratch), and runs of
//! tiny marching/binary-planned sibling chunks coalesce into a shared
//! `Merged` store. Layouts are applied once, at engine construction
//! ([`InferenceEngine::new_with_plan`]), and persist in the `MSCMXMR3`
//! shard envelope; every layout is bitwise identical to the seed `Csc`
//! path (see the [`crate::sparse`] module docs and
//! `rust/tests/layout.rs`).
//!
//! The plan also drives **side-index materialization**: chunk row maps
//! exist only on hash-planned `Csc` chunks, the `O(d)` dense scratch is
//! allocated only when some chunk plans dense without the `DenseRows`
//! layout, and the baseline's per-column maps only materialize under
//! hash-planned chunks. [`InferenceEngine::side_index_bytes`] reports
//! the total in one number (and [`InferenceEngine::weight_bytes`] the
//! layout-applied payload); on mixed-density models `Auto` is strictly
//! below fixed `hash`.
//!
//! # The SIMD kernel tier ([`KernelTier`])
//!
//! Orthogonally to *which* intersection method runs, each chunk carries a
//! kernel **tier**: [`KernelTier::Scalar`] (the seed loops, always
//! available, the exactness oracle) or [`KernelTier::Simd`] (the
//! vectorized variants in [`crate::sparse::simd`] — AVX2 on `x86_64`,
//! NEON on `aarch64`). The hardware level is detected **once, at engine
//! construction** ([`crate::sparse::simd::SimdLevel::detect`], overridden
//! to scalar by `MSCM_FORCE_SCALAR=1`), and the *effective* tier of a
//! block is `planned tier ∧ detected level`: a SIMD-planned shard file
//! serves unchanged on hardware without the instructions, silently
//! running the scalar oracle.
//!
//! Vectorization is **across independent output rows only** — gathered
//! `row_ptr`/scratch probes whose hits are emitted in ascending lane
//! order, and non-fused `mul`+`add` over runs of *consecutive* output
//! columns, where each output lane receives exactly the one
//! multiply-add it would get from the scalar loop. No FMA, no horizontal
//! reductions, no per-entry reassociation: every `(algo, iteration,
//! layout, tier)` combination stays bit-identical (pinned by
//! `rust/tests/simd.rs` over the seeded harness, remainder lanes
//! included). [`plan::CostModel`] carries per-method SIMD constants
//! (`--calibrate` fits them on the real chunks) so `Auto` plans the
//! vector tier only on chunks wide or dense enough to amortize the
//! setup — tiny supports stay scalar.

mod baseline;
mod engine;
mod mscm;
pub mod napkinxc;
mod parallel;
pub mod plan;

pub use engine::{EngineConfig, InferenceEngine, Prediction, Workspace};
pub(crate) use engine::{rank_into, select_top};
pub use plan::{CostModel, KernelPlan, PlanSummary, PlannerConfig};

/// How the support intersection `S(x) ∩ S(K)` (or `S(x) ∩ S(w_j)` for the
/// baseline) is iterated — paper §4 items 1–4, plus the planner's `Auto`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IterationMethod {
    /// Two sorted cursors advanced one step at a time.
    MarchingPointers,
    /// Marching pointers with `LowerBound` jumps (Alg. 4).
    BinarySearch,
    /// Prebuilt row-id hash maps (per chunk for MSCM, per column for the
    /// baseline — the latter is NapkinXC's scheme).
    Hash,
    /// `O(d)` dense scratch: chunk rows scattered once per chunk (MSCM) /
    /// the query scattered once per query (baseline, Parabel/Bonsai).
    DenseLookup,
    /// Per-chunk cost-model selection among the four methods above,
    /// resolved to a [`plan::KernelPlan`] at engine construction. Never
    /// reaches a kernel.
    Auto,
}

impl IterationMethod {
    /// The four concrete methods, in the paper's presentation order
    /// (`Auto` is a planner directive, not a kernel).
    pub const ALL: [IterationMethod; 4] = [
        IterationMethod::MarchingPointers,
        IterationMethod::BinarySearch,
        IterationMethod::Hash,
        IterationMethod::DenseLookup,
    ];

    /// Histogram/serialization index of a concrete method (0..4).
    ///
    /// # Panics
    /// On `Auto`, which never appears in a resolved plan.
    #[inline]
    pub fn index(&self) -> usize {
        match self {
            IterationMethod::MarchingPointers => 0,
            IterationMethod::BinarySearch => 1,
            IterationMethod::Hash => 2,
            IterationMethod::DenseLookup => 3,
            IterationMethod::Auto => panic!("Auto has no kernel index"),
        }
    }

    /// Inverse of [`IterationMethod::index`] (plan deserialization).
    pub fn from_index(i: usize) -> Option<IterationMethod> {
        IterationMethod::ALL.get(i).copied()
    }

    /// Short human-readable name matching the paper's tables.
    pub fn label(&self) -> &'static str {
        match self {
            IterationMethod::MarchingPointers => "Marching Pointers",
            IterationMethod::BinarySearch => "Binary Search",
            IterationMethod::Hash => "Hash",
            IterationMethod::DenseLookup => "Dense Lookup",
            IterationMethod::Auto => "Auto",
        }
    }

    /// Compact name for plan histograms.
    pub fn short(&self) -> &'static str {
        match self {
            IterationMethod::MarchingPointers => "marching",
            IterationMethod::BinarySearch => "binary",
            IterationMethod::Hash => "hash",
            IterationMethod::DenseLookup => "dense",
            IterationMethod::Auto => "auto",
        }
    }
}

impl std::str::FromStr for IterationMethod {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "marching" | "marching-pointers" => Ok(IterationMethod::MarchingPointers),
            "binary" | "binary-search" => Ok(IterationMethod::BinarySearch),
            "hash" => Ok(IterationMethod::Hash),
            "dense" | "dense-lookup" => Ok(IterationMethod::DenseLookup),
            "auto" | "plan" => Ok(IterationMethod::Auto),
            other => Err(format!(
                "unknown iteration method '{other}' (expected marching|binary|hash|dense|auto)"
            )),
        }
    }
}

/// Which kernel *tier* evaluates a chunk's blocks: the scalar seed loops
/// or their runtime-dispatched SIMD variants ([`crate::sparse::simd`]).
///
/// The tier is planned per chunk (like the method and the storage
/// layout) and is purely a speed choice: both tiers are bitwise
/// identical, and a plan's `Simd` entries degrade to `Scalar` at run
/// time when the hardware level detected at engine construction has no
/// vector instructions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelTier {
    /// The portable scalar kernels — always available, and the exactness
    /// oracle the SIMD tier is property-tested against.
    Scalar,
    /// Vectorized probe/emit variants (AVX2 / NEON), dispatched only
    /// when [`crate::sparse::simd::SimdLevel::detect`] reports support.
    Simd,
}

impl KernelTier {
    /// Both tiers, scalar first.
    pub const ALL: [KernelTier; 2] = [KernelTier::Scalar, KernelTier::Simd];

    /// Histogram/serialization index (0..2).
    #[inline]
    pub fn index(&self) -> usize {
        match self {
            KernelTier::Scalar => 0,
            KernelTier::Simd => 1,
        }
    }

    /// Inverse of [`KernelTier::index`].
    pub fn from_index(i: usize) -> Option<KernelTier> {
        KernelTier::ALL.get(i).copied()
    }

    /// Human-readable name.
    pub fn label(&self) -> &'static str {
        match self {
            KernelTier::Scalar => "Scalar",
            KernelTier::Simd => "SIMD",
        }
    }

    /// Compact name for plan histograms and metric keys.
    pub fn short(&self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Simd => "simd",
        }
    }
}

/// Which masked-matmul algorithm evaluates eq. 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatmulAlgo {
    /// Vanilla per-column vector-dot-product evaluation.
    Baseline,
    /// Masked sparse chunk multiplication (the paper's contribution).
    Mscm,
}

impl MatmulAlgo {
    /// Both algorithms.
    pub const ALL: [MatmulAlgo; 2] = [MatmulAlgo::Baseline, MatmulAlgo::Mscm];

    /// Table label ("", " MSCM").
    pub fn label(&self) -> &'static str {
        match self {
            MatmulAlgo::Baseline => "",
            MatmulAlgo::Mscm => " MSCM",
        }
    }
}

impl std::str::FromStr for MatmulAlgo {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" | "vanilla" => Ok(MatmulAlgo::Baseline),
            "mscm" | "chunked" => Ok(MatmulAlgo::Mscm),
            other => Err(format!("unknown algo '{other}' (expected baseline|mscm)")),
        }
    }
}

/// The ranker activation function σ (logistic sigmoid).
#[inline(always)]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
        assert!((sigmoid(1.0) + sigmoid(-1.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn enum_labels() {
        assert_eq!(IterationMethod::Hash.label(), "Hash");
        assert_eq!(IterationMethod::Auto.label(), "Auto");
        assert_eq!(MatmulAlgo::Mscm.label(), " MSCM");
        assert_eq!(IterationMethod::ALL.len(), 4);
    }

    #[test]
    fn method_index_round_trips() {
        for (i, m) in IterationMethod::ALL.into_iter().enumerate() {
            assert_eq!(m.index(), i);
            assert_eq!(IterationMethod::from_index(i), Some(m));
        }
        assert_eq!(IterationMethod::from_index(4), None);
        assert_eq!("auto".parse::<IterationMethod>(), Ok(IterationMethod::Auto));
    }

    #[test]
    fn tier_index_round_trips() {
        for (i, t) in KernelTier::ALL.into_iter().enumerate() {
            assert_eq!(t.index(), i);
            assert_eq!(KernelTier::from_index(i), Some(t));
        }
        assert_eq!(KernelTier::from_index(2), None);
        assert_eq!(KernelTier::Simd.short(), "simd");
        assert_eq!(KernelTier::Scalar.label(), "Scalar");
    }
}
