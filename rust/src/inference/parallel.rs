//! Multi-threaded batch inference (paper §6.1).
//!
//! Batch MSCM is embarrassingly parallel: queries are partitioned into
//! contiguous ranges and each thread runs the whole layer loop on its own
//! slice with a private [`Workspace`] — no synchronization on the hot
//! path. This mirrors the paper's OpenMP row-chunk distribution; dense
//! lookup pays an `O(d)` scratch per thread, which is exactly why the
//! paper finds it uncompetitive when parallelized.

use super::engine::{InferenceEngine, Prediction};
use crate::sparse::CsrMatrix;

impl InferenceEngine {
    /// Batch inference over `threads` OS threads. Equivalent to
    /// [`InferenceEngine::predict_batch`] (bitwise) but partitions rows.
    pub fn predict_batch_parallel(
        &self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
        threads: usize,
    ) -> Vec<Vec<Prediction>> {
        let n = x.rows;
        let threads = threads.max(1).min(n.max(1));
        if threads <= 1 {
            return self.predict_batch(x, beam, topk);
        }
        let mut out: Vec<Vec<Prediction>> = vec![Vec::new(); n];
        // Contiguous, near-equal ranges.
        let per = n / threads;
        let rem = n % threads;
        let mut slices: Vec<&mut [Vec<Prediction>]> = Vec::with_capacity(threads);
        let mut bounds = Vec::with_capacity(threads);
        {
            let mut rest = out.as_mut_slice();
            let mut lo = 0usize;
            for t in 0..threads {
                let len = per + usize::from(t < rem);
                let (head, tail) = rest.split_at_mut(len);
                slices.push(head);
                bounds.push((lo, lo + len));
                lo += len;
                rest = tail;
            }
        }
        std::thread::scope(|scope| {
            for (slice, (qlo, qhi)) in slices.into_iter().zip(bounds) {
                scope.spawn(move || {
                    let mut ws = self.workspace();
                    self.predict_range(x, qlo, qhi, beam, topk, &mut ws, slice);
                });
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::EngineConfig;
    use super::super::{IterationMethod, MatmulAlgo};
    use super::*;
    use crate::sparse::SparseVec;
    use crate::util::Rng;

    fn random_queries(n: usize, d: usize, seed: u64) -> CsrMatrix {
        let mut rng = Rng::seed_from_u64(seed);
        let rows = (0..n)
            .map(|_| {
                let nnz = rng.gen_range(0..d / 2 + 1);
                SparseVec::from_pairs(
                    (0..nnz)
                        .map(|_| (rng.gen_range(0..d) as u32, rng.gen_f32(-1.0, 1.0)))
                        .collect(),
                )
            })
            .collect();
        CsrMatrix::from_rows(rows, d)
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let model = crate::tree::test_util::tiny_model(32, 4, 3, 11);
        let x = random_queries(37, 32, 5);
        for algo in MatmulAlgo::ALL {
            for iter in IterationMethod::ALL {
                let engine =
                    InferenceEngine::new(model.clone(), EngineConfig { algo, iter });
                let serial = engine.predict_batch(&x, 3, 3);
                for threads in [2, 4, 7] {
                    let par = engine.predict_batch_parallel(&x, 3, 3, threads);
                    assert_eq!(par, serial, "{:?}/{:?} t={}", algo, iter, threads);
                }
            }
        }
    }

    #[test]
    fn degenerate_thread_counts() {
        let model = crate::tree::test_util::tiny_model(16, 2, 2, 3);
        let engine = InferenceEngine::new(
            model,
            EngineConfig {
                algo: MatmulAlgo::Mscm,
                iter: IterationMethod::BinarySearch,
            },
        );
        let x = random_queries(3, 16, 9);
        let serial = engine.predict_batch(&x, 2, 2);
        assert_eq!(engine.predict_batch_parallel(&x, 2, 2, 0), serial);
        assert_eq!(engine.predict_batch_parallel(&x, 2, 2, 64), serial);
    }
}
