//! Multi-threaded batch inference (paper §6.1).
//!
//! Batch MSCM is embarrassingly parallel: queries are partitioned into
//! contiguous ranges and each thread runs the whole layer loop on its own
//! slice with a private [`Workspace`] — no synchronization on the hot
//! path. This mirrors the paper's OpenMP row-chunk distribution; dense
//! lookup pays an `O(d)` scratch per thread, which is exactly why the
//! paper finds it uncompetitive when parallelized.
//!
//! [`InferenceEngine::predict_batch_parallel_with`] is the pooled form:
//! the caller owns one workspace per thread and the output buffers, so
//! sustained parallel-batch serving performs no per-batch allocator
//! traffic beyond the scoped-thread spawns themselves.

use super::engine::{InferenceEngine, Prediction, Workspace};
use crate::sparse::CsrMatrix;

impl InferenceEngine {
    /// Batch inference over `threads` OS threads. Equivalent to
    /// [`InferenceEngine::predict_batch`] (bitwise) but partitions rows.
    pub fn predict_batch_parallel(
        &self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
        threads: usize,
    ) -> Vec<Vec<Prediction>> {
        let n = x.rows;
        let threads = threads.max(1).min(n.max(1));
        let mut out: Vec<Vec<Prediction>> = vec![Vec::new(); n];
        if threads <= 1 {
            let mut ws = self.workspace();
            self.predict_range(x, 0, n, beam, topk, &mut ws, &mut out);
            return out;
        }
        let mut workspaces: Vec<Workspace> = (0..threads).map(|_| self.workspace()).collect();
        self.predict_batch_parallel_with(x, beam, topk, &mut workspaces, &mut out);
        out
    }

    /// [`InferenceEngine::predict_batch_parallel`] with caller-owned
    /// per-thread workspaces and output buffers (one thread per entry of
    /// `workspaces`): the distribution, scratch and result storage all
    /// recycle between batches, so a serving loop with a pinned thread
    /// count allocates nothing per batch.
    pub fn predict_batch_parallel_with(
        &self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
        workspaces: &mut [Workspace],
        out: &mut [Vec<Prediction>],
    ) {
        let n = x.rows;
        assert!(out.len() >= n, "output buffer shorter than the batch");
        let threads = workspaces.len().min(n.max(1));
        if threads <= 1 {
            let ws = workspaces.first_mut().expect("need at least one workspace");
            self.predict_range(x, 0, n, beam, topk, ws, &mut out[..n]);
            return;
        }
        // Contiguous, near-equal ranges.
        let per = n / threads;
        let rem = n % threads;
        std::thread::scope(|scope| {
            let mut rest = &mut out[..n];
            let mut ws_rest = &mut workspaces[..threads];
            let mut lo = 0usize;
            for t in 0..threads {
                let len = per + usize::from(t < rem);
                let (head, tail) = rest.split_at_mut(len);
                rest = tail;
                let (ws_head, ws_tail) = ws_rest.split_at_mut(1);
                ws_rest = ws_tail;
                let qlo = lo;
                lo += len;
                let ws = &mut ws_head[0];
                scope.spawn(move || {
                    self.predict_range(x, qlo, qlo + len, beam, topk, ws, head);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::EngineConfig;
    use super::super::{IterationMethod, MatmulAlgo};
    use super::*;
    use crate::sparse::SparseVec;
    use crate::util::Rng;

    fn random_queries(n: usize, d: usize, seed: u64) -> CsrMatrix {
        let mut rng = Rng::seed_from_u64(seed);
        let rows = (0..n)
            .map(|_| {
                let nnz = rng.gen_range(0..d / 2 + 1);
                SparseVec::from_pairs(
                    (0..nnz)
                        .map(|_| (rng.gen_range(0..d) as u32, rng.gen_f32(-1.0, 1.0)))
                        .collect(),
                )
            })
            .collect();
        CsrMatrix::from_rows(rows, d)
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let model = crate::tree::test_util::tiny_model(32, 4, 3, 11);
        let x = random_queries(37, 32, 5);
        for algo in MatmulAlgo::ALL {
            for iter in IterationMethod::ALL {
                let engine =
                    InferenceEngine::new(model.clone(), EngineConfig::new(algo, iter));
                let serial = engine.predict_batch(&x, 3, 3);
                for threads in [2, 4, 7] {
                    let par = engine.predict_batch_parallel(&x, 3, 3, threads);
                    assert_eq!(par, serial, "{:?}/{:?} t={}", algo, iter, threads);
                }
            }
        }
    }

    #[test]
    fn pooled_parallel_buffers_recycle_bitwise() {
        let model = crate::tree::test_util::tiny_model(24, 3, 3, 13);
        let engine = InferenceEngine::new(
            model,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        );
        let mut workspaces: Vec<_> = (0..3).map(|_| engine.workspace()).collect();
        let mut out: Vec<Vec<Prediction>> = vec![Vec::new(); 40];
        // Alternate batch sizes through the same pooled buffers.
        for (seed, n) in [(1u64, 31usize), (2, 40), (3, 7), (4, 40)] {
            let x = random_queries(n, 24, seed);
            let serial = engine.predict_batch(&x, 3, 3);
            engine.predict_batch_parallel_with(&x, 3, 3, &mut workspaces, &mut out);
            assert_eq!(&out[..n], &serial[..], "n={n}");
        }
    }

    #[test]
    fn degenerate_thread_counts() {
        let model = crate::tree::test_util::tiny_model(16, 2, 2, 3);
        let engine = InferenceEngine::new(
            model,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        );
        let x = random_queries(3, 16, 9);
        let serial = engine.predict_batch(&x, 2, 2);
        assert_eq!(engine.predict_batch_parallel(&x, 2, 2, 0), serial);
        assert_eq!(engine.predict_batch_parallel(&x, 2, 2, 64), serial);
    }
}
