//! The beam-search inference engine (paper Algorithm 1).
//!
//! # Workspace layout: flat arenas, zero steady-state allocations
//!
//! The per-thread [`Workspace`] backs the whole layer loop with **flat
//! arena buffers** instead of per-query `Vec`s, so the serving hot path
//! performs no allocator traffic once warm:
//!
//! - **Beam arena** — one `Vec<(node, score)>` plus a CSR-style offset
//!   array: query `q`'s beam is `beam_entries[beam_offsets[q] ..
//!   beam_offsets[q + 1]]`, node ids ascending. The arena is rebuilt
//!   (append-only, `clear()` keeps capacity) once per layer by the beam
//!   selection step, and by [`Workspace::push_beam`] when a sharded
//!   coordinator installs externally-owned beams.
//! - **Candidate arena** — same CSR layout. Candidate counts are known
//!   *before* expansion (each beamed parent contributes exactly its
//!   sibling-chunk width), so [`Workspace::begin_layer`] prefix-sums the
//!   per-query extents and expansion writes each query's candidates at a
//!   per-query cursor. Blocks may therefore be evaluated in chunk order
//!   (cache-optimal, Alg. 3) while every write still lands in its query's
//!   contiguous slice.
//! - **Block list + counting-sort scratch** — the `(chunk, query, parent
//!   score)` blocks of Alg. 3 and the `O(blocks)` scratch used to order
//!   them by chunk without a comparison sort (see
//!   [`crate::inference::mscm`]).
//! - **Online residents** — a reusable single-row query matrix and an
//!   output buffer, so [`InferenceEngine::predict_with`] is allocation-
//!   free after its first (warmup) call. The invariant is enforced by a
//!   counting-allocator test (`rust/tests/alloc.rs`).
//!
//! Buffers only grow; steady-state serving with a bounded batch size and
//! beam width reaches a fixed point after the first batch.

use std::sync::Arc;
use std::time::Instant;

use super::baseline::{baseline_layer, build_col_hash_planned};
use super::mscm::mscm_layer;
use super::plan::{CostModel, KernelPlan, PlannerConfig};
use super::{IterationMethod, KernelTier, MatmulAlgo};
use crate::metrics::{EngineMetrics, LayerTrace, QueryTrace};
use crate::sparse::iterators::DenseScratch;
use crate::sparse::{ChunkStorage, ChunkedMatrix, CsrMatrix, SimdLevel, SparseVec, U32Map};
use crate::tree::XmrModel;

/// One retrieved label.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    /// Label id (column of the bottom layer).
    pub label: u32,
    /// Path score `Π σ(w·x)` (eq. 5).
    pub score: f32,
}

/// Engine configuration: which masked-matmul algorithm and which support
/// iteration method evaluate eq. 6. `iter` may be
/// [`IterationMethod::Auto`], which resolves to a per-chunk
/// [`KernelPlan`] at engine construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct EngineConfig {
    /// Baseline (per column) or MSCM (per chunk).
    pub algo: MatmulAlgo,
    /// Support-intersection iteration method (or `Auto`).
    pub iter: IterationMethod,
    /// Evaluate batch blocks in chunk order (Alg. 3 lines 6–8). Always
    /// on in production; disable only to ablate the cache-reuse win
    /// (`benches/ablation.rs`). Per-engine, so concurrent engines with
    /// different settings are safe.
    pub chunk_order: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            algo: MatmulAlgo::Mscm,
            iter: IterationMethod::Hash,
            chunk_order: true,
        }
    }
}

impl EngineConfig {
    /// A production configuration (chunk-order evaluation on).
    pub fn new(algo: MatmulAlgo, iter: IterationMethod) -> Self {
        Self {
            algo,
            iter,
            chunk_order: true,
        }
    }

    /// All eight fixed `(algo, iter)` combinations, baseline first
    /// (`Auto` engines are resolved plans over the same kernels, so the
    /// fixed grid is the exhaustive kernel surface).
    pub fn all() -> Vec<EngineConfig> {
        let mut v = Vec::new();
        for algo in MatmulAlgo::ALL {
            for iter in IterationMethod::ALL {
                v.push(EngineConfig::new(algo, iter));
            }
        }
        v
    }

    /// Table-row label, e.g. `"Binary Search MSCM"`.
    pub fn label(&self) -> String {
        format!("{}{}", self.iter.label(), self.algo.label())
    }
}

/// Per-thread scratch. Buffers are sized for the model/batch once and
/// recycled across queries and batches so the hot path never allocates
/// (see the module docs for the arena layout).
pub struct Workspace {
    /// `O(d)` chunk-row position scratch (MSCM dense lookup).
    pub(crate) dense_pos: Option<DenseScratch>,
    /// Chunk currently loaded into `dense_pos`.
    pub(crate) loaded_chunk: Option<u32>,
    /// Dequantized f32 values of the quantized chunk currently being
    /// evaluated (approximate `F16`/`Int8` layouts). Grown to the largest
    /// quantized chunk once, then recycled — chunk-order evaluation
    /// dequantizes each chunk once per batch pass.
    pub(crate) dequant: Vec<f32>,
    /// `O(d)` query scatter (baseline dense lookup, Parabel/Bonsai style).
    pub(crate) dense_x: Option<Vec<f32>>,
    /// Dense output for one vector×chunk product (max sibling width).
    pub(crate) out_block: Vec<f32>,
    /// `(chunk, local query, parent score)` blocks of Alg. 3.
    pub(crate) blocks: Vec<(u32, u32, f32)>,
    /// Counting-sort scatter target (swapped with `blocks`).
    pub(crate) blocks_tmp: Vec<(u32, u32, f32)>,
    /// Counting-sort bucket counts/cursors, sized `O(blocks)`.
    pub(crate) chunk_counts: Vec<u32>,
    /// Beam arena: `(node, score)` entries, node ids ascending per query.
    pub(crate) beam_entries: Vec<(u32, f32)>,
    /// Beam arena offsets; query `q` owns `beam_offsets[q]..[q + 1]`.
    pub(crate) beam_offsets: Vec<usize>,
    /// Candidate arena: `(node, path score)` entries.
    pub(crate) cand_entries: Vec<(u32, f32)>,
    /// Candidate arena offsets (prefix sums of the per-query extents).
    pub(crate) cand_offsets: Vec<usize>,
    /// Per-query write cursor into `cand_entries` during expansion.
    pub(crate) cand_cursor: Vec<usize>,
    /// Batch size the arenas are currently laid out for.
    pub(crate) batch_n: usize,
    /// Resident single-row query matrix for online serving.
    query_row: CsrMatrix,
    /// Resident prediction output buffer for online serving.
    out_preds: Vec<Prediction>,
}

impl Workspace {
    /// Allocates scratch for `model` under a fixed-method `config` (the
    /// degenerate uniform plan). `Auto` configurations have no method
    /// set until a plan is resolved — use
    /// [`InferenceEngine::workspace`], which allocates per plan.
    pub fn new(model: &XmrModel, config: EngineConfig) -> Self {
        assert!(
            config.iter != IterationMethod::Auto,
            "Auto needs a resolved plan: build the workspace via InferenceEngine::workspace()"
        );
        let dense = config.iter == IterationMethod::DenseLookup;
        Self::with_needs(
            model,
            config.algo == MatmulAlgo::Mscm && dense,
            config.algo == MatmulAlgo::Baseline && dense,
        )
    }

    /// Allocates scratch for whatever `plan` needs under `config` — the
    /// `O(d)` dense structures exist only when some chunk actually plans
    /// dense lookup (this is what Table 6's "extra memory overhead"
    /// column measures). A chunk stored as
    /// [`ChunkStorage::DenseRows`] is its own position array, so it
    /// needs no scratch at all.
    pub(crate) fn for_plan(model: &XmrModel, config: EngineConfig, plan: &KernelPlan) -> Self {
        Self::with_needs(
            model,
            config.algo == MatmulAlgo::Mscm && plan.needs_dense_scratch(),
            config.algo == MatmulAlgo::Baseline && plan.uses(IterationMethod::DenseLookup),
        )
    }

    fn with_needs(model: &XmrModel, dense_pos: bool, dense_x: bool) -> Self {
        let max_b = model.stats().max_branching;
        Self {
            dense_pos: dense_pos.then(|| DenseScratch::new(model.dim)),
            loaded_chunk: None,
            dequant: Vec::new(),
            dense_x: dense_x.then(|| vec![0.0f32; model.dim]),
            out_block: vec![0.0; max_b],
            blocks: Vec::new(),
            blocks_tmp: Vec::new(),
            chunk_counts: Vec::new(),
            beam_entries: Vec::new(),
            beam_offsets: Vec::new(),
            cand_entries: Vec::new(),
            cand_offsets: Vec::new(),
            cand_cursor: Vec::new(),
            batch_n: 0,
            query_row: CsrMatrix::default(),
            out_preds: Vec::new(),
        }
    }

    /// Resident bytes of the scratch: every side structure (dense
    /// scratch, query scatter) plus the arenas, counted by capacity and
    /// true element width so the planner's memory claims are measurable
    /// in one number.
    pub fn memory_bytes(&self) -> usize {
        fn bytes<T>(cap: usize) -> usize {
            cap * std::mem::size_of::<T>()
        }
        self.dense_pos.as_ref().map_or(0, |d| d.memory_bytes())
            + self.dense_x.as_ref().map_or(0, |d| bytes::<f32>(d.capacity()))
            + bytes::<f32>(self.dequant.capacity())
            + bytes::<f32>(self.out_block.capacity())
            + bytes::<(u32, u32, f32)>(self.blocks.capacity())
            + bytes::<(u32, u32, f32)>(self.blocks_tmp.capacity())
            + bytes::<u32>(self.chunk_counts.capacity())
            + bytes::<(u32, f32)>(self.beam_entries.capacity())
            + bytes::<usize>(self.beam_offsets.capacity())
            + bytes::<(u32, f32)>(self.cand_entries.capacity())
            + bytes::<usize>(self.cand_offsets.capacity())
            + bytes::<usize>(self.cand_cursor.capacity())
            + bytes::<usize>(self.query_row.indptr.capacity())
            + bytes::<u32>(self.query_row.indices.capacity())
            + bytes::<f32>(self.query_row.values.capacity())
            + bytes::<Prediction>(self.out_preds.capacity())
    }

    /// Starts a fresh beam layout for `n` queries; follow with exactly
    /// `n` [`Workspace::push_beam`] calls (the sharded layer-step
    /// protocol installs each shard-local beam slice this way).
    pub(crate) fn begin_beams(&mut self, n: usize) {
        self.batch_n = n;
        self.beam_entries.clear();
        self.beam_offsets.clear();
        self.beam_offsets.push(0);
    }

    /// Appends the next query's beam (node ids ascending).
    pub(crate) fn push_beam(&mut self, beam: &[(u32, f32)]) {
        self.beam_entries.extend_from_slice(beam);
        self.beam_offsets.push(self.beam_entries.len());
    }

    /// Query `q`'s candidates from the last layer expansion.
    pub(crate) fn cand(&self, q: usize) -> &[(u32, f32)] {
        &self.cand_entries[self.cand_offsets[q]..self.cand_offsets[q + 1]]
    }

    /// Every query starts at the implicit root with score 1 (Alg. 1
    /// line 3); the root's children are chunk 0 of layer 0.
    fn reset_for_batch(&mut self, n: usize) {
        self.begin_beams(n);
        for _ in 0..n {
            self.push_beam(&[(0u32, 1.0f32)]);
        }
    }

    /// Lays the candidate arena out for one layer expansion: each beamed
    /// parent contributes exactly its sibling-chunk width, so the
    /// per-query extents are prefix-summed up front and expansion writes
    /// through `cand_cursor` with no further bookkeeping.
    pub(crate) fn begin_layer(&mut self, chunked: &ChunkedMatrix, n: usize) {
        debug_assert_eq!(n, self.batch_n, "beams not installed for this batch");
        self.cand_offsets.clear();
        self.cand_offsets.push(0);
        self.cand_cursor.clear();
        let mut total = 0usize;
        for q in 0..n {
            self.cand_cursor.push(total);
            for &(p, _) in &self.beam_entries[self.beam_offsets[q]..self.beam_offsets[q + 1]] {
                total += chunked.chunk_width(p as usize);
            }
            self.cand_offsets.push(total);
        }
        if self.cand_entries.len() < total {
            self.cand_entries.resize(total, (0, 0.0));
        }
    }

    /// Beam step over the whole batch (Alg. 1 line 9): selects the top
    /// `b` candidates per query out of the candidate arena into a rebuilt
    /// beam arena. Both arenas only recycle capacity.
    pub(crate) fn select_beams(&mut self, b: usize) {
        let n = self.batch_n;
        self.beam_entries.clear();
        self.beam_offsets.clear();
        self.beam_offsets.push(0);
        for q in 0..n {
            let (lo, hi) = (self.cand_offsets[q], self.cand_offsets[q + 1]);
            select_top_into(&mut self.cand_entries[lo..hi], b, &mut self.beam_entries);
            self.beam_offsets.push(self.beam_entries.len());
        }
    }
}

/// The inference engine: a model, an eq.-6 evaluation strategy and the
/// resolved per-chunk [`KernelPlan`] that drives it.
///
/// Fixed iteration methods resolve to degenerate uniform plans, so the
/// layer hot loop has exactly one dispatch path regardless of whether the
/// configuration was fixed or [`IterationMethod::Auto`].
///
/// Engines are cheap to share (`Arc<XmrModel>` inside) and `Sync`; batch
/// inference can be run on many threads via
/// [`InferenceEngine::predict_batch_parallel`].
pub struct InferenceEngine {
    model: Arc<XmrModel>,
    config: EngineConfig,
    /// One concrete method per chunk per layer (shared with sharded
    /// serving so shard files can carry pre-resolved plans).
    plan: Arc<KernelPlan>,
    /// Per-layer, per-column row→position maps (baseline hash method —
    /// NapkinXC's per-column scheme whose memory MSCM amortizes). Only
    /// columns of hash-planned chunks carry live maps; the rest hold
    /// 8-byte [`U32Map::empty`] placeholders.
    pub(crate) col_hash: Option<Vec<Vec<U32Map>>>,
    /// Per-layer timing / plan-drift telemetry, enabled by
    /// [`InferenceEngine::with_metrics`]. `None` (the default) keeps the
    /// hot path untouched: one branch per layer slice, no timers.
    metrics: Option<Arc<EngineMetrics>>,
    /// SIMD capability detected once at construction. The *effective*
    /// tier of a block is the plan's tier gated by this level: on scalar
    /// hardware (or under `MSCM_FORCE_SCALAR=1`) SIMD-planned blocks run
    /// the scalar kernels, bit for bit identically.
    simd: SimdLevel,
}

impl InferenceEngine {
    /// Builds an engine, constructing whatever side indices the
    /// configuration needs (chunk row maps for hash-planned MSCM chunks,
    /// per-column maps for hash-planned baseline chunks). `Auto` resolves
    /// its plan with the default [`PlannerConfig`].
    pub fn new(model: XmrModel, config: EngineConfig) -> Self {
        Self::new_with_planner(model, config, &PlannerConfig::default())
    }

    /// [`InferenceEngine::new`] with explicit planner inputs (workload
    /// hints, calibration budget) — only consulted when `config.iter` is
    /// `Auto`.
    pub fn new_with_planner(model: XmrModel, config: EngineConfig, pc: &PlannerConfig) -> Self {
        let plan = KernelPlan::resolve(&model, config, pc);
        Self::new_with_plan(model, config, plan)
    }

    /// Builds an engine around an owned model and a pre-resolved plan
    /// (e.g. one loaded from a shard file): the plan's **storage
    /// layouts** are applied to the chunked weights (models are built
    /// all-`Csc`; this is the one place layouts materialize), and side
    /// indexes exist exactly where the plan needs them — row maps are
    /// built on hash-planned `Csc` chunks, and under `Auto` any resident
    /// map on a chunk planned away from hash is dropped (the memory the
    /// planner saves).
    pub fn new_with_plan(mut model: XmrModel, config: EngineConfig, mut plan: KernelPlan) -> Self {
        assert!(plan.matches(&model), "kernel plan does not fit this model");
        for (li, layer) in model.layers.iter_mut().enumerate() {
            let frozen = layer.chunked.merged.is_some()
                || layer
                    .chunked
                    .chunks
                    .iter()
                    .any(|c| c.storage != ChunkStorage::Csc);
            if frozen {
                // Layout-resolved models (`MSCMXMR4` loads, possibly
                // mmap-backed — immutable weight arrays) cannot be
                // re-laid: the plan adopts the resident layout instead.
                plan.layers[li].storage =
                    layer.chunked.chunks.iter().map(|c| c.storage).collect();
            } else {
                layer.chunked.apply_layout(plan.layer_storage(li));
            }
        }
        if config.algo == MatmulAlgo::Baseline {
            // Layout-resolved loads carry an empty CSC stub (the chunked
            // side holds the weights); the baseline's per-column walks
            // need real columns, so hydrate them on the heap here.
            for layer in &mut model.layers {
                if layer.csc_is_stub() {
                    layer.csc = layer.chunked.to_csc();
                }
            }
        }
        if config.algo == MatmulAlgo::Mscm {
            // Fixed configs keep whatever maps the model came with (their
            // plan never consults them); Auto owns the memory story. The
            // non-Csc layouts already dropped theirs in apply_layout;
            // quantized chunks keep the Csc structure and stay hashable.
            let prune = config.iter == IterationMethod::Auto;
            for (li, layer) in model.layers.iter_mut().enumerate() {
                let methods = plan.layer_methods(li);
                for (chunk, &m) in layer.chunked.chunks.iter_mut().zip(methods) {
                    if m == IterationMethod::Hash
                        && matches!(
                            chunk.storage,
                            ChunkStorage::Csc | ChunkStorage::F16 | ChunkStorage::Int8
                        )
                    {
                        if chunk.row_map.is_none() {
                            chunk.build_row_map();
                        }
                    } else if prune {
                        chunk.row_map = None;
                    }
                }
            }
        }
        Self::from_parts(Arc::new(model), config, Arc::new(plan))
    }

    /// Builds an engine around a shared model. The model must already
    /// carry chunk row maps on every chunk the resolved plan sends to the
    /// hash kernel (for fixed MSCM+Hash: on every chunk). A shared model
    /// cannot be re-laid out, so `Auto` resolves kernels only and keeps
    /// the model's seed `Csc` layout ([`PlannerConfig::storage`] off).
    pub fn from_arc(model: Arc<XmrModel>, config: EngineConfig) -> Self {
        let pc = PlannerConfig {
            storage: false,
            ..PlannerConfig::default()
        };
        let plan = KernelPlan::resolve(&model, config, &pc);
        Self::from_parts(model, config, Arc::new(plan))
    }

    /// [`InferenceEngine::from_arc`] with a pre-resolved plan.
    pub fn from_arc_with_plan(
        model: Arc<XmrModel>,
        config: EngineConfig,
        plan: Arc<KernelPlan>,
    ) -> Self {
        Self::from_parts(model, config, plan)
    }

    fn from_parts(model: Arc<XmrModel>, config: EngineConfig, plan: Arc<KernelPlan>) -> Self {
        assert!(plan.matches(&model), "kernel plan does not fit this model");
        let laid_out = model.layers.iter().enumerate().all(|(li, l)| {
            l.chunked
                .chunks
                .iter()
                .zip(plan.layer_storage(li))
                .all(|(c, &s)| c.storage == s)
        });
        assert!(
            laid_out,
            "model chunk storage does not match the plan's layouts \
             (apply them by constructing via InferenceEngine::new_with_plan)"
        );
        if config.algo == MatmulAlgo::Baseline {
            assert!(
                model.layers.iter().all(|l| !l.csc_is_stub()),
                "baseline over a layout-resolved (mmap) model needs hydrated CSC \
                 columns — construct via InferenceEngine::new_with_plan"
            );
        }
        if config.algo == MatmulAlgo::Mscm {
            let ok = model.layers.iter().enumerate().all(|(li, l)| {
                l.chunked
                    .chunks
                    .iter()
                    .zip(plan.layer_methods(li))
                    .all(|(c, &m)| {
                        m != IterationMethod::Hash
                            || !matches!(
                                c.storage,
                                ChunkStorage::Csc | ChunkStorage::F16 | ChunkStorage::Int8
                            )
                            || c.row_map.is_some()
                    })
            });
            assert!(
                ok,
                "hash-planned chunks lack row maps (XmrModel::build_row_maps, \
                 or construct via InferenceEngine::new to build them plan-driven)"
            );
        }
        let col_hash = (config.algo == MatmulAlgo::Baseline
            && plan.uses(IterationMethod::Hash))
        .then(|| {
            model
                .layers
                .iter()
                .enumerate()
                .map(|(li, l)| build_col_hash_planned(&l.csc, &l.chunked, plan.layer_methods(li)))
                .collect()
        });
        Self {
            model,
            config,
            plan,
            col_hash,
            metrics: None,
            simd: SimdLevel::detect(),
        }
    }

    /// Enables per-layer engine telemetry ([`EngineMetrics`]): every
    /// layer slice records its wall time and per-chunk-class block
    /// counts, joined at enable time against the default
    /// [`CostModel`]'s predictions (the drift report ROADMAP item 5
    /// recalibrates from). Costs one `Instant` pair plus a bounded set
    /// of relaxed atomic adds per layer slice and **zero** steady-state
    /// allocations (`rust/tests/alloc.rs`).
    pub fn with_metrics(self) -> Self {
        self.with_metrics_costed(&CostModel::default(), &PlannerConfig::default())
    }

    /// [`InferenceEngine::with_metrics`] with an explicit cost model and
    /// planner inputs, so a calibrated model's predictions can be the
    /// drift baseline instead of the defaults.
    pub fn with_metrics_costed(mut self, cost: &CostModel, pc: &PlannerConfig) -> Self {
        self.metrics = Some(Arc::new(EngineMetrics::for_plan(
            &self.model,
            self.config.algo,
            &self.plan,
            self.simd,
            cost,
            pc,
        )));
        self
    }

    /// The engine's telemetry, if [`InferenceEngine::with_metrics`]
    /// enabled it.
    pub fn metrics(&self) -> Option<&Arc<EngineMetrics>> {
        self.metrics.as_ref()
    }

    /// The underlying model.
    pub fn model(&self) -> &Arc<XmrModel> {
        &self.model
    }

    /// This engine's configuration.
    pub fn config(&self) -> EngineConfig {
        self.config
    }

    /// The resolved kernel plan (uniform for fixed methods).
    pub fn plan(&self) -> &Arc<KernelPlan> {
        &self.plan
    }

    /// The SIMD capability this engine detected at construction.
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// Bytes of side-index overhead beyond the raw weights — everything
    /// this engine's *plan requires*, in one number (the measurable
    /// memory-savings claim):
    ///
    /// - chunk row maps on hash-planned MSCM chunks,
    /// - the baseline's per-column maps, container overhead included,
    /// - the `O(d)` dense structures each [`Workspace`] will allocate
    ///   when some chunk plans dense lookup.
    ///
    /// Row maps resident on the shared model but *unused* by this
    /// engine's plan are not counted here — they belong to the model's
    /// own accounting (`ModelStats::chunked_bytes`); fixed configs keep
    /// them untouched, and `Auto` over an owned model prunes them. To
    /// compare configurations fairly, build each engine from a model
    /// without prebuilt maps (see `benches/planner.rs`) or against the
    /// analytical baseline [`super::plan::fixed_hash_side_bytes`].
    pub fn side_index_bytes(&self) -> usize {
        let mut bytes = 0usize;
        if self.config.algo == MatmulAlgo::Mscm {
            for (li, l) in self.model.layers.iter().enumerate() {
                for (c, &m) in l.chunked.chunks.iter().zip(self.plan.layer_methods(li)) {
                    if m == IterationMethod::Hash {
                        bytes += c.row_map.as_ref().map_or(0, |m| m.memory_bytes());
                    }
                }
            }
        }
        if let Some(layers) = &self.col_hash {
            for maps in layers {
                bytes += maps.capacity() * std::mem::size_of::<U32Map>();
                bytes += maps.iter().map(|m| m.memory_bytes()).sum::<usize>();
            }
        }
        // dense_pos (MSCM) or dense_x (baseline): 4 bytes × dim. Chunks
        // stored DenseRows carry their own position array in row_ptr
        // (weight bytes, not side-index bytes) and need neither.
        let needs_dense = match self.config.algo {
            MatmulAlgo::Mscm => self.plan.needs_dense_scratch(),
            MatmulAlgo::Baseline => self.plan.uses(IterationMethod::DenseLookup),
        };
        if needs_dense {
            bytes += self.model.dim * 4;
        }
        bytes
    }

    /// Bytes of the chunked weight payload under this engine's applied
    /// storage layouts (side indexes excluded — see
    /// [`InferenceEngine::side_index_bytes`]). On a plan that re-lays
    /// dense chunks as [`ChunkStorage::DenseRows`] this is strictly
    /// below the all-`Csc` equivalent: the row-index arrays are gone.
    pub fn weight_bytes(&self) -> usize {
        self.model
            .layers
            .iter()
            .map(|l| l.chunked.weight_bytes())
            .sum()
    }

    /// A workspace sized for this engine's plan.
    pub fn workspace(&self) -> Workspace {
        Workspace::for_plan(&self.model, self.config, &self.plan)
    }

    /// Online inference (paper's batch-size-1 setting): top `topk` labels
    /// for one query under beam width `beam`.
    pub fn predict(&self, x: &SparseVec, beam: usize, topk: usize) -> Vec<Prediction> {
        let mut ws = self.workspace();
        self.predict_with(x, beam, topk, &mut ws).to_vec()
    }

    /// Online inference with a caller-provided workspace — the serving
    /// hot path. The query matrix and the returned ranking both live in
    /// workspace-resident buffers, so after the first (warmup) call this
    /// performs **zero allocations** (enforced by `rust/tests/alloc.rs`).
    /// The returned slice is valid until the workspace is next used.
    pub fn predict_with<'ws>(
        &self,
        x: &SparseVec,
        beam: usize,
        topk: usize,
        ws: &'ws mut Workspace,
    ) -> &'ws [Prediction] {
        let mut xm = std::mem::take(&mut ws.query_row);
        xm.reset(self.model.dim);
        xm.push_row(x.view());
        self.beam_search(&xm, 0, 1, beam, ws);
        ws.query_row = xm;
        // Rank the single bottom beam in place, emit into the resident
        // output buffer.
        let (lo, hi) = (ws.beam_offsets[0], ws.beam_offsets[1]);
        rank_into(&mut ws.beam_entries[lo..hi], topk, &mut ws.out_preds);
        &ws.out_preds
    }

    /// Batch inference: top `topk` labels per row of `x`.
    pub fn predict_batch(&self, x: &CsrMatrix, beam: usize, topk: usize) -> Vec<Vec<Prediction>> {
        let mut ws = self.workspace();
        let mut out = vec![Vec::new(); x.rows];
        self.predict_range(x, 0, x.rows, beam, topk, &mut ws, &mut out);
        out
    }

    /// Batch inference over rows `qlo..qhi` of `x`, writing into
    /// `out[0..qhi-qlo]`. This is the unit that
    /// [`InferenceEngine::predict_batch_parallel`] distributes. Reuses
    /// `out`'s inner buffers, so a pooled caller allocates nothing.
    pub fn predict_range(
        &self,
        x: &CsrMatrix,
        qlo: usize,
        qhi: usize,
        beam: usize,
        topk: usize,
        ws: &mut Workspace,
        out: &mut [Vec<Prediction>],
    ) {
        let n = qhi - qlo;
        assert!(out.len() >= n);
        self.beam_search(x, qlo, qhi, beam, ws);
        // Gather final predictions: top-k of the bottom beam.
        for q in 0..n {
            let (lo, hi) = (ws.beam_offsets[q], ws.beam_offsets[q + 1]);
            rank_into(&mut ws.beam_entries[lo..hi], topk, &mut out[q]);
        }
    }

    /// One Alg. 1 layer step without the pruning: expands the parents in
    /// the workspace beam arena (node ids of layer `li - 1`, ascending)
    /// through layer `li`, leaving every generated candidate
    /// `(node, path score)` in the candidate arena ([`Workspace::cand`]).
    /// Scores are bitwise identical to the fused loop in
    /// [`InferenceEngine::predict_range`] — this *is* that loop's body,
    /// split out so a coordinator can interleave global beam selection
    /// between layers (exact sharded search).
    pub(crate) fn expand_layer(
        &self,
        li: usize,
        x: &CsrMatrix,
        qlo: usize,
        n: usize,
        ws: &mut Workspace,
    ) {
        assert!(x.cols == self.model.dim, "query dim mismatch");
        let layer = &self.model.layers[li];
        let methods = self.plan.layer_methods(li);
        ws.begin_layer(&layer.chunked, n);
        // One Instant pair around the whole layer slice — kernels are
        // timed as a unit, attribution to chunk classes comes from the
        // beam arena (exact: one block per beamed parent).
        let timer = self.metrics.as_ref().map(|_| Instant::now());
        match self.config.algo {
            MatmulAlgo::Mscm => {
                mscm_layer(
                    layer,
                    x,
                    qlo,
                    n,
                    methods,
                    self.plan.layer_tiers(li),
                    self.config.chunk_order,
                    self.simd,
                    ws,
                );
            }
            MatmulAlgo::Baseline => {
                let col_hash = self.col_hash.as_ref().map(|c| &c[li]);
                baseline_layer(layer, x, qlo, n, methods, col_hash, ws);
            }
        }
        if let (Some(m), Some(t)) = (self.metrics.as_ref(), timer) {
            let parents = &ws.beam_entries[ws.beam_offsets[0]..ws.beam_offsets[n]];
            m.record_layer(li, t.elapsed().as_nanos() as u64, parents);
        }
        debug_assert!(
            (0..n).all(|q| ws.cand_cursor[q] == ws.cand_offsets[q + 1]),
            "layer expansion did not fill every candidate slot"
        );
    }

    /// Online inference with a full per-stage trace — the cold path
    /// behind `infer --trace` and `serve --trace-sample`. Steps the
    /// Alg. 1 loop layer by layer with an `Instant` pair per stage and
    /// records beam width, candidate counts, and the kernel/storage mix
    /// of every expanded chunk. Results are bitwise identical to
    /// [`InferenceEngine::predict`]; the hot paths carry none of these
    /// hooks (see [`crate::metrics::QueryTrace`] for the JSON schema).
    pub fn predict_traced(
        &self,
        x: &SparseVec,
        beam: usize,
        topk: usize,
    ) -> (Vec<Prediction>, QueryTrace) {
        assert!(beam >= 1, "beam width must be >= 1");
        let mut ws = self.workspace();
        let mut xm = CsrMatrix::default();
        xm.reset(self.model.dim);
        xm.push_row(x.view());
        let t_total = Instant::now();
        ws.reset_for_batch(1);
        let mut layers = Vec::with_capacity(self.model.layers.len());
        for li in 0..self.model.layers.len() {
            let mut lt = LayerTrace {
                layer: li,
                ..LayerTrace::default()
            };
            let parents = &ws.beam_entries[ws.beam_offsets[0]..ws.beam_offsets[1]];
            lt.beam_width = parents.len();
            let methods = self.plan.layer_methods(li);
            let storage = self.plan.layer_storage(li);
            let tiers = self.plan.layer_tiers(li);
            for &(p, _) in parents {
                lt.method_blocks[methods[p as usize].index()] += 1;
                lt.storage_blocks[storage[p as usize].index()] += 1;
                // Effective tier: the plan's tier gated by the hardware.
                let t = if self.simd.is_vector() {
                    tiers[p as usize]
                } else {
                    KernelTier::Scalar
                };
                lt.tier_blocks[t.index()] += 1;
            }
            let t = Instant::now();
            self.expand_layer(li, &xm, 0, 1, &mut ws);
            lt.expand_ns = t.elapsed().as_nanos() as u64;
            lt.candidates = ws.cand(0).len();
            let t = Instant::now();
            ws.select_beams(beam);
            lt.select_ns = t.elapsed().as_nanos() as u64;
            layers.push(lt);
        }
        let t_rank = Instant::now();
        let (lo, hi) = (ws.beam_offsets[0], ws.beam_offsets[1]);
        let mut out = Vec::new();
        rank_into(&mut ws.beam_entries[lo..hi], topk, &mut out);
        let rank_ns = t_rank.elapsed().as_nanos() as u64;
        let trace = QueryTrace {
            query_nnz: x.nnz(),
            beam,
            topk,
            total_ns: t_total.elapsed().as_nanos() as u64,
            rank_ns,
            layers,
        };
        (out, trace)
    }

    /// The Alg. 1 layer loop: leaves the per-query bottom beams in the
    /// workspace beam arena.
    fn beam_search(&self, x: &CsrMatrix, qlo: usize, qhi: usize, beam: usize, ws: &mut Workspace) {
        assert!(beam >= 1, "beam width must be >= 1");
        let n = qhi - qlo;
        ws.reset_for_batch(n);
        for li in 0..self.model.layers.len() {
            self.expand_layer(li, x, qlo, n, ws);
            // Beam step (Alg. 1 line 9): keep the top-b children per query.
            ws.select_beams(beam);
        }
    }
}

/// The ranking comparator — `(score desc, node id asc)` under `total_cmp`
/// (a strict total order, so selection is merge-order independent).
///
/// One definition serves every selection/ranking path (fused loop,
/// sharded gather stage) — any drift would break the bitwise
/// sharded == unsharded property.
#[inline]
pub(crate) fn cmp_score_desc(a: &(u32, f32), b: &(u32, f32)) -> std::cmp::Ordering {
    b.1.total_cmp(&a.1).then(a.0.cmp(&b.0))
}

/// Ranks one bottom-beam slice in place — `(score desc, label asc)` —
/// and emits the top `topk` into `out` (cleared first). THE final-
/// ranking step: shared by the online, batch, and sharded-gather paths
/// ([`crate::shard`]) so they cannot drift apart.
pub(crate) fn rank_into(beamed: &mut [(u32, f32)], topk: usize, out: &mut Vec<Prediction>) {
    beamed.sort_unstable_by(cmp_score_desc);
    let kept = beamed.len().min(topk);
    out.clear();
    out.extend(
        beamed[..kept]
            .iter()
            .map(|&(label, score)| Prediction { label, score }),
    );
}

/// Selects the `b` highest-scoring candidates (ties broken by ascending
/// node id for determinism) and appends them to `beam`, sorted by
/// ascending node id. `cands` is used as selection scratch.
pub(crate) fn select_top_into(cands: &mut [(u32, f32)], b: usize, beam: &mut Vec<(u32, f32)>) {
    let k = cands.len().min(b);
    if cands.len() > b {
        cands.select_nth_unstable_by(b - 1, cmp_score_desc);
    }
    let sel = &mut cands[..k];
    // Ascending node order keeps downstream chunk access monotonic and the
    // result deterministic regardless of selection internals.
    sel.sort_unstable_by_key(|e| e.0);
    beam.extend_from_slice(sel);
}

/// [`select_top_into`] with a `Vec` destination that is cleared first —
/// the form the sharded gather stage ([`crate::shard`]) prunes with.
pub(crate) fn select_top(cands: &mut Vec<(u32, f32)>, b: usize, beam: &mut Vec<(u32, f32)>) {
    beam.clear();
    select_top_into(cands.as_mut_slice(), b, beam);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::sigmoid;
    use crate::tree::XmrModel;

    /// Brute-force reference: score every label by walking its full path
    /// with exhaustive (un-beamed) search at beam = L (so beam search is
    /// exact), using plain dense dot products.
    fn exhaustive_scores(model: &XmrModel, x: &SparseVec) -> Vec<f32> {
        let mut parent_scores = vec![1.0f32];
        for layer in &model.layers {
            let mut scores = vec![0.0f32; layer.num_nodes()];
            for p in 0..layer.chunked.num_chunks() {
                for j in layer.children_of(p) {
                    let a = x.view().dot_marching(layer.csc.col(j));
                    scores[j] = parent_scores[p] * sigmoid(a);
                }
            }
            parent_scores = scores;
        }
        parent_scores
    }

    use crate::sparse::SparseVec;
    use crate::tree::Layer;

    fn model() -> XmrModel {
        crate::tree::XmrModel::new(
            8,
            vec![
                Layer::new(
                    crate::sparse::CscMatrix::from_cols(
                        vec![
                            SparseVec::from_pairs(vec![(0, 1.0), (2, -0.5)]),
                            SparseVec::from_pairs(vec![(1, 0.7), (3, 0.2)]),
                        ],
                        8,
                    ),
                    &[0, 2],
                    true,
                ),
                Layer::new(
                    crate::sparse::CscMatrix::from_cols(
                        vec![
                            SparseVec::from_pairs(vec![(0, 0.3)]),
                            SparseVec::from_pairs(vec![(2, -0.2), (4, 0.9)]),
                            SparseVec::from_pairs(vec![(1, 0.5), (5, 0.5)]),
                            SparseVec::from_pairs(vec![(6, -1.0)]),
                        ],
                        8,
                    ),
                    &[0, 2, 4],
                    true,
                ),
            ],
        )
    }

    #[test]
    fn full_beam_matches_exhaustive() {
        let m = model();
        let x = SparseVec::from_pairs(vec![(0, 1.0), (1, 0.5), (2, 2.0), (4, 1.0)]);
        let expect = exhaustive_scores(&m, &x);
        for cfg in EngineConfig::all() {
            let engine = InferenceEngine::new(m.clone(), cfg);
            // beam = 4 >= L1 so the search is exact
            let preds = engine.predict(&x, 4, 4);
            assert_eq!(preds.len(), 4, "{}", cfg.label());
            for p in &preds {
                assert_eq!(p.score, expect[p.label as usize], "{}", cfg.label());
            }
            // ranking is descending
            for w in preds.windows(2) {
                assert!(w[0].score >= w[1].score);
            }
        }
    }

    #[test]
    fn all_configs_bitwise_identical() {
        let m = model();
        let x = SparseVec::from_pairs(vec![(1, 0.4), (3, -1.0), (5, 2.0)]);
        let reference = InferenceEngine::new(
            m.clone(),
            EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::MarchingPointers),
        )
        .predict(&x, 1, 1);
        for cfg in EngineConfig::all() {
            let engine = InferenceEngine::new(m.clone(), cfg);
            assert_eq!(engine.predict(&x, 1, 1), reference, "{}", cfg.label());
        }
    }

    #[test]
    fn beam_respected() {
        let m = model();
        let x = SparseVec::from_pairs(vec![(0, 1.0)]);
        let engine = InferenceEngine::new(
            m,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch),
        );
        // beam 1 explores only the best top-layer node → 2 leaf candidates
        let preds = engine.predict(&x, 1, 10);
        assert_eq!(preds.len(), 1.min(10)); // beamed to 1 leaf
    }

    #[test]
    fn batch_equals_online() {
        let m = model();
        let rows = vec![
            SparseVec::from_pairs(vec![(0, 1.0), (4, -2.0)]),
            SparseVec::from_pairs(vec![(2, 0.3)]),
            SparseVec::new(),
        ];
        let xm = CsrMatrix::from_rows(rows.clone(), 8);
        for cfg in EngineConfig::all() {
            let engine = InferenceEngine::new(m.clone(), cfg);
            let batch = engine.predict_batch(&xm, 2, 2);
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(batch[i], engine.predict(r, 2, 2), "{}", cfg.label());
            }
        }
    }

    #[test]
    fn workspace_reuse_is_stable() {
        // The same workspace must serve alternating online queries and
        // batches without cross-talk between the recycled arenas.
        let m = model();
        let x0 = SparseVec::from_pairs(vec![(0, 1.0), (4, -2.0)]);
        let x1 = SparseVec::from_pairs(vec![(2, 0.3), (6, 1.5)]);
        let xm = CsrMatrix::from_rows(vec![x0.clone(), x1.clone()], 8);
        for cfg in EngineConfig::all() {
            let engine = InferenceEngine::new(m.clone(), cfg);
            let fresh0 = engine.predict(&x0, 3, 3);
            let fresh1 = engine.predict(&x1, 3, 3);
            let mut ws = engine.workspace();
            let mut out = vec![Vec::new(); 2];
            for _ in 0..3 {
                assert_eq!(engine.predict_with(&x0, 3, 3, &mut ws), &fresh0[..]);
                engine.predict_range(&xm, 0, 2, 3, 3, &mut ws, &mut out);
                assert_eq!(out[0], fresh0, "{}", cfg.label());
                assert_eq!(out[1], fresh1, "{}", cfg.label());
                assert_eq!(engine.predict_with(&x1, 3, 3, &mut ws), &fresh1[..]);
            }
        }
    }

    #[test]
    fn empty_query_gets_prior_scores() {
        // An all-zero query still ranks: every activation is σ(0) = 0.5.
        let m = model();
        let engine = InferenceEngine::new(
            m,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        );
        let preds = engine.predict(&SparseVec::new(), 2, 2);
        assert_eq!(preds.len(), 2);
        assert_eq!(preds[0].score, 0.25);
    }

    #[test]
    fn auto_matches_fixed_methods_bitwise() {
        let m = model();
        let queries = [
            SparseVec::from_pairs(vec![(0, 1.0), (1, 0.5), (2, 2.0), (4, 1.0)]),
            SparseVec::from_pairs(vec![(1, 0.4), (3, -1.0), (5, 2.0)]),
            SparseVec::new(),
        ];
        for algo in MatmulAlgo::ALL {
            let auto = InferenceEngine::new(m.clone(), EngineConfig::new(algo, IterationMethod::Auto));
            assert!(auto.plan().matches(&m));
            for iter in IterationMethod::ALL {
                let fixed = InferenceEngine::new(m.clone(), EngineConfig::new(algo, iter));
                for (qi, q) in queries.iter().enumerate() {
                    assert_eq!(
                        auto.predict(q, 3, 3),
                        fixed.predict(q, 3, 3),
                        "{algo:?}/{iter:?} q={qi}"
                    );
                }
            }
        }
    }

    #[test]
    fn side_indexes_follow_the_plan() {
        // A hand-written mixed plan: only layer 1's second chunk is hash
        // — the engine must build exactly that row map, and the dense
        // scratch must not exist when no chunk plans dense.
        use crate::inference::plan::{KernelPlan, LayerPlan};
        let mut m = model();
        m.drop_row_maps();
        let plan = KernelPlan {
            layers: vec![
                LayerPlan {
                    methods: vec![IterationMethod::MarchingPointers],
                    storage: vec![ChunkStorage::Csc],
                    tiers: vec![KernelTier::Scalar],
                },
                LayerPlan {
                    methods: vec![IterationMethod::BinarySearch, IterationMethod::Hash],
                    storage: vec![ChunkStorage::Csc, ChunkStorage::Csc],
                    tiers: vec![KernelTier::Simd, KernelTier::Scalar],
                },
            ],
        };
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
        let engine = InferenceEngine::new_with_plan(m.clone(), cfg, plan);
        let layers = &engine.model().layers;
        assert!(layers[0].chunked.chunks[0].row_map.is_none());
        assert!(layers[1].chunked.chunks[0].row_map.is_none());
        assert!(layers[1].chunked.chunks[1].row_map.is_some());
        let ws = engine.workspace();
        assert!(ws.dense_pos.is_none() && ws.dense_x.is_none());
        // side bytes = exactly the one built row map
        let map_bytes = layers[1].chunked.chunks[1]
            .row_map
            .as_ref()
            .unwrap()
            .memory_bytes();
        assert_eq!(engine.side_index_bytes(), map_bytes);
        // still bitwise identical to a fixed engine
        let fixed = InferenceEngine::new(
            m,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers),
        );
        let q = SparseVec::from_pairs(vec![(0, 1.0), (5, -0.5)]);
        assert_eq!(engine.predict(&q, 4, 4), fixed.predict(&q, 4, 4));
    }

    #[test]
    fn auto_prunes_unneeded_row_maps() {
        // The seed model carries maps everywhere (with_row_maps = true);
        // an Auto engine must keep only what its plan hashes, so its side
        // bytes are at most (and usually strictly below) fixed hash's.
        let m = model();
        let hash_engine = InferenceEngine::new(
            m.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        );
        let auto_engine =
            InferenceEngine::new(m, EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto));
        assert!(auto_engine.side_index_bytes() <= hash_engine.side_index_bytes());
    }

    #[test]
    #[should_panic(expected = "Auto needs a resolved plan")]
    fn workspace_new_rejects_auto() {
        let m = model();
        Workspace::new(&m, EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto));
    }

    #[test]
    fn forced_layouts_stay_bitwise_identical() {
        // Every uniform storage layout, driven through new_with_plan,
        // must reproduce the seed all-Csc engine bit for bit — for both
        // algos and a mix of methods (the broad grid lives in
        // rust/tests/layout.rs; this is the in-crate smoke version).
        use crate::inference::plan::KernelPlan;
        let m = model();
        let queries = [
            SparseVec::from_pairs(vec![(0, 1.0), (1, 0.5), (2, 2.0), (4, 1.0)]),
            SparseVec::from_pairs(vec![(1, 0.4), (3, -1.0), (5, 2.0)]),
            SparseVec::new(),
        ];
        let reference = InferenceEngine::new(
            m.clone(),
            EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::MarchingPointers),
        );
        for algo in MatmulAlgo::ALL {
            for iter in IterationMethod::ALL {
                for storage in ChunkStorage::ALL {
                    let plan =
                        KernelPlan::uniform(&m, iter).with_uniform_storage(storage);
                    let engine = InferenceEngine::new_with_plan(
                        m.clone(),
                        EngineConfig::new(algo, iter),
                        plan,
                    );
                    for (qi, q) in queries.iter().enumerate() {
                        assert_eq!(
                            engine.predict(q, 3, 3),
                            reference.predict(q, 3, 3),
                            "{algo:?}/{iter:?}/{storage:?} q={qi}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dense_rows_layout_needs_no_scratch() {
        use crate::inference::plan::KernelPlan;
        let m = model();
        let csc_engine = InferenceEngine::new_with_plan(
            m.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup),
            KernelPlan::uniform(&m, IterationMethod::DenseLookup),
        );
        let dr_engine = InferenceEngine::new_with_plan(
            m.clone(),
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::DenseLookup),
            KernelPlan::uniform(&m, IterationMethod::DenseLookup)
                .with_uniform_storage(ChunkStorage::DenseRows),
        );
        // Csc + DenseLookup pays the O(d) scratch; DenseRows does not.
        let ws = csc_engine.workspace();
        assert!(ws.dense_pos.is_some());
        let ws = dr_engine.workspace();
        assert!(ws.dense_pos.is_none());
        assert_eq!(csc_engine.side_index_bytes(), m.dim * 4);
        assert_eq!(dr_engine.side_index_bytes(), 0);
    }

    #[test]
    fn metrics_and_tracing_are_bitwise_invisible() {
        // Enabling telemetry or taking the traced path must not change a
        // single bit of any prediction — and a real run must populate
        // both sides of the drift join.
        let m = model();
        let queries = [
            SparseVec::from_pairs(vec![(0, 1.0), (1, 0.5), (2, 2.0), (4, 1.0)]),
            SparseVec::from_pairs(vec![(1, 0.4), (3, -1.0), (5, 2.0)]),
            SparseVec::new(),
        ];
        for cfg in EngineConfig::all() {
            let plain = InferenceEngine::new(m.clone(), cfg);
            let metered = InferenceEngine::new(m.clone(), cfg).with_metrics();
            for q in &queries {
                let expect = plain.predict(q, 3, 3);
                assert_eq!(metered.predict(q, 3, 3), expect, "{}", cfg.label());
                let (preds, trace) = metered.predict_traced(q, 3, 3);
                assert_eq!(preds, expect, "traced {}", cfg.label());
                assert_eq!(trace.layers.len(), m.layers.len());
                assert_eq!(trace.query_nnz, q.nnz());
                assert!(trace.layers.iter().all(|l| l.beam_width >= 1));
            }
            let metrics = metered.metrics().expect("metrics enabled");
            assert!(metrics.total_ns() > 0);
            let drift = metrics.plan_drift();
            assert!(!drift.layers.is_empty() && !drift.cells.is_empty());
            assert!(drift.total_measured_ns() > 0, "{}", cfg.label());
            assert!(drift.total_predicted_ns() > 0, "{}", cfg.label());
        }
    }

    #[test]
    fn chunk_order_off_is_bitwise_identical() {
        // The ablation path: disabling Alg. 3 chunk ordering changes the
        // evaluation order across queries but not any per-entry sum.
        let m = model();
        let rows = vec![
            SparseVec::from_pairs(vec![(0, 1.0), (4, -2.0)]),
            SparseVec::from_pairs(vec![(2, 0.3)]),
            SparseVec::from_pairs(vec![(1, 0.7), (6, 0.2)]),
        ];
        let xm = CsrMatrix::from_rows(rows, 8);
        for iter in IterationMethod::ALL {
            let ordered = InferenceEngine::new(m.clone(), EngineConfig::new(MatmulAlgo::Mscm, iter));
            let unordered = InferenceEngine::new(
                m.clone(),
                EngineConfig {
                    chunk_order: false,
                    ..EngineConfig::new(MatmulAlgo::Mscm, iter)
                },
            );
            assert_eq!(
                ordered.predict_batch(&xm, 2, 2),
                unordered.predict_batch(&xm, 2, 2),
                "{iter:?}"
            );
        }
    }
}
