//! The beam-search inference engine (paper Algorithm 1).

use std::sync::Arc;

use super::baseline::{baseline_layer, build_col_hash};
use super::mscm::mscm_layer;
use super::{IterationMethod, MatmulAlgo};
use crate::sparse::iterators::DenseScratch;
use crate::sparse::{CsrMatrix, SparseVec, U32Map};
use crate::tree::XmrModel;

/// One retrieved label.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    /// Label id (column of the bottom layer).
    pub label: u32,
    /// Path score `Π σ(w·x)` (eq. 5).
    pub score: f32,
}

/// Engine configuration: which masked-matmul algorithm and which support
/// iteration method evaluate eq. 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct EngineConfig {
    /// Baseline (per column) or MSCM (per chunk).
    pub algo: MatmulAlgo,
    /// Support-intersection iteration method.
    pub iter: IterationMethod,
}

impl EngineConfig {
    /// All eight `(algo, iter)` combinations, baseline first.
    pub fn all() -> Vec<EngineConfig> {
        let mut v = Vec::new();
        for algo in MatmulAlgo::ALL {
            for iter in IterationMethod::ALL {
                v.push(EngineConfig { algo, iter });
            }
        }
        v
    }

    /// Table-row label, e.g. `"Binary Search MSCM"`.
    pub fn label(&self) -> String {
        format!("{}{}", self.iter.label(), self.algo.label())
    }
}

/// Per-thread scratch. Buffers are sized for the model once and recycled
/// across queries/batches so the hot path never allocates.
pub struct Workspace {
    /// `O(d)` chunk-row position scratch (MSCM dense lookup).
    pub(crate) dense_pos: Option<DenseScratch>,
    /// Chunk currently loaded into `dense_pos`.
    pub(crate) loaded_chunk: Option<u32>,
    /// `O(d)` query scatter (baseline dense lookup, Parabel/Bonsai style).
    pub(crate) dense_x: Option<Vec<f32>>,
    /// Dense output for one vector×chunk product (max sibling width).
    pub(crate) out_block: Vec<f32>,
    /// `(chunk, local query, parent score)` blocks of Alg. 3.
    pub(crate) blocks: Vec<(u32, u32, f32)>,
    /// Per-query candidate `(node, score)` buffers.
    pub(crate) cands: Vec<Vec<(u32, f32)>>,
    /// Per-query beams `(node, score)`, node ids ascending.
    pub(crate) beams: Vec<Vec<(u32, f32)>>,
}

impl Workspace {
    /// Allocates scratch for `model` under `config`. Only the structures
    /// the configuration needs are allocated (this is what Table 6's
    /// "extra memory overhead" column measures).
    pub fn new(model: &XmrModel, config: EngineConfig) -> Self {
        let max_b = model.stats().max_branching;
        let dense_pos = (config.algo == MatmulAlgo::Mscm
            && config.iter == IterationMethod::DenseLookup)
            .then(|| DenseScratch::new(model.dim));
        let dense_x = (config.algo == MatmulAlgo::Baseline
            && config.iter == IterationMethod::DenseLookup)
            .then(|| vec![0.0f32; model.dim]);
        Self {
            dense_pos,
            loaded_chunk: None,
            dense_x,
            out_block: vec![0.0; max_b],
            blocks: Vec::new(),
            cands: Vec::new(),
            beams: Vec::new(),
        }
    }

    /// Approximate resident bytes of the scratch.
    pub fn memory_bytes(&self) -> usize {
        self.dense_pos.as_ref().map_or(0, |d| d.memory_bytes())
            + self.dense_x.as_ref().map_or(0, |d| d.len() * 4)
            + self.out_block.len() * 4
    }

    /// Grows the per-query buffers to hold `n` queries without resetting
    /// their contents (the sharded layer-step protocol sets beams itself).
    pub(crate) fn ensure_batch(&mut self, n: usize) {
        if self.cands.len() < n {
            self.cands.resize_with(n, Vec::new);
            self.beams.resize_with(n, Vec::new);
        }
    }

    fn reset_for_batch(&mut self, n: usize) {
        self.ensure_batch(n);
        for q in 0..n {
            self.cands[q].clear();
            // Every query starts at the implicit root with score 1
            // (Alg. 1 line 3); the root's children are chunk 0 of layer 0.
            self.beams[q].clear();
            self.beams[q].push((0u32, 1.0f32));
        }
    }
}

/// The inference engine: a model plus an eq.-6 evaluation strategy.
///
/// Engines are cheap to share (`Arc<XmrModel>` inside) and `Sync`; batch
/// inference can be run on many threads via
/// [`InferenceEngine::predict_batch_parallel`].
pub struct InferenceEngine {
    model: Arc<XmrModel>,
    config: EngineConfig,
    /// Per-layer, per-column row→position maps (baseline hash method —
    /// NapkinXC's per-column scheme whose memory MSCM amortizes).
    pub(crate) col_hash: Option<Vec<Vec<U32Map>>>,
}

impl InferenceEngine {
    /// Builds an engine, constructing whatever side indices the
    /// configuration needs (chunk row maps for MSCM hash, per-column maps
    /// for baseline hash).
    pub fn new(mut model: XmrModel, config: EngineConfig) -> Self {
        if config.algo == MatmulAlgo::Mscm && config.iter == IterationMethod::Hash {
            let missing = model
                .layers
                .iter()
                .any(|l| l.chunked.chunks.iter().any(|c| c.row_map.is_none()));
            if missing {
                model.build_row_maps();
            }
        }
        Self::from_arc(Arc::new(model), config)
    }

    /// Builds an engine around a shared model. The model must already have
    /// chunk row maps when `config` is MSCM+Hash.
    pub fn from_arc(model: Arc<XmrModel>, config: EngineConfig) -> Self {
        if config.algo == MatmulAlgo::Mscm && config.iter == IterationMethod::Hash {
            assert!(
                model
                    .layers
                    .iter()
                    .all(|l| l.chunked.chunks.iter().all(|c| c.row_map.is_some())),
                "MSCM hash engine requires chunk row maps (XmrModel::build_row_maps)"
            );
        }
        let col_hash = (config.algo == MatmulAlgo::Baseline
            && config.iter == IterationMethod::Hash)
            .then(|| model.layers.iter().map(|l| build_col_hash(&l.csc)).collect());
        Self {
            model,
            config,
            col_hash,
        }
    }

    /// The underlying model.
    pub fn model(&self) -> &Arc<XmrModel> {
        &self.model
    }

    /// This engine's configuration.
    pub fn config(&self) -> EngineConfig {
        self.config
    }

    /// Bytes of side-index overhead beyond the model itself (Table 6's
    /// "extra memory" column: per-column hash maps for baseline hash).
    pub fn side_index_bytes(&self) -> usize {
        self.col_hash.as_ref().map_or(0, |layers| {
            layers
                .iter()
                .flat_map(|maps| maps.iter().map(|m| m.memory_bytes()))
                .sum()
        })
    }

    /// A workspace sized for this engine.
    pub fn workspace(&self) -> Workspace {
        Workspace::new(&self.model, self.config)
    }

    /// Online inference (paper's batch-size-1 setting): top `topk` labels
    /// for one query under beam width `beam`.
    pub fn predict(&self, x: &SparseVec, beam: usize, topk: usize) -> Vec<Prediction> {
        let mut ws = self.workspace();
        self.predict_with(x, beam, topk, &mut ws)
    }

    /// Online inference with a caller-provided workspace (alloc-free hot
    /// path for serving).
    pub fn predict_with(
        &self,
        x: &SparseVec,
        beam: usize,
        topk: usize,
        ws: &mut Workspace,
    ) -> Vec<Prediction> {
        let xm = CsrMatrix::from_single_row(x, self.model.dim);
        let mut out = vec![Vec::new()];
        self.predict_range(&xm, 0, 1, beam, topk, ws, &mut out);
        out.pop().unwrap()
    }

    /// Batch inference: top `topk` labels per row of `x`.
    pub fn predict_batch(&self, x: &CsrMatrix, beam: usize, topk: usize) -> Vec<Vec<Prediction>> {
        let mut ws = self.workspace();
        let mut out = vec![Vec::new(); x.rows];
        self.predict_range(x, 0, x.rows, beam, topk, &mut ws, &mut out);
        out
    }

    /// Batch inference over rows `qlo..qhi` of `x`, writing into
    /// `out[0..qhi-qlo]`. This is the unit that
    /// [`InferenceEngine::predict_batch_parallel`] distributes.
    pub fn predict_range(
        &self,
        x: &CsrMatrix,
        qlo: usize,
        qhi: usize,
        beam: usize,
        topk: usize,
        ws: &mut Workspace,
        out: &mut [Vec<Prediction>],
    ) {
        let n = qhi - qlo;
        assert!(out.len() >= n);
        self.beam_search(x, qlo, qhi, beam, ws);
        // Gather final predictions: top-k of the bottom beam.
        for q in 0..n {
            let beamed = &mut ws.beams[q];
            rank_beam(beamed, topk);
            out[q].clear();
            out[q].extend(
                beamed
                    .iter()
                    .map(|&(label, score)| Prediction { label, score }),
            );
        }
    }

    /// One Alg. 1 layer step without the pruning: expands the parents in
    /// `ws.beams[q]` (node ids of layer `li - 1`, ascending) through layer
    /// `li`, leaving every generated candidate `(node, path score)` in
    /// `ws.cands[q]`. Scores are bitwise identical to the fused loop in
    /// [`InferenceEngine::predict_range`] — this *is* that loop's body,
    /// split out so a coordinator can interleave global beam selection
    /// between layers (exact sharded search).
    pub(crate) fn expand_layer(
        &self,
        li: usize,
        x: &CsrMatrix,
        qlo: usize,
        n: usize,
        ws: &mut Workspace,
    ) {
        assert!(x.cols == self.model.dim, "query dim mismatch");
        let layer = &self.model.layers[li];
        for q in 0..n {
            ws.cands[q].clear();
        }
        match self.config.algo {
            MatmulAlgo::Mscm => {
                mscm_layer(layer, x, qlo, n, self.config.iter, ws);
            }
            MatmulAlgo::Baseline => {
                let col_hash = self.col_hash.as_ref().map(|c| &c[li]);
                baseline_layer(layer, x, qlo, n, self.config.iter, col_hash, ws);
            }
        }
    }

    /// The Alg. 1 layer loop: leaves the per-query bottom beams in
    /// `ws.beams`.
    fn beam_search(&self, x: &CsrMatrix, qlo: usize, qhi: usize, beam: usize, ws: &mut Workspace) {
        assert!(beam >= 1, "beam width must be >= 1");
        let n = qhi - qlo;
        ws.reset_for_batch(n);
        for li in 0..self.model.layers.len() {
            self.expand_layer(li, x, qlo, n, ws);
            // Beam step (Alg. 1 line 9): keep the top-b children per query.
            for q in 0..n {
                let (cands, beams) = (&mut ws.cands[q], &mut ws.beams[q]);
                select_top(cands, beam, beams);
            }
        }
    }
}

/// Sorts a bottom beam into final ranking order — `(score desc, label
/// asc)` — and truncates to `topk`.
///
/// Crate-visible so the sharded gather stage ([`crate::shard`]) ranks
/// with *exactly* this comparator — any drift would break the bitwise
/// sharded == unsharded property.
pub(crate) fn rank_beam(beamed: &mut Vec<(u32, f32)>, topk: usize) {
    beamed.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    beamed.truncate(topk);
}

/// Selects the `b` highest-scoring candidates (ties broken by ascending
/// node id for determinism) into `beam`, sorted by ascending node id.
///
/// Crate-visible so the sharded gather stage ([`crate::shard`]) prunes
/// with *exactly* this comparator — any drift would break the bitwise
/// sharded == unsharded property.
pub(crate) fn select_top(cands: &mut Vec<(u32, f32)>, b: usize, beam: &mut Vec<(u32, f32)>) {
    let cmp = |a: &(u32, f32), b: &(u32, f32)| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0));
    if cands.len() > b {
        cands.select_nth_unstable_by(b - 1, cmp);
        cands.truncate(b);
    }
    beam.clear();
    beam.extend_from_slice(cands);
    // Ascending node order keeps downstream chunk access monotonic and the
    // result deterministic regardless of selection internals.
    beam.sort_unstable_by_key(|e| e.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::sigmoid;
    use crate::tree::XmrModel;

    /// Brute-force reference: score every label by walking its full path
    /// with exhaustive (un-beamed) search at beam = L (so beam search is
    /// exact), using plain dense dot products.
    fn exhaustive_scores(model: &XmrModel, x: &SparseVec) -> Vec<f32> {
        let mut parent_scores = vec![1.0f32];
        for layer in &model.layers {
            let mut scores = vec![0.0f32; layer.num_nodes()];
            for p in 0..layer.chunked.num_chunks() {
                for j in layer.children_of(p) {
                    let a = x.view().dot_marching(layer.csc.col(j));
                    scores[j] = parent_scores[p] * sigmoid(a);
                }
            }
            parent_scores = scores;
        }
        parent_scores
    }

    use crate::sparse::SparseVec;
    use crate::tree::Layer;

    fn model() -> XmrModel {
        crate::tree::XmrModel::new(
            8,
            vec![
                Layer::new(
                    crate::sparse::CscMatrix::from_cols(
                        vec![
                            SparseVec::from_pairs(vec![(0, 1.0), (2, -0.5)]),
                            SparseVec::from_pairs(vec![(1, 0.7), (3, 0.2)]),
                        ],
                        8,
                    ),
                    &[0, 2],
                    true,
                ),
                Layer::new(
                    crate::sparse::CscMatrix::from_cols(
                        vec![
                            SparseVec::from_pairs(vec![(0, 0.3)]),
                            SparseVec::from_pairs(vec![(2, -0.2), (4, 0.9)]),
                            SparseVec::from_pairs(vec![(1, 0.5), (5, 0.5)]),
                            SparseVec::from_pairs(vec![(6, -1.0)]),
                        ],
                        8,
                    ),
                    &[0, 2, 4],
                    true,
                ),
            ],
        )
    }

    #[test]
    fn full_beam_matches_exhaustive() {
        let m = model();
        let x = SparseVec::from_pairs(vec![(0, 1.0), (1, 0.5), (2, 2.0), (4, 1.0)]);
        let expect = exhaustive_scores(&m, &x);
        for cfg in EngineConfig::all() {
            let engine = InferenceEngine::new(m.clone(), cfg);
            // beam = 4 >= L1 so the search is exact
            let preds = engine.predict(&x, 4, 4);
            assert_eq!(preds.len(), 4, "{}", cfg.label());
            for p in &preds {
                assert_eq!(p.score, expect[p.label as usize], "{}", cfg.label());
            }
            // ranking is descending
            for w in preds.windows(2) {
                assert!(w[0].score >= w[1].score);
            }
        }
    }

    #[test]
    fn all_configs_bitwise_identical() {
        let m = model();
        let x = SparseVec::from_pairs(vec![(1, 0.4), (3, -1.0), (5, 2.0)]);
        let reference = InferenceEngine::new(
            m.clone(),
            EngineConfig {
                algo: MatmulAlgo::Baseline,
                iter: IterationMethod::MarchingPointers,
            },
        )
        .predict(&x, 1, 1);
        for cfg in EngineConfig::all() {
            let engine = InferenceEngine::new(m.clone(), cfg);
            assert_eq!(engine.predict(&x, 1, 1), reference, "{}", cfg.label());
        }
    }

    #[test]
    fn beam_respected() {
        let m = model();
        let x = SparseVec::from_pairs(vec![(0, 1.0)]);
        let engine = InferenceEngine::new(
            m,
            EngineConfig {
                algo: MatmulAlgo::Mscm,
                iter: IterationMethod::BinarySearch,
            },
        );
        // beam 1 explores only the best top-layer node → 2 leaf candidates
        let preds = engine.predict(&x, 1, 10);
        assert_eq!(preds.len(), 1.min(10)); // beamed to 1 leaf
    }

    #[test]
    fn batch_equals_online() {
        let m = model();
        let rows = vec![
            SparseVec::from_pairs(vec![(0, 1.0), (4, -2.0)]),
            SparseVec::from_pairs(vec![(2, 0.3)]),
            SparseVec::new(),
        ];
        let xm = CsrMatrix::from_rows(rows.clone(), 8);
        for cfg in EngineConfig::all() {
            let engine = InferenceEngine::new(m.clone(), cfg);
            let batch = engine.predict_batch(&xm, 2, 2);
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(batch[i], engine.predict(r, 2, 2), "{}", cfg.label());
            }
        }
    }

    #[test]
    fn empty_query_gets_prior_scores() {
        // An all-zero query still ranks: every activation is σ(0) = 0.5.
        let m = model();
        let engine = InferenceEngine::new(
            m,
            EngineConfig {
                algo: MatmulAlgo::Mscm,
                iter: IterationMethod::Hash,
            },
        );
        let preds = engine.predict(&SparseVec::new(), 2, 2);
        assert_eq!(preds.len(), 2);
        assert_eq!(preds[0].score, 0.25);
    }
}
