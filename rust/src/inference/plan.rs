//! The per-chunk kernel planner: decides which support-intersection
//! iteration method evaluates each chunk's masked product — and which
//! physical **storage layout** ([`ChunkStorage`]) holds the chunk's
//! weights.
//!
//! The paper benchmarks its four iteration methods (§4 items 1–4) as
//! *global* choices and finds no uniform winner — the best method depends
//! on chunk width, chunk density and query support size, all of which
//! vary wildly across the layers of one tree (upper layers are few, wide
//! and dense; bottom layers are many, narrow and sparse). Because every
//! `(algo, iter)` pair in this repo is bitwise identical (pinned by
//! property tests), the method can be chosen **per chunk** with zero
//! accuracy risk: [`KernelPlan`] assigns one
//! [`IterationMethod`](super::IterationMethod) to every chunk of every
//! layer, and `IterationMethod::Auto` resolves to such a plan at engine
//! construction.
//!
//! # Cost model
//!
//! Per block (one query × one chunk product) the paper's complexity terms
//! are, with `q = nnz(x)`, `r = |S(K)|` (stored chunk rows) and `n` the
//! number of blocks sharing one chunk load:
//!
//! | method    | unit count (shape)            | side index        |
//! |-----------|-------------------------------|-------------------|
//! | marching  | `q + r`                       | none              |
//! | binary    | `min(q,r) · log2(max(q,r))`   | none              |
//! | hash      | `q`                           | chunk row map     |
//! | dense     | `1.5q + 2r / n`               | `O(d)` scratch    |
//!
//! (The dense probe is weighted 1.5× a marching step: it is a random read
//! into an `O(d)` array, where marching walks two arrays sequentially.
//! The `2r/n` term is the load + clear walk amortized over the `n` blocks
//! sharing the chunk under chunk-order evaluation.)
//!
//! [`CostModel`] multiplies each shape by a per-method nanosecond
//! constant. The defaults are analytical (a hash probe costs a few
//! dependent loads, a dense probe one, marching one compare per element);
//! [`CostModel::calibrate`] optionally *fits* the constants by timing
//! each kernel on a sample of the model's own chunks against synthetic
//! queries, so the plan adapts to the actual hardware. The emit cost
//! (writing the intersected entries) is identical across methods and is
//! therefore omitted from the comparison.
//!
//! # Storage layout terms
//!
//! The same statistics drive per-chunk **layout** selection
//! ([`CostModel::plan_layer_storage`]), with per-layout byte + time
//! terms, calibration-aware through the fitted constants:
//!
//! - [`ChunkStorage::DenseRows`] — picked when its row-pointer array is
//!   *strictly smaller* than the row-sparse index (`4(d+1) < 8r + 4`,
//!   i.e. the chunk's rows cover over half the feature dimension) and
//!   the direct probe (`1.5q` dense-probe units, no load/clear term) is
//!   no slower than the planned kernel. The chunk then needs no
//!   `row_indices`, no hash row map and no `O(d)` scratch.
//! - [`ChunkStorage::Merged`] — picked for **runs of ≥ 2 adjacent**
//!   marching/binary-planned chunks below the tiny-chunk thresholds
//!   ([`MERGE_MAX_NNZ`], [`MERGE_MAX_WIDTH`]): per-chunk `Vec` overhead
//!   dominates such chunks, and coalescing them puts sibling chunks that
//!   are beam-activated together contiguous in memory. A singleton
//!   candidate gains nothing and stays `Csc`.
//! - [`ChunkStorage::F16`] / [`ChunkStorage::Int8`] — **approximate**
//!   layouts, reachable only under [`PlannerConfig::approx`]: same
//!   `Csc`-shaped structure with the value payload quantized to half
//!   precision (2 B/entry) or per-chunk-scaled bytes (1 B/entry + one
//!   `f32` scale). Default planning never selects them, so exact modes
//!   stay bitwise exact; with the flag on, `Csc` chunks that are not
//!   dense-probed quantize by size (`Int8` from 64 stored entries, `F16`
//!   from 8) and the serving kernels dequantize into a per-workspace
//!   arena. `DenseLookup`-planned chunks never quantize: the `O(d)`
//!   scratch load/clear walk reads the chunk *view*, which quantized
//!   chunks do not expose.
//! - Everything else stays [`ChunkStorage::Csc`].
//!
//! The planner also drives the **side indexes**: chunk row maps are built
//! only for `Csc` chunks planned `Hash`, the `O(d)` dense scratch is
//! allocated only when some chunk plans `DenseLookup` *without* the
//! `DenseRows` layout, and the baseline's per-column maps only
//! materialize under hash-planned chunks — so `Auto` strictly
//! under-spends fixed `hash` on memory whenever any chunk plans away
//! from it ([`crate::inference::InferenceEngine::side_index_bytes`]
//! reports the total in one number, and
//! [`crate::inference::InferenceEngine::weight_bytes`] the layout-applied
//! weight payload).

use std::time::Instant;

use super::{IterationMethod, KernelTier, MatmulAlgo};
use crate::sparse::iterators::{
    vec_chunk_binary, vec_chunk_binary_simd, vec_chunk_dense, vec_chunk_dense_simd,
    vec_chunk_hash, vec_chunk_hash_simd, vec_chunk_marching, vec_chunk_marching_simd,
    DenseScratch,
};
use crate::sparse::{Chunk, ChunkStats, ChunkStorage, SimdLevel, SparseVec, U32Map};
use crate::tree::XmrModel;
use crate::util::rng::{Rng, Zipf};

/// The four concrete methods in plan/histogram order (never `Auto`).
const CONCRETE: [IterationMethod; 4] = IterationMethod::ALL;

/// Largest stored-entry count of a [`ChunkStorage::Merged`] candidate.
pub const MERGE_MAX_NNZ: usize = 32;

/// Largest sibling width of a [`ChunkStorage::Merged`] candidate.
pub const MERGE_MAX_WIDTH: usize = 8;

/// Fixed per-block overhead (ns) charged to the SIMD tier: lane setup,
/// the masked remainder, and the run-detection branches. Keeps tiny
/// chunks — where a whole block is a handful of scalar steps — on the
/// scalar tier even though the per-unit SIMD constant is lower.
pub const SIMD_SETUP_NS: f64 = 16.0;

/// Planner inputs: workload hints and the optional calibration budget.
#[derive(Clone, Copy, Debug)]
pub struct PlannerConfig {
    /// Expected nonzeros per query (`nnz(x)` in the cost shapes).
    pub query_nnz_hint: usize,
    /// Expected concurrent queries per batch — amortizes the dense-lookup
    /// chunk load across the blocks that share it under chunk-order
    /// evaluation (Alg. 3). Use 1 for a strictly online deployment.
    pub batch_hint: usize,
    /// Number of synthetic calibration queries; 0 keeps the analytical
    /// constants ([`CostModel::default`]).
    pub calibrate: usize,
    /// Seed for the calibration query stream.
    pub seed: u64,
    /// Let the plan pick per-chunk weight storage (`DenseRows`/`Merged`)
    /// in addition to kernels. Engines built around *shared* models
    /// ([`crate::inference::InferenceEngine::from_arc`]) plan with this
    /// off — re-laying storage needs an owned model; the flag also
    /// drives the layout-ablation rows of `benches/planner.rs`.
    pub storage: bool,
    /// Allow the **approximate** quantized layouts
    /// ([`ChunkStorage::F16`] / [`ChunkStorage::Int8`]). Off by default:
    /// exact deployments must stay bitwise identical across plans, so
    /// lossy layouts are strictly opt-in (the `--approx` planner flag),
    /// gated by the precision@k regression suite.
    pub approx: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            query_nnz_hint: 64,
            batch_hint: 32,
            calibrate: 0,
            seed: 0x9A7_F17,
            storage: true,
            approx: false,
        }
    }
}

/// Per-method nanosecond constants multiplying the module-doc shapes,
/// one set per kernel tier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// Scalar tier, indexed by [`IterationMethod::index`]: marching,
    /// binary, hash, dense.
    pub k: [f64; 4],
    /// SIMD tier, same indexing. A SIMD block is additionally charged
    /// the flat [`SIMD_SETUP_NS`], so `k_simd[m] < k[m]` does *not* mean
    /// SIMD always wins — only on blocks with enough units to amortize
    /// the setup.
    pub k_simd: [f64; 4],
}

impl Default for CostModel {
    /// Analytical constants: one compare-and-advance per element for
    /// marching, a couple of comparisons per binary-search step, several
    /// dependent loads per hash probe, one array read per dense probe
    /// (the dense load/clear walk is carried in the `2r/n` shape). The
    /// SIMD constants reflect what the lanes actually parallelize: the
    /// serial intersection walks gain only their emit loops (modest),
    /// the probe kernels gain 8-wide gathers (larger).
    fn default() -> Self {
        Self {
            k: [1.0, 2.0, 4.0, 1.0],
            k_simd: [0.85, 1.9, 3.2, 0.5],
        }
    }
}

impl CostModel {
    /// Unit count of `method` for one block: query support `q`, chunk
    /// rows `r`, `amort` blocks sharing one dense chunk load.
    fn units(method: IterationMethod, q: f64, r: f64, amort: f64) -> f64 {
        match method {
            IterationMethod::MarchingPointers => q + r,
            IterationMethod::BinarySearch => q.min(r) * (q.max(r) + 2.0).log2(),
            IterationMethod::Hash => q,
            IterationMethod::DenseLookup => 1.5 * q + 2.0 * r / amort.max(1.0),
            IterationMethod::Auto => unreachable!("Auto is not a kernel"),
        }
    }

    /// Predicted nanoseconds for one MSCM block on a chunk with
    /// build-time statistics `stats`.
    pub fn block_cost(
        &self,
        method: IterationMethod,
        stats: &ChunkStats,
        pc: &PlannerConfig,
    ) -> f64 {
        let q = pc.query_nnz_hint as f64;
        let r = stats.rows as f64;
        self.k[method.index()] * Self::units(method, q, r, pc.batch_hint as f64)
    }

    /// Predicted nanoseconds for one baseline block (per-column walks
    /// over the chunk's `w` columns of average support `e / w`).
    pub fn baseline_block_cost(
        &self,
        method: IterationMethod,
        stats: &ChunkStats,
        pc: &PlannerConfig,
    ) -> f64 {
        let q = pc.query_nnz_hint as f64;
        let w = (stats.width as f64).max(1.0);
        let e = stats.nnz as f64;
        let rc = e / w;
        let k = self.k[method.index()];
        match method {
            IterationMethod::MarchingPointers => k * (w * q + e),
            IterationMethod::BinarySearch => k * w * q.min(rc) * (q.max(rc) + 2.0).log2(),
            IterationMethod::Hash => k * w * q,
            // Parabel/Bonsai scheme: the query scatters once per layer
            // and every masked column reads it — charge the scatter
            // amortized over a nominal beam of chunks.
            IterationMethod::DenseLookup => k * (e + 2.0 * q / 8.0),
            IterationMethod::Auto => unreachable!("Auto is not a kernel"),
        }
    }

    /// Predicted nanoseconds of one [`ChunkStorage::DenseRows`] block:
    /// `1.5q` dense-probe units — the layout bakes the position array
    /// into `row_ptr`, so the `2r/n` load/clear term disappears.
    pub fn dense_rows_block_cost(&self, pc: &PlannerConfig) -> f64 {
        self.k[IterationMethod::DenseLookup.index()] * 1.5 * pc.query_nnz_hint as f64
    }

    /// SIMD-tier price of one MSCM block: the per-unit SIMD constant
    /// plus the flat [`SIMD_SETUP_NS`].
    pub fn block_cost_simd(
        &self,
        method: IterationMethod,
        stats: &ChunkStats,
        pc: &PlannerConfig,
    ) -> f64 {
        let q = pc.query_nnz_hint as f64;
        let r = stats.rows as f64;
        self.k_simd[method.index()] * Self::units(method, q, r, pc.batch_hint as f64)
            + SIMD_SETUP_NS
    }

    /// SIMD-tier price of one [`ChunkStorage::DenseRows`] block (the
    /// 8-wide `row_ptr` gather probe).
    pub fn dense_rows_block_cost_simd(&self, pc: &PlannerConfig) -> f64 {
        self.k_simd[IterationMethod::DenseLookup.index()] * 1.5 * pc.query_nnz_hint as f64
            + SIMD_SETUP_NS
    }

    /// Predicted nanoseconds of one block under its *planned*
    /// `(algo, method, storage, tier)` — the single dispatch the drift
    /// telemetry ([`crate::metrics::PlanDrift`]) joins measurements
    /// against, mirroring how the kernels actually run: a
    /// [`ChunkStorage::DenseRows`] chunk bypasses method dispatch into
    /// the direct probe, every other layout runs `method`'s shape, and
    /// the SIMD tier swaps in the vector constants + setup overhead.
    /// The baseline has no SIMD tier (per-column dots keep a single
    /// serial accumulator — see `inference::baseline`), so its price
    /// ignores `tier`.
    pub fn planned_block_cost(
        &self,
        algo: MatmulAlgo,
        method: IterationMethod,
        storage: ChunkStorage,
        tier: KernelTier,
        stats: &ChunkStats,
        pc: &PlannerConfig,
    ) -> f64 {
        match (algo, storage, tier) {
            (MatmulAlgo::Mscm, ChunkStorage::DenseRows, KernelTier::Scalar) => {
                self.dense_rows_block_cost(pc)
            }
            (MatmulAlgo::Mscm, ChunkStorage::DenseRows, KernelTier::Simd) => {
                self.dense_rows_block_cost_simd(pc)
            }
            (MatmulAlgo::Mscm, _, KernelTier::Scalar) => self.block_cost(method, stats, pc),
            (MatmulAlgo::Mscm, _, KernelTier::Simd) => self.block_cost_simd(method, stats, pc),
            (MatmulAlgo::Baseline, _, _) => self.baseline_block_cost(method, stats, pc),
        }
    }

    /// Picks one layer's per-chunk kernel tiers: SIMD exactly where its
    /// predicted block price (vector constants + setup) beats scalar,
    /// and only when `level` has vector kernels at all. The baseline
    /// stays scalar everywhere.
    pub fn plan_layer_tiers(
        &self,
        algo: MatmulAlgo,
        stats: &[ChunkStats],
        methods: &[IterationMethod],
        storage: &[ChunkStorage],
        level: SimdLevel,
        pc: &PlannerConfig,
    ) -> Vec<KernelTier> {
        if algo == MatmulAlgo::Baseline || !level.is_vector() {
            return vec![KernelTier::Scalar; methods.len()];
        }
        methods
            .iter()
            .zip(storage)
            .zip(stats)
            .map(|((&m, &s), st)| {
                let scalar =
                    self.planned_block_cost(algo, m, s, KernelTier::Scalar, st, pc);
                let simd = self.planned_block_cost(algo, m, s, KernelTier::Simd, st, pc);
                // Strict `<`: ties keep the scalar oracle.
                if simd < scalar {
                    KernelTier::Simd
                } else {
                    KernelTier::Scalar
                }
            })
            .collect()
    }

    /// Cheapest concrete method for one chunk under `algo`.
    pub fn best_method(
        &self,
        algo: MatmulAlgo,
        stats: &ChunkStats,
        pc: &PlannerConfig,
    ) -> IterationMethod {
        let mut best = IterationMethod::MarchingPointers;
        let mut best_cost = f64::INFINITY;
        for m in CONCRETE {
            let c = match algo {
                MatmulAlgo::Mscm => self.block_cost(m, stats, pc),
                MatmulAlgo::Baseline => self.baseline_block_cost(m, stats, pc),
            };
            // Strict `<` keeps the earlier (side-index-free) method on
            // ties: CONCRETE is ordered marching, binary, hash, dense.
            if c < best_cost {
                best_cost = c;
                best = m;
            }
        }
        best
    }

    /// Picks one layer's per-chunk storage layouts (see the module docs
    /// for the byte + time terms), adjusting `methods` in place where a
    /// layout implies its kernel (`DenseRows` → direct probe, recorded
    /// as `DenseLookup`). `dim` is the feature dimension `d`.
    pub fn plan_layer_storage(
        &self,
        algo: MatmulAlgo,
        stats: &[ChunkStats],
        methods: &mut [IterationMethod],
        dim: usize,
        pc: &PlannerConfig,
    ) -> Vec<ChunkStorage> {
        let n = methods.len();
        let mut storage = vec![ChunkStorage::Csc; n];
        if algo == MatmulAlgo::Baseline {
            // The baseline evaluates per column off the CSC arrays; a
            // chunk layout change would alter nothing it reads, so it
            // keeps the seed layout.
            return storage;
        }
        for c in 0..n {
            let s = &stats[c];
            // DenseRows: strictly fewer weight bytes (4(d+1) pointer
            // entries versus 8r+4 of row-sparse indexing — the row map
            // it also drops is pure extra savings) and a probe no slower
            // than the planned kernel.
            if 4 * (dim + 1) < 8 * s.rows + 4
                && self.dense_rows_block_cost(pc) <= self.block_cost(methods[c], s, pc)
            {
                storage[c] = ChunkStorage::DenseRows;
                methods[c] = IterationMethod::DenseLookup;
                continue;
            }
            if matches!(
                methods[c],
                IterationMethod::MarchingPointers | IterationMethod::BinarySearch
            ) && s.nnz <= MERGE_MAX_NNZ
                && s.width <= MERGE_MAX_WIDTH
            {
                storage[c] = ChunkStorage::Merged;
            }
        }
        // A merged run of one chunk saves nothing: revert singletons.
        let mut i = 0;
        while i < n {
            if storage[i] == ChunkStorage::Merged {
                let mut j = i;
                while j < n && storage[j] == ChunkStorage::Merged {
                    j += 1;
                }
                if j - i < 2 {
                    storage[i] = ChunkStorage::Csc;
                }
                i = j;
            } else {
                i += 1;
            }
        }
        // Approximate mode: quantize the value payload of the remaining
        // row-sparse chunks by size. Int8 (1 B/entry + per-chunk scale)
        // once a chunk is big enough for the scale to be representative,
        // F16 (2 B/entry, no calibration risk) below that, and tiny
        // chunks stay exact — their bytes don't matter. DenseLookup
        // chunks are excluded: the dense scratch load/clear walk reads
        // the chunk view, which quantized chunks don't expose.
        if pc.approx {
            for c in 0..n {
                if storage[c] == ChunkStorage::Csc
                    && methods[c] != IterationMethod::DenseLookup
                {
                    if stats[c].nnz >= 64 {
                        storage[c] = ChunkStorage::Int8;
                    } else if stats[c].nnz >= 8 {
                        storage[c] = ChunkStorage::F16;
                    }
                }
            }
        }
        storage
    }

    /// Fits the per-method constants by timing each kernel on a sample of
    /// `model`'s chunks against `n` synthetic queries of
    /// `pc.query_nnz_hint` nonzeros (Zipf-popular features, like the
    /// benchmark generators). Returns `self` unchanged when `n == 0` or
    /// the model has no nonzero chunk to time.
    pub fn calibrate(mut self, model: &XmrModel, pc: &PlannerConfig) -> Self {
        let n = pc.calibrate;
        if n == 0 {
            return self;
        }
        // Sample chunks round-robin across layers so wide top chunks and
        // narrow bottom chunks both contribute.
        const MAX_CHUNKS: usize = 32;
        let mut sample: Vec<&Chunk> = Vec::new();
        let mut li = 0usize;
        let mut taken = vec![0usize; model.layers.len()];
        while sample.len() < MAX_CHUNKS {
            let layer = &model.layers[li % model.layers.len()];
            let c = taken[li % model.layers.len()];
            if c < layer.chunked.num_chunks() {
                let chunk = &layer.chunked.chunks[c];
                if chunk.storage == ChunkStorage::Csc && chunk.nnz_rows() > 0 {
                    sample.push(chunk);
                }
                taken[li % model.layers.len()] += 1;
            }
            li += 1;
            if li > model.layers.len() * (MAX_CHUNKS + 1) {
                break;
            }
        }
        if sample.is_empty() {
            return self;
        }
        let mut rng = Rng::seed_from_u64(pc.seed);
        let zipf = Zipf::new(model.dim, 1.0);
        let queries: Vec<SparseVec> = (0..n.max(1))
            .map(|_| {
                SparseVec::from_pairs(
                    (0..pc.query_nnz_hint.max(1))
                        .map(|_| (zipf.sample(&mut rng) as u32, rng.gen_f32(-1.0, 1.0)))
                        .collect(),
                )
            })
            .collect();
        // Hash timing needs row maps; time against clones so calibration
        // never mutates (or depends on) the model's own side indexes.
        let hashed: Vec<Chunk> = sample
            .iter()
            .map(|c| {
                let mut c = (*c).clone();
                if c.row_map.is_none() {
                    c.build_row_map();
                }
                c
            })
            .collect();
        let mut scratch = DenseScratch::new(model.dim);
        let max_w = sample.iter().map(|c| c.ncols as usize).max().unwrap_or(1);
        let mut out = vec![0.0f32; max_w];
        // Pass 1 fits the scalar constants; pass 2 (SIMD hardware only)
        // fits the vector constants by timing the `_simd` kernels on the
        // same chunks and queries — apples to apples.
        let mut tiers = vec![None];
        let level = SimdLevel::detect();
        if level.is_vector() {
            tiers.push(Some(level));
        }
        for tier in tiers {
            for m in CONCRETE {
                let mut units = 0.0f64;
                let t = Instant::now();
                for (s, chunk) in sample.iter().enumerate() {
                    let chunk = if m == IterationMethod::Hash { &hashed[s] } else { *chunk };
                    let cv = chunk.view();
                    // One load per chunk, shared by the whole query sample —
                    // mirrors chunk-order evaluation; the `2r/n` shape below
                    // charges the same amortization.
                    if m == IterationMethod::DenseLookup {
                        scratch.load(cv);
                    }
                    for x in &queries {
                        let o = &mut out[..chunk.ncols as usize];
                        o.fill(0.0);
                        let xv = x.view();
                        match (m, tier) {
                            (IterationMethod::MarchingPointers, None) => {
                                vec_chunk_marching(xv, cv, o)
                            }
                            (IterationMethod::BinarySearch, None) => vec_chunk_binary(xv, cv, o),
                            (IterationMethod::Hash, None) => vec_chunk_hash(xv, cv, o),
                            (IterationMethod::DenseLookup, None) => {
                                vec_chunk_dense(xv, cv, &scratch, o)
                            }
                            (IterationMethod::MarchingPointers, Some(lv)) => {
                                vec_chunk_marching_simd(xv, cv, o, lv)
                            }
                            (IterationMethod::BinarySearch, Some(lv)) => {
                                vec_chunk_binary_simd(xv, cv, o, lv)
                            }
                            (IterationMethod::Hash, Some(lv)) => vec_chunk_hash_simd(xv, cv, o, lv),
                            (IterationMethod::DenseLookup, Some(lv)) => {
                                vec_chunk_dense_simd(xv, cv, &scratch, o, lv)
                            }
                            (IterationMethod::Auto, _) => unreachable!(),
                        }
                        std::hint::black_box(&mut *o);
                        units += Self::units(
                            m,
                            x.nnz() as f64,
                            chunk.nnz_rows() as f64,
                            queries.len() as f64,
                        );
                    }
                    if m == IterationMethod::DenseLookup {
                        scratch.clear(cv);
                    }
                }
                let ns = t.elapsed().as_nanos() as f64;
                if units > 0.0 && ns > 0.0 {
                    match tier {
                        None => self.k[m.index()] = ns / units,
                        Some(_) => self.k_simd[m.index()] = ns / units,
                    }
                }
            }
        }
        self
    }
}

/// One iteration method + storage layout + kernel tier per chunk of one
/// layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerPlan {
    /// Indexed by chunk id; never contains `Auto`.
    pub methods: Vec<IterationMethod>,
    /// Physical weight layout per chunk, co-indexed with `methods`.
    pub storage: Vec<ChunkStorage>,
    /// Kernel tier per chunk, co-indexed with `methods`. `Simd` entries
    /// degrade to scalar at run time when the serving hardware has no
    /// vector unit ([`SimdLevel::detect`]), bitwise identically.
    pub tiers: Vec<KernelTier>,
}

/// A resolved kernel plan: one concrete method and one storage layout
/// per chunk per layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelPlan {
    /// One entry per model layer, top to bottom.
    pub layers: Vec<LayerPlan>,
}

impl KernelPlan {
    /// The degenerate plan a fixed configuration resolves to: `method`
    /// everywhere, seed `Csc` storage everywhere. `method` must be
    /// concrete.
    pub fn uniform(model: &XmrModel, method: IterationMethod) -> Self {
        assert!(
            method != IterationMethod::Auto,
            "uniform plans need a concrete method"
        );
        Self {
            layers: model
                .layers
                .iter()
                .map(|l| LayerPlan {
                    methods: vec![method; l.chunked.num_chunks()],
                    storage: vec![ChunkStorage::Csc; l.chunked.num_chunks()],
                    tiers: vec![KernelTier::Scalar; l.chunked.num_chunks()],
                })
                .collect(),
        }
    }

    /// Forces `storage` on every chunk of every layer (test/ablation
    /// harnesses pin layouts this way; the planner itself mixes them
    /// per chunk).
    pub fn with_uniform_storage(mut self, storage: ChunkStorage) -> Self {
        for l in &mut self.layers {
            l.storage = vec![storage; l.methods.len()];
        }
        self
    }

    /// Forces `tier` on every chunk of every layer (the tier-ablation
    /// and zero-alloc harnesses pin the SIMD tier this way; the planner
    /// itself mixes tiers per chunk). Safe on any hardware: `Simd`
    /// entries degrade to the scalar kernels when the detected level has
    /// no vector unit.
    pub fn with_uniform_tier(mut self, tier: KernelTier) -> Self {
        for l in &mut self.layers {
            l.tiers = vec![tier; l.methods.len()];
        }
        self
    }

    /// Plans `model` per chunk under `algo` with the (optionally
    /// calibrated) cost model.
    pub fn auto(model: &XmrModel, algo: MatmulAlgo, pc: &PlannerConfig) -> Self {
        let cost = CostModel::default().calibrate(model, pc);
        Self::auto_with_cost(model, algo, &cost, pc)
    }

    /// Plans `model` per chunk under an explicit cost model.
    pub fn auto_with_cost(
        model: &XmrModel,
        algo: MatmulAlgo,
        cost: &CostModel,
        pc: &PlannerConfig,
    ) -> Self {
        // Tiers are planned against the hardware doing the planning: on
        // scalar-only machines every chunk stays scalar (plans still
        // serve anywhere — the tier is a speed hint, not a requirement).
        let level = SimdLevel::detect();
        Self {
            layers: model
                .layers
                .iter()
                .map(|l| {
                    let stats: Vec<ChunkStats> = (0..l.chunked.num_chunks())
                        .map(|c| l.chunked.chunk_stats(c))
                        .collect();
                    let mut methods: Vec<IterationMethod> = stats
                        .iter()
                        .map(|s| cost.best_method(algo, s, pc))
                        .collect();
                    let storage = if pc.storage {
                        cost.plan_layer_storage(algo, &stats, &mut methods, model.dim, pc)
                    } else {
                        vec![ChunkStorage::Csc; methods.len()]
                    };
                    let tiers =
                        cost.plan_layer_tiers(algo, &stats, &methods, &storage, level, pc);
                    LayerPlan {
                        methods,
                        storage,
                        tiers,
                    }
                })
                .collect(),
        }
    }

    /// Resolves a configuration: fixed methods become uniform plans,
    /// `Auto` runs the planner.
    pub fn resolve(
        model: &XmrModel,
        config: super::EngineConfig,
        pc: &PlannerConfig,
    ) -> Self {
        match config.iter {
            IterationMethod::Auto => Self::auto(model, config.algo, pc),
            fixed => Self::uniform(model, fixed),
        }
    }

    /// True when the plan's shape matches `model` (one method + one
    /// layout + one tier per chunk per layer) and every entry is
    /// concrete.
    pub fn matches(&self, model: &XmrModel) -> bool {
        self.layers.len() == model.layers.len()
            && self
                .layers
                .iter()
                .zip(&model.layers)
                .all(|(p, l)| {
                    p.methods.len() == l.chunked.num_chunks()
                        && p.storage.len() == p.methods.len()
                        && p.tiers.len() == p.methods.len()
                })
            && !self.uses(IterationMethod::Auto)
    }

    /// Per-chunk methods of layer `li` (the hot-loop lookup — a plain
    /// slice index, no allocation).
    #[inline]
    pub fn layer_methods(&self, li: usize) -> &[IterationMethod] {
        &self.layers[li].methods
    }

    /// Per-chunk storage layouts of layer `li`.
    #[inline]
    pub fn layer_storage(&self, li: usize) -> &[ChunkStorage] {
        &self.layers[li].storage
    }

    /// Per-chunk kernel tiers of layer `li`.
    #[inline]
    pub fn layer_tiers(&self, li: usize) -> &[KernelTier] {
        &self.layers[li].tiers
    }

    /// True when any chunk of any layer plans the SIMD tier.
    pub fn uses_simd(&self) -> bool {
        self.layers
            .iter()
            .any(|l| l.tiers.iter().any(|&t| t == KernelTier::Simd))
    }

    /// True when any chunk of any layer plans `method`.
    pub fn uses(&self, method: IterationMethod) -> bool {
        self.layers
            .iter()
            .any(|l| l.methods.iter().any(|&m| m == method))
    }

    /// True when any chunk of any layer uses `storage`.
    pub fn uses_storage(&self, storage: ChunkStorage) -> bool {
        self.layers
            .iter()
            .any(|l| l.storage.iter().any(|&s| s == storage))
    }

    /// True when the plan needs the `O(d)` dense scratch: some chunk
    /// plans `DenseLookup` *without* the `DenseRows` layout (that layout
    /// is its own position array).
    pub fn needs_dense_scratch(&self) -> bool {
        self.layers.iter().any(|l| {
            l.methods
                .iter()
                .zip(&l.storage)
                .any(|(&m, &s)| {
                    m == IterationMethod::DenseLookup && s != ChunkStorage::DenseRows
                })
        })
    }

    /// Model-level summary: per-layer and total method histograms plus
    /// the storage-layout and kernel-tier histograms.
    pub fn summary(&self) -> PlanSummary {
        let per_layer: Vec<[usize; 4]> = self
            .layers
            .iter()
            .map(|l| {
                let mut h = [0usize; 4];
                for m in &l.methods {
                    h[m.index()] += 1;
                }
                h
            })
            .collect();
        let mut total = [0usize; 4];
        for h in &per_layer {
            for (t, c) in total.iter_mut().zip(h) {
                *t += c;
            }
        }
        let mut storage_total = [0usize; 5];
        for l in &self.layers {
            for s in &l.storage {
                storage_total[s.index()] += 1;
            }
        }
        let per_layer_simd: Vec<usize> = self
            .layers
            .iter()
            .map(|l| l.tiers.iter().filter(|&&t| t == KernelTier::Simd).count())
            .collect();
        let mut tier_total = [0usize; 2];
        for l in &self.layers {
            for t in &l.tiers {
                tier_total[t.index()] += 1;
            }
        }
        PlanSummary {
            per_layer,
            total,
            storage_total,
            per_layer_simd,
            tier_total,
        }
    }
}

/// Side-index bytes the fixed `hash` configuration would materialize for
/// `model` under `algo`, priced analytically from the build-time chunk
/// statistics — no map is constructed. [`U32Map`] sizing is deterministic
/// in the entry count ([`U32Map::capacity_bytes_for`]), so this equals
/// what a fixed-hash engine's
/// [`side_index_bytes`](super::InferenceEngine::side_index_bytes) reports
/// after actually building the index; `plan`-style inspection tooling
/// uses it to show the planner's savings without paying for the baseline.
pub fn fixed_hash_side_bytes(model: &XmrModel, algo: MatmulAlgo) -> usize {
    match algo {
        // One row map per chunk, sized by the chunk's touched rows.
        MatmulAlgo::Mscm => model
            .layers
            .iter()
            .map(|l| {
                (0..l.chunked.num_chunks())
                    .map(|c| U32Map::capacity_bytes_for(l.chunked.chunk_stats(c).rows))
                    .sum::<usize>()
            })
            .sum(),
        // One map per column (NapkinXC scheme), plus the container.
        MatmulAlgo::Baseline => model
            .layers
            .iter()
            .map(|l| {
                l.csc.cols * std::mem::size_of::<U32Map>()
                    + (0..l.csc.cols)
                        .map(|j| U32Map::capacity_bytes_for(l.csc.col(j).nnz()))
                        .sum::<usize>()
            })
            .sum(),
    }
}

/// Method + layout histograms of a [`KernelPlan`] (method counts indexed
/// by [`IterationMethod::index`], layout counts by
/// [`ChunkStorage::index`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanSummary {
    /// Chunk counts per method, one row per layer.
    pub per_layer: Vec<[usize; 4]>,
    /// Chunk counts per method over the whole model.
    pub total: [usize; 4],
    /// Chunk counts per storage layout over the whole model, indexed by
    /// [`ChunkStorage::index`] over [`ChunkStorage::EVERY`] (the two
    /// trailing slots count the approximate `F16`/`Int8` layouts and
    /// stay zero outside `--approx` plans).
    pub storage_total: [usize; 5],
    /// SIMD-tier chunk count per layer (the scalar count is the layer's
    /// chunk total minus this).
    pub per_layer_simd: Vec<usize>,
    /// Chunk counts per kernel tier over the whole model, indexed by
    /// [`KernelTier::index`].
    pub tier_total: [usize; 2],
}

impl std::fmt::Display for PlanSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (li, h) in self.per_layer.iter().enumerate() {
            write!(f, "layer {li}:")?;
            for (m, &c) in CONCRETE.iter().zip(h) {
                write!(f, "  {}={}", m.short(), c)?;
            }
            let chunks: usize = h.iter().sum();
            writeln!(f, "  [simd {}/{}]", self.per_layer_simd[li], chunks)?;
        }
        write!(f, "total:  ")?;
        for (m, &c) in CONCRETE.iter().zip(&self.total) {
            write!(f, "  {}={}", m.short(), c)?;
        }
        writeln!(f)?;
        write!(f, "layouts:")?;
        for (s, &c) in ChunkStorage::EVERY.iter().zip(&self.storage_total) {
            write!(f, "  {}={}", s.short(), c)?;
        }
        writeln!(f)?;
        write!(f, "tiers:  ")?;
        for (t, &c) in KernelTier::ALL.iter().zip(&self.tier_total) {
            write!(f, "  {}={}", t.short(), c)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{CscMatrix, SparseVec};
    use crate::tree::test_util::tiny_model;
    use crate::tree::Layer;

    /// A chunk with `rows` stored rows of one entry each.
    fn chunk_with_rows(rows: usize, width: usize) -> Chunk {
        let cols: Vec<SparseVec> = (0..width)
            .map(|j| {
                SparseVec::from_pairs(
                    (0..rows)
                        .filter(|r| r % width == j % width.max(1))
                        .map(|r| (r as u32, 1.0))
                        .collect(),
                )
            })
            .collect();
        let csc = CscMatrix::from_cols(cols, rows.max(1));
        crate::sparse::ChunkedMatrix::from_csc(&csc, &[0, width as u32], false).chunks[0].clone()
    }

    #[test]
    fn cost_model_picks_dense_for_wide_dense_chunks_in_batch() {
        let cost = CostModel::default();
        let pc = PlannerConfig {
            query_nnz_hint: 64,
            batch_hint: 32,
            ..Default::default()
        };
        let chunk = chunk_with_rows(2000, 32);
        assert_eq!(
            cost.best_method(MatmulAlgo::Mscm, &chunk.stats(), &pc),
            IterationMethod::DenseLookup
        );
    }

    #[test]
    fn cost_model_picks_hash_for_dense_chunks_online() {
        // With no batch to amortize the O(r) load, dense loses to hash.
        let cost = CostModel::default();
        let pc = PlannerConfig {
            query_nnz_hint: 64,
            batch_hint: 1,
            ..Default::default()
        };
        let chunk = chunk_with_rows(2000, 32);
        assert_eq!(
            cost.best_method(MatmulAlgo::Mscm, &chunk.stats(), &pc),
            IterationMethod::Hash
        );
    }

    #[test]
    fn cost_model_picks_marching_for_tiny_supports() {
        let cost = CostModel::default();
        let pc = PlannerConfig {
            query_nnz_hint: 8,
            batch_hint: 1,
            ..Default::default()
        };
        let chunk = chunk_with_rows(2, 2);
        assert_eq!(
            cost.best_method(MatmulAlgo::Mscm, &chunk.stats(), &pc),
            IterationMethod::MarchingPointers
        );
    }

    #[test]
    fn storage_pass_picks_dense_rows_when_rows_cover_the_dim() {
        // rows == d: the direct row-pointer array is strictly smaller
        // than row-sparse indexing, and the probe beats the hash/dense
        // kernels — the chunk re-lays as DenseRows with the probe kernel.
        let cost = CostModel::default();
        let pc = PlannerConfig {
            query_nnz_hint: 64,
            batch_hint: 1,
            ..Default::default()
        };
        let stats = [chunk_with_rows(2000, 32).stats()];
        let mut methods = [cost.best_method(MatmulAlgo::Mscm, &stats[0], &pc)];
        let storage =
            cost.plan_layer_storage(MatmulAlgo::Mscm, &stats, &mut methods, 2000, &pc);
        assert_eq!(storage, vec![ChunkStorage::DenseRows]);
        assert_eq!(methods[0], IterationMethod::DenseLookup);
        // ... but not when the chunk's rows are a sliver of a huge d.
        let mut methods = [IterationMethod::Hash];
        let storage =
            cost.plan_layer_storage(MatmulAlgo::Mscm, &stats, &mut methods, 1_000_000, &pc);
        assert_eq!(storage, vec![ChunkStorage::Csc]);
        assert_eq!(methods[0], IterationMethod::Hash);
    }

    #[test]
    fn storage_pass_merges_runs_of_tiny_chunks_only() {
        let cost = CostModel::default();
        let pc = PlannerConfig {
            query_nnz_hint: 8,
            batch_hint: 1,
            ..Default::default()
        };
        let tiny = chunk_with_rows(2, 2).stats();
        let big = chunk_with_rows(400, 4).stats();
        // tiny tiny big tiny big: only the leading pair merges.
        let stats = [tiny, tiny, big, tiny, big];
        let mut methods = [IterationMethod::MarchingPointers; 5];
        let storage = cost.plan_layer_storage(MatmulAlgo::Mscm, &stats, &mut methods, 400, &pc);
        assert_eq!(storage[0], ChunkStorage::Merged);
        assert_eq!(storage[1], ChunkStorage::Merged);
        assert_eq!(storage[3], ChunkStorage::Csc, "singleton run reverts");
        assert_ne!(storage[2], ChunkStorage::Merged);
    }

    #[test]
    fn approx_flag_gates_quantized_layouts() {
        let cost = CostModel::default();
        let pc = PlannerConfig {
            query_nnz_hint: 8,
            batch_hint: 1,
            ..Default::default()
        };
        // big (nnz >= 64), mid (8 <= nnz < 64), tiny (nnz < 8)
        let stats = [
            chunk_with_rows(400, 4).stats(),
            chunk_with_rows(40, 4).stats(),
            chunk_with_rows(2, 2).stats(),
        ];
        assert!(stats[0].nnz >= 64 && stats[1].nnz >= 8 && stats[1].nnz < 64);
        let mut methods = [IterationMethod::BinarySearch; 3];
        // Default (exact) planning never emits a quantized layout.
        let exact =
            cost.plan_layer_storage(MatmulAlgo::Mscm, &stats, &mut methods, 1_000_000, &pc);
        assert!(exact.iter().all(|s| !s.is_quantized()), "{exact:?}");
        // --approx: Int8 for big chunks, F16 for mid, tiny stays exact.
        let apc = PlannerConfig {
            approx: true,
            ..pc
        };
        let mut methods = [IterationMethod::BinarySearch; 3];
        let approx =
            cost.plan_layer_storage(MatmulAlgo::Mscm, &stats, &mut methods, 1_000_000, &apc);
        assert_eq!(approx[0], ChunkStorage::Int8);
        assert_eq!(approx[1], ChunkStorage::F16);
        assert!(!approx[2].is_quantized());
        // DenseLookup-planned chunks never quantize, even when large.
        let mut methods = [IterationMethod::DenseLookup; 3];
        let dense =
            cost.plan_layer_storage(MatmulAlgo::Mscm, &stats, &mut methods, 1_000_000, &apc);
        for (c, s) in dense.iter().enumerate() {
            assert!(
                !s.is_quantized(),
                "dense-planned chunk {c} must stay exact, got {s:?}"
            );
        }
    }

    #[test]
    fn baseline_storage_stays_csc() {
        let cost = CostModel::default();
        let pc = PlannerConfig::default();
        let stats = [chunk_with_rows(2000, 32).stats(), chunk_with_rows(2, 2).stats()];
        let mut methods = [IterationMethod::Hash, IterationMethod::MarchingPointers];
        let storage =
            cost.plan_layer_storage(MatmulAlgo::Baseline, &stats, &mut methods, 2000, &pc);
        assert!(storage.iter().all(|&s| s == ChunkStorage::Csc));
    }

    #[test]
    fn uniform_plan_matches_and_reports() {
        let m = tiny_model(16, 3, 3, 1);
        let plan = KernelPlan::uniform(&m, IterationMethod::BinarySearch);
        assert!(plan.matches(&m));
        assert!(plan.uses(IterationMethod::BinarySearch));
        assert!(!plan.uses(IterationMethod::Hash));
        assert!(!plan.uses_storage(ChunkStorage::DenseRows));
        assert!(!plan.uses_storage(ChunkStorage::Merged));
        assert!(!plan.uses_simd(), "uniform plans start scalar");
        let s = plan.summary();
        let chunks: usize = m.layers.iter().map(|l| l.chunked.num_chunks()).sum();
        assert_eq!(s.total[IterationMethod::BinarySearch.index()], chunks);
        assert_eq!(s.storage_total[ChunkStorage::Csc.index()], chunks);
        assert_eq!(s.per_layer.len(), m.depth());
        assert_eq!(s.tier_total, [chunks, 0]);

        let plan = plan.with_uniform_tier(KernelTier::Simd);
        assert!(plan.matches(&m));
        assert!(plan.uses_simd());
        assert_eq!(plan.summary().tier_total, [0, chunks]);
    }

    #[test]
    fn tier_pass_prefers_simd_on_big_chunks_only() {
        // Pure cost arithmetic — the level is passed in, so this test is
        // hardware-independent.
        let cost = CostModel::default();
        let pc = PlannerConfig {
            query_nnz_hint: 64,
            batch_hint: 32,
            ..Default::default()
        };
        let big = chunk_with_rows(2000, 32).stats();
        let tiny = chunk_with_rows(2, 2).stats();
        let stats = [big, tiny];
        let methods = [IterationMethod::DenseLookup, IterationMethod::MarchingPointers];
        let storage = [ChunkStorage::DenseRows, ChunkStorage::Csc];
        let tiers = cost.plan_layer_tiers(
            MatmulAlgo::Mscm,
            &stats,
            &methods,
            &storage,
            SimdLevel::Avx2,
            &pc,
        );
        assert_eq!(tiers[0], KernelTier::Simd, "wide dense-rows chunk goes SIMD");
        assert_eq!(
            tiers[1],
            KernelTier::Scalar,
            "a tiny chunk cannot amortize the SIMD setup"
        );
        // No vector unit, or the baseline algo: everything stays scalar.
        let none = cost.plan_layer_tiers(
            MatmulAlgo::Mscm,
            &stats,
            &methods,
            &storage,
            SimdLevel::None,
            &pc,
        );
        assert!(none.iter().all(|&t| t == KernelTier::Scalar));
        let base = cost.plan_layer_tiers(
            MatmulAlgo::Baseline,
            &stats,
            &methods,
            &storage,
            SimdLevel::Avx2,
            &pc,
        );
        assert!(base.iter().all(|&t| t == KernelTier::Scalar));
    }

    #[test]
    fn auto_plan_has_one_method_per_chunk() {
        let m = tiny_model(32, 4, 3, 7);
        for algo in MatmulAlgo::ALL {
            let plan = KernelPlan::auto(&m, algo, &PlannerConfig::default());
            assert!(plan.matches(&m), "{algo:?}");
            for (li, l) in m.layers.iter().enumerate() {
                assert_eq!(plan.layer_methods(li).len(), l.chunked.num_chunks());
                assert_eq!(plan.layer_storage(li).len(), l.chunked.num_chunks());
            }
        }
    }

    #[test]
    fn storage_flag_off_keeps_every_chunk_csc() {
        let m = tiny_model(32, 4, 3, 7);
        let pc = PlannerConfig {
            storage: false,
            ..Default::default()
        };
        let plan = KernelPlan::auto(&m, MatmulAlgo::Mscm, &pc);
        assert!(!plan.uses_storage(ChunkStorage::DenseRows));
        assert!(!plan.uses_storage(ChunkStorage::Merged));
    }

    #[test]
    fn calibration_produces_positive_finite_constants() {
        let m = tiny_model(32, 4, 3, 5);
        let pc = PlannerConfig {
            calibrate: 4,
            query_nnz_hint: 8,
            ..Default::default()
        };
        let cost = CostModel::default().calibrate(&m, &pc);
        for k in cost.k.iter().chain(&cost.k_simd) {
            assert!(k.is_finite() && *k > 0.0, "bad constant {k}");
        }
        // a calibrated model still yields a valid plan
        let plan = KernelPlan::auto_with_cost(&m, MatmulAlgo::Mscm, &cost, &pc);
        assert!(plan.matches(&m));
    }

    #[test]
    fn analytical_hash_baseline_equals_built_engines() {
        use super::super::{EngineConfig, InferenceEngine};
        let mut m = tiny_model(24, 4, 3, 13);
        m.drop_row_maps();
        for algo in MatmulAlgo::ALL {
            let engine = InferenceEngine::new(
                m.clone(),
                EngineConfig::new(algo, IterationMethod::Hash),
            );
            assert_eq!(
                engine.side_index_bytes(),
                fixed_hash_side_bytes(&m, algo),
                "{algo:?}"
            );
        }
    }

    #[test]
    fn mixed_density_model_gets_mixed_plan() {
        // Build a model whose first layer chunk is wide and dense and
        // whose bottom chunks are tiny: the plan must not be uniform.
        let dim = 512;
        let dense_cols: Vec<SparseVec> = (0..8)
            .map(|j| {
                SparseVec::from_pairs((0..400).map(|r| (r as u32, (j + r) as f32 * 0.01)).collect())
            })
            .collect();
        let sparse_cols: Vec<SparseVec> = (0..16)
            .map(|j| SparseVec::from_pairs(vec![(j as u32, 1.0)]))
            .collect();
        let l0 = Layer::new(CscMatrix::from_cols(dense_cols, dim), &[0, 8], false);
        let offsets: Vec<u32> = (0..=8).map(|p| (p * 2) as u32).collect();
        let l1 = Layer::new(CscMatrix::from_cols(sparse_cols, dim), &offsets, false);
        let m = XmrModel::new(dim, vec![l0, l1]);
        let pc = PlannerConfig {
            query_nnz_hint: 48,
            batch_hint: 32,
            ..Default::default()
        };
        let plan = KernelPlan::auto(&m, MatmulAlgo::Mscm, &pc);
        assert_eq!(
            plan.layer_methods(0)[0],
            IterationMethod::DenseLookup,
            "wide dense chunk should plan dense"
        );
        assert_eq!(
            plan.layer_storage(0)[0],
            ChunkStorage::DenseRows,
            "rows cover > d/2, so the layout should drop the row index"
        );
        assert!(
            plan.layer_methods(1)
                .iter()
                .all(|&m| m == IterationMethod::BinarySearch),
            "tiny chunks should plan a side-index-free method: {:?}",
            plan.layer_methods(1)
        );
        assert!(
            plan.layer_storage(1)
                .iter()
                .all(|&s| s == ChunkStorage::Merged),
            "the run of tiny chunks should coalesce: {:?}",
            plan.layer_storage(1)
        );
        // ... which is the point: a mixed plan with no hash-planned chunk.
        assert!(!plan.uses(IterationMethod::Hash));
        assert!(!plan.needs_dense_scratch(), "DenseRows needs no scratch");
    }
}
