//! Shard serialization: a versioned envelope around the [`crate::tree`]
//! model body.
//!
//! Two current formats share the header:
//!
//! - **`MSCMXMR3`** — the portable build-time envelope: the model body in
//!   its all-`Csc` build form plus the resolved kernel plan. Loading
//!   re-applies the plan's storage layouts on the heap.
//! - **`MSCMXMR4`** — the *layout-resolved* serving envelope
//!   ([`save_shard_v4`]): every chunk's arrays are written in their
//!   planned physical layout ([`ChunkStorage`], quantized variants
//!   included), each weight array padded to a 64-byte file offset, so a
//!   host can serve the file directly through a read-only memory map
//!   ([`MmapModel`]) with the kernels reading borrowed slices — models
//!   larger than RAM never materialize on the heap. The same byte layout
//!   parses on the heap too (the default), byte-for-byte into the same
//!   model.
//!
//! `MSCMXMR3` format (little-endian):
//! ```text
//! magic         u64  = 0x4d53_434d_584d_5233 ("MSCMXMR3")
//! shard_id      u64
//! num_shards    u64
//! root_lo       u64   global root-child range [root_lo, root_hi)
//! root_hi       u64
//! label_offset  u64   global label id of local label 0
//! num_labels    u64
//! depth         u64
//! layer_offsets depth x u32   global column start per layer
//! model body    (identical to the MSCMXMR1 payload after its magic)
//! has_plan      u64  (0 = none; 1 = plan costed for MSCM; 2 = plan
//!                     costed for the baseline algo; mandatory — a
//!                     truncated V3 file is rejected)
//! plan          if has_plan: per layer, num_chunks u64 then
//!               num_chunks x u32 method codes, then num_chunks x u32
//!               storage codes (ChunkStorage::index)
//! (end)         trailing bytes are rejected
//! ```
//! A method code folds the chunk's kernel tier into the high range:
//! `IterationMethod::index` (0–3) for scalar chunks,
//! `IterationMethod::index + 4` (4–7) for SIMD-planned chunks; codes ≥ 8
//! are rejected. An all-scalar plan therefore writes codes 0–3 — byte
//! for byte what pre-tier writers produced — and pre-tier readers only
//! choke on files that actually carry SIMD tiers.
//! The body is read/written by the same codec as whole models, so format
//! evolution stays in one place. The trailing kernel-plan section lets a
//! planned (and possibly timing-calibrated) model load and serve without
//! re-planning — plans are per-shard, over the shard's own chunks, and
//! since `MSCMXMR3` they carry the per-chunk **storage layout**
//! ([`ChunkStorage`]) the engine applies at construction.
//!
//! Legacy `MSCMXMR2` files (magic `…5232`) still load: their plan
//! section has no storage codes (every chunk reads as
//! [`ChunkStorage::Csc`]), and pre-planner files that end right after
//! the model body read as plan-less. Both legacy leniencies are V2-only;
//! V3 parsing is strict (fuzzed in `rust/tests/format.rs`).
//!
//! `MSCMXMR4` format (little-endian; same 7-word spec header and
//! `layer_offsets` as V3, then):
//! ```text
//! dim           u64
//! per layer:
//!   cols          u64
//!   num_chunks    u64
//!   chunk_offsets (num_chunks + 1) x u32
//!   per chunk:
//!     storage     u32 (ChunkStorage::index; unknown codes rejected)
//!     ncols       u32 (must match the chunk-offset width)
//!     merged_slot u32
//!     scale       f32 (exactly 1.0 unless Int8; Int8: finite, > 0)
//!     5 array lengths  u64 each (row_indices, row_ptr, col_idx,
//!                      values, qvalues — cross-checked per layout)
//!     5 arrays,   each padded to a 64-byte file offset when nonempty
//!                 (padding bytes must be zero)
//!   merged store  u64 flag (0/1); if 1: num_spans u64, three
//!                 num_spans x u32 span columns, 4 array lengths u64,
//!                 then the 4 shared arrays (64-byte padded)
//! plan flag     u64 (1 = costed for MSCM, 2 = baseline; a V4 file
//!               MUST carry a plan — 0 is rejected)
//! plan          per-layer rows, same encoding as V3
//! (end)         trailing bytes are rejected
//! ```
//! V4 carries no CSC section: loaders install an empty CSC stub per
//! layer ([`crate::tree::Layer::csc_is_stub`]) and
//! [`crate::inference::InferenceEngine::new_with_plan`] rebuilds real
//! columns only when the baseline algo needs them. Hash row maps are
//! always rebuilt on the heap (they are pointer-y side indices, not
//! flat arrays).
//!
//! A shard file is also the deployment unit of cross-process serving:
//! `repro shard-host --shard <file>` loads exactly one of these (stored
//! plan honored) and serves it over the [`super::wire`] protocol to a
//! [`super::RemoteShardedCoordinator`]. Setting `MSCM_FORCE_MMAP=1`
//! routes every V4 [`load_shard`] through the memory-mapped path.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use super::partition::{ShardModel, ShardSpec};
use crate::inference::plan::{KernelPlan, LayerPlan};
use crate::inference::{IterationMethod, KernelTier, MatmulAlgo};
use crate::sparse::{Arr, Chunk, ChunkStorage, ChunkedMatrix, CscMatrix, MergedStore};
use crate::tree::{
    read_model_body, read_u32s, read_u64, write_model_body, write_u32s, write_u64, Layer, XmrModel,
};

/// Layout-resolved envelope magic ("MSCMXMR4") — mmap-servable.
const SHARD_MAGIC_V4: u64 = 0x4d53_434d_584d_5234;
/// Build-time envelope magic ("MSCMXMR3").
const SHARD_MAGIC: u64 = 0x4d53_434d_584d_5233;
/// Legacy envelope magic ("MSCMXMR2") — storage-less plans, still loaded.
const SHARD_MAGIC_V2: u64 = 0x4d53_434d_584d_5232;

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Writes the per-layer plan rows shared by the V3 and V4 envelopes.
fn write_plan(w: &mut impl Write, plan: &KernelPlan) -> io::Result<()> {
    for layer in &plan.layers {
        write_u64(w, layer.methods.len() as u64)?;
        // Kernel tier rides in the method code's high range
        // (+4 for SIMD) so all-scalar plans stay byte-identical
        // to the pre-tier encoding.
        let codes: Vec<u32> = layer
            .methods
            .iter()
            .zip(&layer.tiers)
            .map(|(m, t)| (m.index() + 4 * t.index()) as u32)
            .collect();
        write_u32s(w, &codes)?;
        let codes: Vec<u32> = layer.storage.iter().map(|s| s.index() as u32).collect();
        write_u32s(w, &codes)?;
    }
    Ok(())
}

/// Writes the spec header + layer offsets shared by every envelope
/// version (everything between the magic and the model body).
fn write_header(w: &mut impl Write, shard: &ShardModel) -> io::Result<()> {
    write_u64(w, shard.spec.shard_id as u64)?;
    write_u64(w, shard.spec.num_shards as u64)?;
    write_u64(w, shard.spec.root_lo as u64)?;
    write_u64(w, shard.spec.root_hi as u64)?;
    write_u64(w, shard.spec.label_offset)?;
    write_u64(w, shard.spec.num_labels)?;
    write_u64(w, shard.layer_offsets.len() as u64)?;
    write_u32s(w, &shard.layer_offsets)
}

/// Saves one shard (kernel plan included, when resolved) to `path`.
pub fn save_shard(shard: &ShardModel, path: impl AsRef<Path>) -> io::Result<()> {
    assert!(
        shard.model.layers.iter().all(|l| !l.csc_is_stub()),
        "a layout-resolved (MSCMXMR4-loaded) model has no CSC columns to \
         serialize — re-save it with save_shard_v4"
    );
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    write_u64(&mut w, SHARD_MAGIC)?;
    write_header(&mut w, shard)?;
    write_model_body(&mut w, &shard.model)?;
    match &shard.plan {
        None => write_u64(&mut w, 0)?,
        Some((algo, plan)) => {
            write_u64(
                &mut w,
                match algo {
                    MatmulAlgo::Mscm => 1,
                    MatmulAlgo::Baseline => 2,
                },
            )?;
            write_plan(&mut w, plan)?;
        }
    }
    w.flush()
}

// =====================================================================
// MSCMXMR4: the layout-resolved, mmap-servable envelope
// =====================================================================

/// Pads `buf` with zero bytes to the next 64-byte boundary.
fn pad64(buf: &mut Vec<u8>) {
    while buf.len() % 64 != 0 {
        buf.push(0);
    }
}

fn put_arr_u32(buf: &mut Vec<u8>, v: &[u32]) {
    if !v.is_empty() {
        pad64(buf);
        for &x in v {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn put_arr_u16(buf: &mut Vec<u8>, v: &[u16]) {
    if !v.is_empty() {
        pad64(buf);
        for &x in v {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn put_arr_f32(buf: &mut Vec<u8>, v: &[f32]) {
    if !v.is_empty() {
        pad64(buf);
        for &x in v {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn put_arr_u8(buf: &mut Vec<u8>, v: &[u8]) {
    if !v.is_empty() {
        pad64(buf);
        buf.extend_from_slice(v);
    }
}

/// Saves one shard to `path` in the layout-resolved `MSCMXMR4` envelope.
///
/// The shard **must** carry a resolved kernel plan (V4 files store the
/// *planned* physical layouts, quantization included; there is no
/// "unplanned" V4). The stored model is a clone with the plan's storage
/// applied, so the caller's shard is untouched and the on-disk arrays
/// are exactly what a host serves — over mmap, without rewriting a byte.
pub fn save_shard_v4(shard: &ShardModel, path: impl AsRef<Path>) -> io::Result<()> {
    let (algo, plan) = shard.plan.as_ref().ok_or_else(|| {
        invalid("an MSCMXMR4 shard stores a layout-resolved model: resolve a kernel plan first")
    })?;
    let mut model = shard.model.clone();
    for (li, layer) in model.layers.iter_mut().enumerate() {
        layer.chunked.apply_layout(plan.layer_storage(li));
    }
    let mut buf = Vec::new();
    write_u64(&mut buf, SHARD_MAGIC_V4)?;
    write_header(&mut buf, shard)?;
    write_u64(&mut buf, model.dim as u64)?;
    for layer in &model.layers {
        let cm = &layer.chunked;
        write_u64(&mut buf, cm.cols as u64)?;
        write_u64(&mut buf, cm.chunks.len() as u64)?;
        write_u32s(&mut buf, &cm.chunk_offsets)?;
        for chunk in &cm.chunks {
            write_u32s(&mut buf, &[chunk.storage.index() as u32])?;
            write_u32s(&mut buf, &[chunk.ncols])?;
            write_u32s(&mut buf, &[chunk.merged_slot])?;
            buf.extend_from_slice(&chunk.scale.to_le_bytes());
            write_u64(&mut buf, chunk.row_indices.len() as u64)?;
            write_u64(&mut buf, chunk.row_ptr.len() as u64)?;
            write_u64(&mut buf, chunk.col_idx.len() as u64)?;
            write_u64(&mut buf, chunk.values.len() as u64)?;
            write_u64(&mut buf, chunk.qvalues.len() as u64)?;
            put_arr_u32(&mut buf, &chunk.row_indices);
            put_arr_u32(&mut buf, &chunk.row_ptr);
            put_arr_u16(&mut buf, &chunk.col_idx);
            put_arr_f32(&mut buf, &chunk.values);
            put_arr_u8(&mut buf, &chunk.qvalues);
        }
        match &cm.merged {
            None => write_u64(&mut buf, 0)?,
            Some(store) => {
                write_u64(&mut buf, 1)?;
                let (rows_start, rows, ptr_start) = store.span_columns();
                write_u64(&mut buf, rows_start.len() as u64)?;
                write_u32s(&mut buf, &rows_start)?;
                write_u32s(&mut buf, &rows)?;
                write_u32s(&mut buf, &ptr_start)?;
                let (ri, rp, ci, va) = store.raw_arrays();
                write_u64(&mut buf, ri.len() as u64)?;
                write_u64(&mut buf, rp.len() as u64)?;
                write_u64(&mut buf, ci.len() as u64)?;
                write_u64(&mut buf, va.len() as u64)?;
                put_arr_u32(&mut buf, ri);
                put_arr_u32(&mut buf, rp);
                put_arr_u16(&mut buf, ci);
                put_arr_f32(&mut buf, va);
            }
        }
    }
    write_u64(
        &mut buf,
        match algo {
            MatmulAlgo::Mscm => 1,
            MatmulAlgo::Baseline => 2,
        },
    )?;
    write_plan(&mut buf, plan)?;
    std::fs::write(path, &buf)
}

/// Little-endian plain-old-data element of a V4 weight array.
trait FromLe: Copy + 'static {
    const SIZE: usize;
    fn from_le(b: &[u8]) -> Self;
}

impl FromLe for u8 {
    const SIZE: usize = 1;
    fn from_le(b: &[u8]) -> Self {
        b[0]
    }
}

impl FromLe for u16 {
    const SIZE: usize = 2;
    fn from_le(b: &[u8]) -> Self {
        u16::from_le_bytes(b.try_into().unwrap())
    }
}

impl FromLe for u32 {
    const SIZE: usize = 4;
    fn from_le(b: &[u8]) -> Self {
        u32::from_le_bytes(b.try_into().unwrap())
    }
}

impl FromLe for f32 {
    const SIZE: usize = 4;
    fn from_le(b: &[u8]) -> Self {
        f32::from_le_bytes(b.try_into().unwrap())
    }
}

/// One parser over a complete in-memory V4 image, shared by the heap
/// loader (copies every array into [`Arr::Owned`]) and the mmap loader
/// (`zero_copy`: borrows [`Arr::Mapped`] slices straight out of the
/// mapping — only constructed over little-endian process-lifetime maps).
struct BodyCursor<'a> {
    buf: &'a [u8],
    pos: usize,
    zero_copy: bool,
}

impl io::Read for BodyCursor<'_> {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        let n = out.len().min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl<'a> BodyCursor<'a> {
    fn bytes(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                io::Error::new(io::ErrorKind::UnexpectedEof, "truncated MSCMXMR4 shard file")
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u64v(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn u32v(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f32v(&mut self) -> io::Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    /// Small always-heap header array (chunk offsets, span columns).
    fn u32_vec(&mut self, n: usize) -> io::Result<Vec<u32>> {
        let nbytes = n
            .checked_mul(4)
            .ok_or_else(|| invalid("array length overflow"))?;
        Ok(self
            .bytes(nbytes)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Advances to the next 64-byte boundary, rejecting nonzero padding
    /// (corruption hiding in the slack would otherwise go unnoticed).
    fn align64(&mut self) -> io::Result<()> {
        let next = (self.pos + 63) & !63usize;
        let end = next.min(self.buf.len());
        if self.buf[self.pos..end].iter().any(|&b| b != 0) {
            return Err(invalid("nonzero alignment padding in MSCMXMR4 shard file"));
        }
        if next > self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "truncated MSCMXMR4 shard file",
            ));
        }
        self.pos = next;
        Ok(())
    }

    /// One 64-byte-aligned weight array of `len` elements (empty arrays
    /// are written without padding, mirroring the writer).
    fn arr<T: FromLe>(&mut self, len: usize) -> io::Result<Arr<T>> {
        if len == 0 {
            return Ok(Arr::default());
        }
        self.align64()?;
        let nbytes = len
            .checked_mul(T::SIZE)
            .ok_or_else(|| invalid("array length overflow"))?;
        let bytes = self.bytes(nbytes)?;
        if self.zero_copy {
            let ptr = bytes.as_ptr();
            // The 64-byte file offsets plus the page-aligned mapping
            // base guarantee this; reject rather than UB if a damaged
            // file ever slips through.
            if (ptr as usize) % std::mem::align_of::<T>() != 0 {
                return Err(invalid("misaligned weight array in mapped shard file"));
            }
            // Safety: the pointer spans `len` elements of a read-only,
            // never-unmapped (process-lifetime) PROT_READ mapping, and
            // `T` is plain little-endian data on a little-endian target.
            Ok(Arr::Mapped {
                ptr: ptr as *const T,
                len,
            })
        } else {
            Ok(Arr::Owned(
                bytes.chunks_exact(T::SIZE).map(T::from_le).collect(),
            ))
        }
    }
}

/// Header/body consistency checks shared by every envelope version.
fn validate_shard(shard: &ShardModel, depth: usize) -> io::Result<()> {
    let spec = &shard.spec;
    let model = &shard.model;
    if let Some((_, p)) = &shard.plan {
        if !p.matches(model) {
            return Err(invalid("stored kernel plan does not fit the model body"));
        }
    }
    if spec.shard_id >= spec.num_shards {
        return Err(invalid(format!(
            "shard id {} out of range for {} shards",
            spec.shard_id, spec.num_shards
        )));
    }
    if spec.root_hi < spec.root_lo {
        return Err(invalid("shard root-child range is inverted"));
    }
    if model.depth() != depth {
        return Err(invalid("shard header depth disagrees with model body"));
    }
    if model.num_labels() as u64 != spec.num_labels {
        return Err(invalid("shard label count disagrees with model body"));
    }
    if shard.layer_offsets.last().copied().unwrap_or(0) as u64 != spec.label_offset {
        return Err(invalid("shard label offset disagrees with layer offsets"));
    }
    if shard.layer_offsets.first().copied().unwrap_or(0) != spec.root_lo {
        return Err(invalid("shard root offset disagrees with layer offsets"));
    }
    if model.layers[0].num_nodes() as u64 != (spec.root_hi - spec.root_lo) as u64 {
        return Err(invalid("shard root-child range disagrees with model body"));
    }
    Ok(())
}

/// Parses a complete `MSCMXMR4` image (header validation included).
/// `zero_copy` must only be set over a little-endian, process-lifetime
/// mapping — the returned model then borrows its weight arrays from it.
fn read_shard_v4(buf: &[u8], zero_copy: bool, with_row_maps: bool) -> io::Result<ShardModel> {
    let mut c = BodyCursor {
        buf,
        pos: 0,
        zero_copy,
    };
    if c.u64v()? != SHARD_MAGIC_V4 {
        return Err(invalid("not an MSCMXMR4 shard file"));
    }
    let spec = ShardSpec {
        shard_id: c.u64v()? as u32,
        num_shards: c.u64v()? as u32,
        root_lo: c.u64v()? as u32,
        root_hi: c.u64v()? as u32,
        label_offset: c.u64v()?,
        num_labels: c.u64v()?,
    };
    let depth = c.u64v()? as usize;
    let layer_offsets = c.u32_vec(depth)?;
    let dim = c.u64v()? as usize;
    let mut layers = Vec::with_capacity(depth);
    for li in 0..depth {
        let cols = c.u64v()? as usize;
        let num_chunks = c.u64v()? as usize;
        let chunk_offsets = c.u32_vec(num_chunks.checked_add(1).ok_or_else(|| {
            invalid("array length overflow")
        })?)?;
        if num_chunks == 0
            || chunk_offsets[0] != 0
            || chunk_offsets[num_chunks] as usize != cols
            || chunk_offsets.windows(2).any(|w| w[1] < w[0])
        {
            return Err(invalid(format!(
                "layer {li}: chunk offsets do not tile the layer"
            )));
        }
        let mut chunks = Vec::with_capacity(num_chunks);
        for ci in 0..num_chunks {
            let tag = c.u32v()?;
            let storage = ChunkStorage::from_index(tag as usize)
                .ok_or_else(|| invalid(format!("layer {li}: unknown storage-layout code {tag}")))?;
            let ncols = c.u32v()?;
            let merged_slot = c.u32v()?;
            let scale = c.f32v()?;
            if ncols != chunk_offsets[ci + 1] - chunk_offsets[ci] {
                return Err(invalid(format!(
                    "layer {li} chunk {ci}: width disagrees with chunk offsets"
                )));
            }
            let rows = c.u64v()? as usize;
            let ptr = c.u64v()? as usize;
            let idx = c.u64v()? as usize;
            let val = c.u64v()? as usize;
            let qval = c.u64v()? as usize;
            let shape_ok = match storage {
                ChunkStorage::Merged => {
                    rows == 0 && ptr == 0 && idx == 0 && val == 0 && qval == 0
                }
                ChunkStorage::DenseRows => {
                    rows == 0 && ptr == dim + 1 && val == idx && qval == 0
                }
                ChunkStorage::Csc => ptr == rows + 1 && val == idx && qval == 0,
                ChunkStorage::F16 => ptr == rows + 1 && val == 0 && qval == 2 * idx,
                ChunkStorage::Int8 => ptr == rows + 1 && val == 0 && qval == idx,
            };
            if !shape_ok {
                return Err(invalid(format!(
                    "layer {li} chunk {ci}: array lengths do not fit the {} layout",
                    storage.short()
                )));
            }
            let scale_ok = if storage == ChunkStorage::Int8 {
                scale.is_finite() && scale > 0.0
            } else {
                scale == 1.0
            };
            if !scale_ok {
                return Err(invalid(format!(
                    "layer {li} chunk {ci}: bad quantization scale {scale}"
                )));
            }
            let row_indices = c.arr::<u32>(rows)?;
            let row_ptr = c.arr::<u32>(ptr)?;
            let col_idx = c.arr::<u16>(idx)?;
            let values = c.arr::<f32>(val)?;
            let qvalues = c.arr::<u8>(qval)?;
            chunks.push(Chunk {
                ncols,
                storage,
                row_indices,
                row_ptr,
                col_idx,
                values,
                qvalues,
                scale,
                row_map: None,
                merged_slot,
            });
        }
        let merged = match c.u64v()? {
            0 => None,
            1 => {
                let num_spans = c.u64v()? as usize;
                let rows_start = c.u32_vec(num_spans)?;
                let span_rows = c.u32_vec(num_spans)?;
                let ptr_start = c.u32_vec(num_spans)?;
                let spans: Vec<(u32, u32, u32)> = rows_start
                    .into_iter()
                    .zip(span_rows)
                    .zip(ptr_start)
                    .map(|((a, b), p)| (a, b, p))
                    .collect();
                let rl = c.u64v()? as usize;
                let pl = c.u64v()? as usize;
                let il = c.u64v()? as usize;
                let vl = c.u64v()? as usize;
                if il != vl {
                    return Err(invalid(format!(
                        "layer {li}: merged-store array lengths disagree"
                    )));
                }
                let ri = c.arr::<u32>(rl)?;
                let rp = c.arr::<u32>(pl)?;
                let cidx = c.arr::<u16>(il)?;
                let va = c.arr::<f32>(vl)?;
                Some(Box::new(MergedStore::from_raw(spans, ri, rp, cidx, va)))
            }
            v => return Err(invalid(format!("layer {li}: bad merged-store flag {v}"))),
        };
        let num_spans = merged.as_ref().map(|m| m.num_spans()).unwrap_or(0);
        for (ci, chunk) in chunks.iter().enumerate() {
            if chunk.storage == ChunkStorage::Merged && chunk.merged_slot as usize >= num_spans {
                return Err(invalid(format!(
                    "layer {li} chunk {ci}: merged span slot out of range"
                )));
            }
        }
        let chunked = ChunkedMatrix {
            rows: dim,
            cols,
            chunk_offsets,
            chunks,
            merged,
        };
        // V4 carries no CSC section: install the stub (right shape, no
        // entries). `InferenceEngine::new_with_plan` hydrates real
        // columns from the chunked side iff the baseline algo runs.
        let csc = CscMatrix {
            rows: dim,
            cols,
            indptr: vec![0; cols + 1],
            indices: Vec::new(),
            values: Vec::new(),
        };
        layers.push(Layer::from_parts(csc, chunked));
    }
    let mut model = XmrModel::new(dim, layers);
    if with_row_maps {
        // Side indices always live on the heap, even over a mapping.
        model.build_row_maps();
    }
    let algo = match c.u64v()? {
        1 => MatmulAlgo::Mscm,
        2 => MatmulAlgo::Baseline,
        v => {
            return Err(invalid(format!(
                "an MSCMXMR4 shard must carry a kernel plan (bad flag {v})"
            )))
        }
    };
    let plan = read_plan(&mut c, depth, true)?;
    if c.pos != c.buf.len() {
        return Err(invalid("trailing bytes after the shard payload"));
    }
    let shard = ShardModel {
        spec,
        layer_offsets,
        model,
        plan: Some((algo, plan)),
    };
    validate_shard(&shard, depth)?;
    Ok(shard)
}

/// A read-only, process-lifetime memory map of one `MSCMXMR4` shard
/// file — the dependency-free mmap wrapper the zero-copy loader builds
/// on. The mapping is intentionally never unmapped (models live for the
/// process), which is what makes handing out `'static` slices and
/// pointer-copy clones of [`Arr::Mapped`] sound.
pub struct MmapModel {
    base: *const u8,
    len: usize,
}

// Safety: the mapping is immutable (PROT_READ, MAP_PRIVATE), never
// written and never unmapped; sharing the base pointer across threads
// is reading shared immutable memory.
unsafe impl Send for MmapModel {}
unsafe impl Sync for MmapModel {}

#[cfg(all(unix, target_endian = "little"))]
mod mmap_sys {
    //! Raw `mmap(2)` binding — no libc crate in the dependency budget.
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
    }
}

impl MmapModel {
    /// Maps `path` read-only for the life of the process. Errors on
    /// empty files, OS mapping failures, and (at compile time via the
    /// heap fallback in [`load_shard_mmap`]) on targets without the
    /// mmap path (non-unix or big-endian).
    #[cfg(all(unix, target_endian = "little"))]
    pub fn open(path: impl AsRef<Path>) -> io::Result<Self> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            return Err(invalid("cannot map an empty shard file"));
        }
        let len = usize::try_from(len).map_err(|_| invalid("shard file exceeds address space"))?;
        let ptr = unsafe {
            mmap_sys::mmap(
                std::ptr::null_mut(),
                len,
                mmap_sys::PROT_READ,
                mmap_sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        // `file` closes here; the mapping survives the fd by POSIX.
        Ok(MmapModel {
            base: ptr as *const u8,
            len,
        })
    }

    /// Unsupported-target stub: the zero-copy path needs unix `mmap`
    /// and a little-endian layout; callers fall back to the heap parse.
    #[cfg(not(all(unix, target_endian = "little")))]
    pub fn open(_path: impl AsRef<Path>) -> io::Result<Self> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "memory-mapped shards need a little-endian unix target",
        ))
    }

    /// The mapped file image. `'static` because the mapping is never
    /// torn down.
    pub fn bytes(&self) -> &'static [u8] {
        // Safety: base/len describe a live, never-unmapped PROT_READ
        // mapping.
        unsafe { std::slice::from_raw_parts(self.base, self.len) }
    }

    /// Size of the backing file image in bytes — what the OS pages in
    /// on demand instead of the heap holding it (the residency bound
    /// `rust/tests/quant.rs` pins the mmap path against).
    pub fn file_bytes(&self) -> u64 {
        self.len as u64
    }
}

/// Loads a `MSCMXMR4` shard through a read-only memory map: weight
/// arrays stay borrowed from the page cache ([`Arr::Mapped`]) and only
/// chunk/layer scaffolding (plus hash row maps, when requested) touches
/// the heap — hosts serve models larger than RAM with unchanged
/// kernels. On targets without the mmap path this transparently falls
/// back to the heap parse of the same bytes.
pub fn load_shard_mmap(path: impl AsRef<Path>, with_row_maps: bool) -> io::Result<ShardModel> {
    #[cfg(all(unix, target_endian = "little"))]
    {
        let map = MmapModel::open(&path)?;
        read_shard_v4(map.bytes(), true, with_row_maps)
    }
    #[cfg(not(all(unix, target_endian = "little")))]
    {
        let buf = std::fs::read(&path)?;
        read_shard_v4(&buf, false, with_row_maps)
    }
}

/// Whether `MSCM_FORCE_MMAP=1` routes V4 loads through the mapped path
/// (the CI leg that runs the whole suite over borrowed weight arrays).
fn force_mmap() -> bool {
    std::env::var("MSCM_FORCE_MMAP").map(|v| v == "1").unwrap_or(false)
}

/// Reads the trailing kernel-plan section (`depth` layer rows). V3 rows
/// carry method + storage codes; legacy V2 rows carry methods only and
/// read as all-[`ChunkStorage::Csc`].
fn read_plan(r: &mut impl Read, depth: usize, with_storage: bool) -> io::Result<KernelPlan> {
    let mut layers = Vec::with_capacity(depth);
    for li in 0..depth {
        let n = read_u64(r)? as usize;
        let codes = read_u32s(r, n)?;
        let mut methods = Vec::with_capacity(n);
        let mut tiers = Vec::with_capacity(n);
        for c in codes {
            if c >= 8 {
                return Err(invalid(format!(
                    "layer {li}: unknown iteration-method code {c}"
                )));
            }
            methods.push(IterationMethod::from_index(c as usize % 4).ok_or_else(|| {
                invalid(format!("layer {li}: unknown iteration-method code {c}"))
            })?);
            tiers.push(if c >= 4 {
                KernelTier::Simd
            } else {
                KernelTier::Scalar
            });
        }
        let storage = if with_storage {
            let codes = read_u32s(r, n)?;
            let mut storage = Vec::with_capacity(n);
            for c in codes {
                storage.push(ChunkStorage::from_index(c as usize).ok_or_else(|| {
                    invalid(format!("layer {li}: unknown storage-layout code {c}"))
                })?);
            }
            storage
        } else {
            vec![ChunkStorage::Csc; n]
        };
        layers.push(LayerPlan {
            methods,
            storage,
            tiers,
        });
    }
    Ok(KernelPlan { layers })
}

/// Loads one shard from `path` (hash row maps rebuilt when
/// `with_row_maps`), validating header/body consistency. Handles every
/// envelope version; `MSCMXMR4` files parse onto the heap by default
/// and through [`load_shard_mmap`] when `MSCM_FORCE_MMAP=1`.
pub fn load_shard(path: impl AsRef<Path>, with_row_maps: bool) -> io::Result<ShardModel> {
    let mut r = BufReader::new(std::fs::File::open(&path)?);
    let legacy = match read_u64(&mut r)? {
        SHARD_MAGIC_V4 => {
            drop(r);
            if force_mmap() {
                return load_shard_mmap(&path, with_row_maps);
            }
            let buf = std::fs::read(&path)?;
            return read_shard_v4(&buf, false, with_row_maps);
        }
        SHARD_MAGIC => false,
        SHARD_MAGIC_V2 => true,
        _ => return Err(invalid("not an MSCM-XMR shard file")),
    };
    let spec = ShardSpec {
        shard_id: read_u64(&mut r)? as u32,
        num_shards: read_u64(&mut r)? as u32,
        root_lo: read_u64(&mut r)? as u32,
        root_hi: read_u64(&mut r)? as u32,
        label_offset: read_u64(&mut r)?,
        num_labels: read_u64(&mut r)?,
    };
    let depth = read_u64(&mut r)? as usize;
    let layer_offsets = read_u32s(&mut r, depth)?;
    let model = read_model_body(&mut r, with_row_maps)?;
    let plan = match read_u64(&mut r) {
        // V2 shard files written before the planner end right after the
        // model body (same magic): treat them as carrying no plan. A V3
        // file always writes the flag, so EOF there is corruption.
        Err(e) if legacy && e.kind() == io::ErrorKind::UnexpectedEof => None,
        Err(e) => return Err(e),
        Ok(0) => None,
        Ok(1) => Some((MatmulAlgo::Mscm, read_plan(&mut r, depth, !legacy)?)),
        Ok(2) => Some((MatmulAlgo::Baseline, read_plan(&mut r, depth, !legacy)?)),
        Ok(v) => return Err(invalid(format!("bad plan-presence flag {v}"))),
    };
    if !legacy {
        // Strict V3 parse: the plan section is the end of the file.
        let mut probe = [0u8; 1];
        if r.read(&mut probe)? != 0 {
            return Err(invalid("trailing bytes after the shard payload"));
        }
    }
    let shard = ShardModel {
        spec,
        layer_offsets,
        model,
        plan,
    };
    validate_shard(&shard, depth)?;
    Ok(shard)
}

/// Canonical file name of shard `id` in an `num_shards`-way partition.
pub fn shard_file_name(dir: impl AsRef<Path>, id: u32, num_shards: u32) -> PathBuf {
    dir.as_ref().join(format!("shard-{id:03}-of-{num_shards:03}.bin"))
}

/// Saves every shard of a partition under `dir` (created if missing)
/// with canonical names; returns the written paths.
pub fn save_shards(shards: &[ShardModel], dir: impl AsRef<Path>) -> io::Result<Vec<PathBuf>> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(shards.len());
    for s in shards {
        let path = shard_file_name(dir, s.spec.shard_id, s.spec.num_shards);
        save_shard(s, &path)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Loads a complete partition from `dir`: every `shard-*.bin`, sorted by
/// shard id, validated to be one consistent, gap-free partition.
pub fn load_shards(dir: impl AsRef<Path>, with_row_maps: bool) -> io::Result<Vec<ShardModel>> {
    let dir = dir.as_ref();
    let mut shards = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("shard-") && name.ends_with(".bin") {
            shards.push(load_shard(&path, with_row_maps)?);
        }
    }
    if shards.is_empty() {
        return Err(invalid(format!("no shard-*.bin files in {}", dir.display())));
    }
    shards.sort_by_key(|s| s.spec.shard_id);
    let num_shards = shards[0].spec.num_shards;
    if shards.len() as u64 != num_shards as u64 {
        return Err(invalid(format!(
            "incomplete partition: found {} of {} shards",
            shards.len(),
            num_shards
        )));
    }
    let mut next_root = 0u32;
    let mut next_label = 0u64;
    // Every layer's column ranges must tile contiguously across shards —
    // this is what catches shard files mixed from different partitions
    // (or different trainings) that happen to agree on the root split.
    let depth = shards[0].model.depth();
    let mut next_cols = vec![0u32; depth];
    for (i, s) in shards.iter().enumerate() {
        if s.spec.shard_id != i as u32 || s.spec.num_shards != num_shards {
            return Err(invalid("duplicate or mismatched shard ids"));
        }
        if s.spec.root_lo != next_root || s.spec.label_offset != next_label {
            return Err(invalid(format!("shard {i} is not contiguous with its predecessor")));
        }
        if s.model.depth() != depth {
            return Err(invalid(format!("shard {i} depth disagrees with shard 0")));
        }
        for (l, nc) in next_cols.iter_mut().enumerate() {
            if s.layer_offsets[l] != *nc {
                return Err(invalid(format!(
                    "shard {i} layer {l} columns are not contiguous with its predecessor"
                )));
            }
            *nc += s.model.layers[l].num_nodes() as u32;
        }
        next_root = s.spec.root_hi;
        next_label += s.spec.num_labels;
    }
    Ok(shards)
}

#[cfg(test)]
mod tests {
    use super::super::partition::partition;
    use super::*;
    use crate::tree::test_util::tiny_model;

    #[test]
    fn shard_save_load_round_trip() {
        let m = tiny_model(20, 4, 3, 21);
        let shards = partition(&m, 3);
        let dir = crate::util::temp_dir("shard-io");
        let paths = save_shards(&shards, &dir).unwrap();
        assert_eq!(paths.len(), 3);
        let loaded = load_shards(&dir, true).unwrap();
        assert_eq!(loaded.len(), shards.len());
        for (a, b) in shards.iter().zip(&loaded) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.layer_offsets, b.layer_offsets);
            assert_eq!(a.model.dim, b.model.dim);
            for (la, lb) in a.model.layers.iter().zip(&b.model.layers) {
                assert_eq!(la.csc, lb.csc);
                assert_eq!(la.chunked.chunk_offsets, lb.chunked.chunk_offsets);
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn plan_round_trips_in_envelope() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(20, 4, 3, 22);
        let mut shards = partition(&m, 2);
        shards[0].plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
        // shard 1 stays unplanned: mixed directories must round-trip too
        let dir = crate::util::temp_dir("shard-io-plan");
        save_shards(&shards, &dir).unwrap();
        let loaded = load_shards(&dir, false).unwrap();
        assert!(loaded[0].plan.is_some());
        assert_eq!(loaded[0].plan, shards[0].plan);
        assert!(loaded[1].plan.is_none());
        let (algo, plan) = loaded[0].plan.as_ref().unwrap();
        assert_eq!(*algo, MatmulAlgo::Mscm);
        assert!(plan.matches(&loaded[0].model));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn baseline_costed_plan_keeps_its_algo_tag() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(16, 3, 2, 4);
        let mut shards = partition(&m, 2);
        for s in &mut shards {
            s.plan_auto(MatmulAlgo::Baseline, &PlannerConfig::default());
        }
        let dir = crate::util::temp_dir("shard-io-plan-algo");
        save_shards(&shards, &dir).unwrap();
        for s in load_shards(&dir, false).unwrap() {
            assert_eq!(s.plan.as_ref().unwrap().0, MatmulAlgo::Baseline);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pre_planner_v2_shard_files_still_load() {
        // A V2 file written before the plan section existed ends right
        // after the model body; patching the magic down to V2 and
        // chopping the trailing flag off a fresh plan-less file
        // reproduces that layout exactly.
        let m = tiny_model(16, 3, 2, 8);
        let shards = partition(&m, 2);
        let dir = crate::util::temp_dir("shard-io-preplan");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        full[0] = 0x32; // LE magic: "…MXR3" -> "…MXR2"
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        let loaded = load_shard(&path, false).unwrap();
        assert!(loaded.plan.is_none());
        assert_eq!(loaded.spec, shards[0].spec);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_v3_shard_file_is_rejected() {
        // V3 always writes the plan-presence flag; a file cut at the end
        // of the model body is corruption, not a pre-planner file.
        let m = tiny_model(16, 3, 2, 8);
        let shards = partition(&m, 2);
        let dir = crate::util::temp_dir("shard-io-trunc");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        assert!(load_shard(&path, false).is_err());
        // ... and so are trailing bytes after a complete payload.
        let mut padded = full.clone();
        padded.push(0);
        std::fs::write(&path, &padded).unwrap();
        assert!(load_shard(&path, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn storage_layouts_round_trip_in_envelope() {
        use crate::inference::{IterationMethod, KernelPlan};
        let m = tiny_model(20, 4, 3, 23);
        let mut shards = partition(&m, 2);
        // A hand-mixed layout: merged run up top, dense rows at the
        // bottom — exercises every storage code in one file.
        for sh in &mut shards {
            let mut plan = KernelPlan::uniform(&sh.model, IterationMethod::BinarySearch);
            for l in &mut plan.layers {
                let n = l.storage.len();
                if n >= 2 {
                    l.storage[0] = ChunkStorage::Merged;
                    l.storage[1] = ChunkStorage::Merged;
                }
                if n >= 3 {
                    l.storage[n - 1] = ChunkStorage::DenseRows;
                }
            }
            sh.plan = Some((MatmulAlgo::Mscm, plan));
        }
        let dir = crate::util::temp_dir("shard-io-layouts");
        save_shards(&shards, &dir).unwrap();
        let loaded = load_shards(&dir, false).unwrap();
        for (a, b) in shards.iter().zip(&loaded) {
            assert_eq!(a.plan, b.plan, "shard {}", a.spec.shard_id);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn simd_tiers_round_trip_in_envelope() {
        use crate::inference::{IterationMethod, KernelPlan};
        let m = tiny_model(20, 4, 3, 24);
        let mut shards = partition(&m, 2);
        // A hand-mixed tier assignment: first chunk of every layer SIMD,
        // the rest scalar — exercises both halves of the code range.
        for sh in &mut shards {
            let mut plan = KernelPlan::uniform(&sh.model, IterationMethod::MarchingPointers);
            for l in &mut plan.layers {
                l.tiers[0] = KernelTier::Simd;
            }
            sh.plan = Some((MatmulAlgo::Mscm, plan));
        }
        let dir = crate::util::temp_dir("shard-io-tiers");
        save_shards(&shards, &dir).unwrap();
        let loaded = load_shards(&dir, false).unwrap();
        for (a, b) in shards.iter().zip(&loaded) {
            assert_eq!(a.plan, b.plan, "shard {}", a.spec.shard_id);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_method_code_is_rejected() {
        // Method codes 0–7 are the tier-folded range; 8+ must be
        // rejected, not wrapped around.
        use crate::inference::{IterationMethod, KernelPlan};
        let m = tiny_model(16, 3, 2, 4);
        let mut shards = partition(&m, 2);
        let plan = KernelPlan::uniform(&shards[0].model, IterationMethod::MarchingPointers);
        let nc_bottom = plan.layers.last().unwrap().methods.len();
        shards[0].plan = Some((MatmulAlgo::Mscm, plan));
        let dir = crate::util::temp_dir("shard-io-badmethod");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        // The bottom layer's plan row is methods then storage (u32 LE
        // each): the last method code sits nc_bottom u32s from the end.
        let off = full.len() - 4 * (nc_bottom + 1);
        full[off] = 8;
        std::fs::write(&path, &full).unwrap();
        let err = load_shard(&path, false).unwrap_err();
        assert!(err.to_string().contains("iteration-method"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_storage_code_is_rejected() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(16, 3, 2, 4);
        let mut shards = partition(&m, 2);
        shards[0].plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
        let dir = crate::util::temp_dir("shard-io-badcode");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        // The file tail is the bottom layer's storage codes (u32 LE).
        let n = full.len();
        full[n - 4] = 0xEE;
        std::fs::write(&path, &full).unwrap();
        let err = load_shard(&path, false).unwrap_err();
        assert!(err.to_string().contains("storage-layout"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn incomplete_partition_rejected() {
        let m = tiny_model(16, 4, 2, 5);
        let shards = partition(&m, 4);
        let dir = crate::util::temp_dir("shard-io-missing");
        save_shards(&shards, &dir).unwrap();
        std::fs::remove_file(shard_file_name(&dir, 2, 4)).unwrap();
        let err = load_shards(&dir, false).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn v4_round_trip_heap_and_mmap() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(20, 4, 3, 25);
        let mut shards = partition(&m, 2);
        for s in &mut shards {
            s.plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
        }
        let dir = crate::util::temp_dir("shard-io-v4");
        std::fs::create_dir_all(&dir).unwrap();
        for s in &shards {
            let path = shard_file_name(&dir, s.spec.shard_id, s.spec.num_shards);
            save_shard_v4(s, &path).unwrap();
            let heap = load_shard(&path, true).unwrap();
            assert_eq!(heap.spec, s.spec);
            assert_eq!(heap.plan, s.plan);
            let (_, plan) = heap.plan.as_ref().unwrap();
            for (li, layer) in heap.model.layers.iter().enumerate() {
                // no CSC section in a V4 file: the stub stands in
                assert_eq!(layer.csc.nnz(), 0);
                for (c, chunk) in layer.chunked.chunks.iter().enumerate() {
                    assert_eq!(chunk.storage, plan.layer_storage(li)[c], "layer {li} chunk {c}");
                }
            }
            // the mapped load parses the same bytes to the same model
            let mapped = load_shard_mmap(&path, true).unwrap();
            assert_eq!(mapped.spec, heap.spec);
            assert_eq!(mapped.plan, heap.plan);
            for (la, lb) in mapped.model.layers.iter().zip(&heap.model.layers) {
                assert_eq!(la.chunked.chunk_offsets, lb.chunked.chunk_offsets);
                for (ca, cb) in la.chunked.chunks.iter().zip(&lb.chunked.chunks) {
                    assert_eq!(ca.storage, cb.storage);
                    assert_eq!(ca.ncols, cb.ncols);
                    assert_eq!(ca.row_indices, cb.row_indices);
                    assert_eq!(ca.row_ptr, cb.row_ptr);
                    assert_eq!(ca.col_idx, cb.col_idx);
                    assert_eq!(ca.values, cb.values);
                    assert_eq!(ca.qvalues, cb.qvalues);
                    assert_eq!(ca.scale, cb.scale);
                }
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn v4_requires_a_plan() {
        let m = tiny_model(16, 3, 2, 26);
        let shards = partition(&m, 2);
        let dir = crate::util::temp_dir("shard-io-v4-noplan");
        std::fs::create_dir_all(&dir).unwrap();
        let err = save_shard_v4(&shards[0], dir.join("s.bin")).unwrap_err();
        assert!(err.to_string().contains("kernel plan"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn model_file_rejected_as_shard() {
        let m = tiny_model(16, 2, 2, 5);
        let dir = crate::util::temp_dir("shard-io-magic");
        let path = dir.join("model.bin");
        crate::tree::save_model(&m, &path).unwrap();
        assert!(load_shard(&path, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
