//! Shard serialization: a versioned envelope around the [`crate::tree`]
//! model body.
//!
//! Current format (`MSCMXMR3`, little-endian):
//! ```text
//! magic         u64  = 0x4d53_434d_584d_5233 ("MSCMXMR3")
//! shard_id      u64
//! num_shards    u64
//! root_lo       u64   global root-child range [root_lo, root_hi)
//! root_hi       u64
//! label_offset  u64   global label id of local label 0
//! num_labels    u64
//! depth         u64
//! layer_offsets depth x u32   global column start per layer
//! model body    (identical to the MSCMXMR1 payload after its magic)
//! has_plan      u64  (0 = none; 1 = plan costed for MSCM; 2 = plan
//!                     costed for the baseline algo; mandatory — a
//!                     truncated V3 file is rejected)
//! plan          if has_plan: per layer, num_chunks u64 then
//!               num_chunks x u32 method codes, then num_chunks x u32
//!               storage codes (ChunkStorage::index)
//! (end)         trailing bytes are rejected
//! ```
//! A method code folds the chunk's kernel tier into the high range:
//! `IterationMethod::index` (0–3) for scalar chunks,
//! `IterationMethod::index + 4` (4–7) for SIMD-planned chunks; codes ≥ 8
//! are rejected. An all-scalar plan therefore writes codes 0–3 — byte
//! for byte what pre-tier writers produced — and pre-tier readers only
//! choke on files that actually carry SIMD tiers.
//! The body is read/written by the same codec as whole models, so format
//! evolution stays in one place. The trailing kernel-plan section lets a
//! planned (and possibly timing-calibrated) model load and serve without
//! re-planning — plans are per-shard, over the shard's own chunks, and
//! since `MSCMXMR3` they carry the per-chunk **storage layout**
//! ([`ChunkStorage`]) the engine applies at construction.
//!
//! Legacy `MSCMXMR2` files (magic `…5232`) still load: their plan
//! section has no storage codes (every chunk reads as
//! [`ChunkStorage::Csc`]), and pre-planner files that end right after
//! the model body read as plan-less. Both legacy leniencies are V2-only;
//! V3 parsing is strict (fuzzed in `rust/tests/format.rs`).
//!
//! A shard file is also the deployment unit of cross-process serving:
//! `repro shard-host --shard <file>` loads exactly one of these (stored
//! plan honored) and serves it over the [`super::wire`] protocol to a
//! [`super::RemoteShardedCoordinator`].

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use super::partition::{ShardModel, ShardSpec};
use crate::inference::plan::{KernelPlan, LayerPlan};
use crate::inference::{IterationMethod, KernelTier, MatmulAlgo};
use crate::sparse::ChunkStorage;
use crate::tree::{read_model_body, read_u32s, read_u64, write_model_body, write_u32s, write_u64};

/// Current envelope magic ("MSCMXMR3").
const SHARD_MAGIC: u64 = 0x4d53_434d_584d_5233;
/// Legacy envelope magic ("MSCMXMR2") — storage-less plans, still loaded.
const SHARD_MAGIC_V2: u64 = 0x4d53_434d_584d_5232;

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Saves one shard (kernel plan included, when resolved) to `path`.
pub fn save_shard(shard: &ShardModel, path: impl AsRef<Path>) -> io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    write_u64(&mut w, SHARD_MAGIC)?;
    write_u64(&mut w, shard.spec.shard_id as u64)?;
    write_u64(&mut w, shard.spec.num_shards as u64)?;
    write_u64(&mut w, shard.spec.root_lo as u64)?;
    write_u64(&mut w, shard.spec.root_hi as u64)?;
    write_u64(&mut w, shard.spec.label_offset)?;
    write_u64(&mut w, shard.spec.num_labels)?;
    write_u64(&mut w, shard.layer_offsets.len() as u64)?;
    write_u32s(&mut w, &shard.layer_offsets)?;
    write_model_body(&mut w, &shard.model)?;
    match &shard.plan {
        None => write_u64(&mut w, 0)?,
        Some((algo, plan)) => {
            write_u64(
                &mut w,
                match algo {
                    MatmulAlgo::Mscm => 1,
                    MatmulAlgo::Baseline => 2,
                },
            )?;
            for layer in &plan.layers {
                write_u64(&mut w, layer.methods.len() as u64)?;
                // Kernel tier rides in the method code's high range
                // (+4 for SIMD) so all-scalar plans stay byte-identical
                // to the pre-tier encoding.
                let codes: Vec<u32> = layer
                    .methods
                    .iter()
                    .zip(&layer.tiers)
                    .map(|(m, t)| (m.index() + 4 * t.index()) as u32)
                    .collect();
                write_u32s(&mut w, &codes)?;
                let codes: Vec<u32> = layer.storage.iter().map(|s| s.index() as u32).collect();
                write_u32s(&mut w, &codes)?;
            }
        }
    }
    w.flush()
}

/// Reads the trailing kernel-plan section (`depth` layer rows). V3 rows
/// carry method + storage codes; legacy V2 rows carry methods only and
/// read as all-[`ChunkStorage::Csc`].
fn read_plan(r: &mut impl Read, depth: usize, with_storage: bool) -> io::Result<KernelPlan> {
    let mut layers = Vec::with_capacity(depth);
    for li in 0..depth {
        let n = read_u64(r)? as usize;
        let codes = read_u32s(r, n)?;
        let mut methods = Vec::with_capacity(n);
        let mut tiers = Vec::with_capacity(n);
        for c in codes {
            if c >= 8 {
                return Err(invalid(format!(
                    "layer {li}: unknown iteration-method code {c}"
                )));
            }
            methods.push(IterationMethod::from_index(c as usize % 4).ok_or_else(|| {
                invalid(format!("layer {li}: unknown iteration-method code {c}"))
            })?);
            tiers.push(if c >= 4 {
                KernelTier::Simd
            } else {
                KernelTier::Scalar
            });
        }
        let storage = if with_storage {
            let codes = read_u32s(r, n)?;
            let mut storage = Vec::with_capacity(n);
            for c in codes {
                storage.push(ChunkStorage::from_index(c as usize).ok_or_else(|| {
                    invalid(format!("layer {li}: unknown storage-layout code {c}"))
                })?);
            }
            storage
        } else {
            vec![ChunkStorage::Csc; n]
        };
        layers.push(LayerPlan {
            methods,
            storage,
            tiers,
        });
    }
    Ok(KernelPlan { layers })
}

/// Loads one shard from `path` (hash row maps rebuilt when
/// `with_row_maps`), validating header/body consistency.
pub fn load_shard(path: impl AsRef<Path>, with_row_maps: bool) -> io::Result<ShardModel> {
    let mut r = BufReader::new(std::fs::File::open(&path)?);
    let legacy = match read_u64(&mut r)? {
        SHARD_MAGIC => false,
        SHARD_MAGIC_V2 => true,
        _ => return Err(invalid("not an MSCM-XMR shard file")),
    };
    let spec = ShardSpec {
        shard_id: read_u64(&mut r)? as u32,
        num_shards: read_u64(&mut r)? as u32,
        root_lo: read_u64(&mut r)? as u32,
        root_hi: read_u64(&mut r)? as u32,
        label_offset: read_u64(&mut r)?,
        num_labels: read_u64(&mut r)?,
    };
    let depth = read_u64(&mut r)? as usize;
    let layer_offsets = read_u32s(&mut r, depth)?;
    let model = read_model_body(&mut r, with_row_maps)?;
    let plan = match read_u64(&mut r) {
        // V2 shard files written before the planner end right after the
        // model body (same magic): treat them as carrying no plan. A V3
        // file always writes the flag, so EOF there is corruption.
        Err(e) if legacy && e.kind() == io::ErrorKind::UnexpectedEof => None,
        Err(e) => return Err(e),
        Ok(0) => None,
        Ok(1) => Some((MatmulAlgo::Mscm, read_plan(&mut r, depth, !legacy)?)),
        Ok(2) => Some((MatmulAlgo::Baseline, read_plan(&mut r, depth, !legacy)?)),
        Ok(v) => return Err(invalid(format!("bad plan-presence flag {v}"))),
    };
    if !legacy {
        // Strict V3 parse: the plan section is the end of the file.
        let mut probe = [0u8; 1];
        if r.read(&mut probe)? != 0 {
            return Err(invalid("trailing bytes after the shard payload"));
        }
    }
    if let Some((_, p)) = &plan {
        if !p.matches(&model) {
            return Err(invalid("stored kernel plan does not fit the model body"));
        }
    }
    if spec.shard_id >= spec.num_shards {
        return Err(invalid(format!(
            "shard id {} out of range for {} shards",
            spec.shard_id, spec.num_shards
        )));
    }
    if spec.root_hi < spec.root_lo {
        return Err(invalid("shard root-child range is inverted"));
    }
    if model.depth() != depth {
        return Err(invalid("shard header depth disagrees with model body"));
    }
    if model.num_labels() as u64 != spec.num_labels {
        return Err(invalid("shard label count disagrees with model body"));
    }
    if layer_offsets.last().copied().unwrap_or(0) as u64 != spec.label_offset {
        return Err(invalid("shard label offset disagrees with layer offsets"));
    }
    if layer_offsets.first().copied().unwrap_or(0) != spec.root_lo {
        return Err(invalid("shard root offset disagrees with layer offsets"));
    }
    if model.layers[0].num_nodes() as u64 != (spec.root_hi - spec.root_lo) as u64 {
        return Err(invalid("shard root-child range disagrees with model body"));
    }
    Ok(ShardModel {
        spec,
        layer_offsets,
        model,
        plan,
    })
}

/// Canonical file name of shard `id` in an `num_shards`-way partition.
pub fn shard_file_name(dir: impl AsRef<Path>, id: u32, num_shards: u32) -> PathBuf {
    dir.as_ref().join(format!("shard-{id:03}-of-{num_shards:03}.bin"))
}

/// Saves every shard of a partition under `dir` (created if missing)
/// with canonical names; returns the written paths.
pub fn save_shards(shards: &[ShardModel], dir: impl AsRef<Path>) -> io::Result<Vec<PathBuf>> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(shards.len());
    for s in shards {
        let path = shard_file_name(dir, s.spec.shard_id, s.spec.num_shards);
        save_shard(s, &path)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Loads a complete partition from `dir`: every `shard-*.bin`, sorted by
/// shard id, validated to be one consistent, gap-free partition.
pub fn load_shards(dir: impl AsRef<Path>, with_row_maps: bool) -> io::Result<Vec<ShardModel>> {
    let dir = dir.as_ref();
    let mut shards = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("shard-") && name.ends_with(".bin") {
            shards.push(load_shard(&path, with_row_maps)?);
        }
    }
    if shards.is_empty() {
        return Err(invalid(format!("no shard-*.bin files in {}", dir.display())));
    }
    shards.sort_by_key(|s| s.spec.shard_id);
    let num_shards = shards[0].spec.num_shards;
    if shards.len() as u64 != num_shards as u64 {
        return Err(invalid(format!(
            "incomplete partition: found {} of {} shards",
            shards.len(),
            num_shards
        )));
    }
    let mut next_root = 0u32;
    let mut next_label = 0u64;
    // Every layer's column ranges must tile contiguously across shards —
    // this is what catches shard files mixed from different partitions
    // (or different trainings) that happen to agree on the root split.
    let depth = shards[0].model.depth();
    let mut next_cols = vec![0u32; depth];
    for (i, s) in shards.iter().enumerate() {
        if s.spec.shard_id != i as u32 || s.spec.num_shards != num_shards {
            return Err(invalid("duplicate or mismatched shard ids"));
        }
        if s.spec.root_lo != next_root || s.spec.label_offset != next_label {
            return Err(invalid(format!("shard {i} is not contiguous with its predecessor")));
        }
        if s.model.depth() != depth {
            return Err(invalid(format!("shard {i} depth disagrees with shard 0")));
        }
        for (l, nc) in next_cols.iter_mut().enumerate() {
            if s.layer_offsets[l] != *nc {
                return Err(invalid(format!(
                    "shard {i} layer {l} columns are not contiguous with its predecessor"
                )));
            }
            *nc += s.model.layers[l].num_nodes() as u32;
        }
        next_root = s.spec.root_hi;
        next_label += s.spec.num_labels;
    }
    Ok(shards)
}

#[cfg(test)]
mod tests {
    use super::super::partition::partition;
    use super::*;
    use crate::tree::test_util::tiny_model;

    #[test]
    fn shard_save_load_round_trip() {
        let m = tiny_model(20, 4, 3, 21);
        let shards = partition(&m, 3);
        let dir = crate::util::temp_dir("shard-io");
        let paths = save_shards(&shards, &dir).unwrap();
        assert_eq!(paths.len(), 3);
        let loaded = load_shards(&dir, true).unwrap();
        assert_eq!(loaded.len(), shards.len());
        for (a, b) in shards.iter().zip(&loaded) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.layer_offsets, b.layer_offsets);
            assert_eq!(a.model.dim, b.model.dim);
            for (la, lb) in a.model.layers.iter().zip(&b.model.layers) {
                assert_eq!(la.csc, lb.csc);
                assert_eq!(la.chunked.chunk_offsets, lb.chunked.chunk_offsets);
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn plan_round_trips_in_envelope() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(20, 4, 3, 22);
        let mut shards = partition(&m, 2);
        shards[0].plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
        // shard 1 stays unplanned: mixed directories must round-trip too
        let dir = crate::util::temp_dir("shard-io-plan");
        save_shards(&shards, &dir).unwrap();
        let loaded = load_shards(&dir, false).unwrap();
        assert!(loaded[0].plan.is_some());
        assert_eq!(loaded[0].plan, shards[0].plan);
        assert!(loaded[1].plan.is_none());
        let (algo, plan) = loaded[0].plan.as_ref().unwrap();
        assert_eq!(*algo, MatmulAlgo::Mscm);
        assert!(plan.matches(&loaded[0].model));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn baseline_costed_plan_keeps_its_algo_tag() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(16, 3, 2, 4);
        let mut shards = partition(&m, 2);
        for s in &mut shards {
            s.plan_auto(MatmulAlgo::Baseline, &PlannerConfig::default());
        }
        let dir = crate::util::temp_dir("shard-io-plan-algo");
        save_shards(&shards, &dir).unwrap();
        for s in load_shards(&dir, false).unwrap() {
            assert_eq!(s.plan.as_ref().unwrap().0, MatmulAlgo::Baseline);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pre_planner_v2_shard_files_still_load() {
        // A V2 file written before the plan section existed ends right
        // after the model body; patching the magic down to V2 and
        // chopping the trailing flag off a fresh plan-less file
        // reproduces that layout exactly.
        let m = tiny_model(16, 3, 2, 8);
        let shards = partition(&m, 2);
        let dir = crate::util::temp_dir("shard-io-preplan");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        full[0] = 0x32; // LE magic: "…MXR3" -> "…MXR2"
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        let loaded = load_shard(&path, false).unwrap();
        assert!(loaded.plan.is_none());
        assert_eq!(loaded.spec, shards[0].spec);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_v3_shard_file_is_rejected() {
        // V3 always writes the plan-presence flag; a file cut at the end
        // of the model body is corruption, not a pre-planner file.
        let m = tiny_model(16, 3, 2, 8);
        let shards = partition(&m, 2);
        let dir = crate::util::temp_dir("shard-io-trunc");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        assert!(load_shard(&path, false).is_err());
        // ... and so are trailing bytes after a complete payload.
        let mut padded = full.clone();
        padded.push(0);
        std::fs::write(&path, &padded).unwrap();
        assert!(load_shard(&path, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn storage_layouts_round_trip_in_envelope() {
        use crate::inference::{IterationMethod, KernelPlan};
        let m = tiny_model(20, 4, 3, 23);
        let mut shards = partition(&m, 2);
        // A hand-mixed layout: merged run up top, dense rows at the
        // bottom — exercises every storage code in one file.
        for sh in &mut shards {
            let mut plan = KernelPlan::uniform(&sh.model, IterationMethod::BinarySearch);
            for l in &mut plan.layers {
                let n = l.storage.len();
                if n >= 2 {
                    l.storage[0] = ChunkStorage::Merged;
                    l.storage[1] = ChunkStorage::Merged;
                }
                if n >= 3 {
                    l.storage[n - 1] = ChunkStorage::DenseRows;
                }
            }
            sh.plan = Some((MatmulAlgo::Mscm, plan));
        }
        let dir = crate::util::temp_dir("shard-io-layouts");
        save_shards(&shards, &dir).unwrap();
        let loaded = load_shards(&dir, false).unwrap();
        for (a, b) in shards.iter().zip(&loaded) {
            assert_eq!(a.plan, b.plan, "shard {}", a.spec.shard_id);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn simd_tiers_round_trip_in_envelope() {
        use crate::inference::{IterationMethod, KernelPlan};
        let m = tiny_model(20, 4, 3, 24);
        let mut shards = partition(&m, 2);
        // A hand-mixed tier assignment: first chunk of every layer SIMD,
        // the rest scalar — exercises both halves of the code range.
        for sh in &mut shards {
            let mut plan = KernelPlan::uniform(&sh.model, IterationMethod::MarchingPointers);
            for l in &mut plan.layers {
                l.tiers[0] = KernelTier::Simd;
            }
            sh.plan = Some((MatmulAlgo::Mscm, plan));
        }
        let dir = crate::util::temp_dir("shard-io-tiers");
        save_shards(&shards, &dir).unwrap();
        let loaded = load_shards(&dir, false).unwrap();
        for (a, b) in shards.iter().zip(&loaded) {
            assert_eq!(a.plan, b.plan, "shard {}", a.spec.shard_id);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_method_code_is_rejected() {
        // Method codes 0–7 are the tier-folded range; 8+ must be
        // rejected, not wrapped around.
        use crate::inference::{IterationMethod, KernelPlan};
        let m = tiny_model(16, 3, 2, 4);
        let mut shards = partition(&m, 2);
        let plan = KernelPlan::uniform(&shards[0].model, IterationMethod::MarchingPointers);
        let nc_bottom = plan.layers.last().unwrap().methods.len();
        shards[0].plan = Some((MatmulAlgo::Mscm, plan));
        let dir = crate::util::temp_dir("shard-io-badmethod");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        // The bottom layer's plan row is methods then storage (u32 LE
        // each): the last method code sits nc_bottom u32s from the end.
        let off = full.len() - 4 * (nc_bottom + 1);
        full[off] = 8;
        std::fs::write(&path, &full).unwrap();
        let err = load_shard(&path, false).unwrap_err();
        assert!(err.to_string().contains("iteration-method"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_storage_code_is_rejected() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(16, 3, 2, 4);
        let mut shards = partition(&m, 2);
        shards[0].plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
        let dir = crate::util::temp_dir("shard-io-badcode");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        // The file tail is the bottom layer's storage codes (u32 LE).
        let n = full.len();
        full[n - 4] = 0xEE;
        std::fs::write(&path, &full).unwrap();
        let err = load_shard(&path, false).unwrap_err();
        assert!(err.to_string().contains("storage-layout"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn incomplete_partition_rejected() {
        let m = tiny_model(16, 4, 2, 5);
        let shards = partition(&m, 4);
        let dir = crate::util::temp_dir("shard-io-missing");
        save_shards(&shards, &dir).unwrap();
        std::fs::remove_file(shard_file_name(&dir, 2, 4)).unwrap();
        let err = load_shards(&dir, false).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn model_file_rejected_as_shard() {
        let m = tiny_model(16, 2, 2, 5);
        let dir = crate::util::temp_dir("shard-io-magic");
        let path = dir.join("model.bin");
        crate::tree::save_model(&m, &path).unwrap();
        assert!(load_shard(&path, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
