//! Shard serialization: a versioned envelope around the [`crate::tree`]
//! model body.
//!
//! Format (little-endian):
//! ```text
//! magic         u64  = 0x4d53_434d_584d_5232 ("MSCMXMR2")
//! shard_id      u64
//! num_shards    u64
//! root_lo       u64   global root-child range [root_lo, root_hi)
//! root_hi       u64
//! label_offset  u64   global label id of local label 0
//! num_labels    u64
//! depth         u64
//! layer_offsets depth x u32   global column start per layer
//! model body    (identical to the MSCMXMR1 payload after its magic)
//! has_plan      u64  (0 = none; 1 = plan costed for MSCM; 2 = plan
//!                     costed for the baseline algo; absent in
//!                     pre-planner files — EOF here reads as "no plan")
//! plan          if has_plan: per layer, num_chunks u64 then
//!               num_chunks x u32 method codes (IterationMethod::index)
//! ```
//! The body is read/written by the same codec as whole models, so format
//! evolution stays in one place. The trailing kernel-plan section lets a
//! planned (and possibly timing-calibrated) model load and serve without
//! re-planning — plans are per-shard, over the shard's own chunks.
//!
//! A shard file is also the deployment unit of cross-process serving:
//! `repro shard-host --shard <file>` loads exactly one of these (stored
//! plan honored) and serves it over the [`super::wire`] protocol to a
//! [`super::RemoteShardedCoordinator`].

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use super::partition::{ShardModel, ShardSpec};
use crate::inference::plan::{KernelPlan, LayerPlan};
use crate::inference::{IterationMethod, MatmulAlgo};
use crate::tree::{read_model_body, read_u32s, read_u64, write_model_body, write_u32s, write_u64};

const SHARD_MAGIC: u64 = 0x4d53_434d_584d_5232;

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Saves one shard (kernel plan included, when resolved) to `path`.
pub fn save_shard(shard: &ShardModel, path: impl AsRef<Path>) -> io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    write_u64(&mut w, SHARD_MAGIC)?;
    write_u64(&mut w, shard.spec.shard_id as u64)?;
    write_u64(&mut w, shard.spec.num_shards as u64)?;
    write_u64(&mut w, shard.spec.root_lo as u64)?;
    write_u64(&mut w, shard.spec.root_hi as u64)?;
    write_u64(&mut w, shard.spec.label_offset)?;
    write_u64(&mut w, shard.spec.num_labels)?;
    write_u64(&mut w, shard.layer_offsets.len() as u64)?;
    write_u32s(&mut w, &shard.layer_offsets)?;
    write_model_body(&mut w, &shard.model)?;
    match &shard.plan {
        None => write_u64(&mut w, 0)?,
        Some((algo, plan)) => {
            write_u64(
                &mut w,
                match algo {
                    MatmulAlgo::Mscm => 1,
                    MatmulAlgo::Baseline => 2,
                },
            )?;
            for layer in &plan.layers {
                write_u64(&mut w, layer.methods.len() as u64)?;
                let codes: Vec<u32> = layer.methods.iter().map(|m| m.index() as u32).collect();
                write_u32s(&mut w, &codes)?;
            }
        }
    }
    w.flush()
}

/// Reads the trailing kernel-plan section (`depth` layer rows).
fn read_plan(r: &mut impl Read, depth: usize) -> io::Result<KernelPlan> {
    let mut layers = Vec::with_capacity(depth);
    for li in 0..depth {
        let n = read_u64(r)? as usize;
        let codes = read_u32s(r, n)?;
        let mut methods = Vec::with_capacity(n);
        for c in codes {
            methods.push(IterationMethod::from_index(c as usize).ok_or_else(|| {
                invalid(format!("layer {li}: unknown iteration-method code {c}"))
            })?);
        }
        layers.push(LayerPlan { methods });
    }
    Ok(KernelPlan { layers })
}

/// Loads one shard from `path` (hash row maps rebuilt when
/// `with_row_maps`), validating header/body consistency.
pub fn load_shard(path: impl AsRef<Path>, with_row_maps: bool) -> io::Result<ShardModel> {
    let mut r = BufReader::new(std::fs::File::open(&path)?);
    if read_u64(&mut r)? != SHARD_MAGIC {
        return Err(invalid("not an MSCM-XMR shard file"));
    }
    let spec = ShardSpec {
        shard_id: read_u64(&mut r)? as u32,
        num_shards: read_u64(&mut r)? as u32,
        root_lo: read_u64(&mut r)? as u32,
        root_hi: read_u64(&mut r)? as u32,
        label_offset: read_u64(&mut r)?,
        num_labels: read_u64(&mut r)?,
    };
    let depth = read_u64(&mut r)? as usize;
    let layer_offsets = read_u32s(&mut r, depth)?;
    let model = read_model_body(&mut r, with_row_maps)?;
    let plan = match read_u64(&mut r) {
        // Shard files written before the planner end right after the
        // model body (same magic): treat them as carrying no plan.
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => None,
        Err(e) => return Err(e),
        Ok(0) => None,
        Ok(1) => Some((MatmulAlgo::Mscm, read_plan(&mut r, depth)?)),
        Ok(2) => Some((MatmulAlgo::Baseline, read_plan(&mut r, depth)?)),
        Ok(v) => return Err(invalid(format!("bad plan-presence flag {v}"))),
    };
    if let Some((_, p)) = &plan {
        if !p.matches(&model) {
            return Err(invalid("stored kernel plan does not fit the model body"));
        }
    }
    if spec.shard_id >= spec.num_shards {
        return Err(invalid(format!(
            "shard id {} out of range for {} shards",
            spec.shard_id, spec.num_shards
        )));
    }
    if spec.root_hi < spec.root_lo {
        return Err(invalid("shard root-child range is inverted"));
    }
    if model.depth() != depth {
        return Err(invalid("shard header depth disagrees with model body"));
    }
    if model.num_labels() as u64 != spec.num_labels {
        return Err(invalid("shard label count disagrees with model body"));
    }
    if layer_offsets.last().copied().unwrap_or(0) as u64 != spec.label_offset {
        return Err(invalid("shard label offset disagrees with layer offsets"));
    }
    if layer_offsets.first().copied().unwrap_or(0) != spec.root_lo {
        return Err(invalid("shard root offset disagrees with layer offsets"));
    }
    if model.layers[0].num_nodes() as u64 != (spec.root_hi - spec.root_lo) as u64 {
        return Err(invalid("shard root-child range disagrees with model body"));
    }
    Ok(ShardModel {
        spec,
        layer_offsets,
        model,
        plan,
    })
}

/// Canonical file name of shard `id` in an `num_shards`-way partition.
pub fn shard_file_name(dir: impl AsRef<Path>, id: u32, num_shards: u32) -> PathBuf {
    dir.as_ref().join(format!("shard-{id:03}-of-{num_shards:03}.bin"))
}

/// Saves every shard of a partition under `dir` (created if missing)
/// with canonical names; returns the written paths.
pub fn save_shards(shards: &[ShardModel], dir: impl AsRef<Path>) -> io::Result<Vec<PathBuf>> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(shards.len());
    for s in shards {
        let path = shard_file_name(dir, s.spec.shard_id, s.spec.num_shards);
        save_shard(s, &path)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Loads a complete partition from `dir`: every `shard-*.bin`, sorted by
/// shard id, validated to be one consistent, gap-free partition.
pub fn load_shards(dir: impl AsRef<Path>, with_row_maps: bool) -> io::Result<Vec<ShardModel>> {
    let dir = dir.as_ref();
    let mut shards = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("shard-") && name.ends_with(".bin") {
            shards.push(load_shard(&path, with_row_maps)?);
        }
    }
    if shards.is_empty() {
        return Err(invalid(format!("no shard-*.bin files in {}", dir.display())));
    }
    shards.sort_by_key(|s| s.spec.shard_id);
    let num_shards = shards[0].spec.num_shards;
    if shards.len() as u64 != num_shards as u64 {
        return Err(invalid(format!(
            "incomplete partition: found {} of {} shards",
            shards.len(),
            num_shards
        )));
    }
    let mut next_root = 0u32;
    let mut next_label = 0u64;
    // Every layer's column ranges must tile contiguously across shards —
    // this is what catches shard files mixed from different partitions
    // (or different trainings) that happen to agree on the root split.
    let depth = shards[0].model.depth();
    let mut next_cols = vec![0u32; depth];
    for (i, s) in shards.iter().enumerate() {
        if s.spec.shard_id != i as u32 || s.spec.num_shards != num_shards {
            return Err(invalid("duplicate or mismatched shard ids"));
        }
        if s.spec.root_lo != next_root || s.spec.label_offset != next_label {
            return Err(invalid(format!("shard {i} is not contiguous with its predecessor")));
        }
        if s.model.depth() != depth {
            return Err(invalid(format!("shard {i} depth disagrees with shard 0")));
        }
        for (l, nc) in next_cols.iter_mut().enumerate() {
            if s.layer_offsets[l] != *nc {
                return Err(invalid(format!(
                    "shard {i} layer {l} columns are not contiguous with its predecessor"
                )));
            }
            *nc += s.model.layers[l].num_nodes() as u32;
        }
        next_root = s.spec.root_hi;
        next_label += s.spec.num_labels;
    }
    Ok(shards)
}

#[cfg(test)]
mod tests {
    use super::super::partition::partition;
    use super::*;
    use crate::tree::test_util::tiny_model;

    #[test]
    fn shard_save_load_round_trip() {
        let m = tiny_model(20, 4, 3, 21);
        let shards = partition(&m, 3);
        let dir = crate::util::temp_dir("shard-io");
        let paths = save_shards(&shards, &dir).unwrap();
        assert_eq!(paths.len(), 3);
        let loaded = load_shards(&dir, true).unwrap();
        assert_eq!(loaded.len(), shards.len());
        for (a, b) in shards.iter().zip(&loaded) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(a.layer_offsets, b.layer_offsets);
            assert_eq!(a.model.dim, b.model.dim);
            for (la, lb) in a.model.layers.iter().zip(&b.model.layers) {
                assert_eq!(la.csc, lb.csc);
                assert_eq!(la.chunked.chunk_offsets, lb.chunked.chunk_offsets);
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn plan_round_trips_in_envelope() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(20, 4, 3, 22);
        let mut shards = partition(&m, 2);
        shards[0].plan_auto(MatmulAlgo::Mscm, &PlannerConfig::default());
        // shard 1 stays unplanned: mixed directories must round-trip too
        let dir = crate::util::temp_dir("shard-io-plan");
        save_shards(&shards, &dir).unwrap();
        let loaded = load_shards(&dir, false).unwrap();
        assert!(loaded[0].plan.is_some());
        assert_eq!(loaded[0].plan, shards[0].plan);
        assert!(loaded[1].plan.is_none());
        let (algo, plan) = loaded[0].plan.as_ref().unwrap();
        assert_eq!(*algo, MatmulAlgo::Mscm);
        assert!(plan.matches(&loaded[0].model));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn baseline_costed_plan_keeps_its_algo_tag() {
        use crate::inference::PlannerConfig;
        let m = tiny_model(16, 3, 2, 4);
        let mut shards = partition(&m, 2);
        for s in &mut shards {
            s.plan_auto(MatmulAlgo::Baseline, &PlannerConfig::default());
        }
        let dir = crate::util::temp_dir("shard-io-plan-algo");
        save_shards(&shards, &dir).unwrap();
        for s in load_shards(&dir, false).unwrap() {
            assert_eq!(s.plan.as_ref().unwrap().0, MatmulAlgo::Baseline);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pre_planner_shard_files_still_load() {
        // A file written before the plan section existed ends right
        // after the model body; chopping the trailing flag off a fresh
        // plan-less file reproduces that layout exactly.
        let m = tiny_model(16, 3, 2, 8);
        let shards = partition(&m, 2);
        let dir = crate::util::temp_dir("shard-io-preplan");
        let path = shard_file_name(&dir, 0, 2);
        std::fs::create_dir_all(&dir).unwrap();
        save_shard(&shards[0], &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        let loaded = load_shard(&path, false).unwrap();
        assert!(loaded.plan.is_none());
        assert_eq!(loaded.spec, shards[0].spec);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn incomplete_partition_rejected() {
        let m = tiny_model(16, 4, 2, 5);
        let shards = partition(&m, 4);
        let dir = crate::util::temp_dir("shard-io-missing");
        save_shards(&shards, &dir).unwrap();
        std::fs::remove_file(shard_file_name(&dir, 2, 4)).unwrap();
        let err = load_shards(&dir, false).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn model_file_rejected_as_shard() {
        let m = tiny_model(16, 2, 2, 5);
        let dir = crate::util::temp_dir("shard-io-magic");
        let path = dir.join("model.bin");
        crate::tree::save_model(&m, &path).unwrap();
        assert!(load_shard(&path, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
