//! Deterministic, seeded fault injection for the cross-process serving
//! stack.
//!
//! Chaos testing a networked system is only useful if a failure found
//! under chaos can be replayed. Everything here is therefore driven by
//! one seed: a [`FaultPlan`] holds the *probabilities and shapes* of the
//! faults, and [`FaultPlan::schedule`] expands it into a concrete
//! [`ConnSchedule`] for the n-th accepted connection using the same
//! splitmix-style stream derivation as the seeded property harness
//! (`rust/tests/common`), so `MSCM_TEST_SEED=<seed>` reproduces the
//! exact same fault sequence — same connections refused, same frame
//! ordinals corrupted, same delays.
//!
//! Two halves:
//!
//! - **Host side** ([`ShardHost::with_faults`](super::ShardHost::with_faults)):
//!   every reply frame the host writes passes through a per-connection
//!   [`ConnFaultSession`], which can delay it, stutter it (write it in
//!   two chunks with a gap — the slow-loris case), truncate it
//!   mid-frame, corrupt its header, or sever the connection after N
//!   replies. A [`FaultInjector`] also carries a process-wide
//!   `pause`/`resume` latch modelling the dead-but-connected host: the
//!   socket stays open but no bytes ever come back.
//! - **Client side** ([`RemoteConfig::faults`](super::RemoteConfig)):
//!   the gather transport consults the injector when opening
//!   connections (seeded connect refusal) and before sends (fixed
//!   delay), exercising the reconnect/backoff path without any host
//!   cooperation.
//!
//! ### Why corruption targets the frame *header* only
//!
//! The wire protocol has no payload checksum: a flipped byte inside a
//! `Cands` payload would decode into different-but-valid scores and
//! silently break the bitwise-exactness contract the whole shard layer
//! is built on. A flipped byte in the fixed 12-byte header (magic /
//! version / type / length) is *always* detected by
//! [`wire::read_frame`](super::wire) and surfaces as a clean
//! `InvalidData` error, which the client treats like any other replica
//! failure: drop the connection and fail over. Injecting only
//! detectable corruption keeps the chaos suite's strongest assertion —
//! "every non-degraded result is bitwise identical to the unsharded
//! oracle" — meaningful under corruption faults.

use std::io::{self, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::Rng;

/// Stream-splitting constant shared with the seeded test harness: the
/// i-th connection draws from `seed ^ i * GOLDEN`, so schedules are
/// independent per connection but fully determined by `(seed, i)`.
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// A seeded description of the faults to inject. All faults default to
/// off; a default plan is a no-op even when installed.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Base seed for every per-connection schedule. Tests derive this
    /// from `MSCM_TEST_SEED` so failures replay.
    pub seed: u64,
    /// Probability in `[0, 1]` that a connection is refused outright
    /// (host side: accepted then immediately closed, which the client
    /// observes as EOF during the handshake; client side: the connect
    /// attempt errors before touching the network).
    pub refuse_connect: f64,
    /// Sever the connection after this many reply frames have been
    /// written (`None` = never). The handshake `ShardInfo` reply counts.
    pub drop_after_frames: Option<u32>,
    /// Fixed delay inserted before every reply frame (host) or request
    /// frame (client). `Duration::ZERO` = off.
    pub delay_replies: Duration,
    /// Probability that one reply frame of a connection has a header
    /// byte flipped (detectable corruption; see module docs).
    pub corrupt_frame: f64,
    /// Probability that one reply frame of a connection is truncated
    /// mid-frame, after which the connection is severed.
    pub truncate_frame: f64,
    /// Write every reply frame in two chunks separated by this gap
    /// (slow-loris). `None` = off.
    pub stutter: Option<Duration>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0x5EED_CA5E,
            refuse_connect: 0.0,
            drop_after_frames: None,
            delay_replies: Duration::ZERO,
            corrupt_frame: 0.0,
            truncate_frame: 0.0,
            stutter: None,
        }
    }
}

impl FaultPlan {
    /// Expands the plan into the concrete schedule for connection
    /// ordinal `conn_id`. Pure: the same `(plan, conn_id)` always
    /// yields the same schedule, which is what makes chaos runs
    /// replayable from a single logged seed.
    pub fn schedule(&self, conn_id: u64) -> ConnSchedule {
        let mut rng = Rng::seed_from_u64(self.seed ^ conn_id.wrapping_mul(GOLDEN));
        let refuse = self.refuse_connect > 0.0 && rng.gen_bool(self.refuse_connect);
        let corrupt_at = (self.corrupt_frame > 0.0 && rng.gen_bool(self.corrupt_frame))
            .then(|| rng.gen_below(8) as u32);
        let truncate_at = (self.truncate_frame > 0.0 && rng.gen_bool(self.truncate_frame))
            .then(|| rng.gen_below(8) as u32);
        ConnSchedule {
            refuse,
            drop_after: self.drop_after_frames,
            delay: self.delay_replies,
            corrupt_at,
            truncate_at,
            stutter: self.stutter,
        }
    }
}

/// The concrete faults one connection will experience, expanded from a
/// [`FaultPlan`] by [`FaultPlan::schedule`]. Frame ordinals are 0-based
/// over the reply frames written on that connection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConnSchedule {
    /// Close the connection before serving anything.
    pub refuse: bool,
    /// Sever after this many reply frames.
    pub drop_after: Option<u32>,
    /// Delay before every reply frame.
    pub delay: Duration,
    /// Reply ordinal whose header byte is flipped (then keep serving).
    pub corrupt_at: Option<u32>,
    /// Reply ordinal truncated mid-frame (then sever).
    pub truncate_at: Option<u32>,
    /// Two-chunk slow-loris gap applied to every reply frame.
    pub stutter: Option<Duration>,
}

/// Shared runtime state for an installed [`FaultPlan`]: hands out
/// per-connection ordinals (host accepts and client connect attempts
/// draw from separate counters so both sides stay deterministic) and
/// carries the `pause`/`resume` latch.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    paused: AtomicBool,
    host_conns: AtomicU64,
    client_attempts: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Arc<Self> {
        Arc::new(FaultInjector {
            plan,
            paused: AtomicBool::new(false),
            host_conns: AtomicU64::new(0),
            client_attempts: AtomicU64::new(0),
        })
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Freeze the host: connections stay open but every pending and
    /// future reply stalls until [`resume`](Self::resume). Models the
    /// dead-but-connected host that motivates deadline budgets.
    pub fn pause(&self) {
        self.paused.store(true, Ordering::SeqCst);
    }

    pub fn resume(&self) {
        self.paused.store(false, Ordering::SeqCst);
    }

    pub fn is_paused(&self) -> bool {
        self.paused.load(Ordering::SeqCst)
    }

    /// Next host-side connection ordinal (one per accepted connection).
    pub(crate) fn next_host_conn(&self) -> u64 {
        self.host_conns.fetch_add(1, Ordering::SeqCst)
    }

    /// Client side: should this connect attempt be refused? Each
    /// attempt consumes one ordinal from the client stream, so a retry
    /// can succeed where the first attempt was refused — exactly the
    /// transient-connect-failure shape the backoff path handles.
    pub(crate) fn client_connect_refused(&self) -> bool {
        let i = self.client_attempts.fetch_add(1, Ordering::SeqCst);
        self.plan.schedule(i).refuse
    }

    /// Fixed delay the client inserts before request frames.
    pub(crate) fn client_send_delay(&self) -> Duration {
        self.plan.delay_replies
    }
}

/// Per-connection host-side fault state: the schedule plus how many
/// reply frames have been written so far. Owned by the connection's
/// serving thread; all writes to the peer go through
/// [`write_reply`](Self::write_reply).
pub(crate) struct ConnFaultSession {
    inj: Arc<FaultInjector>,
    sched: ConnSchedule,
    stop: Arc<AtomicBool>,
    replies: u32,
}

impl ConnFaultSession {
    pub(crate) fn new(inj: Arc<FaultInjector>, conn_id: u64, stop: Arc<AtomicBool>) -> Self {
        let sched = inj.plan().schedule(conn_id);
        ConnFaultSession {
            inj,
            sched,
            stop,
            replies: 0,
        }
    }

    /// Whether this connection should be refused outright.
    pub(crate) fn refuse(&self) -> bool {
        self.sched.refuse
    }

    /// Writes one reply frame, applying the schedule. `Ok(true)` means
    /// keep serving; `Ok(false)` means the schedule severed the
    /// connection (drop-after / truncation) and the caller should stop.
    pub(crate) fn write_reply(&mut self, w: &mut TcpStream, frame: &[u8]) -> io::Result<bool> {
        let i = self.replies;
        self.replies += 1;

        // Pause latch: stall, don't fail — the peer sees a connected
        // socket that never produces bytes. Host shutdown breaks the
        // stall so a paused host can still be killed cleanly.
        while self.inj.is_paused() && !self.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(2));
        }

        if let Some(n) = self.sched.drop_after {
            if i >= n {
                return Ok(false);
            }
        }
        if !self.sched.delay.is_zero() {
            std::thread::sleep(self.sched.delay);
        }
        if self.sched.truncate_at == Some(i) && frame.len() > 1 {
            // A strict prefix, never the whole frame: the peer must see
            // an interrupted frame, not a clean short read.
            let cut = (frame.len() / 2).max(1);
            w.write_all(&frame[..cut])?;
            let _ = w.flush();
            return Ok(false);
        }
        if self.sched.corrupt_at == Some(i) {
            // Header-only corruption — always detectable (module docs).
            let mut buf = frame.to_vec();
            buf[0] ^= 0xFF;
            w.write_all(&buf)?;
            return Ok(true);
        }
        if let Some(gap) = self.sched.stutter {
            if frame.len() > 1 {
                let cut = frame.len() / 2;
                w.write_all(&frame[..cut])?;
                w.flush()?;
                std::thread::sleep(gap);
                w.write_all(&frame[cut..])?;
                return Ok(true);
            }
        }
        w.write_all(frame)?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_per_connection() {
        let plan = FaultPlan {
            seed: 42,
            refuse_connect: 0.3,
            corrupt_frame: 0.5,
            truncate_frame: 0.5,
            drop_after_frames: Some(7),
            delay_replies: Duration::from_millis(3),
            stutter: Some(Duration::from_millis(1)),
        };
        for conn in 0..64u64 {
            assert_eq!(plan.schedule(conn), plan.schedule(conn));
        }
        // Different connections see different draws somewhere in a
        // modest window (overwhelmingly likely at these probabilities).
        let distinct = (0..64u64)
            .map(|c| plan.schedule(c))
            .collect::<Vec<_>>()
            .windows(2)
            .any(|w| w[0] != w[1]);
        assert!(distinct, "all 64 connection schedules identical");
    }

    #[test]
    fn default_plan_is_a_no_op() {
        let plan = FaultPlan::default();
        for conn in 0..16u64 {
            let s = plan.schedule(conn);
            assert!(!s.refuse);
            assert_eq!(s.drop_after, None);
            assert_eq!(s.corrupt_at, None);
            assert_eq!(s.truncate_at, None);
            assert_eq!(s.stutter, None);
            assert!(s.delay.is_zero());
        }
    }

    #[test]
    fn seed_changes_the_schedule_stream() {
        let a = FaultPlan {
            seed: 1,
            refuse_connect: 0.5,
            ..FaultPlan::default()
        };
        let b = FaultPlan {
            seed: 2,
            ..a.clone()
        };
        let sa: Vec<bool> = (0..128).map(|c| a.schedule(c).refuse).collect();
        let sb: Vec<bool> = (0..128).map(|c| b.schedule(c).refuse).collect();
        assert_ne!(sa, sb, "independent seeds produced identical refusal streams");
    }
}
