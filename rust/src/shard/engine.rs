//! The in-process sharded engine: layer-synchronized exact scatter-gather
//! (see the [`crate::shard`] module docs for why this reproduces the
//! unsharded search bit for bit).
//!
//! # Pooled round state
//!
//! Every layer round moves two buffer families: per-shard local beams out
//! to the shards and per-shard candidates back. Both live in
//! [`ShardRound`]s owned by a [`GatherArena`] — the gather stage's
//! steady-state arena. Rounds *cycle* rather than churn: the serving
//! coordinator ships each `ShardRound` to its shard pool inside a
//! `LayerJob` and receives the same buffers back on the reply channel,
//! so after the first batch at a given size the whole layer-synchronized
//! protocol performs no allocations (enforced in-process by
//! `rust/tests/alloc.rs`; across the channel hop only the mpsc node
//! itself is allocated).

use std::sync::Arc;

use super::partition::{ShardModel, ShardSpec};
use crate::inference::{
    rank_into, select_top, EngineConfig, InferenceEngine, IterationMethod, PlannerConfig,
    Prediction, Workspace,
};
use crate::metrics::EngineMetrics;
use crate::sparse::{CsrMatrix, SparseVec};

/// One shard hosted by the engine.
struct ShardUnit {
    engine: InferenceEngine,
    spec: ShardSpec,
    layer_offsets: Vec<u32>,
}

/// One shard's pooled round buffers, cycling gather → shard → gather.
///
/// `beams[q]` carries the shard-local slice of the global beam for the
/// layer being expanded; the shard fills `cands[q]` with the generated
/// `(local node, path score)` candidates. Only the first `n` entries are
/// live — the buffers never shrink, so fluctuating batch sizes reuse the
/// high-water capacity.
///
/// Fields are public because the round is also the unit the wire codec
/// ([`super::wire`]) encodes and decodes in place — remote rounds move
/// through the exact same pooled buffers as in-process ones.
#[derive(Debug, Default)]
pub struct ShardRound {
    /// Live query count; only the first `n` entries of each buffer hold
    /// this round's data.
    pub n: usize,
    /// Per query: the shard-local beam slice (node ids ascending).
    pub beams: Vec<Vec<(u32, f32)>>,
    /// Per query: the generated `(local node, path score)` candidates.
    pub cands: Vec<Vec<(u32, f32)>>,
}

impl ShardRound {
    /// Grows the per-query buffers to `n` (never shrinks).
    pub fn ensure(&mut self, n: usize) {
        self.n = n;
        if self.beams.len() < n {
            self.beams.resize_with(n, Vec::new);
        }
        if self.cands.len() < n {
            self.cands.resize_with(n, Vec::new);
        }
    }

    /// Resets the round to `n` live queries with no beam and no
    /// candidates — the (empty) contribution a dead shard makes to a
    /// degraded merge, and the shape that keeps later layers from
    /// reading stale buffers left by the shard's last successful round.
    pub fn clear_round(&mut self, n: usize) {
        self.ensure(n);
        for q in 0..n {
            self.beams[q].clear();
            self.cands[q].clear();
        }
    }
}

/// Expands one layer of one shard engine for every query of `round`:
/// installs `round.beams` into the workspace arena, runs the engine's
/// layer step, refills `round.cands`. THE scatter-side kernel shared by
/// the in-process [`ShardedEngine`], the serving coordinator's shard
/// pools and the remote [`super::ShardHost`] — one definition, so the
/// transports cannot drift from the in-process computation.
pub(crate) fn expand_round(
    engine: &InferenceEngine,
    x: &CsrMatrix,
    layer: usize,
    round: &mut ShardRound,
    ws: &mut Workspace,
) {
    let n = round.n;
    ws.begin_beams(n);
    for b in &round.beams[..n] {
        ws.push_beam(b);
    }
    engine.expand_layer(layer, x, 0, n, ws);
    for (q, c) in round.cands[..n].iter_mut().enumerate() {
        c.clear();
        c.extend_from_slice(ws.cand(q));
    }
}

/// Gather half of one layer, shared by the in-process engine and the
/// remote gather stage: merges every shard's candidates into global node
/// ids (`range_of(s)` is shard `s`'s global column range `[lo, hi)` at
/// this layer), prunes with the engine's own `select_top` comparator,
/// and splits the surviving global beam back into per-shard local beams.
/// `arena.global_beams[q]` is left holding the pruned global beam.
pub(crate) fn merge_and_split_layer<F>(
    s_count: usize,
    range_of: F,
    beam: usize,
    arena: &mut GatherArena,
) where
    F: Fn(usize) -> (u32, u32),
{
    let n = arena.n;
    for q in 0..n {
        arena.merge.clear();
        for s in 0..s_count {
            let (lo, _) = range_of(s);
            for &(node, score) in &arena.rounds[s].cands[q] {
                arena.merge.push((node + lo, score));
            }
        }
        // Global beam step: exactly InferenceEngine's select_top.
        select_top(&mut arena.merge, beam, &mut arena.global_beams[q]);
        for s in 0..s_count {
            let (lo, hi) = range_of(s);
            let local = &mut arena.rounds[s].beams[q];
            local.clear();
            local.extend(
                arena.global_beams[q]
                    .iter()
                    .filter(|&&(node, _)| node >= lo && node < hi)
                    .map(|&(node, score)| (node - lo, score)),
            );
        }
    }
}

/// Builds the serving engine for one shard, honoring a stored kernel
/// plan: a plan is served verbatim only when it was costed for the
/// serving algo — the cost shapes differ per algo, so an MSCM-costed
/// plan driving the baseline kernels (or vice versa) would be
/// systematically mis-planned. Mismatches fall through to a fresh
/// per-shard resolution. Shared by [`ShardedEngine`] and the remote
/// [`super::ShardHost`].
pub(crate) fn build_shard_engine(
    s: ShardModel,
    config: EngineConfig,
    pc: &PlannerConfig,
) -> (ShardSpec, Vec<u32>, InferenceEngine) {
    let spec = s.spec;
    let layer_offsets = s.layer_offsets;
    let engine = match (config.iter, s.plan) {
        (IterationMethod::Auto, Some((algo, plan))) if algo == config.algo => {
            InferenceEngine::new_with_plan(s.model, config, plan)
        }
        _ => InferenceEngine::new_with_planner(s.model, config, pc),
    };
    (spec, layer_offsets, engine)
}

/// The gather stage's reusable arena: per-shard [`ShardRound`]s, the
/// global beams, the merge scratch and the result buffers. One arena per
/// gather worker (or per caller thread for the in-process paths); it
/// reaches its steady-state size after the first batch and never
/// allocates again at a bounded batch size.
#[derive(Default)]
pub struct GatherArena {
    pub(crate) rounds: Vec<ShardRound>,
    pub(crate) global_beams: Vec<Vec<(u32, f32)>>,
    pub(crate) merge: Vec<(u32, f32)>,
    pub(crate) out: Vec<Vec<Prediction>>,
    pub(crate) n: usize,
    /// Resident single-row query matrix for the online path.
    pub(crate) query_row: CsrMatrix,
}

impl GatherArena {
    /// An empty arena; it sizes itself on first use.
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, s_count: usize, n: usize) {
        self.n = n;
        if self.rounds.len() < s_count {
            self.rounds.resize_with(s_count, ShardRound::default);
        }
        for r in &mut self.rounds[..s_count] {
            r.ensure(n);
        }
        if self.global_beams.len() < n {
            self.global_beams.resize_with(n, Vec::new);
        }
        if self.out.len() < n {
            self.out.resize_with(n, Vec::new);
        }
    }

    /// Per-query results of the last completed drive (`n` rows).
    pub fn results(&self) -> &[Vec<Prediction>] {
        &self.out[..self.n]
    }

    /// Sizes the arena for an `s_count`-shard round over `n` queries and
    /// resets every per-shard beam to the implicit root — the first
    /// scatter of the layer-synchronized protocol, shared by the
    /// in-process driver and the remote gather stage.
    pub(crate) fn begin_rounds(&mut self, s_count: usize, n: usize) {
        self.ensure(s_count, n);
        for r in &mut self.rounds[..s_count] {
            for q in 0..n {
                r.beams[q].clear();
                r.beams[q].push((0u32, 1.0f32));
            }
        }
    }
}

/// An inference engine over a complete shard partition.
///
/// The driver owns the *global* beam: at every layer each shard expands
/// exactly the surviving beam nodes that live in its column range
/// ([`InferenceEngine::expand_layer`] behind
/// [`ShardedEngine::expand_shard_layer`]), the candidates are merged with
/// their global node ids, and one global `select_top` prunes — the same
/// computation as the unsharded engine with candidate *generation*
/// partitioned by shard, hence bit-identical output.
pub struct ShardedEngine {
    units: Vec<ShardUnit>,
    config: EngineConfig,
    dim: usize,
    depth: usize,
    num_labels: usize,
}

impl ShardedEngine {
    /// Builds per-shard engines (each constructing whatever side indices
    /// its plan needs). `shards` must be one complete partition; shards
    /// may arrive in any order. Under [`IterationMethod::Auto`], a shard
    /// carrying a stored plan (shard files persist them) serves it as-is
    /// — no re-planning, no re-calibration; shards without one plan
    /// themselves over their own chunks with the default
    /// [`PlannerConfig`].
    pub fn new(shards: Vec<ShardModel>, config: EngineConfig) -> Self {
        Self::new_with_planner(shards, config, &PlannerConfig::default())
    }

    /// [`ShardedEngine::new`] with explicit planner inputs for shards
    /// that need a fresh plan resolved.
    pub fn new_with_planner(
        shards: Vec<ShardModel>,
        config: EngineConfig,
        pc: &PlannerConfig,
    ) -> Self {
        assert!(!shards.is_empty(), "need at least one shard");
        let mut shards = shards;
        shards.sort_by_key(|s| s.spec.shard_id);
        let dim = shards[0].model.dim;
        let depth = shards[0].model.depth();
        let num_shards = shards[0].spec.num_shards;
        assert_eq!(
            shards.len() as u64,
            num_shards as u64,
            "incomplete partition: {} of {} shards",
            shards.len(),
            num_shards
        );
        let mut next_label = 0u64;
        let mut units = Vec::with_capacity(shards.len());
        for (i, s) in shards.into_iter().enumerate() {
            assert_eq!(s.spec.shard_id as usize, i, "duplicate shard id");
            assert_eq!(s.model.dim, dim, "shard dim mismatch");
            assert_eq!(s.model.depth(), depth, "shard depth mismatch");
            assert_eq!(s.spec.label_offset, next_label, "label gap before shard {i}");
            next_label += s.spec.num_labels;
            let (spec, layer_offsets, engine) = build_shard_engine(s, config, pc);
            units.push(ShardUnit {
                engine,
                spec,
                layer_offsets,
            });
        }
        Self {
            units,
            config,
            dim,
            depth,
            num_labels: next_label as usize,
        }
    }

    /// Convenience: partition `model` and build the engine in one step.
    pub fn from_model(
        model: &crate::tree::XmrModel,
        num_shards: usize,
        config: EngineConfig,
    ) -> Self {
        Self::new(super::partition(model, num_shards), config)
    }

    /// [`ShardedEngine::from_model`] with explicit planner inputs.
    pub fn from_model_with_planner(
        model: &crate::tree::XmrModel,
        num_shards: usize,
        config: EngineConfig,
        pc: &PlannerConfig,
    ) -> Self {
        Self::new_with_planner(super::partition(model, num_shards), config, pc)
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.units.len()
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Tree depth in ranker layers.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total labels across shards.
    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    /// The shared engine configuration.
    pub fn config(&self) -> EngineConfig {
        self.config
    }

    /// The per-shard inference engine (shard workers pull workspaces
    /// from this).
    pub fn shard_engine(&self, shard: usize) -> &InferenceEngine {
        &self.units[shard].engine
    }

    /// Enables per-layer engine telemetry on every shard unit (see
    /// [`InferenceEngine::with_metrics`]); read back per shard via
    /// [`ShardedEngine::shard_metrics`].
    pub fn with_metrics(mut self) -> Self {
        self.units = self
            .units
            .into_iter()
            .map(|mut u| {
                u.engine = u.engine.with_metrics();
                u
            })
            .collect();
        self
    }

    /// Shard `shard`'s engine telemetry, if enabled.
    pub fn shard_metrics(&self, shard: usize) -> Option<&Arc<EngineMetrics>> {
        self.units[shard].engine.metrics()
    }

    /// The identity of shard `shard`.
    pub fn shard_spec(&self, shard: usize) -> ShardSpec {
        self.units[shard].spec
    }

    /// Global node-id range `[lo, hi)` that shard `shard` owns at `layer`.
    pub fn layer_range(&self, shard: usize, layer: usize) -> (u32, u32) {
        let u = &self.units[shard];
        let lo = u.layer_offsets[layer];
        (lo, lo + u.engine.model().layers[layer].num_nodes() as u32)
    }

    /// Scatter half, one shard × one layer × one batch: installs the
    /// shard-local beams of `round` (parents in layer `layer - 1`, local
    /// ids ascending) into the workspace arena, expands layer `layer`,
    /// and refills `round.cands` with the generated `(local node, path
    /// score)` candidates per query. This is the unit the serving
    /// coordinator ships to per-shard worker pools; the round's buffers
    /// travel out and back, so the exchange is allocation-free once warm.
    pub fn expand_shard_layer(
        &self,
        shard: usize,
        x: &CsrMatrix,
        layer: usize,
        round: &mut ShardRound,
        ws: &mut Workspace,
    ) {
        expand_round(&self.units[shard].engine, x, layer, round, ws);
    }

    /// Gather half, one layer: [`merge_and_split_layer`] over this
    /// engine's shard ranges.
    pub(crate) fn merge_and_split(&self, layer: usize, beam: usize, arena: &mut GatherArena) {
        merge_and_split_layer(self.units.len(), |s| self.layer_range(s, layer), beam, arena);
    }

    /// The layer-synchronized protocol driver, shared by the in-process
    /// paths below and the serving coordinator's gather workers (one
    /// place owns the exactness-critical sequence). `expand` maps
    /// `(layer, per-shard rounds)` to filled `cands` in those rounds —
    /// in process it calls [`ShardedEngine::expand_shard_layer`]
    /// directly; the coordinator ships the rounds to shard pools and
    /// restores them from the replies. Returning `false` aborts (a shard
    /// vanished mid-batch during shutdown). On success the per-query
    /// rankings are left in `arena.out` ([`GatherArena::results`]).
    pub(crate) fn drive<F>(
        &self,
        n: usize,
        beam: usize,
        topk: usize,
        arena: &mut GatherArena,
        mut expand: F,
    ) -> bool
    where
        F: FnMut(usize, &mut [ShardRound]) -> bool,
    {
        assert!(beam >= 1, "beam width must be >= 1");
        let s_count = self.units.len();
        // Per-shard local beams: every shard starts at its own root.
        arena.begin_rounds(s_count, n);
        for l in 0..self.depth {
            if !expand(l, &mut arena.rounds[..s_count]) {
                return false;
            }
            self.merge_and_split(l, beam, arena);
        }
        // Final ranking, identical to InferenceEngine::predict_range's
        // bottom step (the shared rank_into).
        for q in 0..n {
            rank_into(&mut arena.global_beams[q], topk, &mut arena.out[q]);
        }
        true
    }

    /// One freshly-sized workspace per shard, for the `_with`/`_into`
    /// entry points (serving paths keep these per worker and reuse them).
    pub fn workspaces(&self) -> Vec<Workspace> {
        self.units.iter().map(|u| u.engine.workspace()).collect()
    }

    /// Online scatter-gather for a single query.
    pub fn predict(&self, x: &SparseVec, beam: usize, topk: usize) -> Vec<Prediction> {
        let mut wss = self.workspaces();
        let mut arena = GatherArena::new();
        self.predict_with(x, beam, topk, &mut wss, &mut arena).to_vec()
    }

    /// Online scatter-gather reusing caller-held per-shard workspaces and
    /// a gather arena — the alloc-free sharded hot path, mirroring
    /// [`InferenceEngine::predict_with`]. The returned slice lives in the
    /// arena and is valid until it is next used.
    pub fn predict_with<'a>(
        &self,
        x: &SparseVec,
        beam: usize,
        topk: usize,
        wss: &mut [Workspace],
        arena: &'a mut GatherArena,
    ) -> &'a [Prediction] {
        let mut xm = std::mem::take(&mut arena.query_row);
        xm.reset(self.dim);
        xm.push_row(x.view());
        self.predict_batch_into(&xm, beam, topk, false, wss, arena);
        arena.query_row = xm;
        &arena.out[0]
    }

    /// Batch scatter-gather: each layer is expanded by every shard (chunk
    /// loads amortized across the batch, as in Alg. 3), then one global
    /// beam selection runs per query. Scatter uses one thread per shard
    /// when `parallel`.
    pub fn predict_batch(
        &self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
        parallel: bool,
    ) -> Vec<Vec<Prediction>> {
        let mut wss = self.workspaces();
        let mut arena = GatherArena::new();
        self.predict_batch_into(x, beam, topk, parallel, &mut wss, &mut arena);
        arena.results().to_vec()
    }

    /// [`ShardedEngine::predict_batch`] against caller-held workspaces
    /// (`wss[s]` belongs to shard `s`) and a gather arena; the rankings
    /// land in [`GatherArena::results`]. When `parallel`, each layer
    /// round scatters on one scoped thread per shard — fine for batches,
    /// where the `depth × S` spawns amortize across the whole batch;
    /// sustained serving should use [`super::ShardedCoordinator`]'s
    /// persistent pools instead.
    pub fn predict_batch_into(
        &self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
        parallel: bool,
        wss: &mut [Workspace],
        arena: &mut GatherArena,
    ) {
        let n = x.rows;
        let s_count = self.units.len();
        assert_eq!(wss.len(), s_count, "need one workspace per shard");
        let ok = self.drive(n, beam, topk, arena, |l, rounds| {
            if parallel {
                std::thread::scope(|scope| {
                    for ((s, r), ws) in rounds.iter_mut().enumerate().zip(wss.iter_mut()) {
                        scope.spawn(move || self.expand_shard_layer(s, x, l, r, ws));
                    }
                });
            } else {
                for ((s, r), ws) in rounds.iter_mut().enumerate().zip(wss.iter_mut()) {
                    self.expand_shard_layer(s, x, l, r, ws);
                }
            }
            true
        });
        assert!(ok, "in-process expansion cannot abort");
    }

    /// Approximate resident bytes of every shard model (chunked form).
    pub fn memory_bytes(&self) -> usize {
        self.units
            .iter()
            .map(|u| u.engine.model().stats().chunked_bytes)
            .sum()
    }

    /// Side-index bytes across all shards, one number
    /// ([`InferenceEngine::side_index_bytes`] summed).
    pub fn side_index_bytes(&self) -> usize {
        self.units.iter().map(|u| u.engine.side_index_bytes()).sum()
    }

    /// Chunked weight-payload bytes across all shards under the applied
    /// storage layouts ([`InferenceEngine::weight_bytes`] summed).
    pub fn weight_bytes(&self) -> usize {
        self.units.iter().map(|u| u.engine.weight_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::{IterationMethod, MatmulAlgo};
    use crate::tree::test_util::tiny_model;
    use crate::util::Rng;

    fn rand_query(rng: &mut Rng, dim: usize) -> SparseVec {
        SparseVec::from_pairs(
            (0..rng.gen_range(1..dim / 2))
                .map(|_| (rng.gen_range(0..dim) as u32, rng.gen_f32(-1.0, 1.0)))
                .collect(),
        )
    }

    #[test]
    fn sharded_equals_unsharded_bitwise_tiny() {
        let m = tiny_model(32, 4, 3, 2024); // 4 root children, 64 labels
        let mut rng = Rng::seed_from_u64(8);
        let queries: Vec<SparseVec> = (0..12).map(|_| rand_query(&mut rng, 32)).collect();
        for cfg in EngineConfig::all() {
            let reference = InferenceEngine::new(m.clone(), cfg);
            for s in [1usize, 2, 3, 4] {
                let sharded = ShardedEngine::from_model(&m, s, cfg);
                assert_eq!(sharded.num_shards(), s);
                for (qi, q) in queries.iter().enumerate() {
                    for beam in [1usize, 2, 5, 64] {
                        let want = reference.predict(q, beam, 10);
                        let got = sharded.predict(q, beam, 10);
                        assert_eq!(got, want, "{} S={s} beam={beam} q={qi}", cfg.label());
                    }
                }
            }
        }
    }

    #[test]
    fn batch_gather_matches_online_gather() {
        let m = tiny_model(24, 3, 3, 77);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
        let sharded = ShardedEngine::from_model(&m, 3, cfg);
        let mut rng = Rng::seed_from_u64(4);
        let rows: Vec<SparseVec> = (0..9).map(|_| rand_query(&mut rng, 24)).collect();
        let x = CsrMatrix::from_rows(rows.clone(), 24);
        for parallel in [false, true] {
            let batch = sharded.predict_batch(&x, 3, 5, parallel);
            for (i, q) in rows.iter().enumerate() {
                assert_eq!(batch[i], sharded.predict(q, 3, 5), "parallel={parallel} q={i}");
            }
        }
    }

    #[test]
    fn pooled_arena_reuse_stays_exact() {
        // The same workspaces + arena serve alternating online queries
        // and batches of changing size; recycled rounds must never leak
        // state between batches.
        let m = tiny_model(24, 4, 3, 91);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch);
        let reference = InferenceEngine::new(m.clone(), cfg);
        let sharded = ShardedEngine::from_model(&m, 4, cfg);
        let mut wss = sharded.workspaces();
        let mut arena = GatherArena::new();
        let mut rng = Rng::seed_from_u64(3);
        for round in 0..3 {
            let q = rand_query(&mut rng, 24);
            assert_eq!(
                sharded.predict_with(&q, 3, 5, &mut wss, &mut arena),
                &reference.predict(&q, 3, 5)[..],
                "online round {round}"
            );
            for n in [5usize, 1, 8] {
                let rows: Vec<SparseVec> = (0..n).map(|_| rand_query(&mut rng, 24)).collect();
                let x = CsrMatrix::from_rows(rows.clone(), 24);
                sharded.predict_batch_into(&x, 3, 5, false, &mut wss, &mut arena);
                for (i, row) in rows.iter().enumerate() {
                    assert_eq!(
                        arena.results()[i],
                        reference.predict(row, 3, 5),
                        "round {round} n={n} q={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn beam_narrower_than_shard_count_stays_exact() {
        // The case the naive per-shard merge gets wrong: with beam 1 only
        // one shard's subtree may survive each layer; the others must
        // expand nothing rather than vote their own best leaf in.
        let m = tiny_model(24, 4, 3, 31);
        for cfg in EngineConfig::all() {
            let reference = InferenceEngine::new(m.clone(), cfg);
            let sharded = ShardedEngine::from_model(&m, 4, cfg);
            let mut rng = Rng::seed_from_u64(17);
            for qi in 0..20 {
                let q = rand_query(&mut rng, 24);
                assert_eq!(
                    sharded.predict(&q, 1, 3),
                    reference.predict(&q, 1, 3),
                    "{} q={qi}",
                    cfg.label()
                );
            }
        }
    }

    #[test]
    fn stored_plans_are_served_verbatim() {
        // Pre-planned shards must serve their stored plan (no
        // re-planning) and stay bitwise exact against the unsharded
        // engine under any fixed method.
        let m = tiny_model(24, 4, 3, 61);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Auto);
        let mut shards = crate::shard::partition(&m, 3);
        for s in &mut shards {
            s.plan_auto(MatmulAlgo::Mscm, &crate::inference::PlannerConfig::default());
        }
        let plans: Vec<_> = shards.iter().map(|s| s.plan.clone().unwrap().1).collect();
        let sharded = ShardedEngine::new(shards, cfg);
        for (s, want) in plans.iter().enumerate() {
            assert_eq!(sharded.shard_engine(s).plan().as_ref(), want, "shard {s}");
        }
        let reference = InferenceEngine::new(
            m,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers),
        );
        let mut rng = Rng::seed_from_u64(9);
        for qi in 0..10 {
            let q = rand_query(&mut rng, 24);
            assert_eq!(sharded.predict(&q, 3, 5), reference.predict(&q, 3, 5), "q={qi}");
        }
    }

    #[test]
    #[should_panic(expected = "incomplete partition")]
    fn missing_shard_panics() {
        let m = tiny_model(16, 4, 2, 3);
        let mut shards = crate::shard::partition(&m, 4);
        shards.remove(1);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers);
        ShardedEngine::new(shards, cfg);
    }
}
