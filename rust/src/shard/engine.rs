//! The in-process sharded engine: layer-synchronized exact scatter-gather
//! (see the [`crate::shard`] module docs for why this reproduces the
//! unsharded search bit for bit).

use super::partition::{ShardModel, ShardSpec};
use crate::inference::{
    rank_beam, select_top, EngineConfig, InferenceEngine, Prediction, Workspace,
};
use crate::sparse::{CsrMatrix, SparseVec};

/// One shard hosted by the engine.
struct ShardUnit {
    engine: InferenceEngine,
    spec: ShardSpec,
    layer_offsets: Vec<u32>,
}

/// An inference engine over a complete shard partition.
///
/// The driver owns the *global* beam: at every layer each shard expands
/// exactly the surviving beam nodes that live in its column range
/// ([`InferenceEngine::expand_layer`] behind
/// [`ShardedEngine::expand_shard_layer`]), the candidates are merged with
/// their global node ids, and one global `select_top` prunes — the same
/// computation as the unsharded engine with candidate *generation*
/// partitioned by shard, hence bit-identical output.
pub struct ShardedEngine {
    units: Vec<ShardUnit>,
    config: EngineConfig,
    dim: usize,
    depth: usize,
    num_labels: usize,
}

impl ShardedEngine {
    /// Builds per-shard engines (each constructing whatever side indices
    /// `config` needs). `shards` must be one complete partition; shards
    /// may arrive in any order.
    pub fn new(shards: Vec<ShardModel>, config: EngineConfig) -> Self {
        assert!(!shards.is_empty(), "need at least one shard");
        let mut shards = shards;
        shards.sort_by_key(|s| s.spec.shard_id);
        let dim = shards[0].model.dim;
        let depth = shards[0].model.depth();
        let num_shards = shards[0].spec.num_shards;
        assert_eq!(
            shards.len() as u64,
            num_shards as u64,
            "incomplete partition: {} of {} shards",
            shards.len(),
            num_shards
        );
        let mut next_label = 0u64;
        let mut units = Vec::with_capacity(shards.len());
        for (i, s) in shards.into_iter().enumerate() {
            assert_eq!(s.spec.shard_id as usize, i, "duplicate shard id");
            assert_eq!(s.model.dim, dim, "shard dim mismatch");
            assert_eq!(s.model.depth(), depth, "shard depth mismatch");
            assert_eq!(s.spec.label_offset, next_label, "label gap before shard {i}");
            next_label += s.spec.num_labels;
            units.push(ShardUnit {
                engine: InferenceEngine::new(s.model, config),
                spec: s.spec,
                layer_offsets: s.layer_offsets,
            });
        }
        Self {
            units,
            config,
            dim,
            depth,
            num_labels: next_label as usize,
        }
    }

    /// Convenience: partition `model` and build the engine in one step.
    pub fn from_model(
        model: &crate::tree::XmrModel,
        num_shards: usize,
        config: EngineConfig,
    ) -> Self {
        Self::new(super::partition(model, num_shards), config)
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.units.len()
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Tree depth in ranker layers.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total labels across shards.
    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    /// The shared engine configuration.
    pub fn config(&self) -> EngineConfig {
        self.config
    }

    /// The per-shard inference engine (shard workers pull workspaces
    /// from this).
    pub fn shard_engine(&self, shard: usize) -> &InferenceEngine {
        &self.units[shard].engine
    }

    /// The identity of shard `shard`.
    pub fn shard_spec(&self, shard: usize) -> ShardSpec {
        self.units[shard].spec
    }

    /// Global node-id range `[lo, hi)` that shard `shard` owns at `layer`.
    pub fn layer_range(&self, shard: usize, layer: usize) -> (u32, u32) {
        let u = &self.units[shard];
        let lo = u.layer_offsets[layer];
        (lo, lo + u.engine.model().layers[layer].num_nodes() as u32)
    }

    /// Scatter half, one shard × one layer × one batch: installs the
    /// shard-local `beams` (parents in layer `layer - 1`, local ids
    /// ascending), expands layer `layer`, and returns the generated
    /// `(local node, path score)` candidates per query. This is the unit
    /// the serving coordinator ships to per-shard worker pools.
    pub fn expand_shard_layer(
        &self,
        shard: usize,
        x: &CsrMatrix,
        layer: usize,
        beams: Vec<Vec<(u32, f32)>>,
        ws: &mut Workspace,
    ) -> Vec<Vec<(u32, f32)>> {
        let n = beams.len();
        let engine = &self.units[shard].engine;
        ws.ensure_batch(n);
        for (q, b) in beams.into_iter().enumerate() {
            ws.beams[q] = b;
        }
        engine.expand_layer(layer, x, 0, n, ws);
        (0..n).map(|q| std::mem::take(&mut ws.cands[q])).collect()
    }

    /// Gather half, one layer: merges per-shard candidates into global
    /// ids, prunes with the engine's own comparator, and splits the
    /// surviving beam back into per-shard local beams for the next layer.
    /// `global_beams[q]` is left holding the pruned global beam.
    pub(crate) fn merge_and_split(
        &self,
        layer: usize,
        shard_cands: &[Vec<Vec<(u32, f32)>>],
        beam: usize,
        scratch: &mut Vec<(u32, f32)>,
        global_beams: &mut [Vec<(u32, f32)>],
        next_local: &mut [Vec<Vec<(u32, f32)>>],
    ) {
        let n = global_beams.len();
        for q in 0..n {
            scratch.clear();
            for (s, u) in self.units.iter().enumerate() {
                let off = u.layer_offsets[layer];
                for &(node, score) in &shard_cands[s][q] {
                    scratch.push((node + off, score));
                }
            }
            // Global beam step: exactly InferenceEngine's select_top.
            select_top(scratch, beam, &mut global_beams[q]);
            for s in 0..self.units.len() {
                let (lo, hi) = self.layer_range(s, layer);
                let local = &mut next_local[s][q];
                local.clear();
                local.extend(
                    global_beams[q]
                        .iter()
                        .filter(|&&(node, _)| node >= lo && node < hi)
                        .map(|&(node, score)| (node - lo, score)),
                );
            }
        }
    }

    /// Final ranking, identical to [`InferenceEngine::predict_range`]'s
    /// bottom step (the shared `rank_beam`): sort the last global beam
    /// and keep the top `topk`.
    pub(crate) fn finalize(beamed: &mut Vec<(u32, f32)>, topk: usize) -> Vec<Prediction> {
        rank_beam(beamed, topk);
        beamed
            .iter()
            .map(|&(label, score)| Prediction { label, score })
            .collect()
    }

    /// The layer-synchronized protocol driver, shared by the in-process
    /// paths below and the serving coordinator's gather workers (one
    /// place owns the exactness-critical sequence). `expand` maps
    /// `(layer, per-shard local beams)` to per-shard candidates — in
    /// process it calls [`ShardedEngine::expand_shard_layer`] directly;
    /// the coordinator ships `LayerJob`s to shard pools. Returning `None`
    /// aborts (a shard vanished mid-batch during shutdown).
    pub(crate) fn drive<F>(
        &self,
        n: usize,
        beam: usize,
        topk: usize,
        mut expand: F,
    ) -> Option<Vec<Vec<Prediction>>>
    where
        F: FnMut(usize, Vec<Vec<Vec<(u32, f32)>>>) -> Option<Vec<Vec<Vec<(u32, f32)>>>>,
    {
        assert!(beam >= 1, "beam width must be >= 1");
        let s_count = self.units.len();
        // Per-shard local beams: every shard starts at its own root.
        let mut local: Vec<Vec<Vec<(u32, f32)>>> =
            vec![vec![vec![(0u32, 1.0f32)]; n]; s_count];
        let mut global_beams: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for l in 0..self.depth {
            let cands = expand(l, std::mem::take(&mut local))?;
            local = vec![vec![Vec::new(); n]; s_count];
            self.merge_and_split(l, &cands, beam, &mut scratch, &mut global_beams, &mut local);
        }
        Some(
            global_beams
                .iter_mut()
                .map(|b| Self::finalize(b, topk))
                .collect(),
        )
    }

    /// One freshly-sized workspace per shard, for the `_with` entry
    /// points (serving paths keep these per worker and reuse them).
    pub fn workspaces(&self) -> Vec<Workspace> {
        self.units.iter().map(|u| u.engine.workspace()).collect()
    }

    /// Online scatter-gather for a single query.
    pub fn predict(&self, x: &SparseVec, beam: usize, topk: usize) -> Vec<Prediction> {
        let xm = CsrMatrix::from_single_row(x, self.dim);
        self.predict_batch(&xm, beam, topk, false).pop().unwrap()
    }

    /// Online scatter-gather reusing caller-held per-shard workspaces
    /// (alloc-light hot path, mirroring
    /// [`InferenceEngine::predict_with`]).
    pub fn predict_with(
        &self,
        x: &SparseVec,
        beam: usize,
        topk: usize,
        wss: &mut [Workspace],
    ) -> Vec<Prediction> {
        let xm = CsrMatrix::from_single_row(x, self.dim);
        self.predict_batch_with(&xm, beam, topk, false, wss).pop().unwrap()
    }

    /// Batch scatter-gather: each layer is expanded by every shard (chunk
    /// loads amortized across the batch, as in Alg. 3), then one global
    /// beam selection runs per query. Scatter uses one thread per shard
    /// when `parallel`.
    pub fn predict_batch(
        &self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
        parallel: bool,
    ) -> Vec<Vec<Prediction>> {
        let mut wss = self.workspaces();
        self.predict_batch_with(x, beam, topk, parallel, &mut wss)
    }

    /// [`ShardedEngine::predict_batch`] with caller-held workspaces
    /// (`wss[s]` belongs to shard `s`). When `parallel`, each layer round
    /// scatters on one scoped thread per shard — fine for batches, where
    /// the `depth × S` spawns amortize across the whole batch; sustained
    /// serving should use [`super::ShardedCoordinator`]'s persistent
    /// pools instead.
    pub fn predict_batch_with(
        &self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
        parallel: bool,
        wss: &mut [Workspace],
    ) -> Vec<Vec<Prediction>> {
        let n = x.rows;
        let s_count = self.units.len();
        assert_eq!(wss.len(), s_count, "need one workspace per shard");
        self.drive(n, beam, topk, |l, beams_in| {
            Some(if parallel {
                let mut out: Vec<Option<Vec<Vec<(u32, f32)>>>> =
                    (0..s_count).map(|_| None).collect();
                std::thread::scope(|scope| {
                    for (((s, beams), ws), slot) in beams_in
                        .into_iter()
                        .enumerate()
                        .zip(wss.iter_mut())
                        .zip(out.iter_mut())
                    {
                        scope.spawn(move || {
                            *slot = Some(self.expand_shard_layer(s, x, l, beams, ws));
                        });
                    }
                });
                out.into_iter().map(|o| o.unwrap()).collect()
            } else {
                beams_in
                    .into_iter()
                    .enumerate()
                    .zip(wss.iter_mut())
                    .map(|((s, beams), ws)| self.expand_shard_layer(s, x, l, beams, ws))
                    .collect()
            })
        })
        .expect("in-process expansion cannot abort")
    }

    /// Approximate resident bytes of every shard model (chunked form).
    pub fn memory_bytes(&self) -> usize {
        self.units
            .iter()
            .map(|u| u.engine.model().stats().chunked_bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::{IterationMethod, MatmulAlgo};
    use crate::tree::test_util::tiny_model;
    use crate::util::Rng;

    fn rand_query(rng: &mut Rng, dim: usize) -> SparseVec {
        SparseVec::from_pairs(
            (0..rng.gen_range(1..dim / 2))
                .map(|_| (rng.gen_range(0..dim) as u32, rng.gen_f32(-1.0, 1.0)))
                .collect(),
        )
    }

    #[test]
    fn sharded_equals_unsharded_bitwise_tiny() {
        let m = tiny_model(32, 4, 3, 2024); // 4 root children, 64 labels
        let mut rng = Rng::seed_from_u64(8);
        let queries: Vec<SparseVec> = (0..12).map(|_| rand_query(&mut rng, 32)).collect();
        for cfg in EngineConfig::all() {
            let reference = InferenceEngine::new(m.clone(), cfg);
            for s in [1usize, 2, 3, 4] {
                let sharded = ShardedEngine::from_model(&m, s, cfg);
                assert_eq!(sharded.num_shards(), s);
                for (qi, q) in queries.iter().enumerate() {
                    for beam in [1usize, 2, 5, 64] {
                        let want = reference.predict(q, beam, 10);
                        let got = sharded.predict(q, beam, 10);
                        assert_eq!(got, want, "{} S={s} beam={beam} q={qi}", cfg.label());
                    }
                }
            }
        }
    }

    #[test]
    fn batch_gather_matches_online_gather() {
        let m = tiny_model(24, 3, 3, 77);
        let cfg = EngineConfig {
            algo: MatmulAlgo::Mscm,
            iter: IterationMethod::Hash,
        };
        let sharded = ShardedEngine::from_model(&m, 3, cfg);
        let mut rng = Rng::seed_from_u64(4);
        let rows: Vec<SparseVec> = (0..9).map(|_| rand_query(&mut rng, 24)).collect();
        let x = CsrMatrix::from_rows(rows.clone(), 24);
        for parallel in [false, true] {
            let batch = sharded.predict_batch(&x, 3, 5, parallel);
            for (i, q) in rows.iter().enumerate() {
                assert_eq!(batch[i], sharded.predict(q, 3, 5), "parallel={parallel} q={i}");
            }
        }
    }

    #[test]
    fn beam_narrower_than_shard_count_stays_exact() {
        // The case the naive per-shard merge gets wrong: with beam 1 only
        // one shard's subtree may survive each layer; the others must
        // expand nothing rather than vote their own best leaf in.
        let m = tiny_model(24, 4, 3, 31);
        for cfg in EngineConfig::all() {
            let reference = InferenceEngine::new(m.clone(), cfg);
            let sharded = ShardedEngine::from_model(&m, 4, cfg);
            let mut rng = Rng::seed_from_u64(17);
            for qi in 0..20 {
                let q = rand_query(&mut rng, 24);
                assert_eq!(
                    sharded.predict(&q, 1, 3),
                    reference.predict(&q, 1, 3),
                    "{} q={qi}",
                    cfg.label()
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "incomplete partition")]
    fn missing_shard_panics() {
        let m = tiny_model(16, 4, 2, 3);
        let mut shards = crate::shard::partition(&m, 4);
        shards.remove(1);
        let cfg = EngineConfig {
            algo: MatmulAlgo::Mscm,
            iter: IterationMethod::MarchingPointers,
        };
        ShardedEngine::new(shards, cfg);
    }
}
