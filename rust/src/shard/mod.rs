//! Label-space sharding: split an XMR tree into shards and serve them
//! with an **exact** scatter-gather coordinator.
//!
//! The paper's §6 deployment (100M products served at 0.88 ms/query)
//! assumes the whole model is resident on one machine. At fleet scale,
//! weight residency is the binding constraint, so XR-Linear-style systems
//! shard the *label space*: the root's children are split into `S`
//! contiguous subtree groups, each a standalone model a fraction of the
//! size. This module adds that layer:
//!
//! - [`partition`] splits an [`XmrModel`](crate::tree::XmrModel) into
//!   [`ShardModel`]s — each wraps a self-contained `XmrModel` over a
//!   contiguous root-child range plus the remap back to global ids. Cuts
//!   are balanced by per-subtree weight nnz ([`subtree_nnz`]) rather than
//!   root-child count, so shard residency stays even on skewed trees;
//!   [`partition_planned`] balances by **planned resident bytes**
//!   ([`subtree_weight_bytes`]) instead — under a resolved plan, nnz is
//!   no longer proportional to bytes (a `DenseRows` chunk pays `d + 1`
//!   pointer slots, a quantized chunk a quarter the payload), and the
//!   byte-weighted cuts keep per-machine residency even where nnz cuts
//!   drift.
//!   Each shard optionally carries its own resolved
//!   [`KernelPlan`](crate::inference::KernelPlan)
//!   ([`ShardModel::plan_auto`]) — plans are per-shard, computed over the
//!   shard's own chunks (which survive the label remap verbatim), persist
//!   inside the shard file tagged with the algo they were costed for,
//!   and are served as-is under `--iter auto` with the same algo, so a
//!   calibrated model never re-plans at load (an algo mismatch falls
//!   back to a fresh resolution).
//! - [`save_shard`] / [`load_shard`] (+ the `save_shards`/[`load_shards`]
//!   directory helpers) persist shards in a versioned extension of the
//!   [`crate::tree`] binary format (magic `MSCMXMR3`, a shard-index
//!   header, the ordinary model body, then the plan section carrying
//!   each chunk's method *and* storage layout
//!   ([`crate::sparse::ChunkStorage`]); legacy `MSCMXMR2` files load as
//!   all-CSC).
//! - [`save_shard_v4`] writes the **layout-resolved** `MSCMXMR4`
//!   envelope: every chunk serialized in its *planned* physical layout
//!   (quantized payloads included), weight arrays 64-byte-aligned so the
//!   file doubles as an in-memory image. [`load_shard`] reads V4
//!   transparently (heap parse); [`load_shard_mmap`] serves the same
//!   file zero-copy off a private read-only mapping ([`MmapModel`] —
//!   raw `mmap(2)`, no libc crate), pinning only per-chunk structs on
//!   the heap while the weight bytes stay in the page cache. Exact
//!   layouts serve bitwise-identically either way; `MSCM_FORCE_MMAP=1`
//!   routes every V4 `load_shard` through the mapping (the CI leg).
//!   Byte layout and validation rules are specified in the `io` module
//!   docs and fuzzed by `rust/tests/format.rs`.
//! - [`ShardedEngine`] runs a query against every shard and merges the
//!   results; [`ShardedCoordinator`] serves it with dynamic batching,
//!   per-shard worker pools (each worker holding its own
//!   [`Workspace`](crate::inference::Workspace)) and bounded-queue
//!   backpressure, reusing [`crate::coordinator`]'s machinery.
//!
//! # Why the gather stage is exact
//!
//! Eq. 5 path scores are independent across root subtrees, but global
//! beam search is not: at every layer the unsharded engine keeps the top
//! `b` candidates across *all* subtrees. Fully independent per-shard beam
//! searches therefore cannot be merged exactly — a shard's local beam can
//! be crowded by children of parents the global beam already pruned,
//! displacing (and so never expanding) a node the global search keeps.
//!
//! The coordinator instead runs the **layer-synchronized** protocol: it
//! owns the global beam, and each round every shard expands exactly the
//! surviving beam nodes that fall in its column range, returning the
//! generated `(node, path score)` candidates. The gather stage merges
//! them under the global node ids and prunes with the engine's own
//! `select_top` comparator. This performs the unsharded computation
//! *verbatim* with candidate generation partitioned by shard:
//!
//! 1. The candidate set each layer is identical — the union over shards
//!    of "children of the global beam restricted to the shard" is the
//!    children of the global beam, because sibling chunks never straddle
//!    a shard boundary (the partition cuts between root children).
//! 2. Per-candidate scores are bitwise identical — a shard's columns are
//!    verbatim slices of the global weight matrices, every iteration
//!    method accumulates each column's dot product in the same ascending
//!    feature order, and parent path scores multiply through the same
//!    chain of f32 operations.
//! 3. Selection is order-independent — `(score desc, node id asc)` under
//!    `total_cmp` is a strict total order, so the top-`b` set does not
//!    depend on the order shards' candidates are merged in.
//!
//! The surviving bottom beam, sorted and truncated exactly as the engine
//! does, equals the unsharded output bit for bit — enforced for
//! `S ∈ {1, 2, 4, 7}`, both [`MatmulAlgo`](crate::inference::MatmulAlgo)s
//! and all four iteration methods by the `rust/tests/sharding.rs`
//! property suite. The cost is `depth` scatter rounds per batch instead
//! of one; the dynamic batcher amortizes the rounds across every query
//! in the batch, and every round buffer is pooled ([`GatherArena`] /
//! [`ShardRound`] cycling gather → shard → gather) so the steady-state
//! rounds are allocation-free.
//!
//! # Cross-process serving: the wire protocol
//!
//! The [`wire`] + [`remote`] pair lifts the same protocol across
//! processes: a [`ShardHost`] loads one shard file and answers layer
//! rounds over TCP; a [`RemoteGather`] (or the batching
//! [`RemoteShardedCoordinator`]) drives N hosts with the very same
//! merge/split/prune code the in-process engine uses, so remote serving
//! is bitwise identical to unsharded inference (property-tested over
//! loopback in `rust/tests/remote.rs`).
//!
//! Frames are versioned and length-prefixed (see [`wire`] for the exact
//! layout): a 12-byte header — magic, version (exact match required),
//! message type, payload length — then the payload. The conversation is
//! `Hello → ShardInfo` once per connection, then `Expand → Cands` per
//! layer round; protocol violations are answered with an `Error` frame
//! and a close. An `Expand` carries the query rows *and* the shard-local
//! beam slice, so every round is stateless and self-contained.
//!
//! # Live stats over the wire
//!
//! Protocol v2 adds a `Stats` frame: an **empty-payload** `Stats` is a
//! poll request, answered with a `Stats` frame carrying the host's full
//! metrics [`Snapshot`](crate::metrics::Snapshot) — named counters
//! (connections, expand frames, stats polls), plus the shard engine's
//! per-layer / per-chunk-class telemetry under the `engine.` prefix when
//! the host runs with [`ShardHostConfig::metrics`] enabled (the
//! default). Polls are valid any time after the handshake and leave
//! round state untouched, so a monitor can share a connection with live
//! traffic or ride a dedicated one. [`poll_stats`] is the one-call
//! client: connect, handshake, poll, decode. The `metrics` CLI
//! subcommand wraps it with text/Prometheus/JSON rendering and
//! windowed diffing ([`Snapshot::diff`](crate::metrics::Snapshot::diff)).
//! The frame layout and its strict-parse caps are documented in
//! [`wire`]; `rust/tests/metrics.rs` fuzzes every truncation prefix and
//! pins that a live host keeps serving bitwise-identical results while
//! being polled.
//!
//! # Distributed tracing and the flight recorder (protocol v3)
//!
//! Protocol v3 makes every scatter round *traceable* without making any
//! round *slower*. An `Expand` frame may carry a trace flag plus the
//! coordinator-minted batch trace id; a traced host times its own
//! decode → expand → encode split and piggybacks a fixed-size host span
//! (plus the effective kernel-tier mask for the layer) on the `Cands`
//! reply. Untraced frames are byte-identical to the v2 payloads, so
//! tracing never perturbs the bytes it measures. The client side
//! ([`RemoteGather`] and the in-process [`ShardedCoordinator`])
//! assembles a per-batch **trace tree** — one
//! [`RoundSpan`](crate::metrics::RoundSpan) per shard per layer round,
//! carrying send time, round wall time, join-wait skew, the host span,
//! and the chaos events (hedge / failover / ejection / dead shard /
//! degraded batch / speculation hit or miss) attributed to that round.
//!
//! Completed traces land in a fixed-capacity lock-free
//! [`FlightRecorder`](crate::metrics::FlightRecorder) ring on both ends
//! (tail-based retention: batches over the live p99 are pinned, the
//! rest 1-in-N sampled; recording is allocation-free and drops under
//! contention rather than blocking). A host's ring is pollable over the
//! wire: an **empty-payload** `Traces` frame is a poll request, answered
//! with the retained [`TraceRecord`](crate::metrics::TraceRecord)s,
//! newest first. [`poll_traces`] is the one-call client; `metrics
//! --traces` wraps it, and `serve --flight-recorder N` sizes (or, at 0,
//! fully disables) the coordinator-side ring. `rust/tests/tracing.rs`
//! pins the contract: traced serving is bitwise identical to untraced,
//! span sums stay inside their enclosing rounds, and injected-slow
//! queries are provably retained by the tail sampler.
//!
//! # Failover and replica health
//!
//! Each shard is addressable by one or more replicas. Every replica
//! carries its own health record — consecutive-failure count, EWMA round
//! latency, circuit-breaker cooldown — and walks this machine:
//!
//! ```text
//!                     success (resets failure count)
//!          ┌───────────────────────────────────────────────┐
//!          ▼                                               │
//!    ┌──────────┐  round fails   ┌─────────┐  fails reach  │
//!    │ HEALTHY  ├───────────────►│ SUSPECT ├── threshold ──┤
//!    └────┬─────┘                └────┬────┘               │
//!         │ rotates round-robin       │ still selectable   │
//!         │ with its peers            ▼                    │
//!         │                     ┌──────────┐ cooldown  ┌───┴───────┐
//!         │                     │ EJECTED  ├── ends ──►│ PROBATION │
//!         │                     └────▲─────┘           └───┬───────┘
//!         │   circuit open: no       │   one more failure: │
//!         │   traffic, cooldown      └── re-ejected with a ┘
//!         │   doubles per ejection       doubled cooldown
//! ```
//!
//! Per round, the client sends on the **active** replica and on any io
//! error or timeout drops that connection, records the failure, advances
//! round-robin to the next selectable replica (skipping open circuits),
//! and re-sends the retained `Expand` frame there — bounded attempts,
//! capped exponential backoff with seeded jitter between full cycles.
//! Because the encoded frame is retained until its reply is decoded,
//! failover is a byte-identical re-send — a replica killed mid-query
//! costs one reconnect, never a failed query (demonstrated by
//! `examples/remote_search.rs`, the failover tests and the
//! `rust/tests/chaos.rs` suite).
//!
//! **Hedging fast path** ([`RemoteConfig::hedge`](remote::RemoteConfig)):
//! once a shard's round histogram is warm, the first reply read is
//! bounded by the shard's observed p99 — a slower reply is abandoned and
//! the round re-issued on the next healthy replica. First valid reply
//! wins; replies are deterministic, so hedging trades tail latency for
//! duplicated work without ever changing results.
//!
//! **Deadline budgets** ([`RemoteConfig::deadline`](remote::RemoteConfig)):
//! a per-batch budget caps every round read, reconnect and backoff sleep;
//! when it runs out the batch fails with `TimedOut` rather than retrying
//! further, so no batch outlives its budget. An *exhausted* budget is
//! distinguished from the `Duration::ZERO` "no deadline" config
//! sentinel: a remaining budget that computes to zero surfaces as
//! `TimedOut` rather than being passed on as a zero socket timeout
//! (which `std` reads as *unbounded* — the collision `rust/tests/chaos.rs`
//! pins against).
//!
//! **Degraded-mode contract**
//! ([`RemoteConfig::allow_partial`](remote::RemoteConfig)): by default a
//! shard whose replicas are *all* down fails the batch (exact-or-fail).
//! With `--allow-partial`, the batch instead completes over the live
//! shards: the dead shard contributes no candidates, the response is
//! explicitly flagged `degraded`, and `remote.degraded_batches` counts
//! it. A degraded ranking is exactly the beam search over the live
//! shards' label subspace — deterministic and bitwise equal to serving
//! that sub-partition alone — never a silently wrong full-space answer.
//! Deadline expiry still fails the batch even under `--allow-partial`.
//!
//! All of the above is chaos-tested: [`fault`] injects seeded,
//! replayable fault schedules (refused connects, dropped/delayed/
//! truncated/corrupted/stuttered replies, paused hosts) into
//! [`ShardHost`] and the client transport, and `rust/tests/chaos.rs`
//! pins exactness, deadline bounds, ejection/rejoin and the degraded
//! contract under them. Speculative expansion ([`remote`] module docs)
//! additionally halves the number of network rounds per query without
//! touching exactness.

mod engine;
pub mod fault;
mod io;
mod partition;
pub mod remote;
mod serve;
pub mod wire;

pub use engine::{GatherArena, ShardRound, ShardedEngine};
pub use fault::{ConnSchedule, FaultInjector, FaultPlan};
pub use io::{
    load_shard, load_shard_mmap, load_shards, save_shard, save_shard_v4, save_shards,
    shard_file_name, MmapModel,
};
pub use partition::{
    partition, partition_planned, subtree_nnz, subtree_weight_bytes, ShardModel, ShardSpec,
};
pub use remote::{
    discover, poll_stats, poll_traces, RemoteConfig, RemoteCoordinatorConfig, RemoteGather,
    RemoteShardedCoordinator, RemoteStats, ReplicaPhase, ShardHost, ShardHostConfig,
};
pub use serve::{ShardedCoordinator, ShardedCoordinatorConfig};
