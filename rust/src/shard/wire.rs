//! The shard wire protocol: versioned, length-prefixed binary frames for
//! the layer-synchronized scatter-gather rounds between a gather stage
//! and remote shard hosts.
//!
//! # Framing
//!
//! Every message is one frame (all integers little-endian):
//!
//! ```text
//! magic        u32  = 0x4d58_5750 ("PWXM" on the wire)
//! version      u16  = WIRE_VERSION (exact match required)
//! msg_type     u16  (MsgType)
//! payload_len  u32  (bytes after this header; capped at MAX_FRAME)
//! payload      payload_len bytes
//! ```
//!
//! [`read_frame`] validates magic, version and length before touching the
//! payload; a version mismatch is a hard error (the peer replies with an
//! [`MsgType::Error`] frame and closes). Truncated headers or payloads
//! surface as `UnexpectedEof`; structural violations inside a payload
//! (list lengths past the frame end, trailing bytes, out-of-range ids)
//! surface as `InvalidData`.
//!
//! # Messages
//!
//! | type        | direction     | payload |
//! |-------------|---------------|---------|
//! | `Hello`     | client → host | empty (version rides in the header) |
//! | `ShardInfo` | host → client | shard identity + per-layer topology |
//! | `Expand`    | client → host | one layer round: queries + beam slices (+ trace id, v3) |
//! | `Cands`     | host → client | per-query candidates (+ speculation, + host span v3) |
//! | `Stats`     | both          | empty = poll request; reply = snapshot (v2) |
//! | `Traces`    | both          | empty = poll request; reply = flight-recorder records (v3) |
//! | `Error`     | host → client | code + message, then the host closes |
//!
//! A `Stats` frame with an **empty** payload is a poll: the host replies
//! with a `Stats` frame carrying a serialized [`crate::metrics::Snapshot`]
//! of its registry (engine telemetry included) —
//!
//! ```text
//! u32 n_counters   n × { str name; u64 value }
//! u32 n_gauges     n × { str name; u64 f64_bits }
//! u32 n_histograms n × { str name; u64 count; u64 sum_us; u64 max_us;
//!                        u32 n_buckets; n_buckets × u64 }
//! str = u32 len + that many UTF-8 bytes
//! ```
//!
//! decoded as strictly as every other frame (list lengths pre-checked,
//! names bounded, UTF-8 validated, no trailing bytes). Polls are valid at
//! any point after the handshake and leave round state untouched, so a
//! monitor can share a connection with live traffic.
//!
//! An `Expand` carries *everything* the round needs — the query rows and
//! the shard-local beam slice — so rounds are stateless: a round that
//! times out on one replica re-issues byte-identically to the next
//! ([`super::remote`]'s failover).
//!
//! # v3: distributed tracing
//!
//! Protocol v3 threads the cross-process trace tree through the round
//! frames without changing untraced bytes:
//!
//! - The `Expand` speculation flag became a **flag word**: bit 0 =
//!   speculate (the v2 meaning), bit 1 = trace. When the trace bit is
//!   set, a `u64` batch span id (`trace_id`) follows the flag word;
//!   every other bit is rejected. An untraced v3 `Expand` payload is
//!   byte-identical to its v2 encoding.
//! - The `Cands` speculation flag is the same flag word: bit 0 = the
//!   reply carries a speculation section, bit 1 = it ends with a **host
//!   span** — `decode_ns`/`expand_ns`/`encode_ns` (`u64` each) measured
//!   around the host's round handling, plus a `u32` effective
//!   kernel-tier bitmask. `encode_ns` is backpatched into the encoded
//!   frame ([`patch_cands_encode_ns`]) because the encode duration is
//!   only known once the encode finishes. An untraced reply is
//!   byte-identical to v2.
//! - A `Traces` frame with an **empty** payload polls the peer's
//!   [`crate::metrics::FlightRecorder`]; the reply is a `Traces` frame
//!   carrying its retained [`crate::metrics::TraceRecord`]s —
//!
//! ```text
//! u32 n_records    n × {
//!   u64 trace_id; u32 batch; u32 beam; u64 total_ns;
//!   u32 events; u32 flags (bit 0 = pinned); u32 truncated; u32 n_spans;
//!   n_spans × { u32 shard; u32 layer;
//!               u64 tx_ns; u64 round_ns; u64 wait_ns;
//!               u64 decode_ns; u64 expand_ns; u64 encode_ns;
//!               u32 tiers; u32 events }
//! }
//! ```
//!
//! decoded as strictly as the `Stats` reply: record/span counts are
//! capped ([`MAX_TRACE_RECORDS`], [`crate::metrics::MAX_TRACE_SPANS`]),
//! unknown flag bits are rejected, and trailing bytes fail the frame.
//! Like `Stats`, polls are valid any time after the handshake and leave
//! round state untouched.
//!
//! # Partial writes and corruption
//!
//! [`read_frame`] blocks until the full header and payload arrive, so a
//! peer that writes a frame in several chunks (slow-loris) either
//! completes — parsed like any other frame — or hits the reader's socket
//! timeout, which the client treats as a replica failure: the stream is
//! mid-frame and unrecoverable, so the connection is dropped and the
//! round re-issued elsewhere, never parsed as truncation garbage
//! (`rust/tests/wire.rs` and the chaos suite pin this). Note there is no
//! payload checksum: the protocol detects *framing* damage (bad magic /
//! version / type / length), not flipped payload bytes — which is why
//! the seeded corruption in [`super::fault`] targets the header only.
//!
//! # Pooling
//!
//! Encoders write whole frames into a caller-held `Vec<u8>` (cleared, so
//! capacity is recycled); decoders fill the caller's pooled
//! [`ShardRound`] / [`SpecRound`] / `CsrMatrix` buffers in place. After
//! warmup at a bounded batch size the codec performs no allocations
//! beyond amortized buffer growth.

use std::io::{self, Read};

use super::engine::ShardRound;
use crate::metrics::{
    HistogramSnapshot, HostSpan, RoundSpan, Snapshot, TraceRecord, MAX_TRACE_SPANS,
};
use crate::sparse::CsrMatrix;

/// Frame magic ("MXWP" as a little-endian u32).
pub const WIRE_MAGIC: u32 = 0x4d58_5750;
/// Protocol version; peers must match exactly. v2 added the `Stats`
/// poll/reply frame; v3 added the `Expand`/`Cands` trace sections and
/// the `Traces` poll (untraced round payloads are byte-identical to
/// v2).
pub const WIRE_VERSION: u16 = 3;
/// Frame header length in bytes.
pub const HEADER_LEN: usize = 12;
/// Maximum accepted payload (guards against garbage length fields).
pub const MAX_FRAME: usize = 1 << 28;

/// Error code: the peer speaks a different protocol version.
pub const ERR_VERSION: u32 = 1;
/// Error code: malformed or out-of-range frame contents.
pub const ERR_MALFORMED: u32 = 2;
/// Error code: frame type not valid in the current protocol state.
pub const ERR_PROTOCOL: u32 = 3;

/// Frame types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgType {
    /// Client handshake (empty payload).
    Hello,
    /// Host handshake reply: shard identity + topology.
    ShardInfo,
    /// One scatter round: beam slices (+ queries) for one layer.
    Expand,
    /// Round reply: per-query candidates, optionally with speculation.
    Cands,
    /// Metrics poll (empty payload) or its snapshot reply.
    Stats,
    /// Flight-recorder poll (empty payload) or its trace-record reply
    /// (v3).
    Traces,
    /// Protocol failure; the sender closes after this frame.
    Error,
}

impl MsgType {
    fn code(self) -> u16 {
        match self {
            MsgType::Hello => 1,
            MsgType::ShardInfo => 2,
            MsgType::Expand => 3,
            MsgType::Cands => 4,
            MsgType::Error => 5,
            MsgType::Stats => 6,
            MsgType::Traces => 7,
        }
    }

    fn from_code(c: u16) -> Option<MsgType> {
        Some(match c {
            1 => MsgType::Hello,
            2 => MsgType::ShardInfo,
            3 => MsgType::Expand,
            4 => MsgType::Cands,
            5 => MsgType::Error,
            6 => MsgType::Stats,
            7 => MsgType::Traces,
            _ => return None,
        })
    }
}

/// Shard identity + topology, as announced in the handshake — everything
/// the gather stage needs to merge this shard's candidates into global
/// node ids and split the global beam back ([`super::RemoteGather`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireShardInfo {
    /// Shard index in `0..num_shards`.
    pub shard_id: u32,
    /// Total shards in the partition.
    pub num_shards: u32,
    /// Tree depth in ranker layers.
    pub depth: u32,
    /// Feature dimension `d`.
    pub dim: u64,
    /// Global label id of local label 0.
    pub label_offset: u64,
    /// Labels owned by this shard.
    pub num_labels: u64,
    /// Global column id of each layer's local node 0.
    pub layer_offsets: Vec<u32>,
    /// Local node count per layer.
    pub layer_nodes: Vec<u32>,
}

/// Header of an [`MsgType::Expand`] round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpandHeader {
    /// Client-chosen round id, echoed in the reply (desync detector).
    pub round_id: u64,
    /// Layer being expanded.
    pub layer: u32,
    /// Global beam width (also the speculation width).
    pub beam: u32,
    /// Ask the host to piggyback its local top-`beam` expansion of the
    /// *next* layer onto the reply.
    pub speculate: bool,
    /// Ask the host to time this round and piggyback a [`HostSpan`] on
    /// the reply (v3). When unset the encoded payload is byte-identical
    /// to v2.
    pub trace: bool,
    /// Batch span id carried to the host when `trace` is set (0
    /// otherwise; not encoded for untraced rounds).
    pub trace_id: u64,
}

/// Header of an [`MsgType::Cands`] reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CandsHeader {
    /// Echo of the request's round id.
    pub round_id: u64,
    /// Echo of the expanded layer.
    pub layer: u32,
    /// The reply carries a speculation section.
    pub has_spec: bool,
    /// The host's round timings, when the reply ends with a v3 span
    /// section (`None` from an untraced host).
    pub host_span: Option<HostSpan>,
}

/// A host's speculative expansion of one layer, pooled like
/// [`ShardRound`]: for each query, the shard-local top-`beam` candidates
/// of the *previous* layer (`parents`, node ids ascending) and, flattened
/// in parent order, every child candidate those parents generate
/// (`children`, `child_counts[p]` entries per parent).
///
/// Because the true local beam slice of the global beam is always a
/// subset of the shard's local top-`beam` (anything that survives the
/// global cut survives the shard-local cut a fortiori), the gather stage
/// can assemble the next layer's exact candidates from this hint and skip
/// the network round entirely — see [`super::remote`].
#[derive(Debug, Default)]
pub struct SpecRound {
    /// Live query count; only the first `n` entries of each buffer hold
    /// this round's data.
    pub n: usize,
    /// Per query: speculated parents (local node ids ascending).
    pub parents: Vec<Vec<(u32, f32)>>,
    /// Per query: children generated per parent (sibling-chunk widths).
    pub child_counts: Vec<Vec<u32>>,
    /// Per query: flattened `(local node, path score)` children.
    pub children: Vec<Vec<(u32, f32)>>,
}

impl SpecRound {
    /// Grows the per-query buffers to `n` (never shrinks — high-water
    /// capacity is the pooling contract).
    pub fn ensure(&mut self, n: usize) {
        self.n = n;
        if self.parents.len() < n {
            self.parents.resize_with(n, Vec::new);
        }
        if self.child_counts.len() < n {
            self.child_counts.resize_with(n, Vec::new);
        }
        if self.children.len() < n {
            self.children.resize_with(n, Vec::new);
        }
    }
}

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Stable marker embedded in version-mismatch errors; classification
/// happens via [`error_code_for`], never by peers matching free text.
const VERSION_MSG: &str = "protocol version mismatch";

/// Maps a frame-reading error to the [`MsgType::Error`] code a host
/// should reply with — the single place tying error construction to
/// wire codes, so rewording messages cannot silently change the code a
/// peer receives.
pub fn error_code_for(e: &io::Error) -> u32 {
    if e.to_string().contains(VERSION_MSG) {
        ERR_VERSION
    } else {
        ERR_PROTOCOL
    }
}

// ---------------------------------------------------------------------
// primitive writers
// ---------------------------------------------------------------------

#[inline]
fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_pairs(buf: &mut Vec<u8>, pairs: &[(u32, f32)]) {
    for &(a, b) in pairs {
        put_u32(buf, a);
        put_f32(buf, b);
    }
}

/// Starts a frame: header with a length placeholder.
fn begin_frame(buf: &mut Vec<u8>, ty: MsgType) {
    buf.clear();
    put_u32(buf, WIRE_MAGIC);
    put_u16(buf, WIRE_VERSION);
    put_u16(buf, ty.code());
    put_u32(buf, 0); // payload length backpatched by end_frame
}

/// Backpatches the payload length.
fn end_frame(buf: &mut Vec<u8>) {
    let len = buf.len() - HEADER_LEN;
    debug_assert!(len <= MAX_FRAME, "frame over MAX_FRAME");
    buf[8..12].copy_from_slice(&(len as u32).to_le_bytes());
}

// ---------------------------------------------------------------------
// primitive reader
// ---------------------------------------------------------------------

/// Bounds-checked payload cursor; every read fails loudly on truncation.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.b.len() - self.pos < n {
            return Err(invalid("truncated payload"));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Checks `n` more bytes exist without consuming them — used before
    /// list reads so a garbage length field fails fast instead of
    /// looping.
    fn need(&self, n: usize) -> io::Result<()> {
        if self.b.len() - self.pos < n {
            return Err(invalid("truncated payload (list length past frame end)"));
        }
        Ok(())
    }

    fn u32(&mut self) -> io::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> io::Result<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }

    fn f32(&mut self) -> io::Result<f32> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn pairs_into(&mut self, count: usize, out: &mut Vec<(u32, f32)>) -> io::Result<()> {
        self.need(count * 8)?;
        out.clear();
        out.reserve(count);
        for _ in 0..count {
            let a = self.u32()?;
            let b = self.f32()?;
            out.push((a, b));
        }
        Ok(())
    }

    fn u32s_into(&mut self, count: usize, out: &mut Vec<u32>) -> io::Result<()> {
        self.need(count * 4)?;
        out.clear();
        out.reserve(count);
        for _ in 0..count {
            out.push(self.u32()?);
        }
        Ok(())
    }

    /// Payloads must be consumed exactly — trailing bytes mean the peer
    /// and we disagree about the message layout.
    fn done(&self) -> io::Result<()> {
        if self.pos != self.b.len() {
            return Err(invalid("trailing bytes in frame payload"));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// frame IO
// ---------------------------------------------------------------------

/// Reads one frame: validates the header, fills `payload` (pooled; only
/// its capacity is recycled) and returns the message type. A closed
/// stream surfaces as `UnexpectedEof`; bad magic, an unknown type, an
/// oversized length or a **version mismatch** surface as `InvalidData`.
pub fn read_frame(r: &mut impl Read, payload: &mut Vec<u8>) -> io::Result<MsgType> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr)?;
    let magic = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]);
    if magic != WIRE_MAGIC {
        return Err(invalid(format!("bad wire magic {magic:#010x}")));
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if version != WIRE_VERSION {
        return Err(invalid(format!(
            "{VERSION_MSG}: peer v{version}, ours v{WIRE_VERSION}"
        )));
    }
    let ty = u16::from_le_bytes([hdr[6], hdr[7]]);
    let ty = MsgType::from_code(ty).ok_or_else(|| invalid(format!("unknown frame type {ty}")))?;
    let len = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]) as usize;
    if len > MAX_FRAME {
        return Err(invalid(format!("frame of {len} bytes exceeds MAX_FRAME")));
    }
    payload.clear();
    payload.resize(len, 0);
    r.read_exact(payload)?;
    Ok(ty)
}

// ---------------------------------------------------------------------
// message encoders / decoders
// ---------------------------------------------------------------------

/// Encodes the client handshake.
pub fn encode_hello(buf: &mut Vec<u8>) {
    begin_frame(buf, MsgType::Hello);
    end_frame(buf);
}

/// Encodes the host's handshake reply.
pub fn encode_shard_info(buf: &mut Vec<u8>, info: &WireShardInfo) {
    debug_assert_eq!(info.layer_offsets.len(), info.depth as usize);
    debug_assert_eq!(info.layer_nodes.len(), info.depth as usize);
    begin_frame(buf, MsgType::ShardInfo);
    put_u32(buf, info.shard_id);
    put_u32(buf, info.num_shards);
    put_u32(buf, info.depth);
    put_u64(buf, info.dim);
    put_u64(buf, info.label_offset);
    put_u64(buf, info.num_labels);
    for &o in &info.layer_offsets {
        put_u32(buf, o);
    }
    for &c in &info.layer_nodes {
        put_u32(buf, c);
    }
    end_frame(buf);
}

/// Decodes a [`MsgType::ShardInfo`] payload.
pub fn decode_shard_info(payload: &[u8]) -> io::Result<WireShardInfo> {
    let mut rd = Rd::new(payload);
    let shard_id = rd.u32()?;
    let num_shards = rd.u32()?;
    let depth = rd.u32()?;
    let dim = rd.u64()?;
    let label_offset = rd.u64()?;
    let num_labels = rd.u64()?;
    if num_shards == 0 || shard_id >= num_shards {
        return Err(invalid("shard id out of range"));
    }
    if depth == 0 || depth as usize > 1 << 16 {
        return Err(invalid("implausible shard depth"));
    }
    let mut layer_offsets = Vec::new();
    rd.u32s_into(depth as usize, &mut layer_offsets)?;
    let mut layer_nodes = Vec::new();
    rd.u32s_into(depth as usize, &mut layer_nodes)?;
    rd.done()?;
    Ok(WireShardInfo {
        shard_id,
        num_shards,
        depth,
        dim,
        label_offset,
        num_labels,
        layer_offsets,
        layer_nodes,
    })
}

/// Encodes one scatter round: the query rows (`x.rows == n`) and each
/// query's shard-local beam slice (`beams[q]`, node ids ascending).
pub fn encode_expand(
    buf: &mut Vec<u8>,
    hdr: &ExpandHeader,
    x: &CsrMatrix,
    beams: &[Vec<(u32, f32)>],
    n: usize,
) {
    debug_assert_eq!(x.rows, n, "query matrix disagrees with batch size");
    debug_assert!(beams.len() >= n);
    begin_frame(buf, MsgType::Expand);
    put_u64(buf, hdr.round_id);
    put_u32(buf, hdr.layer);
    put_u32(buf, hdr.beam);
    put_u32(buf, hdr.speculate as u32 | (hdr.trace as u32) << 1);
    if hdr.trace {
        put_u64(buf, hdr.trace_id);
    }
    put_u32(buf, n as u32);
    for q in 0..n {
        let row = x.row(q);
        put_u32(buf, row.indices.len() as u32);
        for &i in row.indices {
            put_u32(buf, i);
        }
        for &v in row.values {
            put_f32(buf, v);
        }
    }
    for b in &beams[..n] {
        put_u32(buf, b.len() as u32);
        put_pairs(buf, b);
    }
    end_frame(buf);
}

/// Decodes an [`MsgType::Expand`] payload into the host's pooled query
/// matrix and round buffers (`round.beams` filled, `round.cands` left to
/// the expansion). Validates feature ids against `dim` and requires
/// monotone query indices / strictly ascending beam node ids, so a
/// malformed frame can never reach the kernels.
pub fn decode_expand(
    payload: &[u8],
    dim: usize,
    x: &mut CsrMatrix,
    round: &mut ShardRound,
) -> io::Result<ExpandHeader> {
    let mut rd = Rd::new(payload);
    let round_id = rd.u64()?;
    let layer = rd.u32()?;
    let beam = rd.u32()?;
    let flags = rd.u32()?;
    if flags & !0b11 != 0 {
        return Err(invalid(format!("bad speculate flag {flags}")));
    }
    let speculate = flags & 0b01 != 0;
    let trace = flags & 0b10 != 0;
    let trace_id = if trace { rd.u64()? } else { 0 };
    let n = rd.u32()? as usize;
    if n == 0 {
        return Err(invalid("empty round (n = 0)"));
    }
    if beam == 0 {
        return Err(invalid("beam width must be >= 1"));
    }
    x.reset(dim);
    for _ in 0..n {
        let nnz = rd.u32()? as usize;
        rd.need(nnz * 8)?;
        let mut prev: Option<u32> = None;
        for _ in 0..nnz {
            let idx = rd.u32()?;
            if idx as usize >= dim {
                return Err(invalid(format!("query feature {idx} out of range (dim {dim})")));
            }
            if prev.is_some_and(|p| idx < p) {
                return Err(invalid("query feature ids not ascending"));
            }
            prev = Some(idx);
            x.indices.push(idx);
        }
        for _ in 0..nnz {
            let v = rd.f32()?;
            x.values.push(v);
        }
        x.indptr.push(x.indices.len());
        x.rows += 1;
    }
    round.ensure(n);
    for q in 0..n {
        let len = rd.u32()? as usize;
        rd.pairs_into(len, &mut round.beams[q])?;
        let mut prev: Option<u32> = None;
        for &(node, _) in &round.beams[q] {
            if prev.is_some_and(|p| node <= p) {
                return Err(invalid("beam node ids not strictly ascending"));
            }
            prev = Some(node);
        }
    }
    rd.done()?;
    Ok(ExpandHeader {
        round_id,
        layer,
        beam,
        speculate,
        trace,
        trace_id,
    })
}

/// Encodes a round reply from the host's pooled buffers: per-query
/// candidates out of `round.cands`, plus the speculation section when
/// `spec` is given, plus the v3 host span when the round was traced.
///
/// `span.encode_ns` is typically 0 here — the host cannot time the
/// encode it is still inside of. Measure after this returns and
/// backpatch with [`patch_cands_encode_ns`].
pub fn encode_cands(
    buf: &mut Vec<u8>,
    round_id: u64,
    layer: u32,
    round: &ShardRound,
    spec: Option<&SpecRound>,
    span: Option<&HostSpan>,
) {
    let n = round.n;
    begin_frame(buf, MsgType::Cands);
    put_u64(buf, round_id);
    put_u32(buf, layer);
    put_u32(buf, spec.is_some() as u32 | (span.is_some() as u32) << 1);
    put_u32(buf, n as u32);
    for c in &round.cands[..n] {
        put_u32(buf, c.len() as u32);
        put_pairs(buf, c);
    }
    if let Some(sp) = spec {
        debug_assert_eq!(sp.n, n, "speculation batch size disagrees with reply");
        for q in 0..n {
            let parents = &sp.parents[q];
            let counts = &sp.child_counts[q];
            debug_assert_eq!(parents.len(), counts.len());
            put_u32(buf, parents.len() as u32);
            put_pairs(buf, parents);
            for &c in counts {
                put_u32(buf, c);
            }
            debug_assert_eq!(
                counts.iter().map(|&c| c as usize).sum::<usize>(),
                sp.children[q].len(),
                "speculated children disagree with per-parent counts"
            );
            put_pairs(buf, &sp.children[q]);
        }
    }
    if let Some(sp) = span {
        put_u64(buf, sp.decode_ns);
        put_u64(buf, sp.expand_ns);
        put_u64(buf, sp.encode_ns);
        put_u32(buf, sp.tiers);
    }
    end_frame(buf);
}

/// Backpatches the `encode_ns` field of the trailing host span in an
/// already-encoded [`MsgType::Cands`] frame. The span section ends the
/// payload as `decode_ns u64, expand_ns u64, encode_ns u64, tiers u32`,
/// so `encode_ns` occupies `frame[len-12..len-4]`. Only valid on a frame
/// produced by [`encode_cands`] with `span = Some(..)`.
pub fn patch_cands_encode_ns(frame: &mut [u8], encode_ns: u64) {
    let len = frame.len();
    debug_assert!(len >= HEADER_LEN + 12, "frame too short to hold a host span");
    frame[len - 12..len - 4].copy_from_slice(&encode_ns.to_le_bytes());
}

/// Decodes an [`MsgType::Cands`] payload into the gather stage's pooled
/// round (`round.cands`; `round.beams` untouched) and, when present, the
/// speculation buffers.
pub fn decode_cands(
    payload: &[u8],
    round: &mut ShardRound,
    spec: &mut SpecRound,
) -> io::Result<CandsHeader> {
    let mut rd = Rd::new(payload);
    let round_id = rd.u64()?;
    let layer = rd.u32()?;
    let flags = rd.u32()?;
    if flags & !0b11 != 0 {
        return Err(invalid(format!("bad speculation flag {flags}")));
    }
    let has_spec = flags & 0b01 != 0;
    let has_span = flags & 0b10 != 0;
    let n = rd.u32()? as usize;
    if n == 0 {
        return Err(invalid("empty reply (n = 0)"));
    }
    round.ensure(n);
    for q in 0..n {
        let len = rd.u32()? as usize;
        rd.pairs_into(len, &mut round.cands[q])?;
    }
    if has_spec {
        spec.ensure(n);
        for q in 0..n {
            let p = rd.u32()? as usize;
            rd.pairs_into(p, &mut spec.parents[q])?;
            rd.u32s_into(p, &mut spec.child_counts[q])?;
            let total: usize = spec.child_counts[q].iter().map(|&c| c as usize).sum();
            rd.pairs_into(total, &mut spec.children[q])?;
            let mut prev: Option<u32> = None;
            for &(node, _) in &spec.parents[q] {
                if prev.is_some_and(|pn| node <= pn) {
                    return Err(invalid("speculated parents not strictly ascending"));
                }
                prev = Some(node);
            }
        }
    } else {
        spec.n = 0;
    }
    let host_span = if has_span {
        Some(HostSpan {
            decode_ns: rd.u64()?,
            expand_ns: rd.u64()?,
            encode_ns: rd.u64()?,
            tiers: rd.u32()?,
        })
    } else {
        None
    };
    rd.done()?;
    Ok(CandsHeader {
        round_id,
        layer,
        has_spec,
        host_span,
    })
}

/// Encodes a protocol-error reply.
pub fn encode_error(buf: &mut Vec<u8>, code: u32, msg: &str) {
    begin_frame(buf, MsgType::Error);
    put_u32(buf, code);
    let bytes = msg.as_bytes();
    put_u32(buf, bytes.len() as u32);
    buf.extend_from_slice(bytes);
    end_frame(buf);
}

/// Decodes an [`MsgType::Error`] payload.
pub fn decode_error(payload: &[u8]) -> io::Result<(u32, String)> {
    let mut rd = Rd::new(payload);
    let code = rd.u32()?;
    let len = rd.u32()? as usize;
    let bytes = rd.take(len)?;
    rd.done()?;
    let msg = String::from_utf8_lossy(bytes).into_owned();
    Ok((code, msg))
}

/// Turns a received [`MsgType::Error`] payload into an `io::Error`.
pub fn error_from_frame(payload: &[u8]) -> io::Error {
    match decode_error(payload) {
        Ok((code, msg)) => invalid(format!("shard host error {code}: {msg}")),
        Err(e) => e,
    }
}

/// Most series a [`MsgType::Stats`] reply may carry per kind — far above
/// any real registry, low enough that a garbage count fails fast.
const MAX_STATS_SERIES: usize = 65_536;
/// Longest accepted metric name.
const MAX_STATS_NAME: usize = 256;
/// Most histogram buckets (the in-crate histogram has 96).
const MAX_STATS_BUCKETS: usize = 4_096;

fn put_name(buf: &mut Vec<u8>, name: &str) {
    debug_assert!(name.len() <= MAX_STATS_NAME, "metric name over wire cap");
    let bytes = name.as_bytes();
    put_u32(buf, bytes.len() as u32);
    buf.extend_from_slice(bytes);
}

impl<'a> Rd<'a> {
    fn name(&mut self) -> io::Result<String> {
        let len = self.u32()? as usize;
        if len > MAX_STATS_NAME {
            return Err(invalid(format!("metric name of {len} bytes too long")));
        }
        let bytes = self.take(len)?;
        std::str::from_utf8(bytes)
            .map(str::to_owned)
            .map_err(|_| invalid("metric name is not UTF-8"))
    }

    fn series_count(&mut self) -> io::Result<usize> {
        let n = self.u32()? as usize;
        if n > MAX_STATS_SERIES {
            return Err(invalid(format!("{n} stats series exceeds wire cap")));
        }
        Ok(n)
    }
}

/// Encodes a metrics poll: a [`MsgType::Stats`] frame with an empty
/// payload.
pub fn encode_stats_poll(buf: &mut Vec<u8>) {
    begin_frame(buf, MsgType::Stats);
    end_frame(buf);
}

/// Validates a [`MsgType::Stats`] poll payload (must be empty — a
/// non-empty payload at the host means the peer sent a snapshot where a
/// poll belongs).
pub fn decode_stats_poll(payload: &[u8]) -> io::Result<()> {
    if !payload.is_empty() {
        return Err(invalid("stats poll must have an empty payload"));
    }
    Ok(())
}

/// Encodes a host's snapshot reply (layout in the module docs).
pub fn encode_stats(buf: &mut Vec<u8>, snap: &Snapshot) {
    begin_frame(buf, MsgType::Stats);
    put_u32(buf, snap.counters.len() as u32);
    for (name, &v) in &snap.counters {
        put_name(buf, name);
        put_u64(buf, v);
    }
    put_u32(buf, snap.gauges.len() as u32);
    for (name, &v) in &snap.gauges {
        put_name(buf, name);
        put_u64(buf, v.to_bits());
    }
    put_u32(buf, snap.histograms.len() as u32);
    for (name, h) in &snap.histograms {
        put_name(buf, name);
        put_u64(buf, h.count);
        put_u64(buf, h.sum_us);
        put_u64(buf, h.max_us);
        put_u32(buf, h.buckets.len() as u32);
        for &b in &h.buckets {
            put_u64(buf, b);
        }
    }
    end_frame(buf);
}

/// Decodes a [`MsgType::Stats`] snapshot reply.
pub fn decode_stats(payload: &[u8]) -> io::Result<Snapshot> {
    let mut rd = Rd::new(payload);
    let mut snap = Snapshot::default();
    let nc = rd.series_count()?;
    rd.need(nc * 12)?;
    for _ in 0..nc {
        let name = rd.name()?;
        let v = rd.u64()?;
        snap.counters.insert(name, v);
    }
    let ng = rd.series_count()?;
    rd.need(ng * 12)?;
    for _ in 0..ng {
        let name = rd.name()?;
        let v = f64::from_bits(rd.u64()?);
        snap.gauges.insert(name, v);
    }
    let nh = rd.series_count()?;
    rd.need(nh * 32)?;
    for _ in 0..nh {
        let name = rd.name()?;
        let count = rd.u64()?;
        let sum_us = rd.u64()?;
        let max_us = rd.u64()?;
        let nb = rd.u32()? as usize;
        if nb > MAX_STATS_BUCKETS {
            return Err(invalid(format!("{nb} histogram buckets exceeds wire cap")));
        }
        rd.need(nb * 8)?;
        let mut buckets = Vec::with_capacity(nb);
        for _ in 0..nb {
            buckets.push(rd.u64()?);
        }
        snap.histograms.insert(
            name,
            HistogramSnapshot {
                buckets,
                count,
                sum_us,
                max_us,
            },
        );
    }
    rd.done()?;
    Ok(snap)
}

/// Most trace records a [`MsgType::Traces`] reply may carry — far above
/// any real flight recorder, low enough that a garbage count fails fast.
const MAX_TRACE_RECORDS: usize = 65_536;

/// Encodes a flight-recorder poll: a [`MsgType::Traces`] frame with an
/// empty payload.
pub fn encode_traces_poll(buf: &mut Vec<u8>) {
    begin_frame(buf, MsgType::Traces);
    end_frame(buf);
}

/// Validates a [`MsgType::Traces`] poll payload (must be empty — a
/// non-empty payload at the host means the peer sent a dump where a
/// poll belongs).
pub fn decode_traces_poll(payload: &[u8]) -> io::Result<()> {
    if !payload.is_empty() {
        return Err(invalid("traces poll must have an empty payload"));
    }
    Ok(())
}

/// Encodes a flight-recorder dump (layout in the module docs): newest
/// records first, exactly as [`crate::metrics::FlightRecorder::export`]
/// returns them.
pub fn encode_traces(buf: &mut Vec<u8>, records: &[TraceRecord]) {
    debug_assert!(records.len() <= MAX_TRACE_RECORDS, "trace dump over wire cap");
    begin_frame(buf, MsgType::Traces);
    put_u32(buf, records.len() as u32);
    for rec in records {
        put_u64(buf, rec.trace_id);
        put_u32(buf, rec.batch);
        put_u32(buf, rec.beam);
        put_u64(buf, rec.total_ns);
        put_u32(buf, rec.events);
        put_u32(buf, rec.pinned as u32);
        put_u32(buf, rec.truncated);
        debug_assert!(rec.spans.len() <= MAX_TRACE_SPANS);
        put_u32(buf, rec.spans.len() as u32);
        for sp in &rec.spans {
            put_u32(buf, sp.shard);
            put_u32(buf, sp.layer);
            put_u64(buf, sp.tx_ns);
            put_u64(buf, sp.round_ns);
            put_u64(buf, sp.wait_ns);
            put_u64(buf, sp.host.decode_ns);
            put_u64(buf, sp.host.expand_ns);
            put_u64(buf, sp.host.encode_ns);
            put_u32(buf, sp.host.tiers);
            put_u32(buf, sp.events);
        }
    }
    end_frame(buf);
}

/// Decodes a [`MsgType::Traces`] dump reply.
pub fn decode_traces(payload: &[u8]) -> io::Result<Vec<TraceRecord>> {
    let mut rd = Rd::new(payload);
    let nr = rd.u32()? as usize;
    if nr > MAX_TRACE_RECORDS {
        return Err(invalid(format!("{nr} trace records exceeds wire cap")));
    }
    rd.need(nr * 36)?;
    let mut records = Vec::with_capacity(nr);
    for _ in 0..nr {
        let mut rec = TraceRecord::with_capacity();
        rec.trace_id = rd.u64()?;
        rec.batch = rd.u32()?;
        rec.beam = rd.u32()?;
        rec.total_ns = rd.u64()?;
        rec.events = rd.u32()?;
        let flags = rd.u32()?;
        if flags & !0b1 != 0 {
            return Err(invalid(format!("bad trace record flags {flags}")));
        }
        rec.pinned = flags & 0b1 != 0;
        rec.truncated = rd.u32()?;
        let ns = rd.u32()? as usize;
        if ns > MAX_TRACE_SPANS {
            return Err(invalid(format!("{ns} trace spans exceeds wire cap")));
        }
        rd.need(ns * 56)?;
        for _ in 0..ns {
            rec.spans.push(RoundSpan {
                shard: rd.u32()?,
                layer: rd.u32()?,
                tx_ns: rd.u64()?,
                round_ns: rd.u64()?,
                wait_ns: rd.u64()?,
                host: HostSpan {
                    decode_ns: rd.u64()?,
                    expand_ns: rd.u64()?,
                    encode_ns: rd.u64()?,
                    tiers: rd.u32()?,
                },
                events: rd.u32()?,
            });
        }
        records.push(rec);
    }
    rd.done()?;
    Ok(records)
}
