//! Model partitioning: contiguous root-subtree groups → standalone shard
//! models plus the remap back to the global id spaces.

use crate::data::synthetic::even_offsets;
use crate::tree::{Layer, XmrModel};

/// Identity of one shard within a partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// This shard's index in `0..num_shards`.
    pub shard_id: u32,
    /// Total shards in the partition.
    pub num_shards: u32,
    /// First global root-child owned by this shard.
    pub root_lo: u32,
    /// One past the last global root-child owned by this shard.
    pub root_hi: u32,
    /// Global label id of this shard's local label 0. Because the
    /// partition is contiguous, the label remap is the affine map
    /// `global = local + label_offset`.
    pub label_offset: u64,
    /// Labels (leaves) owned by this shard.
    pub num_labels: u64,
}

/// A standalone shard: a self-contained [`XmrModel`] over one contiguous
/// group of root subtrees, plus the per-layer node remap back to the
/// global model.
#[derive(Clone, Debug)]
pub struct ShardModel {
    /// Shard identity and label remap.
    pub spec: ShardSpec,
    /// Global column (node) id of each layer's local node 0; the bottom
    /// entry equals `spec.label_offset`.
    pub layer_offsets: Vec<u32>,
    /// The shard's own tree model (same feature dimension `d`, same
    /// depth, a contiguous column slice of every layer).
    pub model: XmrModel,
}

impl ShardModel {
    /// Maps a shard-local node of `layer` to its global node id.
    #[inline]
    pub fn global_node(&self, layer: usize, local: u32) -> u32 {
        local + self.layer_offsets[layer]
    }

    /// Maps a shard-local label to its global label id.
    #[inline]
    pub fn global_label(&self, local: u32) -> u32 {
        local + self.spec.label_offset as u32
    }
}

/// Splits `model` into (at most) `num_shards` standalone shard models by
/// near-even contiguous grouping of the root's children.
///
/// Each shard's layer `l` is the verbatim column slice covering the
/// shard's subtrees — entries are copied bit-for-bit and sibling chunks
/// never straddle a shard boundary (the cut is between root children), so
/// per-shard inference scores are bitwise identical to the global model's
/// (see the [`crate::shard`] module docs for why the gather stage stays
/// exact under beam search).
///
/// When `num_shards` exceeds the number of root children the partition
/// degrades gracefully to one shard per root child (a shard must own at
/// least one subtree); the returned vector's length is the effective
/// shard count.
pub fn partition(model: &XmrModel, num_shards: usize) -> Vec<ShardModel> {
    assert!(num_shards >= 1, "need at least one shard");
    let root_children = model.layers[0].num_nodes();
    let s = num_shards.min(root_children);
    let bounds = even_offsets(root_children, s);
    let mut shards = Vec::with_capacity(s);
    for i in 0..s {
        // Node range of the previous layer, driving this layer's chunk
        // range; starts as the shard's root-child range.
        let (mut lo, mut hi) = (bounds[i] as usize, bounds[i + 1] as usize);
        let mut layers = Vec::with_capacity(model.layers.len());
        let mut layer_offsets = Vec::with_capacity(model.layers.len());
        for (li, layer) in model.layers.iter().enumerate() {
            // Column range of this layer owned by the shard: layer 0 is
            // cut directly at root children; deeper layers follow the
            // chunk ranges of the previous layer's nodes.
            let (c0, c1) = if li == 0 {
                (lo, hi)
            } else {
                let offs = &layer.chunked.chunk_offsets;
                (offs[lo] as usize, offs[hi] as usize)
            };
            layer_offsets.push(c0 as u32);
            let csc = layer.csc.slice_cols(c0, c1);
            let offsets: Vec<u32> = if li == 0 {
                // The shard's root children become a single chunk under
                // its own implicit root.
                vec![0, (c1 - c0) as u32]
            } else {
                layer.chunked.chunk_offsets[lo..=hi]
                    .iter()
                    .map(|&o| o - c0 as u32)
                    .collect()
            };
            // Row maps are not built here; engines build whatever side
            // indices their configuration needs.
            layers.push(Layer::new(csc, &offsets, false));
            (lo, hi) = (c0, c1);
        }
        // (lo, hi) now bound the bottom layer: the shard's label range.
        let spec = ShardSpec {
            shard_id: i as u32,
            num_shards: s as u32,
            root_lo: bounds[i],
            root_hi: bounds[i + 1],
            label_offset: lo as u64,
            num_labels: (hi - lo) as u64,
        };
        shards.push(ShardModel {
            spec,
            layer_offsets,
            model: XmrModel::new(model.dim, layers),
        });
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::test_util::tiny_model;

    #[test]
    fn partition_covers_every_column_once() {
        let m = tiny_model(24, 4, 3, 9); // 4 root children, 64 labels
        for s in [1usize, 2, 3, 4, 9] {
            let shards = partition(&m, s);
            assert_eq!(shards.len(), s.min(4));
            assert_eq!(shards[0].spec.num_shards as usize, shards.len());
            for l in 0..m.depth() {
                let mut covered = 0u32;
                for sh in &shards {
                    assert_eq!(sh.layer_offsets[l], covered, "layer {l} contiguity");
                    covered += sh.model.layers[l].num_nodes() as u32;
                }
                assert_eq!(covered as usize, m.layers[l].num_nodes(), "layer {l} total");
            }
            let total_labels: u64 = shards.iter().map(|s| s.spec.num_labels).sum();
            assert_eq!(total_labels as usize, m.num_labels());
        }
    }

    #[test]
    fn shard_columns_are_verbatim_slices() {
        let m = tiny_model(16, 3, 3, 4);
        let shards = partition(&m, 2);
        for sh in &shards {
            assert_eq!(sh.model.dim, m.dim);
            assert_eq!(sh.model.depth(), m.depth());
            for (l, layer) in sh.model.layers.iter().enumerate() {
                let off = sh.layer_offsets[l] as usize;
                for j in 0..layer.num_nodes() {
                    let local = layer.csc.col(j);
                    let global = m.layers[l].csc.col(off + j);
                    assert_eq!(local.indices, global.indices);
                    assert_eq!(local.values, global.values);
                }
            }
            // label remap round-trips
            assert_eq!(
                sh.global_label(0) as u64,
                sh.spec.label_offset,
                "label remap base"
            );
            assert_eq!(
                sh.layer_offsets.last().copied().unwrap() as u64,
                sh.spec.label_offset
            );
        }
    }

    #[test]
    fn chunk_topology_preserved_per_shard() {
        let m = tiny_model(16, 4, 3, 12);
        for sh in partition(&m, 4) {
            // layer 0 is one chunk; deeper layers one chunk per parent
            assert_eq!(sh.model.layers[0].chunked.num_chunks(), 1);
            for l in 1..sh.model.depth() {
                assert_eq!(
                    sh.model.layers[l].chunked.num_chunks(),
                    sh.model.layers[l - 1].num_nodes()
                );
                // chunk widths match the global model's chunks
                let node0 = sh.layer_offsets[l - 1] as usize;
                for c in 0..sh.model.layers[l].chunked.num_chunks() {
                    assert_eq!(
                        sh.model.layers[l].chunked.chunk_width(c),
                        m.layers[l].chunked.chunk_width(node0 + c)
                    );
                }
            }
        }
    }

    #[test]
    fn oversharding_clamps_to_root_children() {
        let m = tiny_model(16, 3, 2, 1); // 3 root children
        let shards = partition(&m, 100);
        assert_eq!(shards.len(), 3);
        for (i, sh) in shards.iter().enumerate() {
            assert_eq!(sh.spec.root_hi - sh.spec.root_lo, 1, "shard {i}");
        }
    }
}
