//! Model partitioning: contiguous root-subtree groups → standalone shard
//! models plus the remap back to the global id spaces.
//!
//! Cuts are placed by **per-subtree residency weight**, not by
//! root-child count: on skewed trees a count-even split can leave one
//! shard holding most of the model. [`partition`] weighs subtrees by
//! weight nnz; [`partition_planned`] weighs them by the **bytes the
//! planned storage layouts actually keep resident**
//! ([`subtree_weight_bytes`]) — under quantized (`F16`/`Int8`) or
//! dense-rows layouts, equal nnz is far from equal bytes, and the byte
//! weighting is what keeps per-host memory even. Either weighting only
//! changes *where* the contiguous boundaries fall — every exactness
//! argument of [`crate::shard`] is boundary-agnostic.

use crate::inference::{KernelPlan, MatmulAlgo, PlannerConfig};
use crate::sparse::{ChunkStats, ChunkStorage};
use crate::tree::{Layer, XmrModel};

/// Identity of one shard within a partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// This shard's index in `0..num_shards`.
    pub shard_id: u32,
    /// Total shards in the partition.
    pub num_shards: u32,
    /// First global root-child owned by this shard.
    pub root_lo: u32,
    /// One past the last global root-child owned by this shard.
    pub root_hi: u32,
    /// Global label id of this shard's local label 0. Because the
    /// partition is contiguous, the label remap is the affine map
    /// `global = local + label_offset`.
    pub label_offset: u64,
    /// Labels (leaves) owned by this shard.
    pub num_labels: u64,
}

/// A standalone shard: a self-contained [`XmrModel`] over one contiguous
/// group of root subtrees, plus the per-layer node remap back to the
/// global model.
#[derive(Clone, Debug)]
pub struct ShardModel {
    /// Shard identity and label remap.
    pub spec: ShardSpec,
    /// Global column (node) id of each layer's local node 0; the bottom
    /// entry equals `spec.label_offset`.
    pub layer_offsets: Vec<u32>,
    /// The shard's own tree model (same feature dimension `d`, same
    /// depth, a contiguous column slice of every layer).
    pub model: XmrModel,
    /// Optional pre-resolved kernel plan over this shard's own chunks,
    /// paired with the masked-matmul algorithm it was costed for (the
    /// cost shapes differ per algo, so a stored plan is only served
    /// under the same algo). Serialized with the shard, so a planned
    /// model loads without re-calibration. Plans are per-shard: the
    /// chunk structure survives `partition`'s label remap verbatim, so a
    /// plan computed on the shard is exactly a plan over the global
    /// chunks it owns.
    pub plan: Option<(MatmulAlgo, KernelPlan)>,
}

impl ShardModel {
    /// Maps a shard-local node of `layer` to its global node id.
    #[inline]
    pub fn global_node(&self, layer: usize, local: u32) -> u32 {
        local + self.layer_offsets[layer]
    }

    /// Maps a shard-local label to its global label id.
    #[inline]
    pub fn global_label(&self, local: u32) -> u32 {
        local + self.spec.label_offset as u32
    }

    /// Resolves and stores this shard's kernel plan for `algo` (what
    /// `shard --iter auto` persists). Planning is a read-only pass over
    /// the shard's chunk statistics plus the optional timing calibration.
    pub fn plan_auto(&mut self, algo: MatmulAlgo, pc: &PlannerConfig) {
        self.plan = Some((algo, KernelPlan::auto(&self.model, algo, pc)));
    }
}

/// Weight nnz of each root child's whole subtree (every layer's column
/// slice under it) — the residency weight the partition balances.
pub fn subtree_nnz(model: &XmrModel) -> Vec<u64> {
    let root_children = model.layers[0].num_nodes();
    (0..root_children)
        .map(|r| {
            let (mut lo, mut hi) = (r, r + 1);
            let mut total = 0u64;
            for (li, layer) in model.layers.iter().enumerate() {
                let (c0, c1) = if li == 0 {
                    (lo, hi)
                } else {
                    let offs = &layer.chunked.chunk_offsets;
                    (offs[lo] as usize, offs[hi] as usize)
                };
                total += (layer.csc.indptr[c1] - layer.csc.indptr[c0]) as u64;
                (lo, hi) = (c0, c1);
            }
            total
        })
        .collect()
}

/// Resident bytes of one chunk's weight arrays under `storage`,
/// computed from structural stats alone — the planned-layout analogue
/// of `Chunk::weight_bytes`, usable *before* the layout is applied.
fn layout_weight_bytes(storage: ChunkStorage, stats: &ChunkStats, dim: usize) -> u64 {
    let rows = stats.rows as u64;
    let nnz = stats.nnz as u64;
    match storage {
        // row_indices (4B) + row_ptr (4B, rows+1) + col_idx (2B) +
        // values (4B)
        ChunkStorage::Csc => rows * 8 + 4 + nnz * 6,
        // row_ptr indexed by row id (d+1 entries); no row_indices
        ChunkStorage::DenseRows => 4 * (dim as u64 + 1) + nnz * 6,
        // Csc arrays in the shared store plus a 12-byte span entry
        ChunkStorage::Merged => 12 + rows * 8 + 4 + nnz * 6,
        // Csc scaffolding, 2-byte packed values instead of 4-byte f32
        ChunkStorage::F16 => rows * 8 + 4 + nnz * 4,
        // Csc scaffolding, 1-byte values plus the dequantization scale
        ChunkStorage::Int8 => rows * 8 + 4 + nnz * 3 + 4,
    }
}

/// Bytes each root child's whole subtree keeps resident under the
/// planned storage layouts (`plan`; `None` reads each chunk's current
/// layout — all-`Csc` on freshly built models). Layer 0 is one chunk
/// shared by every subtree, so its bytes are attributed per entry.
pub fn subtree_weight_bytes(model: &XmrModel, plan: Option<&KernelPlan>) -> Vec<u64> {
    let root_children = model.layers[0].num_nodes();
    let dim = model.dim;
    (0..root_children)
        .map(|r| {
            let (mut lo, mut hi) = (r, r + 1);
            let mut total = 0u64;
            for (li, layer) in model.layers.iter().enumerate() {
                if li == 0 {
                    // 6 bytes per stored entry (col_idx + value); the
                    // shared chunk scaffolding is not attributable.
                    total += 6 * (layer.csc.indptr[hi] - layer.csc.indptr[lo]) as u64;
                    continue;
                }
                // Chunks of layer `li` are one per node of layer
                // `li - 1`: the subtree owns chunk ids `lo..hi` and its
                // node range advances to their column span.
                let offs = &layer.chunked.chunk_offsets;
                let (c0, c1) = (offs[lo] as usize, offs[hi] as usize);
                for c in lo..hi {
                    let stats = layer.chunked.chunk_stats(c);
                    let storage = match plan {
                        Some(p) => p.layer_storage(li)[c],
                        None => layer.chunked.chunks[c].storage,
                    };
                    total += layout_weight_bytes(storage, &stats, dim);
                }
                (lo, hi) = (c0, c1);
            }
            total
        })
        .collect()
}

/// Contiguous cuts of `weights.len()` items into `parts` groups with
/// near-equal weight sums: boundary `p` is the first index where the
/// cumulative weight reaches `p/parts` of the total, clamped so every
/// group keeps at least one item.
fn balanced_cuts(weights: &[u64], parts: usize) -> Vec<u32> {
    let n = weights.len();
    let s = parts.min(n).max(1);
    let total: u128 = weights.iter().map(|&w| w as u128).sum();
    let mut cum = 0u128;
    let mut cuts = Vec::with_capacity(s + 1);
    cuts.push(0u32);
    let mut i = 0usize;
    for p in 1..s {
        let target = total * p as u128 / s as u128;
        while cum < target && i < n {
            cum += weights[i] as u128;
            i += 1;
        }
        // >= 1 item per group, on both sides of the cut.
        let prev = *cuts.last().unwrap() as usize;
        i = i.clamp(prev + 1, n - (s - p));
        // Keep `cum` consistent with the clamped boundary.
        cum = weights[..i].iter().map(|&w| w as u128).sum();
        cuts.push(i as u32);
    }
    cuts.push(n as u32);
    cuts
}

/// Splits `model` into (at most) `num_shards` standalone shard models by
/// contiguous grouping of the root's children, **balanced by subtree
/// weight nnz** so shard residency stays even on skewed trees.
///
/// Each shard's layer `l` is the verbatim column slice covering the
/// shard's subtrees — entries are copied bit-for-bit and sibling chunks
/// never straddle a shard boundary (the cut is between root children), so
/// per-shard inference scores are bitwise identical to the global model's
/// (see the [`crate::shard`] module docs for why the gather stage stays
/// exact under beam search).
///
/// When `num_shards` exceeds the number of root children the partition
/// degrades gracefully to one shard per root child (a shard must own at
/// least one subtree); the returned vector's length is the effective
/// shard count.
pub fn partition(model: &XmrModel, num_shards: usize) -> Vec<ShardModel> {
    assert!(num_shards >= 1, "need at least one shard");
    let root_children = model.layers[0].num_nodes();
    let s = num_shards.min(root_children);
    let bounds = balanced_cuts(&subtree_nnz(model), s);
    partition_at(model, &bounds)
}

/// [`partition`], but balanced by the bytes each subtree keeps
/// resident under `plan`'s storage layouts ([`subtree_weight_bytes`])
/// instead of raw weight nnz. With quantized or dense-rows layouts in
/// the plan the two weightings diverge, and this is the one that keeps
/// per-host memory even. `plan` must be a plan over the **global**
/// model (`shard --iter auto` resolves one before cutting); per-shard
/// plans are still re-resolved per shard afterwards.
pub fn partition_planned(
    model: &XmrModel,
    num_shards: usize,
    plan: &KernelPlan,
) -> Vec<ShardModel> {
    assert!(num_shards >= 1, "need at least one shard");
    let root_children = model.layers[0].num_nodes();
    let s = num_shards.min(root_children);
    let bounds = balanced_cuts(&subtree_weight_bytes(model, Some(plan)), s);
    partition_at(model, &bounds)
}

/// Builds the standalone shard models for the given root-child cut
/// boundaries (the shared back half of [`partition`] /
/// [`partition_planned`]).
fn partition_at(model: &XmrModel, bounds: &[u32]) -> Vec<ShardModel> {
    let s = bounds.len() - 1;
    let mut shards = Vec::with_capacity(s);
    for i in 0..s {
        // Node range of the previous layer, driving this layer's chunk
        // range; starts as the shard's root-child range.
        let (mut lo, mut hi) = (bounds[i] as usize, bounds[i + 1] as usize);
        let mut layers = Vec::with_capacity(model.layers.len());
        let mut layer_offsets = Vec::with_capacity(model.layers.len());
        for (li, layer) in model.layers.iter().enumerate() {
            // Column range of this layer owned by the shard: layer 0 is
            // cut directly at root children; deeper layers follow the
            // chunk ranges of the previous layer's nodes.
            let (c0, c1) = if li == 0 {
                (lo, hi)
            } else {
                let offs = &layer.chunked.chunk_offsets;
                (offs[lo] as usize, offs[hi] as usize)
            };
            layer_offsets.push(c0 as u32);
            let csc = layer.csc.slice_cols(c0, c1);
            let offsets: Vec<u32> = if li == 0 {
                // The shard's root children become a single chunk under
                // its own implicit root.
                vec![0, (c1 - c0) as u32]
            } else {
                layer.chunked.chunk_offsets[lo..=hi]
                    .iter()
                    .map(|&o| o - c0 as u32)
                    .collect()
            };
            // Row maps are not built here; engines build whatever side
            // indices their plan needs.
            layers.push(Layer::new(csc, &offsets, false));
            (lo, hi) = (c0, c1);
        }
        // (lo, hi) now bound the bottom layer: the shard's label range.
        let spec = ShardSpec {
            shard_id: i as u32,
            num_shards: s as u32,
            root_lo: bounds[i],
            root_hi: bounds[i + 1],
            label_offset: lo as u64,
            num_labels: (hi - lo) as u64,
        };
        shards.push(ShardModel {
            spec,
            layer_offsets,
            model: XmrModel::new(model.dim, layers),
            plan: None,
        });
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{even_offsets, synth_model_skewed, DatasetSpec};
    use crate::tree::test_util::tiny_model;

    #[test]
    fn partition_covers_every_column_once() {
        let m = tiny_model(24, 4, 3, 9); // 4 root children, 64 labels
        for s in [1usize, 2, 3, 4, 9] {
            let shards = partition(&m, s);
            assert_eq!(shards.len(), s.min(4));
            assert_eq!(shards[0].spec.num_shards as usize, shards.len());
            for l in 0..m.depth() {
                let mut covered = 0u32;
                for sh in &shards {
                    assert_eq!(sh.layer_offsets[l], covered, "layer {l} contiguity");
                    covered += sh.model.layers[l].num_nodes() as u32;
                }
                assert_eq!(covered as usize, m.layers[l].num_nodes(), "layer {l} total");
            }
            let total_labels: u64 = shards.iter().map(|s| s.spec.num_labels).sum();
            assert_eq!(total_labels as usize, m.num_labels());
        }
    }

    #[test]
    fn shard_columns_are_verbatim_slices() {
        let m = tiny_model(16, 3, 3, 4);
        let shards = partition(&m, 2);
        for sh in &shards {
            assert_eq!(sh.model.dim, m.dim);
            assert_eq!(sh.model.depth(), m.depth());
            for (l, layer) in sh.model.layers.iter().enumerate() {
                let off = sh.layer_offsets[l] as usize;
                for j in 0..layer.num_nodes() {
                    let local = layer.csc.col(j);
                    let global = m.layers[l].csc.col(off + j);
                    assert_eq!(local.indices, global.indices);
                    assert_eq!(local.values, global.values);
                }
            }
            // label remap round-trips
            assert_eq!(
                sh.global_label(0) as u64,
                sh.spec.label_offset,
                "label remap base"
            );
            assert_eq!(
                sh.layer_offsets.last().copied().unwrap() as u64,
                sh.spec.label_offset
            );
        }
    }

    #[test]
    fn chunk_topology_preserved_per_shard() {
        let m = tiny_model(16, 4, 3, 12);
        for sh in partition(&m, 4) {
            // layer 0 is one chunk; deeper layers one chunk per parent
            assert_eq!(sh.model.layers[0].chunked.num_chunks(), 1);
            for l in 1..sh.model.depth() {
                assert_eq!(
                    sh.model.layers[l].chunked.num_chunks(),
                    sh.model.layers[l - 1].num_nodes()
                );
                // chunk widths match the global model's chunks
                let node0 = sh.layer_offsets[l - 1] as usize;
                for c in 0..sh.model.layers[l].chunked.num_chunks() {
                    assert_eq!(
                        sh.model.layers[l].chunked.chunk_width(c),
                        m.layers[l].chunked.chunk_width(node0 + c)
                    );
                }
            }
        }
    }

    #[test]
    fn oversharding_clamps_to_root_children() {
        let m = tiny_model(16, 3, 2, 1); // 3 root children
        let shards = partition(&m, 100);
        assert_eq!(shards.len(), 3);
        for (i, sh) in shards.iter().enumerate() {
            assert_eq!(sh.spec.root_hi - sh.spec.root_lo, 1, "shard {i}");
        }
    }

    #[test]
    fn subtree_nnz_sums_to_model_nnz() {
        let m = tiny_model(24, 4, 3, 33);
        let w = subtree_nnz(&m);
        assert_eq!(w.len(), 4);
        let total: u64 = w.iter().sum();
        let model_total: u64 = m.layers.iter().map(|l| l.csc.nnz() as u64).sum();
        assert_eq!(total, model_total);
    }

    #[test]
    fn planned_partition_balances_resident_bytes() {
        use crate::inference::IterationMethod;
        // 16 root children; quantize everything under the first half of
        // the tree to Int8, so equal nnz is very unequal bytes.
        let m = tiny_model(24, 16, 2, 41);
        let mut plan = KernelPlan::uniform(&m, IterationMethod::MarchingPointers);
        for li in 1..m.depth() {
            let n = plan.layers[li].storage.len();
            for c in 0..n / 2 {
                plan.layers[li].storage[c] = ChunkStorage::Int8;
            }
        }
        let w = subtree_weight_bytes(&m, Some(&plan));
        assert_eq!(w.len(), 16);
        // plan-free weights over a built (all-Csc) model read the
        // chunks' own layout: heavier than the half-quantized plan
        let w_csc = subtree_weight_bytes(&m, None);
        assert!(w.iter().zip(&w_csc).take(8).all(|(a, b)| a < b));
        assert!(w.iter().zip(&w_csc).skip(8).all(|(a, b)| a == b));
        let s = 4usize;
        let bytes_of = |shards: &[ShardModel]| -> Vec<u64> {
            shards
                .iter()
                .map(|sh| {
                    w[sh.spec.root_lo as usize..sh.spec.root_hi as usize]
                        .iter()
                        .sum()
                })
                .collect()
        };
        let ratio = |g: &[u64]| -> f64 {
            let max = *g.iter().max().unwrap() as f64;
            let min = *g.iter().min().unwrap() as f64;
            max / min.max(1.0)
        };
        let by_nnz = ratio(&bytes_of(&partition(&m, s)));
        let planned_shards = partition_planned(&m, s, &plan);
        let by_bytes = ratio(&bytes_of(&planned_shards));
        assert!(
            by_bytes < by_nnz * 0.9,
            "planned cut must balance planned bytes: {by_bytes:.3} vs nnz-cut {by_nnz:.3} (w={w:?})"
        );
        // still a complete, contiguous partition
        assert_eq!(planned_shards.len(), s);
        let labels: u64 = planned_shards.iter().map(|sh| sh.spec.num_labels).sum();
        assert_eq!(labels as usize, m.num_labels());
    }

    #[test]
    fn weighted_cuts_balance_residency_on_skewed_trees() {
        // A geometrically skewed tree: the count-even split must leave a
        // far worse max/min shard-nnz ratio than the weighted cut.
        let spec = DatasetSpec {
            name: "skewed-rebalance",
            dim: 1_500,
            num_labels: 4_000,
            paper_dim: 0,
            paper_labels: 0,
            query_nnz: 20,
            col_nnz: 12,
            sibling_overlap: 0.6,
            zipf_theta: 1.0,
        };
        let m = synth_model_skewed(&spec, 16, 77, 0.8); // 16 root children
        let w = subtree_nnz(&m);
        let r = w.len();
        assert!(r >= 8, "want many root children, got {r}");
        let s = 4usize;
        let group = |bounds: &[u32]| -> Vec<u64> {
            (0..s)
                .map(|i| w[bounds[i] as usize..bounds[i + 1] as usize].iter().sum())
                .collect()
        };
        let ratio = |g: &[u64]| -> f64 {
            let max = *g.iter().max().unwrap() as f64;
            let min = *g.iter().min().unwrap() as f64;
            max / min.max(1.0)
        };
        let even = ratio(&group(&even_offsets(r, s)));
        let shards = partition(&m, s);
        let actual: Vec<u64> = shards
            .iter()
            .map(|sh| sh.model.layers.iter().map(|l| l.csc.nnz() as u64).sum())
            .collect();
        let weighted = ratio(&actual);
        assert!(
            weighted < even * 0.75,
            "weighted cut must improve balance: weighted {weighted:.2} vs even {even:.2} (w={w:?})"
        );
        // the per-shard models really carry the balanced slices
        let total: u64 = actual.iter().sum();
        let model_total: u64 = m.layers.iter().map(|l| l.csc.nnz() as u64).sum();
        assert_eq!(total, model_total);
    }
}
