//! Cross-process shard serving: TCP shard hosts, the remote gather
//! client with replica failover, and the [`RemoteShardedCoordinator`].
//!
//! This is the first subsystem that lets the scatter-gather protocol of
//! [`crate::shard`] span machines: a [`ShardHost`] loads **one**
//! [`ShardModel`] (stored kernel plan honored) and answers layer rounds
//! over persistent connections, while a [`RemoteGather`] drives N hosts
//! exactly like the in-process [`ShardedEngine`] drives its units — the
//! merge/split/prune code *is* the in-process code
//! ([`merge_and_split_layer`], [`expand_round`], `select_top`,
//! `rank_into`), so remote results are bitwise identical to the
//! unsharded engine by construction (property-tested over loopback).
//!
//! # Failover
//!
//! Every shard is addressable by ≥ 1 replica. An [`wire::MsgType::Expand`]
//! frame carries *everything* its round needs (query rows + beam slice),
//! so rounds are **stateless**: when a round times out or errors on the
//! active replica mid-query, the client drops that connection, advances
//! to the next replica, re-sends the identical frame and reads the reply
//! there — the query never fails, and the re-executed expansion is the
//! same pure computation. See the failover state machine in the
//! [`crate::shard`] module docs.
//!
//! # Replica health, deadlines, degradation
//!
//! Replica choice is health-driven: every replica carries a
//! consecutive-failure count, an EWMA of its successful round latency,
//! and a half-open circuit breaker — healthy replicas rotate
//! round-robin per batch, ejected replicas sit out an exponentially
//! growing (seeded-jitter) cooldown and rejoin through a probation
//! probe. A batch may carry a deadline budget
//! ([`RemoteConfig::deadline`]) threaded through every round, reconnect
//! and backoff sleep, so no batch outlives it. [`RemoteConfig::hedge`]
//! re-issues a round on the next healthy replica once the active one
//! exceeds the shard's observed p99 (replies are deterministic, so
//! hedging cannot change results), and [`RemoteConfig::allow_partial`]
//! serves a batch from the live shards — flagged `degraded` on the
//! response — instead of failing it when every replica of a shard is
//! down. The seeded chaos machinery that tests all of this lives in
//! [`crate::shard::fault`].
//!
//! # Speculative expansion
//!
//! The layer-synchronized protocol costs one network round trip per tree
//! layer (latency = RTT × depth). When speculation is on, a host answers
//! a layer-`l` round with its candidates **plus** a hint: its *local*
//! top-`beam` layer-`l` candidates, pre-expanded one layer further. Any
//! node that survives the *global* beam cut necessarily survives the
//! shard-local cut (fewer than `beam` candidates beat it globally, so
//! fewer than `beam` beat it within the shard), so the speculated parent
//! set always covers the true beam slice — the gather stage assembles
//! layer `l + 1`'s exact candidates from the hint and skips that round's
//! network hop entirely. Per-candidate scores depend only on the parent's
//! `(node, score)` and the query, not on which other parents are beamed,
//! so assembled candidates are bit-identical to a real round's. Network
//! rounds per query drop from `depth` to `ceil(depth / 2)`; a host that
//! declines to speculate (or a malformed hint) falls back to a real
//! round, never to an approximation.

use std::io::{self, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::engine::{
    build_shard_engine, expand_round, merge_and_split_layer, GatherArena, ShardRound,
};
use super::fault::{ConnFaultSession, FaultInjector, FaultPlan};
use super::partition::ShardModel;
use super::wire::{self, CandsHeader, ExpandHeader, MsgType, SpecRound, WireShardInfo};
use crate::coordinator::batcher::{spawn_batcher, WorkerPool};
use crate::coordinator::{
    CoordinatorConfig, CoordinatorStats, Request, Response, Router, SubmitError,
};
use crate::inference::{
    rank_into, select_top, EngineConfig, InferenceEngine, PlannerConfig, Prediction, Workspace,
};
use crate::metrics::{
    FlightRecorder, FlightRecorderConfig, HostSpan, Registry, RoundSpan, ScatterMetrics, Snapshot,
    TraceRecord, EV_DEAD, EV_DEGRADED, EV_EJECTION, EV_FAILOVER, EV_HEDGE, EV_SPEC_HIT,
    EV_SPEC_MISS, MAX_TRACE_SPANS,
};
use crate::sparse::{CsrMatrix, SparseVec, SparseVecView};
use crate::util::Rng;

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

// =====================================================================
// Shard host (server side)
// =====================================================================

/// Shard-host configuration.
#[derive(Clone, Debug)]
pub struct ShardHostConfig {
    /// Engine configuration the shard serves under (a stored kernel plan
    /// is honored when it matches `engine.algo` under `--iter auto`).
    pub engine: EngineConfig,
    /// Planner inputs for shards that need a fresh plan resolved.
    pub planner: PlannerConfig,
    /// Answer speculation requests (pre-expand the local top-`beam` of
    /// each reply one layer further). Costs host CPU per round; saves
    /// the gather stage every other network round trip.
    pub speculate: bool,
    /// Record per-layer engine telemetry
    /// ([`InferenceEngine::with_metrics`]) and answer
    /// [`wire::MsgType::Stats`] polls with it. On by default: the cost is
    /// one timer pair per layer round and zero steady-state allocations
    /// (`rust/tests/alloc.rs`).
    pub metrics: bool,
    /// Capacity of the host-side [`FlightRecorder`] ring. When > 0
    /// (default 256) every round is timed (decode/expand/encode) and fed
    /// to the recorder, traced rounds piggyback a [`HostSpan`] on their
    /// reply, and [`wire::MsgType::Traces`] polls answer with the
    /// retained records. 0 disables the recorder *and* all round timing
    /// — fully-disabled tracing costs zero.
    pub flight_recorder: usize,
}

impl Default for ShardHostConfig {
    fn default() -> Self {
        Self {
            engine: EngineConfig::default(),
            planner: PlannerConfig::default(),
            speculate: true,
            metrics: true,
            flight_recorder: 256,
        }
    }
}

struct HostShared {
    engine: InferenceEngine,
    info: WireShardInfo,
    speculate: bool,
    stop: Arc<AtomicBool>,
    /// Host-level counters (connections, frames served); engine telemetry
    /// is merged in per poll by [`HostShared::snapshot`].
    registry: Registry,
    /// Installed fault plan ([`ShardHost::with_faults`]); `None` on
    /// production hosts — the serve path then writes directly.
    faults: Option<Arc<FaultInjector>>,
    /// Host-side flight recorder ([`ShardHostConfig::flight_recorder`]);
    /// `None` disables all round timing.
    recorder: Option<Arc<FlightRecorder>>,
}

impl HostShared {
    /// Point-in-time view of everything this host measures: the host
    /// registry plus, when enabled, the engine's per-layer telemetry
    /// under the `engine.` prefix — the payload of a
    /// [`wire::MsgType::Stats`] reply.
    fn snapshot(&self) -> Snapshot {
        let mut snap = self.registry.snapshot();
        if let Some(m) = self.engine.metrics() {
            m.export_into(&mut snap, "engine.");
        }
        snap
    }
}

/// Live-connection registry: `(connection id, severable handle)`. Conn
/// threads unregister themselves on exit so a long-running host does not
/// accumulate dead fds.
type ConnRegistry = Arc<Mutex<Vec<(u64, TcpStream)>>>;

/// A running TCP shard host: one loaded shard, one accept loop, one
/// serving thread per connection (each owning its private
/// [`Workspace`] and pooled round/codec buffers).
pub struct ShardHost {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: ConnRegistry,
    accept: Option<JoinHandle<()>>,
    faults: Option<Arc<FaultInjector>>,
}

impl ShardHost {
    /// Builds the shard's engine (stored plan honored, exactly as the
    /// in-process [`ShardedEngine`] would) and starts listening on
    /// `addr` (use port 0 for an OS-assigned port;
    /// [`ShardHost::local_addr`] reports it).
    pub fn spawn(
        shard: ShardModel,
        config: ShardHostConfig,
        addr: impl ToSocketAddrs,
    ) -> io::Result<ShardHost> {
        Self::spawn_inner(shard, config, addr, None)
    }

    /// [`ShardHost::spawn`] with a seeded [`FaultPlan`] installed: every
    /// accepted connection draws a deterministic fault schedule from the
    /// plan (refused connects, dropped/delayed/stuttered/truncated/
    /// corrupted replies), and the host can be frozen mid-stream with
    /// [`ShardHost::pause`] / [`ShardHost::resume`]. The chaos suite's
    /// (and the `shard-host` CLI's `--fault-*` flags') entry point.
    pub fn with_faults(
        shard: ShardModel,
        config: ShardHostConfig,
        addr: impl ToSocketAddrs,
        plan: FaultPlan,
    ) -> io::Result<ShardHost> {
        Self::spawn_inner(shard, config, addr, Some(FaultInjector::new(plan)))
    }

    fn spawn_inner(
        shard: ShardModel,
        config: ShardHostConfig,
        addr: impl ToSocketAddrs,
        faults: Option<Arc<FaultInjector>>,
    ) -> io::Result<ShardHost> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let (spec, layer_offsets, engine) =
            build_shard_engine(shard, config.engine, &config.planner);
        let engine = if config.metrics {
            engine.with_metrics()
        } else {
            engine
        };
        let info = WireShardInfo {
            shard_id: spec.shard_id,
            num_shards: spec.num_shards,
            depth: engine.model().depth() as u32,
            dim: engine.model().dim as u64,
            label_offset: spec.label_offset,
            num_labels: spec.num_labels,
            layer_offsets,
            layer_nodes: engine
                .model()
                .layers
                .iter()
                .map(|l| l.num_nodes() as u32)
                .collect(),
        };
        let stop = Arc::new(AtomicBool::new(false));
        let conns: ConnRegistry = Arc::new(Mutex::new(Vec::new()));
        let recorder = (config.flight_recorder > 0).then(|| {
            Arc::new(FlightRecorder::new(FlightRecorderConfig {
                capacity: config.flight_recorder,
                ..FlightRecorderConfig::default()
            }))
        });
        let shared = Arc::new(HostShared {
            engine,
            info,
            speculate: config.speculate,
            stop: Arc::clone(&stop),
            registry: Registry::new(),
            faults: faults.clone(),
            recorder,
        });
        let conns2 = Arc::clone(&conns);
        let accept = std::thread::Builder::new()
            .name(format!("mscm-host-{}", shared.info.shard_id))
            .spawn(move || accept_loop(listener, shared, conns2))
            .expect("spawn shard host");
        Ok(Self {
            addr,
            stop,
            conns,
            accept: Some(accept),
            faults,
        })
    }

    /// The address the host is listening on.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Freezes every reply mid-stream (dead-but-connected host): sockets
    /// stay open, no bytes come back until [`ShardHost::resume`]. No-op
    /// on hosts spawned without faults.
    pub fn pause(&self) {
        if let Some(f) = &self.faults {
            f.pause();
        }
    }

    /// Releases a [`ShardHost::pause`] freeze.
    pub fn resume(&self) {
        if let Some(f) = &self.faults {
            f.resume();
        }
    }

    /// The installed fault injector, if this host was spawned with one.
    pub fn fault_injector(&self) -> Option<&Arc<FaultInjector>> {
        self.faults.as_ref()
    }

    /// Hard-stops the host **immediately**: the listener stops accepting
    /// and every live connection is severed mid-stream — exactly the
    /// failure the client-side failover must absorb (the failover tests
    /// and `examples/remote_search.rs` kill a replica this way).
    pub fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for (_, c) in self.conns.lock().unwrap().iter() {
            let _ = c.shutdown(Shutdown::Both);
        }
        // Unblock the accept loop so it observes the stop flag.
        let _ = TcpStream::connect(self.addr);
    }

    /// [`ShardHost::kill`] + join the accept loop.
    pub fn shutdown(mut self) {
        self.kill();
        if let Some(a) = self.accept.take() {
            a.join().ok();
        }
    }

    /// Blocks until the host is killed — the `shard-host` CLI's serve
    /// loop.
    pub fn wait(mut self) {
        if let Some(a) = self.accept.take() {
            a.join().ok();
        }
    }
}

impl Drop for ShardHost {
    fn drop(&mut self) {
        if let Some(a) = self.accept.take() {
            self.kill();
            a.join().ok();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<HostShared>, conns: ConnRegistry) {
    let mut next_id = 0u64;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                let _ = stream.set_nodelay(true);
                let faults = shared.faults.as_ref().map(|f| {
                    ConnFaultSession::new(Arc::clone(f), f.next_host_conn(), Arc::clone(&shared.stop))
                });
                if faults.as_ref().is_some_and(|f| f.refuse()) {
                    // Seeded connect refusal: the peer sees an accepted
                    // socket that closes before any handshake reply.
                    let _ = stream.shutdown(Shutdown::Both);
                    continue;
                }
                let id = next_id;
                next_id += 1;
                if let Ok(clone) = stream.try_clone() {
                    conns.lock().unwrap().push((id, clone));
                }
                let sh = Arc::clone(&shared);
                let reg = Arc::clone(&conns);
                // Connection threads are detached: they exit when the
                // peer disconnects or the host is killed (the severed
                // socket fails their next read), unregistering their fd
                // so long-running hosts don't leak one per connection.
                std::thread::Builder::new()
                    .name(format!("mscm-host{}-conn", sh.info.shard_id))
                    .spawn(move || {
                        let _ = serve_conn(&sh, stream, faults);
                        reg.lock().unwrap().retain(|(cid, _)| *cid != id);
                    })
                    .ok();
            }
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // Transient accept failure (e.g. fd pressure): back off
                // instead of spinning.
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Sends a protocol-error frame (best effort) before the connection
/// closes.
fn reply_error(w: &mut TcpStream, tx: &mut Vec<u8>, code: u32, msg: &str) -> io::Result<()> {
    wire::encode_error(tx, code, msg);
    w.write_all(tx)
}

/// Routes one host reply frame through the connection's fault session
/// when one is installed. `Ok(false)` means the schedule severed the
/// connection and the serve loop should stop.
fn host_write(
    w: &mut TcpStream,
    frame: &[u8],
    faults: &mut Option<ConnFaultSession>,
) -> io::Result<bool> {
    match faults {
        Some(f) => f.write_reply(w, frame),
        None => w.write_all(frame).map(|()| true),
    }
}

/// One connection's serve loop: handshake, then Expand → Cands until the
/// peer goes away. All state is connection-private and pooled, so a
/// steady round stream does no allocator traffic beyond amortized buffer
/// growth. Every reply passes through `faults` when the host carries a
/// [`FaultPlan`].
fn serve_conn(
    sh: &HostShared,
    stream: TcpStream,
    mut faults: Option<ConnFaultSession>,
) -> io::Result<()> {
    let mut r = BufReader::new(stream.try_clone()?);
    let mut w = stream;
    let mut tx: Vec<u8> = Vec::new();
    let mut rx: Vec<u8> = Vec::new();
    // Handshake: exactly one Hello, answered with this shard's identity.
    match wire::read_frame(&mut r, &mut rx) {
        Ok(MsgType::Hello) => {}
        Ok(_) => return reply_error(&mut w, &mut tx, wire::ERR_PROTOCOL, "expected Hello"),
        Err(e) if e.kind() == io::ErrorKind::InvalidData => {
            return reply_error(&mut w, &mut tx, wire::error_code_for(&e), &e.to_string());
        }
        Err(e) => return Err(e),
    }
    wire::encode_shard_info(&mut tx, &sh.info);
    if !host_write(&mut w, &tx, &mut faults)? {
        return Ok(());
    }
    // Handles resolved once per connection — the serve loop below only
    // bumps atomics.
    sh.registry.counter("host.connections").inc();
    let expand_frames = sh.registry.counter("host.expand_frames");
    let stats_polls = sh.registry.counter("host.stats_polls");
    let trace_polls = sh.registry.counter("host.trace_polls");

    let engine = &sh.engine;
    let dim = engine.model().dim;
    let depth = engine.model().depth();
    let mut ws = engine.workspace();
    let mut x = CsrMatrix::default();
    let mut round = ShardRound::default();
    let mut spec = SpecRound::default();
    let mut spec_round = ShardRound::default();
    let mut sel: Vec<(u32, f32)> = Vec::new();
    loop {
        let ty = match wire::read_frame(&mut r, &mut rx) {
            Ok(t) => t,
            // Peer closed the connection (or the host was killed).
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                return reply_error(&mut w, &mut tx, wire::ERR_PROTOCOL, &e.to_string());
            }
            Err(e) => return Err(e),
        };
        match ty {
            MsgType::Expand => {}
            // A metrics poll: reply with the registry snapshot and keep
            // serving — polls leave all round state untouched, so a
            // monitor may share the connection with live traffic.
            MsgType::Stats => {
                if let Err(e) = wire::decode_stats_poll(&rx) {
                    return reply_error(&mut w, &mut tx, wire::ERR_MALFORMED, &e.to_string());
                }
                stats_polls.inc();
                wire::encode_stats(&mut tx, &sh.snapshot());
                if !host_write(&mut w, &tx, &mut faults)? {
                    return Ok(());
                }
                continue;
            }
            // A flight-recorder poll: reply with the retained trace
            // records (empty when the recorder is disabled). Like Stats,
            // polls leave all round state untouched.
            MsgType::Traces => {
                if let Err(e) = wire::decode_traces_poll(&rx) {
                    return reply_error(&mut w, &mut tx, wire::ERR_MALFORMED, &e.to_string());
                }
                trace_polls.inc();
                let records = sh.recorder.as_ref().map(|r| r.export()).unwrap_or_default();
                wire::encode_traces(&mut tx, &records);
                if !host_write(&mut w, &tx, &mut faults)? {
                    return Ok(());
                }
                continue;
            }
            _ => {
                return reply_error(
                    &mut w,
                    &mut tx,
                    wire::ERR_PROTOCOL,
                    "expected Expand, Stats or Traces",
                );
            }
        }
        expand_frames.inc();
        // All round timing is gated on the recorder: with it disabled
        // the serve loop takes no timestamps at all and the reply never
        // carries a span — fully-disabled tracing costs zero.
        let t0 = sh.recorder.as_ref().map(|_| Instant::now());
        let hdr = match wire::decode_expand(&rx, dim, &mut x, &mut round) {
            Ok(h) => h,
            Err(e) => return reply_error(&mut w, &mut tx, wire::ERR_MALFORMED, &e.to_string()),
        };
        let layer = hdr.layer as usize;
        if layer >= depth {
            return reply_error(&mut w, &mut tx, wire::ERR_MALFORMED, "layer out of range");
        }
        // Beam parents index this layer's sibling chunks; bound them here
        // so a malformed frame can never panic the kernels.
        let max_parent = engine.model().layers[layer].chunked.num_chunks() as u32;
        for q in 0..round.n {
            if round.beams[q].iter().any(|&(p, _)| p >= max_parent) {
                return reply_error(&mut w, &mut tx, wire::ERR_MALFORMED, "beam node out of range");
            }
        }
        let decode_ns = t0.map_or(0, |t| t.elapsed().as_nanos() as u64);
        let t_expand = t0.map(|_| Instant::now());
        expand_round(engine, &x, layer, &mut round, &mut ws);
        let do_spec = hdr.speculate && sh.speculate && layer + 1 < depth;
        if do_spec {
            speculate_next_layer(
                engine,
                &x,
                layer + 1,
                hdr.beam as usize,
                &round,
                &mut spec,
                &mut spec_round,
                &mut sel,
                &mut ws,
            );
        }
        let mut hspan = HostSpan {
            decode_ns,
            expand_ns: t_expand.map_or(0, |t| t.elapsed().as_nanos() as u64),
            encode_ns: 0,
            tiers: t0
                .and(engine.metrics())
                .map_or(0, |m| m.layer_tier_mask(layer)),
        };
        // The reply carries the span only when the round asked for one
        // (an untraced reply stays byte-identical to v2). `encode_ns` is
        // backpatched: the encode can't time itself from the inside.
        let attach = hdr.trace && t0.is_some();
        let t_encode = t0.map(|_| Instant::now());
        wire::encode_cands(
            &mut tx,
            hdr.round_id,
            hdr.layer,
            &round,
            do_spec.then_some(&spec),
            attach.then_some(&hspan),
        );
        if let Some(t) = t_encode {
            hspan.encode_ns = t.elapsed().as_nanos() as u64;
            if attach {
                wire::patch_cands_encode_ns(&mut tx, hspan.encode_ns);
            }
        }
        if !host_write(&mut w, &tx, &mut faults)? {
            return Ok(());
        }
        // Feed the host recorder (untraced rounds too, under trace id
        // 0): one span covering this round, total = decode → written.
        if let (Some(rec), Some(t)) = (sh.recorder.as_ref(), t0) {
            let shard_id = sh.info.shard_id;
            let n = round.n;
            rec.record(t.elapsed(), |r| {
                r.trace_id = hdr.trace_id;
                r.batch = n as u32;
                r.beam = hdr.beam;
                r.push_span(RoundSpan {
                    shard: shard_id,
                    layer: hdr.layer,
                    tx_ns: 0,
                    round_ns: hspan.total_ns(),
                    wait_ns: 0,
                    host: hspan,
                    events: 0,
                });
            });
        }
    }
}

/// Builds the speculation hint for `next_layer`: per query, the shard's
/// local top-`beam` of the just-computed candidates (by the engine's own
/// `select_top` comparator — a guaranteed superset of the shard's slice
/// of the global beam) expanded one layer further through the very same
/// [`expand_round`] kernel a real round would run.
fn speculate_next_layer(
    engine: &InferenceEngine,
    x: &CsrMatrix,
    next_layer: usize,
    beam: usize,
    round: &ShardRound,
    spec: &mut SpecRound,
    spec_round: &mut ShardRound,
    sel: &mut Vec<(u32, f32)>,
    ws: &mut Workspace,
) {
    let n = round.n;
    spec.ensure(n);
    spec_round.ensure(n);
    let chunked = &engine.model().layers[next_layer].chunked;
    for q in 0..n {
        sel.clear();
        sel.extend_from_slice(&round.cands[q]);
        // Local beam cut: parents come out sorted by ascending node id.
        select_top(sel, beam, &mut spec.parents[q]);
        spec.child_counts[q].clear();
        spec.child_counts[q].extend(
            spec.parents[q]
                .iter()
                .map(|&(p, _)| chunked.chunk_width(p as usize) as u32),
        );
        spec_round.beams[q].clear();
        spec_round.beams[q].extend_from_slice(&spec.parents[q]);
    }
    expand_round(engine, x, next_layer, spec_round, ws);
    for q in 0..n {
        spec.children[q].clear();
        spec.children[q].extend_from_slice(&spec_round.cands[q]);
    }
}

// =====================================================================
// Remote shard (client side): one shard, N replicas, failover
// =====================================================================

/// Client-side transport configuration.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// Ask hosts for speculative expansion and consume the hints
    /// (halves the network rounds per query; exactness is unaffected).
    pub speculate: bool,
    /// Per-round read/write timeout; an expired round fails over to the
    /// next replica. `Duration::ZERO` disables the timeout (rounds then
    /// fail over only on connection errors).
    pub round_timeout: Duration,
    /// TCP connect timeout per replica attempt. Also bounds the
    /// handshake round, so an accept-then-hang host can stall a probe
    /// (or [`discover`]) for at most this long.
    pub connect_timeout: Duration,
    /// Per-batch deadline budget, threaded through every round read,
    /// reconnect and backoff sleep of the batch: once spent, the batch
    /// fails with `TimedOut` instead of retrying further.
    /// `Duration::ZERO` disables the budget.
    pub deadline: Duration,
    /// Hedge slow rounds: once a shard's round histogram is warm, a
    /// reply slower than the shard's observed p99 is abandoned and the
    /// round re-issued on the next healthy replica (first valid reply
    /// wins; replies are deterministic, so results cannot change).
    pub hedge: bool,
    /// When every replica of a shard is down, degrade the batch to the
    /// live shards (response flagged `degraded`, `remote.degraded_batches`
    /// bumped) instead of failing it. Off by default: exact-or-fail.
    pub allow_partial: bool,
    /// Consecutive failures after which a replica's circuit opens.
    pub eject_after: u32,
    /// Base cooldown of an ejected replica; doubles per consecutive
    /// ejection (seeded jitter) up to [`RemoteConfig::eject_cooldown_cap`].
    pub eject_cooldown: Duration,
    /// Upper bound on the ejection cooldown.
    pub eject_cooldown_cap: Duration,
    /// Base reconnect backoff once a full replica cycle has failed;
    /// doubles per cycle (seeded jitter) up to [`RemoteConfig::backoff_cap`].
    pub backoff_base: Duration,
    /// Upper bound on the reconnect backoff.
    pub backoff_cap: Duration,
    /// Seed for the backoff/cooldown jitter streams — chaos runs replay
    /// exactly under one seed (`MSCM_TEST_SEED` convention).
    pub seed: u64,
    /// Capacity of the client-side [`FlightRecorder`] ring (shared by
    /// every gather worker of a coordinator). When > 0 (default 256)
    /// every batch is traced: `Expand` frames carry the trace flag + a
    /// batch span id, hosts piggyback their decode/expand/encode timing
    /// on each reply, and the per-batch trace tree (per-shard per-round
    /// spans + hedge/failover/ejection/degraded/speculation events) is
    /// recorded with tail-based retention. 0 disables tracing entirely —
    /// round payloads are then byte-identical to v2.
    pub flight_recorder: usize,
    /// Client-transport fault injection (seeded connect refusal, send
    /// delay); test machinery, `None` in production.
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        Self {
            speculate: true,
            round_timeout: Duration::from_secs(5),
            connect_timeout: Duration::from_secs(2),
            deadline: Duration::ZERO,
            hedge: false,
            allow_partial: false,
            eject_after: 3,
            eject_cooldown: Duration::from_millis(100),
            eject_cooldown_cap: Duration::from_secs(2),
            backoff_base: Duration::from_millis(2),
            backoff_cap: Duration::from_millis(200),
            seed: 0x5EED_CA5E,
            flight_recorder: 256,
            faults: None,
        }
    }
}

/// `Duration::ZERO`-means-disabled, as an `Option`.
fn nonzero(d: Duration) -> Option<Duration> {
    (d > Duration::ZERO).then_some(d)
}

/// What remains of the batch deadline, with exhaustion surfaced as an
/// error instead of a duration. `RemoteConfig` timeouts use
/// `Duration::ZERO` as the "disabled" sentinel, and a remaining budget
/// that clips to exactly zero would alias into that sentinel downstream
/// (a zero "timeout" reading as *no* timeout — an expired deadline
/// turned into an unbounded wait). Budget exhaustion must therefore
/// fail the batch with `TimedOut` *before* any further socket op, never
/// flow onward as a `Duration`.
fn checked_budget(deadline: Option<Instant>) -> io::Result<Option<Duration>> {
    match deadline {
        None => Ok(None),
        Some(d) => {
            let rem = d.saturating_duration_since(Instant::now());
            if rem.is_zero() {
                Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "batch deadline exhausted",
                ))
            } else {
                Ok(Some(rem))
            }
        }
    }
}

/// Socket timeout for one round: the configured round timeout capped by
/// what remains of the batch deadline (`None` = unbounded). Fails with
/// `TimedOut` when the budget is already spent ([`checked_budget`]) so
/// an exhausted deadline can never read as "no timeout".
fn effective_timeout(
    round_timeout: Duration,
    deadline: Option<Instant>,
) -> io::Result<Option<Duration>> {
    let rem = checked_budget(deadline)?;
    Ok(match (nonzero(round_timeout), rem) {
        (Some(b), Some(r)) => Some(b.min(r)),
        (Some(b), None) => Some(b),
        (None, r) => r,
    })
}

/// Transport-level serving statistics, shared by every gather worker of
/// a remote coordinator.
#[derive(Debug)]
pub struct RemoteStats {
    /// Layer rounds shipped over the network (per batch, not per shard).
    pub rounds: AtomicU64,
    /// Layer rounds answered from speculation hints (no network hop).
    pub spec_rounds_saved: AtomicU64,
    /// Speculation attempts that fell back to a real round.
    pub spec_misses: AtomicU64,
    /// Replica failovers (connection drops, timeouts, reconnects).
    pub failovers: AtomicU64,
    /// Rounds hedged to a second replica because the active one
    /// exceeded the shard's observed p99 ([`RemoteConfig::hedge`]).
    pub hedges: AtomicU64,
    /// Circuit-breaker ejections (a replica put on cooldown after
    /// [`RemoteConfig::eject_after`] consecutive failures).
    pub ejections: AtomicU64,
    /// Batches abandoned because every replica of some shard failed.
    pub failed_batches: AtomicU64,
    /// Batches served from the live shards only
    /// ([`RemoteConfig::allow_partial`]) with some shard down.
    pub degraded_batches: AtomicU64,
    /// Per-shard round latency + gather join wait. Caveat: a gather
    /// worker reads replies sequentially in shard order (blocking std
    /// sockets, one thread), so each shard's recorded latency is its
    /// *read-completion* time — an upper bound that can absorb
    /// head-of-line waiting on lower-numbered shards — and the join wait
    /// is `last − first` in that order. The in-process coordinator's
    /// channel-based scatter records true arrival order; treat the
    /// remote histograms as a join-cost bound, not per-shard truth.
    pub scatter: ScatterMetrics,
}

impl RemoteStats {
    fn new(num_shards: usize) -> Self {
        Self {
            rounds: AtomicU64::new(0),
            spec_rounds_saved: AtomicU64::new(0),
            spec_misses: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            ejections: AtomicU64::new(0),
            failed_batches: AtomicU64::new(0),
            degraded_batches: AtomicU64::new(0),
            scatter: ScatterMetrics::new(num_shards),
        }
    }

    /// One-line transport summary.
    pub fn summary(&self) -> String {
        format!(
            "rounds={} spec_saved={} spec_misses={} failovers={} hedges={} ejections={} \
             failed_batches={} degraded_batches={}",
            self.rounds.load(Ordering::Relaxed),
            self.spec_rounds_saved.load(Ordering::Relaxed),
            self.spec_misses.load(Ordering::Relaxed),
            self.failovers.load(Ordering::Relaxed),
            self.hedges.load(Ordering::Relaxed),
            self.ejections.load(Ordering::Relaxed),
            self.failed_batches.load(Ordering::Relaxed),
            self.degraded_batches.load(Ordering::Relaxed),
        )
    }

    /// Adds the transport counters and scatter histograms to `snap`
    /// under the `remote.` namespace.
    pub fn snapshot_into(&self, snap: &mut Snapshot) {
        let counters = [
            ("remote.rounds", &self.rounds),
            ("remote.spec_rounds_saved", &self.spec_rounds_saved),
            ("remote.spec_misses", &self.spec_misses),
            ("remote.failovers", &self.failovers),
            ("remote.hedges", &self.hedges),
            ("remote.ejections", &self.ejections),
            ("remote.failed_batches", &self.failed_batches),
            ("remote.degraded_batches", &self.degraded_batches),
        ];
        for (name, c) in counters {
            snap.counters.insert(name.to_string(), c.load(Ordering::Relaxed));
        }
        self.scatter.snapshot_into(snap, "remote.scatter");
    }

    /// Point-in-time [`Snapshot`] of the transport statistics.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        self.snapshot_into(&mut snap);
        snap
    }
}

struct Conn {
    r: BufReader<TcpStream>,
    w: TcpStream,
}

impl Conn {
    /// (Re)arms the socket timeouts. `w` is a `try_clone` of the stream
    /// inside `r` — one fd — so arming through `w` bounds both
    /// directions, including reads through the `BufReader`.
    fn set_timeouts(&self, t: Option<Duration>) -> io::Result<()> {
        // Clamp away zero: std rejects a zero timeout, and a deadline
        // with under 1ms left should surface as TimedOut, not EINVAL.
        let t = t.map(|d| d.max(Duration::from_millis(1)));
        self.w.set_read_timeout(t)?;
        self.w.set_write_timeout(t)
    }
}

/// Externally visible health phase of one replica — the circuit-breaker
/// state machine drawn in the [`crate::shard`] module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaPhase {
    /// No outstanding failures; serves in the round-robin rotation.
    Healthy,
    /// Recent failures below the ejection threshold; still selectable.
    Suspect,
    /// Circuit open: sits out its cooldown and receives no traffic.
    Ejected,
    /// Cooldown elapsed: selectable again, but one more failure
    /// re-ejects immediately (the half-open probe).
    Probation,
}

/// Per-replica health record: consecutive-failure count, circuit-breaker
/// cooldown, EWMA round latency, and the lazily (re)opened connection.
struct ReplicaState {
    addr: SocketAddr,
    conn: Option<Conn>,
    /// Consecutive failures since the last success.
    fails: u32,
    /// Consecutive ejections — the cooldown doubles with each.
    ejections: u32,
    /// While in the future, the circuit is open; once elapsed, the
    /// replica is on probation until a success or failure resolves it.
    ejected_until: Option<Instant>,
    /// EWMA of successful round latency in ms (0 until the first
    /// sample) — the per-replica slowness signal next to the per-shard
    /// scatter histograms.
    ewma_ms: f64,
}

impl ReplicaState {
    fn new(addr: SocketAddr) -> Self {
        Self {
            addr,
            conn: None,
            fails: 0,
            ejections: 0,
            ejected_until: None,
            ewma_ms: 0.0,
        }
    }

    fn phase(&self, now: Instant) -> ReplicaPhase {
        match self.ejected_until {
            Some(t) if t > now => ReplicaPhase::Ejected,
            Some(_) => ReplicaPhase::Probation,
            None if self.fails == 0 => ReplicaPhase::Healthy,
            None => ReplicaPhase::Suspect,
        }
    }

    fn selectable(&self, now: Instant) -> bool {
        self.phase(now) != ReplicaPhase::Ejected
    }

    /// A successful round closes the circuit entirely (a probation probe
    /// that succeeds rejoins here) and feeds the latency EWMA.
    fn on_success(&mut self, elapsed: Duration) {
        self.fails = 0;
        self.ejections = 0;
        self.ejected_until = None;
        let ms = elapsed.as_secs_f64() * 1e3;
        self.ewma_ms = if self.ewma_ms == 0.0 {
            ms
        } else {
            0.8 * self.ewma_ms + 0.2 * ms
        };
    }

    /// Records a failure; opens the circuit once `cfg.eject_after`
    /// consecutive failures accumulate. A probation failure re-ejects
    /// immediately (the count never reset), with a doubled cooldown up
    /// to the cap; seeded jitter keeps replicas ejected together from
    /// probing in lockstep.
    fn on_failure(&mut self, cfg: &RemoteConfig, rng: &mut Rng, stats: &RemoteStats, now: Instant) {
        self.fails = self.fails.saturating_add(1);
        if self.fails >= cfg.eject_after.max(1) {
            let shift = self.ejections.min(5);
            self.ejections = self.ejections.saturating_add(1);
            let base = cfg.eject_cooldown.max(Duration::from_millis(1));
            let cd = base
                .saturating_mul(1u32 << shift)
                .min(cfg.eject_cooldown_cap.max(base));
            self.ejected_until = Some(now + cd.mul_f64(0.5 + 0.5 * rng.gen_f64()));
            stats.ejections.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Builds the terminal failover error: names what failed, how many
/// attempts were burned, and the last replica tried.
fn exhausted_error(attempts: usize, last: &Option<(SocketAddr, io::Error)>) -> io::Error {
    match last {
        Some((addr, e)) => io::Error::new(
            e.kind(),
            format!("shard round failed after {attempts} attempt(s); last replica {addr}: {e}"),
        ),
        None => invalid(format!(
            "shard round failed after {attempts} attempt(s) with no replica reachable"
        )),
    }
}

/// Deadline-exhaustion variant of [`exhausted_error`]; always `TimedOut`.
fn deadline_error(attempts: usize, last: &Option<(SocketAddr, io::Error)>) -> io::Error {
    let detail = match last {
        Some((addr, e)) => format!(
            "batch deadline exhausted after {attempts} failover attempt(s); last replica {addr}: {e}"
        ),
        None => format!("batch deadline exhausted after {attempts} failover attempt(s)"),
    };
    io::Error::new(io::ErrorKind::TimedOut, detail)
}

/// One shard's replica set (per-replica health + connection), plus the
/// pooled encode/decode buffers. The retained `tx` frame is what makes
/// failover and hedging trivial: a failed or abandoned round re-sends
/// the identical bytes elsewhere.
struct RemoteShard {
    replicas: Vec<ReplicaState>,
    active: usize,
    info: WireShardInfo,
    tx: Vec<u8>,
    rx: Vec<u8>,
    /// Jitter stream for backoff sleeps and ejection cooldowns, seeded
    /// per shard from [`RemoteConfig::seed`] so chaos runs replay.
    rng: Rng,
}

impl RemoteShard {
    /// Connects and handshakes one replica, optionally under an extra
    /// time budget (what remains of a batch deadline). The connect
    /// timeout also bounds the handshake reads, so an accept-then-hang
    /// host cannot stall a probe beyond it; the socket is re-armed with
    /// the round timeout before being returned.
    fn connect_with(
        addr: SocketAddr,
        cfg: &RemoteConfig,
        budget: Option<Duration>,
    ) -> io::Result<(Conn, WireShardInfo)> {
        if let Some(f) = &cfg.faults {
            if f.client_connect_refused() {
                return Err(io::Error::new(
                    io::ErrorKind::ConnectionRefused,
                    format!("fault injection refused connect to {addr}"),
                ));
            }
        }
        let ct = match (nonzero(cfg.connect_timeout), budget) {
            (Some(c), Some(b)) => Some(c.min(b)),
            (Some(c), None) => Some(c),
            (None, b) => b,
        };
        let stream = match ct {
            Some(t) => TcpStream::connect_timeout(&addr, t.max(Duration::from_millis(1)))?,
            None => TcpStream::connect(addr)?,
        };
        stream.set_nodelay(true)?;
        let w = stream.try_clone()?;
        let mut conn = Conn {
            r: BufReader::new(stream),
            w,
        };
        conn.set_timeouts(ct)?;
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf);
        conn.w.write_all(&buf)?;
        match wire::read_frame(&mut conn.r, &mut buf)? {
            MsgType::ShardInfo => {
                let info = wire::decode_shard_info(&buf)?;
                // Steady-state rounds run under the round timeout.
                conn.set_timeouts(nonzero(cfg.round_timeout))?;
                Ok((conn, info))
            }
            MsgType::Error => Err(wire::error_from_frame(&buf)),
            _ => Err(invalid("handshake: unexpected frame type")),
        }
    }

    /// Connects and handshakes one replica ([`discover`] / [`poll_stats`]
    /// probe path).
    fn connect_addr(addr: SocketAddr, cfg: &RemoteConfig) -> io::Result<(Conn, WireShardInfo)> {
        Self::connect_with(addr, cfg, None)
    }

    /// Connects the first reachable replica and pins its identity; later
    /// reconnects must report the same identity. The error names the
    /// last address tried.
    fn new(addrs: Vec<SocketAddr>, cfg: &RemoteConfig) -> io::Result<Self> {
        assert!(!addrs.is_empty(), "shard needs at least one replica address");
        let mut last: Option<io::Error> = None;
        for (i, &a) in addrs.iter().enumerate() {
            match Self::connect_addr(a, cfg) {
                Ok((conn, info)) => {
                    let mut replicas: Vec<ReplicaState> =
                        addrs.iter().map(|&r| ReplicaState::new(r)).collect();
                    replicas[i].conn = Some(conn);
                    let rng = Rng::seed_from_u64(
                        cfg.seed
                            ^ (info.shard_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    return Ok(Self {
                        replicas,
                        active: i,
                        info,
                        tx: Vec::new(),
                        rx: Vec::new(),
                        rng,
                    });
                }
                Err(e) => last = Some(io::Error::new(e.kind(), format!("replica {a}: {e}"))),
            }
        }
        Err(last.expect("replica list is non-empty"))
    }

    fn active_addr(&self) -> SocketAddr {
        self.replicas[self.active].addr
    }

    fn drop_conns(&mut self) {
        for r in &mut self.replicas {
            r.conn = None;
        }
    }

    /// Moves the active slot to the next selectable replica in
    /// round-robin order. When every circuit is open, settles on the
    /// replica whose cooldown ends soonest and returns the wait until
    /// that probation probe is due.
    fn advance(&mut self, now: Instant) -> Option<Duration> {
        let len = self.replicas.len();
        for k in 1..=len {
            let i = (self.active + k) % len;
            if self.replicas[i].selectable(now) {
                self.active = i;
                return None;
            }
        }
        let (i, until) = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| (i, r.ejected_until.unwrap_or(now)))
            .min_by_key(|&(_, t)| t)
            .expect("replica list is non-empty");
        self.active = i;
        Some(until.saturating_duration_since(now))
    }

    /// Per-batch rotation: healthy replicas share load round-robin
    /// instead of pinning whichever connected first.
    fn rotate(&mut self, now: Instant) {
        if self.replicas.len() > 1 {
            self.advance(now);
        }
    }

    /// Ensures the active replica has a live, identity-checked
    /// connection, spending at most the remaining deadline on it.
    fn ensure_conn(&mut self, cfg: &RemoteConfig, deadline: Option<Instant>) -> io::Result<()> {
        if self.replicas[self.active].conn.is_some() {
            return Ok(());
        }
        let addr = self.active_addr();
        // An exhausted budget errs here, before the connect: a zero
        // remainder must not alias into the "no connect timeout"
        // sentinel and wait unboundedly.
        let budget = checked_budget(deadline)?;
        let (conn, info) = Self::connect_with(addr, cfg, budget)?;
        if info != self.info {
            return Err(invalid(format!(
                "replica {addr} reports a different shard identity"
            )));
        }
        self.replicas[self.active].conn = Some(conn);
        Ok(())
    }

    /// Records a failure on the active replica (possibly opening its
    /// circuit), drops its connection, and advances to the next
    /// selectable replica. Returns the cooldown wait when every circuit
    /// is open.
    fn fail_over(&mut self, cfg: &RemoteConfig, stats: &RemoteStats) -> Option<Duration> {
        let now = Instant::now();
        {
            let Self {
                replicas,
                rng,
                active,
                ..
            } = self;
            let r = &mut replicas[*active];
            r.conn = None;
            r.on_failure(cfg, rng, stats, now);
        }
        stats.failovers.fetch_add(1, Ordering::Relaxed);
        self.advance(now)
    }

    /// Best-effort scatter: write the retained `tx` frame on the active
    /// connection, armed with the effective timeout so the write itself
    /// is bounded by the deadline remainder (a paused peer with full
    /// socket buffers must not stall a batch past its budget, even with
    /// the round timeout disabled). An exhausted budget does **no**
    /// socket op at all — [`RemoteShard::recv`] fails the batch with the
    /// deadline error. Other failures are absorbed silently; `recv` runs
    /// the full failover loop.
    fn send(&mut self, cfg: &RemoteConfig, deadline: Option<Instant>) {
        let Ok(eff) = effective_timeout(cfg.round_timeout, deadline) else {
            return;
        };
        if self.ensure_conn(cfg, deadline).is_err() {
            return;
        }
        if let Some(f) = &cfg.faults {
            let d = f.client_send_delay();
            if !d.is_zero() {
                std::thread::sleep(d);
            }
        }
        let conn = self.replicas[self.active]
            .conn
            .as_mut()
            .expect("connection just ensured");
        if conn.set_timeouts(eff).is_err() || conn.w.write_all(&self.tx).is_err() {
            self.replicas[self.active].conn = None;
        }
    }

    /// One attempt on the active replica: (re)connect, arm the effective
    /// timeout (round timeout capped by the deadline remainder), re-send
    /// the retained frame, read the reply. Success resets the replica's
    /// failure count and feeds its latency EWMA.
    fn try_round(&mut self, cfg: &RemoteConfig, deadline: Option<Instant>) -> io::Result<MsgType> {
        // Budget check first: exhaustion must fail before the connect or
        // any other socket op ([`checked_budget`]).
        let eff = effective_timeout(cfg.round_timeout, deadline)?;
        self.ensure_conn(cfg, deadline)?;
        if let Some(f) = &cfg.faults {
            let d = f.client_send_delay();
            if !d.is_zero() {
                std::thread::sleep(d);
            }
        }
        let t0 = Instant::now();
        let ty = {
            let active = self.active;
            let Self {
                replicas, rx, tx, ..
            } = self;
            let conn = replicas[active]
                .conn
                .as_mut()
                .expect("connection just ensured");
            conn.set_timeouts(eff)?;
            conn.w.write_all(tx)?;
            wire::read_frame(&mut conn.r, rx)?
        };
        self.replicas[self.active].on_success(t0.elapsed());
        Ok(ty)
    }

    /// Bounded failover loop with deadline budget and backoff: try the
    /// active replica, record failures, advance round-robin past open
    /// circuits, sleep a capped exponential backoff (seeded jitter)
    /// after each full replica cycle — or wait out the soonest cooldown
    /// when every circuit is open — and give up when the attempt budget
    /// or the batch deadline runs out. Rounds are stateless, so re-issue
    /// is always safe.
    fn round_trip(
        &mut self,
        cfg: &RemoteConfig,
        stats: &RemoteStats,
        deadline: Option<Instant>,
    ) -> io::Result<MsgType> {
        let len = self.replicas.len();
        let max_attempts = (2 * len).max(2);
        let mut last: Option<(SocketAddr, io::Error)> = None;
        let mut backoff = cfg.backoff_base.max(Duration::from_micros(100));
        let mut attempts = 0usize;
        while attempts < max_attempts {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return Err(deadline_error(attempts, &last));
            }
            attempts += 1;
            let addr = self.active_addr();
            match self.try_round(cfg, deadline) {
                // A decoded Error frame is deterministic — replicas of
                // the same shard would answer the same; do not fail over.
                Ok(MsgType::Error) => return Err(wire::error_from_frame(&self.rx)),
                Ok(ty) => return Ok(ty),
                Err(e) => {
                    last = Some((addr, e));
                    // Distinguish budget expiry from replica failure
                    // *before* penalizing anyone: a round that died only
                    // because the deadline ran out mid-attempt must not
                    // bump the replica's failure count, open its
                    // circuit, or count as a failover — the replica may
                    // be perfectly healthy.
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        return Err(deadline_error(attempts, &last));
                    }
                    let all_ejected = self.fail_over(cfg, stats);
                    let mut pause = match all_ejected {
                        Some(wait) => wait.min(cfg.eject_cooldown_cap.max(cfg.eject_cooldown)),
                        None if attempts % len == 0 => {
                            let p = backoff.mul_f64(0.5 + 0.5 * self.rng.gen_f64());
                            backoff = (backoff * 2).min(cfg.backoff_cap.max(backoff));
                            p
                        }
                        None => Duration::ZERO,
                    };
                    if let Some(d) = deadline {
                        pause = pause.min(d.saturating_duration_since(Instant::now()));
                    }
                    if !pause.is_zero() {
                        std::thread::sleep(pause);
                    }
                }
            }
        }
        Err(exhausted_error(attempts, &last))
    }

    /// Reads this round's reply into the pooled `rx` buffer, failing
    /// over (reconnect + re-send + re-read) as needed. `hedge_after`
    /// bounds the first read to the shard's observed-p99 budget: a
    /// reply slower than that abandons the connection and re-issues the
    /// round on the next healthy replica — the sequential form of a
    /// hedged request. First valid reply wins, and replies are
    /// deterministic, so hedging cannot change results.
    fn recv(
        &mut self,
        cfg: &RemoteConfig,
        stats: &RemoteStats,
        deadline: Option<Instant>,
        hedge_after: Option<Duration>,
    ) -> io::Result<MsgType> {
        if self.replicas[self.active].conn.is_some() {
            let base = effective_timeout(cfg.round_timeout, deadline)?;
            let (first, hedged) = match (hedge_after, base) {
                (Some(h), Some(b)) => (Some(h.min(b)), h < b),
                (Some(h), None) => (Some(h), true),
                (None, b) => (b, false),
            };
            let t0 = Instant::now();
            let read = {
                let active = self.active;
                let Self { replicas, rx, .. } = self;
                let conn = replicas[active].conn.as_mut().expect("conn checked above");
                conn.set_timeouts(first)
                    .and_then(|()| wire::read_frame(&mut conn.r, rx))
            };
            match read {
                Ok(MsgType::Error) => return Err(wire::error_from_frame(&self.rx)),
                Ok(ty) => {
                    self.replicas[self.active].on_success(t0.elapsed());
                    return Ok(ty);
                }
                Err(e) => {
                    // Budget expiry is not a replica failure: if the
                    // read died because the batch deadline ran out,
                    // surface `deadline_error` without penalizing the
                    // (possibly healthy) replica — the caller drops
                    // every connection on error, so skipping
                    // `fail_over` leaves no desynced stream behind.
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        let last = Some((self.replicas[self.active].addr, e));
                        return Err(deadline_error(1, &last));
                    }
                    // A timeout mid-frame leaves the stream desynced and
                    // any read error poisons it: drop the connection
                    // either way and re-issue elsewhere.
                    if hedged
                        && matches!(e.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock)
                    {
                        stats.hedges.fetch_add(1, Ordering::Relaxed);
                    }
                    self.fail_over(cfg, stats);
                }
            }
        }
        self.round_trip(cfg, stats, deadline)
    }
}

// =====================================================================
// Remote gather stage
// =====================================================================

/// Probes every address (connect + handshake), groups them by the shard
/// id each host reports, and returns the replica groups ordered by shard
/// id — the discovery step behind `serve --remote a:p,b:p,...` (replicas
/// need no special syntax; hosts identify themselves).
pub fn discover(addrs: &[SocketAddr], cfg: &RemoteConfig) -> io::Result<Vec<Vec<SocketAddr>>> {
    if addrs.is_empty() {
        return Err(invalid("no shard-host addresses given"));
    }
    let mut num_shards: Option<u32> = None;
    let mut groups: Vec<Vec<SocketAddr>> = Vec::new();
    for &a in addrs {
        let (_, info) = RemoteShard::connect_addr(a, cfg)
            .map_err(|e| io::Error::new(e.kind(), format!("probing {a}: {e}")))?;
        let s = *num_shards.get_or_insert(info.num_shards);
        if info.num_shards != s {
            return Err(invalid(format!(
                "{a} reports a {}-shard partition, earlier hosts reported {s}",
                info.num_shards
            )));
        }
        if groups.len() < s as usize {
            groups.resize_with(s as usize, Vec::new);
        }
        groups[info.shard_id as usize].push(a);
    }
    let missing: Vec<String> = groups
        .iter()
        .enumerate()
        .filter(|(_, g)| g.is_empty())
        .map(|(i, _)| i.to_string())
        .collect();
    if !missing.is_empty() {
        return Err(invalid(format!(
            "incomplete partition: no host for shard(s) {}",
            missing.join(", ")
        )));
    }
    Ok(groups)
}

/// Polls one shard host's live metrics over a fresh connection
/// (handshake + one [`wire::MsgType::Stats`] round) — the `metrics` CLI
/// subcommand's transport. Needs no partition: any single host answers
/// for itself.
pub fn poll_stats(addr: SocketAddr, cfg: &RemoteConfig) -> io::Result<Snapshot> {
    let (mut conn, _) = RemoteShard::connect_addr(addr, cfg)?;
    let mut buf = Vec::new();
    wire::encode_stats_poll(&mut buf);
    conn.w.write_all(&buf)?;
    match wire::read_frame(&mut conn.r, &mut buf)? {
        MsgType::Stats => wire::decode_stats(&buf),
        MsgType::Error => Err(wire::error_from_frame(&buf)),
        ty => Err(invalid(format!("expected Stats, got {ty:?}"))),
    }
}

/// Polls one shard host's flight recorder over a fresh connection
/// (handshake + one [`wire::MsgType::Traces`] round) — the
/// `metrics --traces` transport. Newest records first; empty when the
/// host's recorder is disabled.
pub fn poll_traces(addr: SocketAddr, cfg: &RemoteConfig) -> io::Result<Vec<TraceRecord>> {
    let (mut conn, _) = RemoteShard::connect_addr(addr, cfg)?;
    let mut buf = Vec::new();
    wire::encode_traces_poll(&mut buf);
    conn.w.write_all(&buf)?;
    match wire::read_frame(&mut conn.r, &mut buf)? {
        MsgType::Traces => wire::decode_traces(&buf),
        MsgType::Error => Err(wire::error_from_frame(&buf)),
        ty => Err(invalid(format!("expected Traces, got {ty:?}"))),
    }
}

/// The remote gather stage: drives N shard hosts through the
/// layer-synchronized protocol exactly like the in-process
/// [`ShardedEngine`] drives its units, with replica failover and
/// speculative round skipping. One `RemoteGather` per serving thread —
/// it owns its connections, its [`GatherArena`] and every codec buffer,
/// so rounds are alloc-bounded once warm.
pub struct RemoteGather {
    shards: Vec<RemoteShard>,
    cfg: RemoteConfig,
    depth: usize,
    dim: usize,
    num_labels: u64,
    arena: GatherArena,
    spec: Vec<SpecRound>,
    spec_ok: Vec<bool>,
    /// Shards marked down for the current batch
    /// ([`RemoteConfig::allow_partial`]); reset at every batch start.
    dead: Vec<bool>,
    x: CsrMatrix,
    round_id: u64,
    stats: Arc<RemoteStats>,
    /// Client flight recorder; `Some` traces every batch
    /// ([`RemoteConfig::flight_recorder`]). Shared across a coordinator's
    /// gather workers ([`RemoteGather::set_recorder`]).
    recorder: Option<Arc<FlightRecorder>>,
    /// Pooled span buffer of the batch being assembled (hard-capped at
    /// [`MAX_TRACE_SPANS`]; overflow counted in `span_drop`).
    spans: Vec<RoundSpan>,
    /// Spans dropped past the cap in the current batch.
    span_drop: u32,
    /// Host span decoded off each shard's latest reply (zeros when the
    /// host sent none).
    host_spans: Vec<HostSpan>,
    /// Per-shard encode+send time of the current round, ns.
    tx_ns: Vec<u64>,
}

/// Hedge only once a shard's round histogram holds this many samples —
/// a cold p99 is noise, and a noise threshold would hedge every round.
const HEDGE_MIN_SAMPLES: u64 = 64;

impl RemoteGather {
    /// Discovers the partition behind `addrs` and connects every shard.
    pub fn connect(addrs: &[SocketAddr], cfg: RemoteConfig) -> io::Result<Self> {
        let groups = discover(addrs, &cfg)?;
        Self::connect_groups(&groups, cfg, None)
    }

    /// Connects explicit replica groups (`groups[i]` = addresses of shard
    /// `i`'s replicas), validating that the hosts form one complete,
    /// contiguous partition. `stats` shares transport telemetry across
    /// gather workers; `None` creates a fresh set.
    pub fn connect_groups(
        groups: &[Vec<SocketAddr>],
        cfg: RemoteConfig,
        stats: Option<Arc<RemoteStats>>,
    ) -> io::Result<Self> {
        if groups.is_empty() {
            return Err(invalid("no shard replica groups"));
        }
        let mut shards = Vec::with_capacity(groups.len());
        for g in groups {
            shards.push(RemoteShard::new(g.clone(), &cfg)?);
        }
        shards.sort_by_key(|s| s.info.shard_id);
        let (depth, dim, num_labels) = validate_topology(&shards)?;
        let s_count = shards.len();
        let stats = stats.unwrap_or_else(|| Arc::new(RemoteStats::new(s_count)));
        if stats.scatter.num_shards() != s_count {
            return Err(invalid("shared stats sized for a different shard count"));
        }
        let recorder = (cfg.flight_recorder > 0).then(|| {
            Arc::new(FlightRecorder::new(FlightRecorderConfig {
                capacity: cfg.flight_recorder,
                ..FlightRecorderConfig::default()
            }))
        });
        Ok(Self {
            shards,
            cfg,
            depth,
            dim,
            num_labels,
            arena: GatherArena::new(),
            spec: (0..s_count).map(|_| SpecRound::default()).collect(),
            spec_ok: vec![false; s_count],
            dead: vec![false; s_count],
            x: CsrMatrix::default(),
            round_id: 0,
            stats,
            recorder,
            spans: Vec::with_capacity(MAX_TRACE_SPANS),
            span_drop: 0,
            host_spans: vec![HostSpan::default(); s_count],
            tx_ns: vec![0; s_count],
        })
    }

    /// Number of shards in the partition.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Tree depth in ranker layers.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total labels across shards.
    pub fn num_labels(&self) -> u64 {
        self.num_labels
    }

    /// Shared transport statistics.
    pub fn stats(&self) -> &Arc<RemoteStats> {
        &self.stats
    }

    /// The client-side flight recorder (`None` when tracing is off).
    pub fn recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.recorder.as_ref()
    }

    /// Replaces the flight recorder — how a coordinator shares one ring
    /// across its gather workers (mirrors the shared [`RemoteStats`]).
    pub fn set_recorder(&mut self, recorder: Option<Arc<FlightRecorder>>) {
        self.recorder = recorder;
    }

    /// Polls shard `shard`'s flight recorder over the
    /// [`wire::MsgType::Traces`] frame, with the same failover the
    /// rounds use. Newest records first; empty when the host's recorder
    /// is disabled.
    pub fn poll_shard_traces(&mut self, shard: usize) -> io::Result<Vec<TraceRecord>> {
        let sh = &mut self.shards[shard];
        wire::encode_traces_poll(&mut sh.tx);
        match sh.round_trip(&self.cfg, &self.stats, None)? {
            MsgType::Traces => wire::decode_traces(&sh.rx),
            ty => Err(invalid(format!("shard {shard}: expected Traces, got {ty:?}"))),
        }
    }

    /// Polls shard `shard`'s live metrics over the
    /// [`wire::MsgType::Stats`] frame, with the same failover the rounds
    /// use. The reply carries the host's registry plus its engine
    /// telemetry under the `engine.` prefix.
    pub fn poll_shard_stats(&mut self, shard: usize) -> io::Result<Snapshot> {
        let sh = &mut self.shards[shard];
        wire::encode_stats_poll(&mut sh.tx);
        match sh.round_trip(&self.cfg, &self.stats, None)? {
            MsgType::Stats => wire::decode_stats(&sh.rx),
            ty => Err(invalid(format!("shard {shard}: expected Stats, got {ty:?}"))),
        }
    }

    /// Health phases of shard `shard`'s replicas: `(address, phase,
    /// EWMA round-latency ms — 0 until the first sample)`. Operator
    /// observability; the chaos suite asserts ejection and rejoin
    /// through it.
    pub fn replica_phases(&self, shard: usize) -> Vec<(SocketAddr, ReplicaPhase, f64)> {
        let now = Instant::now();
        self.shards[shard]
            .replicas
            .iter()
            .map(|r| (r.addr, r.phase(now), r.ewma_ms))
            .collect()
    }

    /// `true` when the last completed batch was served degraded (some
    /// shard down under [`RemoteConfig::allow_partial`]).
    pub fn last_batch_degraded(&self) -> bool {
        self.dead.iter().any(|&d| d)
    }

    /// Shard ids that were down for the last completed batch (empty =
    /// full fidelity).
    pub fn degraded_shards(&self) -> Vec<u32> {
        self.dead
            .iter()
            .enumerate()
            .filter_map(|(i, &d)| d.then_some(i as u32))
            .collect()
    }

    /// Per-query results of the last completed batch.
    pub fn results(&self) -> &[Vec<Prediction>] {
        self.arena.results()
    }

    /// Online remote inference for one query; the returned slice lives in
    /// the gather arena until the next call.
    pub fn predict_with(
        &mut self,
        q: &SparseVec,
        beam: usize,
        topk: usize,
    ) -> io::Result<&[Prediction]> {
        self.x.reset(self.dim);
        self.x.push_row(q.view());
        self.run(1, beam, topk)?;
        Ok(&self.arena.results()[0])
    }

    /// Online remote inference, returning an owned ranking.
    pub fn predict(
        &mut self,
        q: &SparseVec,
        beam: usize,
        topk: usize,
    ) -> io::Result<Vec<Prediction>> {
        self.predict_with(q, beam, topk).map(|p| p.to_vec())
    }

    /// Batch remote inference; rankings land in [`RemoteGather::results`].
    pub fn predict_batch_into(
        &mut self,
        x: &CsrMatrix,
        beam: usize,
        topk: usize,
    ) -> io::Result<()> {
        assert_eq!(x.cols, self.dim, "query dim mismatch");
        self.load_queries(x.cols, (0..x.rows).map(|i| x.row(i)));
        self.run(x.rows, beam, topk)
    }

    /// Rebuilds the pooled query matrix in place.
    pub(crate) fn load_queries<'a>(
        &mut self,
        dim: usize,
        rows: impl IntoIterator<Item = SparseVecView<'a>>,
    ) {
        self.x.assign_rows(dim, rows);
    }

    /// The remote layer-synchronized driver over the queries already
    /// loaded into the pooled matrix. The per-layer sequence is the
    /// in-process one — scatter ([`wire`]-shipped [`ShardRound`]s instead
    /// of channel-shipped ones), merge, global `select_top`, split — so
    /// the output is bit-identical to [`ShardedEngine`] and therefore to
    /// the unsharded engine.
    pub(crate) fn run(&mut self, n: usize, beam: usize, topk: usize) -> io::Result<()> {
        let deadline = nonzero(self.cfg.deadline).map(|d| Instant::now() + d);
        let r = self.run_rounds(n, beam, topk, deadline);
        match &r {
            Ok(()) => {
                if self.last_batch_degraded() {
                    self.stats.degraded_batches.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(_) => {
                // A batch that failed mid-join (every replica of some
                // shard gone, deadline spent, or a desynced reply) can
                // leave unread Cands frames buffered on the surviving
                // connections. Drop every connection so the next batch
                // reconnects clean instead of reading stale replies
                // forever — rounds are stateless, so a reconnect costs
                // one handshake and nothing else.
                for sh in &mut self.shards {
                    sh.drop_conns();
                }
            }
        }
        r
    }

    /// The hedge threshold for shard `s`'s next reply: its observed p99
    /// round latency, once the histogram is warm and only when a second
    /// replica exists to hedge to. `None` disables hedging for the read.
    fn hedge_after(&self, s: usize) -> Option<Duration> {
        if !self.cfg.hedge || self.shards[s].replicas.len() < 2 {
            return None;
        }
        self.stats
            .scatter
            .shard(s)
            .quantile_ms_if(0.99, HEDGE_MIN_SAMPLES)
            .map(|p99| Duration::from_secs_f64(p99.max(1.0) / 1e3))
            .filter(|h| match nonzero(self.cfg.round_timeout) {
                Some(rt) => *h < rt,
                None => true,
            })
    }

    /// One shard's contribution to the current join: read the reply
    /// (with failover and hedging), decode it into the shard's round
    /// slot, validate the echo.
    fn join_shard(
        &mut self,
        s: usize,
        rid: u64,
        layer: u32,
        n: usize,
        deadline: Option<Instant>,
    ) -> io::Result<()> {
        let hedge_after = self.hedge_after(s);
        let ty = self.shards[s].recv(&self.cfg, &self.stats, deadline, hedge_after)?;
        if ty != MsgType::Cands {
            return Err(invalid(format!("shard {s}: expected Cands, got {ty:?}")));
        }
        let ch: CandsHeader = wire::decode_cands(
            &self.shards[s].rx,
            &mut self.arena.rounds[s],
            &mut self.spec[s],
        )?;
        if ch.round_id != rid || ch.layer != layer {
            return Err(invalid(format!("shard {s}: reply out of sync")));
        }
        if self.arena.rounds[s].n != n {
            return Err(invalid(format!("shard {s}: reply for a different batch size")));
        }
        self.spec_ok[s] = ch.has_spec && self.spec[s].n == n;
        self.host_spans[s] = ch.host_span.unwrap_or_default();
        Ok(())
    }

    /// Appends one span to the current batch's trace, counting overflow
    /// past the wire cap instead of growing.
    fn push_span(&mut self, span: RoundSpan) {
        if self.spans.len() < MAX_TRACE_SPANS {
            self.spans.push(span);
        } else {
            self.span_drop += 1;
        }
    }

    /// Marks shard `s` down for the rest of the batch: its round slot is
    /// cleared to "n queries, no candidates" so the merge sees an empty
    /// contribution, its speculation hint is void, and its connections
    /// are dropped (any buffered reply is stale).
    fn mark_dead(&mut self, s: usize, n: usize) {
        self.dead[s] = true;
        self.spec_ok[s] = false;
        self.arena.rounds[s].clear_round(n);
        self.shards[s].drop_conns();
    }

    fn run_rounds(
        &mut self,
        n: usize,
        beam: usize,
        topk: usize,
        deadline: Option<Instant>,
    ) -> io::Result<()> {
        assert!(beam >= 1, "beam width must be >= 1");
        assert_eq!(self.x.rows, n, "query matrix not loaded for this batch");
        let s_count = self.shards.len();
        self.arena.begin_rounds(s_count, n);
        self.dead.iter_mut().for_each(|d| *d = false);
        // Trace setup: one trace id per batch, one span per live shard
        // per real network round, assembled into the recorder at batch
        // end. `tracing` is the only flag the hot path checks — with the
        // recorder off nothing below takes a timestamp.
        let tracing = self.recorder.is_some();
        let t_batch = Instant::now();
        self.spans.clear();
        self.span_drop = 0;
        let trace_id = self
            .recorder
            .as_ref()
            .map_or(0, |r| r.next_trace_id());
        let now = Instant::now();
        for sh in &mut self.shards {
            sh.rotate(now);
        }
        let mut l = 0usize;
        while l < self.depth {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    format!("batch deadline exhausted before layer {l}"),
                ));
            }
            let want_spec = self.cfg.speculate && l + 1 < self.depth;
            self.round_id += 1;
            let rid = self.round_id;
            let hdr = ExpandHeader {
                round_id: rid,
                layer: l as u32,
                beam: beam as u32,
                speculate: want_spec,
                trace: tracing,
                trace_id,
            };
            // Scatter: encode every live shard's slice, write them all
            // before reading any reply so hosts expand concurrently.
            for s in 0..s_count {
                if self.dead[s] {
                    continue;
                }
                let t_tx = tracing.then(Instant::now);
                wire::encode_expand(
                    &mut self.shards[s].tx,
                    &hdr,
                    &self.x,
                    &self.arena.rounds[s].beams,
                    n,
                );
                self.shards[s].send(&self.cfg, deadline);
                if let Some(t) = t_tx {
                    self.tx_ns[s] = t.elapsed().as_nanos() as u64;
                }
            }
            // Join: collect replies in shard order, failing over as
            // needed; record per-shard latency and the join wait (read-
            // completion order — see the `RemoteStats::scatter` caveat).
            let round_start = self.spans.len();
            let t_round = Instant::now();
            let mut first_reply: Option<Duration> = None;
            let mut last_reply = Duration::ZERO;
            for s in 0..s_count {
                if self.dead[s] {
                    continue;
                }
                // Joins are sequential, so a diff of the shared failure
                // counters around this shard's join attributes hedges,
                // failovers and ejections to its span.
                let ev0 = if tracing {
                    [&self.stats.hedges, &self.stats.failovers, &self.stats.ejections]
                        .map(|c| c.load(Ordering::Relaxed))
                } else {
                    [0; 3]
                };
                let joined = self.join_shard(s, rid, l as u32, n, deadline);
                let mut events = 0u32;
                if tracing {
                    let [h, f, e] =
                        [&self.stats.hedges, &self.stats.failovers, &self.stats.ejections]
                            .map(|c| c.load(Ordering::Relaxed));
                    if h > ev0[0] {
                        events |= EV_HEDGE;
                    }
                    if f > ev0[1] {
                        events |= EV_FAILOVER;
                    }
                    if e > ev0[2] {
                        events |= EV_EJECTION;
                    }
                }
                if let Err(e) = joined {
                    // Deadline expiry always fails the batch — a partial
                    // result must not cost more than the budget either.
                    let budget_gone = deadline.is_some_and(|d| Instant::now() >= d);
                    if self.cfg.allow_partial && !budget_gone {
                        self.mark_dead(s, n);
                        if tracing {
                            self.push_span(RoundSpan {
                                shard: s as u32,
                                layer: l as u32,
                                tx_ns: self.tx_ns[s],
                                round_ns: t_round.elapsed().as_nanos() as u64,
                                wait_ns: 0,
                                host: HostSpan::default(),
                                events: events | EV_DEAD,
                            });
                        }
                        continue;
                    }
                    return Err(e);
                }
                let elapsed = t_round.elapsed();
                self.stats.scatter.record_round(s, elapsed);
                if tracing {
                    // Join-wait share: this reply minus the round's first
                    // (0 for the shard that answered first).
                    let wait = first_reply.map_or(Duration::ZERO, |f| elapsed.saturating_sub(f));
                    self.push_span(RoundSpan {
                        shard: s as u32,
                        layer: l as u32,
                        tx_ns: self.tx_ns[s],
                        round_ns: elapsed.as_nanos() as u64,
                        wait_ns: wait.as_nanos() as u64,
                        host: self.host_spans[s],
                        events,
                    });
                }
                first_reply.get_or_insert(elapsed);
                last_reply = elapsed;
            }
            if self.dead.iter().all(|&d| d) {
                return Err(invalid("every shard of the partition is down"));
            }
            if let Some(first) = first_reply {
                self.stats.scatter.record_join_wait(last_reply.saturating_sub(first));
            }
            self.stats.rounds.fetch_add(1, Ordering::Relaxed);
            self.merge_layer(l, beam);
            l += 1;
            // Speculative skip: if every host sent a usable hint, the
            // next layer's exact candidates are already here. (A dead
            // shard voids its hint, so degraded batches take real
            // rounds — which skip the dead shard — from then on.)
            if l < self.depth && want_spec {
                if self.try_assemble_spec(n) {
                    self.stats.spec_rounds_saved.fetch_add(1, Ordering::Relaxed);
                    if tracing {
                        for sp in &mut self.spans[round_start..] {
                            sp.events |= EV_SPEC_HIT;
                        }
                    }
                    self.merge_layer(l, beam);
                    l += 1;
                } else {
                    self.stats.spec_misses.fetch_add(1, Ordering::Relaxed);
                    if tracing {
                        for sp in &mut self.spans[round_start..] {
                            sp.events |= EV_SPEC_MISS;
                        }
                    }
                }
            }
        }
        for q in 0..n {
            rank_into(&mut self.arena.global_beams[q], topk, &mut self.arena.out[q]);
        }
        if let Some(rec) = &self.recorder {
            let degraded = self.dead.iter().any(|&d| d);
            let spans = &self.spans;
            let span_drop = self.span_drop;
            rec.record(t_batch.elapsed(), |r| {
                r.trace_id = trace_id;
                r.batch = n as u32;
                r.beam = beam as u32;
                for sp in spans {
                    r.push_span(*sp);
                }
                r.truncated += span_drop;
                if degraded {
                    r.events |= EV_DEGRADED;
                }
            });
        }
        Ok(())
    }

    /// [`merge_and_split_layer`] over the wire-announced shard ranges.
    fn merge_layer(&mut self, layer: usize, beam: usize) {
        let shards = &self.shards;
        merge_and_split_layer(
            shards.len(),
            |s| {
                let info = &shards[s].info;
                let lo = info.layer_offsets[layer];
                (lo, lo + info.layer_nodes[layer])
            },
            beam,
            &mut self.arena,
        );
    }

    /// Assembles the next layer's candidates from the speculation hints:
    /// for each query, walks the **true** local beam (left by the last
    /// merge) against the speculated parents (both ascending) and copies
    /// each surviving parent's children — exactly the candidates a real
    /// round would generate, in the order [`expand_round`] generates
    /// them. Returns `false` (fall back to a real round) if any shard's
    /// hint fails to cover its true beam slice.
    fn try_assemble_spec(&mut self, n: usize) -> bool {
        let s_count = self.shards.len();
        if self.spec_ok[..s_count].iter().any(|&ok| !ok) {
            return false;
        }
        for s in 0..s_count {
            let round = &mut self.arena.rounds[s];
            let sp = &self.spec[s];
            for q in 0..n {
                let beamv = &round.beams[q];
                let cand = &mut round.cands[q];
                cand.clear();
                let parents = &sp.parents[q];
                let counts = &sp.child_counts[q];
                let children = &sp.children[q];
                let mut pi = 0usize; // cursor into parents
                let mut off = 0usize; // flat child offset of parents[..pi]
                for &(node, score) in beamv {
                    while pi < parents.len() && parents[pi].0 < node {
                        off += counts[pi] as usize;
                        pi += 1;
                    }
                    if pi >= parents.len() || parents[pi].0 != node {
                        return false; // hint does not cover the true beam
                    }
                    debug_assert_eq!(
                        parents[pi].1.to_bits(),
                        score.to_bits(),
                        "speculated parent score diverged"
                    );
                    let w = counts[pi] as usize;
                    if off + w > children.len() {
                        return false; // malformed hint
                    }
                    cand.extend_from_slice(&children[off..off + w]);
                    off += w;
                    pi += 1;
                }
            }
        }
        true
    }
}

/// Validates that the connected hosts form one complete, gap-free
/// partition (mirrors `load_shards`' checks): ids `0..S` exactly once,
/// equal depth/dim, every layer's column ranges tiling contiguously,
/// labels contiguous. Returns `(depth, dim, total_labels)`.
fn validate_topology(shards: &[RemoteShard]) -> io::Result<(usize, usize, u64)> {
    let s_count = shards.len();
    let num_shards = shards[0].info.num_shards as usize;
    if num_shards != s_count {
        return Err(invalid(format!(
            "incomplete partition: connected {s_count} of {num_shards} shards"
        )));
    }
    let depth = shards[0].info.depth as usize;
    let dim = shards[0].info.dim as usize;
    let mut next_cols = vec![0u32; depth];
    let mut next_label = 0u64;
    for (i, sh) in shards.iter().enumerate() {
        let info = &sh.info;
        if info.shard_id as usize != i || info.num_shards as usize != num_shards {
            return Err(invalid("duplicate or mismatched shard ids"));
        }
        if info.depth as usize != depth {
            return Err(invalid(format!("shard {i} depth disagrees with shard 0")));
        }
        if info.dim as usize != dim {
            return Err(invalid(format!("shard {i} dim disagrees with shard 0")));
        }
        if info.label_offset != next_label {
            return Err(invalid(format!("shard {i} labels are not contiguous")));
        }
        for (l, nc) in next_cols.iter_mut().enumerate() {
            if info.layer_offsets[l] != *nc {
                return Err(invalid(format!(
                    "shard {i} layer {l} columns are not contiguous with its predecessor"
                )));
            }
            *nc += info.layer_nodes[l];
        }
        next_label += info.num_labels;
    }
    Ok((depth, dim, next_label))
}

// =====================================================================
// Remote sharded coordinator
// =====================================================================

/// Configuration of the remote serving stack.
#[derive(Clone, Debug, Default)]
pub struct RemoteCoordinatorConfig {
    /// Front-door configuration; `base.workers` gather workers each own
    /// their private connections to every shard.
    pub base: CoordinatorConfig,
    /// Transport knobs (speculation, timeouts).
    pub remote: RemoteConfig,
}

struct RemoteInner {
    config: RemoteCoordinatorConfig,
    stats: CoordinatorStats,
    remote_stats: Arc<RemoteStats>,
    /// One flight recorder shared by every gather worker (`None` when
    /// [`RemoteConfig::flight_recorder`] is 0).
    recorder: Option<Arc<FlightRecorder>>,
    router: Router,
    dim: usize,
    num_shards: usize,
    num_labels: u64,
}

/// The cross-process serving system: the same dynamic batcher and router
/// as [`super::ShardedCoordinator`], with gather workers that drive
/// remote shard hosts through [`RemoteGather`] instead of in-process
/// worker pools. Results are bit-identical; shards live wherever their
/// hosts do.
pub struct RemoteShardedCoordinator {
    inner: Arc<RemoteInner>,
    batcher: Option<JoinHandle<()>>,
    gatherers: Option<WorkerPool>,
}

impl RemoteShardedCoordinator {
    /// Discovers the partition behind `addrs` and starts serving.
    pub fn start(addrs: &[SocketAddr], config: RemoteCoordinatorConfig) -> io::Result<Self> {
        let groups = discover(addrs, &config.remote)?;
        Self::start_groups(&groups, config)
    }

    /// Starts serving against explicit replica groups. Every gather
    /// worker connects to every shard up front, so a dead host fails
    /// loudly here rather than on the first query.
    pub fn start_groups(
        groups: &[Vec<SocketAddr>],
        config: RemoteCoordinatorConfig,
    ) -> io::Result<Self> {
        let workers = config.base.workers.max(1);
        let mut gathers = Vec::with_capacity(workers);
        let first = RemoteGather::connect_groups(groups, config.remote.clone(), None)?;
        let remote_stats = Arc::clone(first.stats());
        let recorder = first.recorder().cloned();
        let dim = first.dim();
        let num_shards = first.num_shards();
        let num_labels = first.num_labels();
        gathers.push(first);
        for _ in 1..workers {
            let mut g = RemoteGather::connect_groups(
                groups,
                config.remote.clone(),
                Some(Arc::clone(&remote_stats)),
            )?;
            // All workers feed one ring, so the exported trace set spans
            // the whole coordinator and trace ids never collide.
            g.set_recorder(recorder.clone());
            gathers.push(g);
        }

        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let inner = Arc::new(RemoteInner {
            stats: CoordinatorStats::default(),
            remote_stats,
            recorder,
            router: Router::new(req_tx, config.base.queue_capacity),
            dim,
            num_shards,
            num_labels,
            config: config.clone(),
        });
        let batcher = {
            let inner = Arc::clone(&inner);
            spawn_batcher(
                "mscm-remote-batcher".into(),
                req_rx,
                batch_tx,
                config.base.max_batch,
                config.base.max_batch_delay,
                move |n| {
                    inner.stats.batches.fetch_add(1, Ordering::Relaxed);
                    inner.stats.batched_queries.fetch_add(n as u64, Ordering::Relaxed);
                },
            )
        };
        let gatherers = {
            let inner = Arc::clone(&inner);
            let slots: Arc<Mutex<Vec<Option<RemoteGather>>>> =
                Arc::new(Mutex::new(gathers.into_iter().map(Some).collect()));
            WorkerPool::spawn(
                "mscm-remote-gather",
                workers,
                batch_rx,
                move |w| slots.lock().unwrap()[w].take().expect("gather slot taken twice"),
                move |g, batch: Vec<Request>| remote_batch(&inner, g, batch),
            )
        };
        Ok(Self {
            inner,
            batcher: Some(batcher),
            gatherers: Some(gatherers),
        })
    }

    /// Submits a query; the reply arrives on the returned channel.
    pub fn submit(&self, query: SparseVec) -> Result<(u64, mpsc::Receiver<Response>), SubmitError> {
        self.inner.router.submit(query, &self.inner.stats)
    }

    /// Convenience: submit and block for the response.
    pub fn query_blocking(&self, query: SparseVec) -> Result<Response, SubmitError> {
        let (_, rx) = self.submit(query)?;
        rx.recv().map_err(|_| SubmitError::Shutdown)
    }

    /// Serving statistics (front-door view).
    pub fn stats(&self) -> &CoordinatorStats {
        &self.inner.stats
    }

    /// Transport statistics (rounds, speculation, failover, per-shard
    /// round latency).
    pub fn remote_stats(&self) -> &Arc<RemoteStats> {
        &self.inner.remote_stats
    }

    /// The coordinator-side flight recorder, shared by every gather
    /// worker (`None` when tracing is off).
    pub fn flight_recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.inner.recorder.as_ref()
    }

    /// Point-in-time [`Snapshot`] joining the front-door coordinator
    /// stats with the transport counters — diff two of these for
    /// windowed serving stats.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = self.inner.stats.snapshot();
        self.inner.remote_stats.snapshot_into(&mut snap);
        snap
    }

    /// Feature dimension `d` announced by the hosts.
    pub fn dim(&self) -> usize {
        self.inner.dim
    }

    /// Number of remote shards.
    pub fn num_shards(&self) -> usize {
        self.inner.num_shards
    }

    /// Total labels across shards.
    pub fn num_labels(&self) -> u64 {
        self.inner.num_labels
    }

    /// Stops accepting new work; in-flight batches still complete.
    pub fn stop(&self) {
        self.inner.router.close();
    }

    /// Stops accepting work, drains in-flight batches, joins every
    /// thread. Host connections close as the gather workers drop.
    pub fn shutdown(mut self) {
        self.stop();
        if let Some(b) = self.batcher.take() {
            b.join().ok();
        }
        if let Some(g) = self.gatherers.take() {
            g.join();
        }
    }
}

/// Remote gather-worker body: one batch through [`RemoteGather::run`],
/// then reply per request — the mirror of the in-process coordinator's
/// `scatter_gather`.
fn remote_batch(inner: &RemoteInner, g: &mut RemoteGather, batch: Vec<Request>) {
    let n = batch.len();
    let dispatch_time = Instant::now();
    g.load_queries(inner.dim, batch.iter().map(|req| req.query.view()));
    if g.run(n, inner.config.base.beam, inner.config.base.topk).is_err() {
        // Every replica of some shard is gone: abandon the batch — the
        // dropped reply senders signal the clients.
        inner.remote_stats.failed_batches.fetch_add(1, Ordering::Relaxed);
        for _ in 0..n {
            inner.router.mark_done();
        }
        return;
    }
    // Under allow-partial, a batch that lost a shard still answers —
    // explicitly flagged so callers can tell full fidelity from
    // partial coverage.
    let degraded = g.last_batch_degraded();
    for (q, req) in batch.into_iter().enumerate() {
        let queue_time = dispatch_time.duration_since(req.submitted);
        let total_time = req.submitted.elapsed();
        inner.stats.queue_wait.record(queue_time);
        inner.stats.latency.record(total_time);
        inner.stats.completed.fetch_add(1, Ordering::Relaxed);
        inner.router.mark_done();
        let _ = req.reply.send(Response {
            id: req.id,
            predictions: g.results()[q].clone(),
            queue_time,
            total_time,
            batch_size: n,
            degraded,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::{IterationMethod, MatmulAlgo};
    use crate::shard::partition;
    use crate::tree::test_util::tiny_model;
    use crate::util::Rng;

    fn rand_query(rng: &mut Rng, dim: usize) -> SparseVec {
        SparseVec::from_pairs(
            (0..rng.gen_range(1..dim / 2))
                .map(|_| (rng.gen_range(0..dim) as u32, rng.gen_f32(-1.0, 1.0)))
                .collect(),
        )
    }

    fn spawn_partition(
        model: &crate::tree::XmrModel,
        s: usize,
        cfg: EngineConfig,
        speculate: bool,
    ) -> (Vec<ShardHost>, Vec<Vec<SocketAddr>>) {
        let mut hosts = Vec::new();
        let mut groups = Vec::new();
        for shard in partition(model, s) {
            let host = ShardHost::spawn(
                shard,
                ShardHostConfig {
                    engine: cfg,
                    speculate,
                    ..Default::default()
                },
                "127.0.0.1:0",
            )
            .expect("spawn host");
            groups.push(vec![host.local_addr()]);
            hosts.push(host);
        }
        (hosts, groups)
    }

    #[test]
    fn remote_gather_matches_unsharded_engine() {
        let m = tiny_model(32, 4, 3, 4097);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
        let reference = InferenceEngine::new(m.clone(), cfg);
        for speculate in [false, true] {
            let (hosts, groups) = spawn_partition(&m, 3, cfg, speculate);
            let mut g = RemoteGather::connect_groups(
                &groups,
                RemoteConfig {
                    speculate,
                    ..Default::default()
                },
                None,
            )
            .expect("connect");
            assert_eq!(g.num_shards(), 3);
            assert_eq!(g.dim(), 32);
            let mut rng = Rng::seed_from_u64(11);
            for qi in 0..10 {
                let q = rand_query(&mut rng, 32);
                for beam in [1usize, 3, 8] {
                    assert_eq!(
                        g.predict(&q, beam, 5).expect("predict"),
                        reference.predict(&q, beam, 5),
                        "speculate={speculate} beam={beam} q={qi}"
                    );
                }
            }
            for h in hosts {
                h.shutdown();
            }
        }
    }

    #[test]
    fn speculation_halves_network_rounds() {
        let m = tiny_model(24, 3, 3, 5); // depth 3
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::MarchingPointers);
        let (hosts, groups) = spawn_partition(&m, 2, cfg, true);
        let mut g = RemoteGather::connect_groups(&groups, RemoteConfig::default(), None).unwrap();
        let depth = g.depth();
        assert_eq!(depth, 3);
        let mut rng = Rng::seed_from_u64(2);
        let queries = 6u64;
        for _ in 0..queries {
            g.predict(&rand_query(&mut rng, 24), 4, 5).unwrap();
        }
        let st = g.stats();
        // depth 3 → rounds 0 and 2 ship, round 1 is assembled from hints.
        assert_eq!(st.rounds.load(Ordering::Relaxed), queries * depth.div_ceil(2) as u64);
        assert_eq!(st.spec_rounds_saved.load(Ordering::Relaxed), queries * (depth / 2) as u64);
        assert_eq!(st.spec_misses.load(Ordering::Relaxed), 0);
        for h in hosts {
            h.shutdown();
        }
    }

    #[test]
    fn host_that_declines_speculation_falls_back_to_real_rounds() {
        let m = tiny_model(24, 3, 3, 6);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::BinarySearch);
        let reference = InferenceEngine::new(m.clone(), cfg);
        // Hosts refuse to speculate; the client asks anyway.
        let (hosts, groups) = spawn_partition(&m, 2, cfg, false);
        let mut g = RemoteGather::connect_groups(&groups, RemoteConfig::default(), None).unwrap();
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..5 {
            let q = rand_query(&mut rng, 24);
            assert_eq!(g.predict(&q, 3, 5).unwrap(), reference.predict(&q, 3, 5));
        }
        let st = g.stats();
        assert_eq!(st.spec_rounds_saved.load(Ordering::Relaxed), 0);
        assert!(st.spec_misses.load(Ordering::Relaxed) > 0);
        for h in hosts {
            h.shutdown();
        }
    }

    #[test]
    fn discovery_groups_replicas_by_reported_shard_id() {
        let m = tiny_model(24, 3, 2, 9);
        let shards = partition(&m, 2);
        let cfg = ShardHostConfig::default();
        let h0a = ShardHost::spawn(shards[0].clone(), cfg.clone(), "127.0.0.1:0").unwrap();
        let h0b = ShardHost::spawn(shards[0].clone(), cfg.clone(), "127.0.0.1:0").unwrap();
        let h1 = ShardHost::spawn(shards[1].clone(), cfg, "127.0.0.1:0").unwrap();
        // Deliberately interleaved address order.
        let addrs = vec![h1.local_addr(), h0a.local_addr(), h0b.local_addr()];
        let groups = discover(&addrs, &RemoteConfig::default()).expect("discover");
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], vec![h0a.local_addr(), h0b.local_addr()]);
        assert_eq!(groups[1], vec![h1.local_addr()]);
        // A missing shard is rejected.
        let err = discover(&[h1.local_addr()], &RemoteConfig::default()).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
        h0a.shutdown();
        h0b.shutdown();
        h1.shutdown();
    }
}
