//! The sharded serving system: dynamic batcher → gather workers → per-
//! shard worker pools, all built from [`crate::coordinator`]'s reusable
//! pieces.
//!
//! ```text
//! clients ──submit──► router queue ──batcher──► batch queue ──► gather worker 0..G
//!    ▲                                                         │ layer jobs  ▲ candidates
//!    │                                     ┌───────────────────┼─────────────┤
//!    │                                     ▼                   ▼             │
//!    │                              shard 0 queue   ...   shard S-1 queue    │
//!    │                              workers (each owns a Workspace) ─────────┘
//!    └────────────── per-request reply channel ◄── global beam select / top-k
//! ```
//!
//! A gather worker owns a whole batch and drives the layer-synchronized
//! protocol: for each tree layer it ships every shard a [`LayerJob`]
//! carrying that shard's slice of the *global* beam, joins the returned
//! candidates, and runs the global beam selection itself
//! ([`ShardedEngine::merge_and_split`]). Shards therefore expand exactly
//! what the unsharded engine would — the output is bit-identical by
//! construction, at the cost of `depth` scatter rounds per batch (the
//! batcher amortizes those rounds across every query in the batch).
//!
//! # Buffer pooling protocol
//!
//! The hot path recycles every batch- and round-lifetime buffer instead
//! of allocating per round:
//!
//! - Each gather worker owns a [`GatherArena`] (global beams, merge
//!   scratch, result rows) and a pooled query matrix. The batch's
//!   queries are appended into the pooled `CsrMatrix` in place — no
//!   per-batch row vector, no query clones.
//! - The per-shard round buffers ([`ShardRound`]: local beams out,
//!   candidates back) **cycle through the reply channel**: a `LayerJob`
//!   moves the shard's round to its pool, the shard worker expands into
//!   the same buffers, and the reply returns them to the arena for the
//!   next layer. After the first batch at a given size, the only
//!   allocations left on a round are the mpsc channel nodes themselves.
//! - The shared query matrix is an `Arc` that returns to refcount 1 once
//!   every shard drops its job, so the next batch rebuilds it in place
//!   (with a fresh allocation only on the rare race where a shard worker
//!   has not yet dropped its clone).
//!
//! `rust/tests/alloc.rs` locks the in-process round to zero allocations
//! and bounds the full channel round trip.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::engine::{GatherArena, ShardRound, ShardedEngine};
use crate::coordinator::batcher::{spawn_batcher, WorkerPool};
use crate::coordinator::{CoordinatorConfig, CoordinatorStats, Request, Response, Router};
use crate::metrics::{
    FlightRecorder, FlightRecorderConfig, HostSpan, RoundSpan, MAX_TRACE_SPANS,
};
use crate::sparse::{CsrMatrix, SparseVec};

/// Configuration of the sharded serving system.
#[derive(Clone, Debug)]
pub struct ShardedCoordinatorConfig {
    /// Front-door configuration (batching, gather workers = `workers`,
    /// beam/topk, queue capacity) — identical semantics to the single-
    /// engine coordinator.
    pub base: CoordinatorConfig,
    /// Worker threads *per shard*; each owns a private per-shard
    /// [`crate::inference::Workspace`].
    pub shard_workers: usize,
    /// Capacity of the coordinator's [`FlightRecorder`] ring. When > 0
    /// (default 256) every batch is traced — per-shard per-layer spans
    /// (tx/round/join-wait, shard expand time, effective kernel tiers)
    /// recorded with tail-based retention. 0 disables tracing and all
    /// round timestamps beyond the existing scatter histograms.
    pub flight_recorder: usize,
}

impl Default for ShardedCoordinatorConfig {
    fn default() -> Self {
        Self {
            base: CoordinatorConfig::default(),
            shard_workers: 1,
            flight_recorder: 256,
        }
    }
}

/// One batch × one layer scatter order to a single shard: expand the
/// (shard-local) beams in `round` through `layer` and send the same
/// round — candidates filled — back on `reply`. The round's buffers are
/// on loan from the gather worker's [`GatherArena`].
struct LayerJob {
    shard: usize,
    layer: usize,
    x: Arc<CsrMatrix>,
    round: ShardRound,
    /// Reply: `(shard, round, expand_ns)` — expand time 0 when the
    /// coordinator is not tracing.
    reply: mpsc::Sender<(usize, ShardRound, u64)>,
}

/// Per-gather-worker pooled state (see the module docs).
struct GatherState {
    arena: GatherArena,
    x: Arc<CsrMatrix>,
    /// Pooled span buffer of the batch being traced (hard-capped at
    /// [`MAX_TRACE_SPANS`]).
    spans: Vec<RoundSpan>,
}

struct Inner {
    engine: Arc<ShardedEngine>,
    config: ShardedCoordinatorConfig,
    stats: CoordinatorStats,
    /// Flight recorder shared by the gather workers (`None` when
    /// [`ShardedCoordinatorConfig::flight_recorder`] is 0).
    recorder: Option<Arc<FlightRecorder>>,
    router: Router,
    /// Scatter fan-out senders, one per shard; cleared at shutdown to
    /// disconnect the shard pools.
    shard_txs: Mutex<Vec<mpsc::Sender<LayerJob>>>,
}

/// A running sharded serving system (see module docs for the topology).
pub struct ShardedCoordinator {
    inner: Arc<Inner>,
    batcher: Option<JoinHandle<()>>,
    gatherers: Option<WorkerPool>,
    shard_pools: Vec<WorkerPool>,
}

impl ShardedCoordinator {
    /// Starts the batcher, gather workers and one worker pool per shard.
    pub fn start(engine: Arc<ShardedEngine>, config: ShardedCoordinatorConfig) -> Self {
        let num_shards = engine.num_shards();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // Per-shard scatter queues + pools.
        let timed = config.flight_recorder > 0;
        let mut shard_txs = Vec::with_capacity(num_shards);
        let mut shard_pools = Vec::with_capacity(num_shards);
        for s in 0..num_shards {
            let (tx, rx) = mpsc::channel::<LayerJob>();
            let rx = Arc::new(Mutex::new(rx));
            let engine_init = Arc::clone(&engine);
            let engine_run = Arc::clone(&engine);
            shard_pools.push(WorkerPool::spawn(
                &format!("mscm-shard{s}"),
                config.shard_workers,
                rx,
                move |_w| engine_init.shard_engine(s).workspace(),
                move |ws, job: LayerJob| {
                    let LayerJob {
                        shard,
                        layer,
                        x,
                        mut round,
                        reply,
                    } = job;
                    let t0 = timed.then(Instant::now);
                    engine_run.expand_shard_layer(shard, &x, layer, &mut round, ws);
                    let expand_ns = t0.map_or(0, |t| t.elapsed().as_nanos() as u64);
                    // Gatherer may have bailed (shutdown) — fine; the
                    // loaned buffers die with the channel.
                    let _ = reply.send((shard, round, expand_ns));
                },
            ));
            shard_txs.push(tx);
        }

        let recorder = timed.then(|| {
            Arc::new(FlightRecorder::new(FlightRecorderConfig {
                capacity: config.flight_recorder,
                ..FlightRecorderConfig::default()
            }))
        });
        let inner = Arc::new(Inner {
            engine: Arc::clone(&engine),
            config: config.clone(),
            stats: CoordinatorStats::with_scatter(num_shards),
            recorder,
            router: Router::new(req_tx, config.base.queue_capacity),
            shard_txs: Mutex::new(shard_txs),
        });

        let batcher = {
            let inner = Arc::clone(&inner);
            spawn_batcher(
                "mscm-shard-batcher".into(),
                req_rx,
                batch_tx,
                config.base.max_batch,
                config.base.max_batch_delay,
                move |n| {
                    inner.stats.batches.fetch_add(1, Ordering::Relaxed);
                    inner.stats.batched_queries.fetch_add(n as u64, Ordering::Relaxed);
                },
            )
        };
        let gatherers = {
            let inner = Arc::clone(&inner);
            WorkerPool::spawn(
                "mscm-gather",
                config.base.workers,
                batch_rx,
                |_w| GatherState {
                    arena: GatherArena::new(),
                    x: Arc::new(CsrMatrix::default()),
                    spans: Vec::with_capacity(MAX_TRACE_SPANS),
                },
                move |state, batch: Vec<Request>| scatter_gather(&inner, state, batch),
            )
        };
        Self {
            inner,
            batcher: Some(batcher),
            gatherers: Some(gatherers),
            shard_pools,
        }
    }

    /// Submits a query; the reply arrives on the returned channel. Fails
    /// fast with [`crate::coordinator::SubmitError::Overloaded`] when the
    /// bounded router queue is full.
    pub fn submit(
        &self,
        query: SparseVec,
    ) -> Result<(u64, mpsc::Receiver<Response>), crate::coordinator::SubmitError> {
        self.inner.router.submit(query, &self.inner.stats)
    }

    /// Convenience: submit and block for the response.
    pub fn query_blocking(
        &self,
        query: SparseVec,
    ) -> Result<Response, crate::coordinator::SubmitError> {
        let (_, rx) = self.submit(query)?;
        rx.recv().map_err(|_| crate::coordinator::SubmitError::Shutdown)
    }

    /// Serving statistics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.inner.stats
    }

    /// Point-in-time [`crate::metrics::Snapshot`] of the serving stats
    /// (scatter telemetry included) plus, when the sharded engine was
    /// built [`ShardedEngine::with_metrics`], each shard's per-layer
    /// telemetry under the `shard{s}.engine.` prefix.
    pub fn snapshot(&self) -> crate::metrics::Snapshot {
        let mut snap = self.inner.stats.snapshot();
        for s in 0..self.inner.engine.num_shards() {
            if let Some(m) = self.inner.engine.shard_metrics(s) {
                m.export_into(&mut snap, &format!("shard{s}.engine."));
            }
        }
        snap
    }

    /// The engine being served.
    pub fn engine(&self) -> &Arc<ShardedEngine> {
        &self.inner.engine
    }

    /// The coordinator's flight recorder, shared by every gather worker
    /// (`None` when tracing is off).
    pub fn flight_recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.inner.recorder.as_ref()
    }

    /// Stops accepting new work; in-flight batches still complete.
    pub fn stop(&self) {
        self.inner.router.close();
    }

    /// Stops accepting work, drains in-flight batches, joins every
    /// thread: batcher, gather workers, then the shard pools.
    pub fn shutdown(mut self) {
        self.stop();
        if let Some(b) = self.batcher.take() {
            b.join().ok();
        }
        if let Some(g) = self.gatherers.take() {
            g.join();
        }
        // Only now disconnect the shard queues: gatherers are done, so no
        // scatter is in flight.
        self.inner.shard_txs.lock().unwrap().clear();
        for p in self.shard_pools.drain(..) {
            p.join();
        }
    }
}

/// Gather-worker body: drive the layer-synchronized protocol for one
/// batch (the protocol itself lives in [`ShardedEngine::drive`]; this
/// closure only ships each round over the shard queues and restores the
/// loaned buffers from the replies), then reply per request.
fn scatter_gather(inner: &Inner, state: &mut GatherState, batch: Vec<Request>) {
    let engine = &inner.engine;
    let n = batch.len();
    let num_shards = engine.num_shards();
    let beam = inner.config.base.beam;
    let topk = inner.config.base.topk;
    let dispatch_time = Instant::now();

    let GatherState { arena, x, spans } = state;
    // Rebuild the pooled query matrix in place. The Arc is normally
    // unique again here — every shard dropped its clone when its last
    // LayerJob finished — so this is alloc-free; the fallback covers the
    // race where a shard worker has not yet dropped its handle.
    if Arc::get_mut(x).is_none() {
        *x = Arc::new(CsrMatrix::default());
    }
    Arc::get_mut(x)
        .expect("query matrix uniquely held")
        .assign_rows(engine.dim(), batch.iter().map(|req| req.query.view()));

    // Trace setup: one span per shard per layer round, assembled into
    // the shared recorder at batch end (pooled buffer — no steady-state
    // allocations).
    let tracing = inner.recorder.is_some();
    spans.clear();
    let mut span_drop = 0u32;

    let ok = engine.drive(n, beam, topk, arena, |l, rounds| {
        let (tx, rx) = mpsc::channel();
        let t_round = Instant::now();
        {
            let txs = inner.shard_txs.lock().unwrap();
            for (s, stx) in txs.iter().enumerate() {
                let round = std::mem::take(&mut rounds[s]);
                // A dead shard queue drops the job (and this tx clone)
                // immediately; the short reply count below aborts the
                // batch.
                let _ = stx.send(LayerJob {
                    shard: s,
                    layer: l,
                    x: Arc::clone(x),
                    round,
                    reply: tx.clone(),
                });
            }
        }
        let tx_ns = tracing.then(|| t_round.elapsed().as_nanos() as u64);
        drop(tx);
        let mut received = 0usize;
        // Round telemetry: per-shard reply latency plus the join wait
        // (last reply − first reply — the idle time the slowest shard
        // costs the gather join).
        let mut first_reply = Duration::ZERO;
        let mut last_reply = Duration::ZERO;
        while let Ok((s, round, expand_ns)) = rx.recv() {
            let elapsed = t_round.elapsed();
            if let Some(sc) = &inner.stats.scatter {
                sc.record_round(s, elapsed);
            }
            if received == 0 {
                first_reply = elapsed;
            }
            last_reply = elapsed;
            if let Some(tx_ns) = tx_ns {
                let span = RoundSpan {
                    shard: s as u32,
                    layer: l as u32,
                    tx_ns,
                    round_ns: elapsed.as_nanos() as u64,
                    wait_ns: elapsed.saturating_sub(first_reply).as_nanos() as u64,
                    host: HostSpan {
                        decode_ns: 0,
                        expand_ns,
                        encode_ns: 0,
                        tiers: inner
                            .engine
                            .shard_metrics(s)
                            .map_or(0, |m| m.layer_tier_mask(l)),
                    },
                    events: 0,
                };
                if spans.len() < MAX_TRACE_SPANS {
                    spans.push(span);
                } else {
                    span_drop += 1;
                }
            }
            rounds[s] = round;
            received += 1;
        }
        if let Some(sc) = &inner.stats.scatter {
            sc.record_join_wait(last_reply.saturating_sub(first_reply));
        }
        received == num_shards
    });
    if !ok {
        // A shard queue disappeared mid-batch (shutdown race): account
        // the requests and let the dropped reply senders signal the
        // clients.
        for _ in 0..n {
            inner.router.mark_done();
        }
        return;
    }

    if let Some(rec) = &inner.recorder {
        let trace_id = rec.next_trace_id();
        let spans = &*spans;
        rec.record(dispatch_time.elapsed(), |r| {
            r.trace_id = trace_id;
            r.batch = n as u32;
            r.beam = beam as u32;
            for sp in spans {
                r.push_span(*sp);
            }
            r.truncated += span_drop;
        });
    }

    for (q, req) in batch.into_iter().enumerate() {
        let queue_time = dispatch_time.duration_since(req.submitted);
        let total_time = req.submitted.elapsed();
        inner.stats.queue_wait.record(queue_time);
        inner.stats.latency.record(total_time);
        inner.stats.completed.fetch_add(1, Ordering::Relaxed);
        inner.router.mark_done();
        let _ = req.reply.send(Response {
            id: req.id,
            // The one unavoidable per-request allocation: the client owns
            // its ranking.
            predictions: arena.results()[q].clone(),
            queue_time,
            total_time,
            batch_size: n,
            // In-process shards cannot be partially down.
            degraded: false,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};
    use crate::tree::test_util::tiny_model;
    use crate::util::Rng;
    use std::time::Duration;

    fn rand_query(rng: &mut Rng, dim: usize) -> SparseVec {
        SparseVec::from_pairs(
            (0..rng.gen_range(1..12))
                .map(|_| (rng.gen_range(0..dim) as u32, rng.gen_f32(-1.0, 1.0)))
                .collect(),
        )
    }

    #[test]
    fn sharded_serving_matches_unsharded_engine() {
        let model = tiny_model(32, 4, 3, 55);
        let cfg = EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash);
        let reference = InferenceEngine::new(model.clone(), cfg);
        let engine = Arc::new(ShardedEngine::from_model(&model, 4, cfg));
        let coord = ShardedCoordinator::start(
            Arc::clone(&engine),
            ShardedCoordinatorConfig {
                base: CoordinatorConfig {
                    workers: 2,
                    max_batch: 8,
                    max_batch_delay: Duration::from_micros(200),
                    beam: 3,
                    topk: 5,
                    ..Default::default()
                },
                shard_workers: 2,
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(6);
        let mut pending = Vec::new();
        let mut queries = Vec::new();
        for _ in 0..120 {
            let q = rand_query(&mut rng, 32);
            let (id, rx) = coord.submit(q.clone()).unwrap();
            pending.push((id, rx));
            queries.push(q);
        }
        for (i, (id, rx)) in pending.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
            assert_eq!(resp.id, id);
            let direct = reference.predict(&queries[i], 3, 5);
            assert_eq!(resp.predictions, direct, "query {i}");
        }
        assert_eq!(coord.stats().completed.load(Ordering::Relaxed), 120);
        // Scatter telemetry: every shard's round histogram and the join
        // wait saw every layer round of every batch.
        let sc = coord.stats().scatter.as_ref().expect("sharded stats carry scatter telemetry");
        assert_eq!(sc.num_shards(), 4);
        let rounds = sc.rounds.load(Ordering::Relaxed);
        assert!(rounds > 0, "no scatter rounds recorded");
        for s in 0..4 {
            assert_eq!(sc.shard(s).count(), rounds, "shard {s} missed rounds");
        }
        assert_eq!(sc.join_wait.count(), rounds);
        coord.shutdown();
    }

    #[test]
    fn stop_then_shutdown_is_clean() {
        let model = tiny_model(16, 4, 2, 9);
        let cfg = EngineConfig::new(MatmulAlgo::Baseline, IterationMethod::MarchingPointers);
        let engine = Arc::new(ShardedEngine::from_model(&model, 2, cfg));
        let coord = ShardedCoordinator::start(engine, ShardedCoordinatorConfig::default());
        let mut rng = Rng::seed_from_u64(1);
        coord.query_blocking(rand_query(&mut rng, 16)).unwrap();
        coord.stop();
        assert!(matches!(
            coord.submit(rand_query(&mut rng, 16)),
            Err(crate::coordinator::SubmitError::Shutdown)
        ));
        coord.shutdown();
    }
}
