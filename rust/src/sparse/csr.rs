//! Compressed sparse row matrices — storage for the query matrix `X`.

use super::csc::CscMatrix;
use super::vec::{SparseVec, SparseVecView};

/// CSR matrix with `u32` column indices and `f32` values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CsrMatrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row pointer array, length `rows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, sorted ascending within each row.
    pub indices: Vec<u32>,
    /// Values co-indexed with `indices`.
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// An empty `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            indptr: vec![0; rows + 1],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Builds from per-row sparse vectors.
    pub fn from_rows(rows: Vec<SparseVec>, cols: usize) -> Self {
        let n = rows.len();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let nnz: usize = rows.iter().map(|r| r.nnz()).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for r in &rows {
            debug_assert!(r.indices.iter().all(|&i| (i as usize) < cols));
            indices.extend_from_slice(&r.indices);
            values.extend_from_slice(&r.values);
            indptr.push(indices.len());
        }
        Self {
            rows: n,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Borrowed view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> SparseVecView<'_> {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        SparseVecView {
            indices: &self.indices[s..e],
            values: &self.values[s..e],
        }
    }

    /// Owned copy of row `i`.
    pub fn row_owned(&self, i: usize) -> SparseVec {
        let v = self.row(i);
        SparseVec {
            indices: v.indices.to_vec(),
            values: v.values.to_vec(),
        }
    }

    /// Resets to an empty `0 x cols` matrix **keeping every buffer's
    /// capacity** — the in-place builder used by the pooled serving
    /// paths, which rebuild one query matrix per batch without touching
    /// the allocator. Follow with [`CsrMatrix::push_row`] per row, or
    /// use [`CsrMatrix::assign_rows`] for the whole batch.
    pub fn reset(&mut self, cols: usize) {
        self.rows = 0;
        self.cols = cols;
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
    }

    /// Appends one row to a matrix being (re)built via
    /// [`CsrMatrix::reset`]. Alloc-free once the buffers are warm.
    pub fn push_row(&mut self, row: SparseVecView<'_>) {
        debug_assert!(row.indices.iter().all(|&i| (i as usize) < self.cols));
        self.indices.extend_from_slice(row.indices);
        self.values.extend_from_slice(row.values);
        self.indptr.push(self.indices.len());
        self.rows += 1;
    }

    /// Rebuilds this matrix in place from row views
    /// ([`CsrMatrix::reset`] + [`CsrMatrix::push_row`] over `rows`) —
    /// the one definition of the pooled batch rebuild shared by every
    /// serving path.
    pub fn assign_rows<'a>(
        &mut self,
        cols: usize,
        rows: impl IntoIterator<Item = SparseVecView<'a>>,
    ) {
        self.reset(cols);
        for r in rows {
            self.push_row(r);
        }
    }

    /// Selects a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Self {
        let rows = idx.iter().map(|&i| self.row_owned(i)).collect();
        Self::from_rows(rows, self.cols)
    }

    /// Column-major transpose-free conversion to CSC.
    pub fn to_csc(&self) -> CscMatrix {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for c in 0..self.cols {
            counts[c + 1] += counts[c];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[k] as usize;
                let dst = cursor[c];
                cursor[c] += 1;
                indices[dst] = r as u32;
                values[dst] = self.values[k];
            }
        }
        CscMatrix {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }

    /// L2-normalizes every row in place (standard for TFIDF features).
    pub fn normalize_rows(&mut self) {
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r], self.indptr[r + 1]);
            let n: f32 = self.values[s..e].iter().map(|v| v * v).sum::<f32>().sqrt();
            if n > 0.0 {
                for v in &mut self.values[s..e] {
                    *v /= n;
                }
            }
        }
    }

    /// Average nonzeros per row.
    pub fn avg_row_nnz(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1 0 2], [0 0 0], [3 4 0]]
        CsrMatrix {
            rows: 3,
            cols: 3,
            indptr: vec![0, 2, 2, 4],
            indices: vec![0, 2, 0, 1],
            values: vec![1.0, 2.0, 3.0, 4.0],
        }
    }

    #[test]
    fn row_views() {
        let m = sample();
        assert_eq!(m.row(0).indices, &[0, 2]);
        assert!(m.row(1).is_empty());
        assert_eq!(m.row(2).values, &[3.0, 4.0]);
    }

    #[test]
    fn from_rows_round_trip() {
        let m = sample();
        let rows: Vec<SparseVec> = (0..3).map(|i| m.row_owned(i)).collect();
        assert_eq!(CsrMatrix::from_rows(rows, 3), m);
    }

    #[test]
    fn to_csc_matches_dense() {
        let m = sample();
        let c = m.to_csc();
        assert_eq!(c.col(0).indices, &[0, 2]);
        assert_eq!(c.col(0).values, &[1.0, 3.0]);
        assert_eq!(c.col(1).indices, &[2]);
        assert_eq!(c.col(2).indices, &[0]);
        assert_eq!(c.col(2).values, &[2.0]);
    }

    #[test]
    fn normalize_rows_unit() {
        let mut m = sample();
        m.normalize_rows();
        let r = m.row(2);
        let n: f32 = r.values.iter().map(|v| v * v).sum::<f32>();
        assert!((n - 1.0).abs() < 1e-6);
    }

    #[test]
    fn reset_push_row_rebuilds_in_place() {
        let m = sample();
        let mut b = CsrMatrix::default();
        for _ in 0..2 {
            // two rebuild rounds: the second must reuse the first's buffers
            b.reset(3);
            for i in 0..m.rows {
                b.push_row(m.row(i));
            }
            assert_eq!(b, m);
        }
        // rebuilding with fewer rows shrinks the logical matrix
        b.reset(3);
        b.push_row(m.row(2));
        assert_eq!(b.rows, 1);
        assert_eq!(b.row(0).values, &[3.0, 4.0]);
        // assign_rows is the same rebuild in one call
        b.assign_rows(3, (0..m.rows).map(|i| m.row(i)));
        assert_eq!(b, m);
    }

    #[test]
    fn select_rows_subset() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(0).values, &[3.0, 4.0]);
        assert_eq!(s.row(1).values, &[1.0, 2.0]);
    }
}
