//! The **column-chunked matrix** — the paper's central data structure
//! (eq. 7–8) — with per-chunk, plan-driven **storage layouts**.
//!
//! A layer weight matrix `W ∈ R^{d x L}` is stored as a horizontal array of
//! chunks `K^(i)`, one per *parent node* of the tree layer: the chunk's
//! columns are exactly the sibling nodes sharing that parent. Each chunk is
//! a vertical sparse array of sparse *row* vectors (eq. 8): only nonzero
//! rows are stored, and each stored row holds its within-chunk column ids
//! and values contiguously.
//!
//! Two structural facts make this fast (paper §4 items 1–2): the beam mask
//! activates whole chunks at a time, and sibling columns share similar row
//! support — so the support intersection `S(x) ∩ S(K)` is walked **once per
//! chunk** instead of once per column, over memory that is contiguous.
//!
//! # Storage layouts ([`ChunkStorage`])
//!
//! The row-sparse layout above ([`ChunkStorage::Csc`]) is one of five
//! physical layouts a chunk may use; the kernel plan
//! ([`crate::inference::plan`]) picks one per chunk from the same cost
//! model that picks the kernels:
//!
//! - [`ChunkStorage::Csc`] — the seed layout: sorted `row_indices` plus a
//!   `row_ptr` per stored row.
//! - [`ChunkStorage::DenseRows`] — for chunks whose stored rows cover most
//!   of the feature dimension: `row_ptr` is indexed **directly by row id**
//!   (length `d + 1`), so `row_indices`, the hash row map and the `O(d)`
//!   dense scratch all disappear; a probe is one array read.
//! - [`ChunkStorage::Merged`] — for runs of tiny sibling chunks: their
//!   arrays are coalesced into the layer's shared [`MergedStore`] with a
//!   sub-chunk span table, removing the per-chunk `Vec` overhead and
//!   putting adjacent tiny chunks contiguous in memory.
//! - [`ChunkStorage::F16`] / [`ChunkStorage::Int8`] — **approximate**
//!   quantized layouts for the 100M-label memory regime: the chunk keeps
//!   its exact `Csc` structure (`row_indices`/`row_ptr`/`col_idx`) but
//!   stores values as packed little-endian f16 pairs or as `i8` against a
//!   per-chunk `scale` (`max |v| / 127`), shrinking the value payload 2x
//!   and 4x. They are only ever chosen under the planner's explicit
//!   `--approx` flag; kernels consume them by dequantizing into the
//!   workspace's `dequant` arena ([`Chunk::dequantize_into`]) and running
//!   the ordinary `Csc` kernels over the reconstructed values, so the
//!   only deviation from exact serving is the value rounding itself
//!   (bounded, property-tested in `rust/tests/quant.rs`).
//!
//! Kernels never touch `Chunk` fields directly — they consume a
//! [`ChunkView`] resolved by [`ChunkedMatrix::view`], which presents every
//! layout through one slice-based interface. All **exact** layouts hold
//! the exact same entries in the exact same per-row order, so every exact
//! layout is bitwise identical to `Csc` under every kernel
//! (property-tested in `rust/tests/layout.rs`).
//!
//! # Borrowed backing storage ([`Arr`])
//!
//! Every weight array is an [`Arr`]: either an owned `Vec` (models built
//! or loaded on the heap) or a borrowed slice of a memory-mapped
//! `MSCMXMR4` shard file ([`crate::shard::MmapModel`]) — the kernels read
//! through `Deref<Target = [T]>` either way and cannot tell the
//! difference, which is what lets a host serve models larger than RAM
//! with zero per-chunk copies.

use super::csc::CscMatrix;
use super::hashmap::U32Map;
use super::vec::SparseVec;

/// The physical weight layout of one chunk, chosen by the kernel plan
/// (see the module docs). Models are always *built* all-[`Csc`]
/// (`ChunkStorage::Csc`); other layouts are applied at engine
/// construction via [`ChunkedMatrix::apply_layout`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChunkStorage {
    /// Row-sparse: sorted nonzero `row_indices` + per-stored-row slices.
    Csc,
    /// `row_ptr` indexed directly by row id (length `d + 1`); no
    /// `row_indices`, no row map, no dense scratch needed.
    DenseRows,
    /// Coalesced into the matrix's shared [`MergedStore`]; the chunk
    /// itself keeps only its span slot.
    Merged,
    /// Approximate: `Csc` structure, values packed as little-endian f16
    /// pairs in `qvalues` (2 bytes/entry). `--approx` only.
    F16,
    /// Approximate: `Csc` structure, values stored as `i8` against the
    /// per-chunk `scale` (1 byte/entry). `--approx` only.
    Int8,
}

impl ChunkStorage {
    /// The **exact** layouts, in serialization order — the set every
    /// structural invariant (kernel classes, trace histograms, layout
    /// sweeps) iterates. The quantized layouts run the `Csc`-shaped
    /// kernels over dequantized values, so they add no new kernel class;
    /// use [`ChunkStorage::EVERY`] where all five serialization codes
    /// matter.
    pub const ALL: [ChunkStorage; 3] = [
        ChunkStorage::Csc,
        ChunkStorage::DenseRows,
        ChunkStorage::Merged,
    ];

    /// Every layout — exact and quantized — in serialization order.
    pub const EVERY: [ChunkStorage; 5] = [
        ChunkStorage::Csc,
        ChunkStorage::DenseRows,
        ChunkStorage::Merged,
        ChunkStorage::F16,
        ChunkStorage::Int8,
    ];

    /// Histogram/serialization index (0..5).
    #[inline]
    pub fn index(&self) -> usize {
        match self {
            ChunkStorage::Csc => 0,
            ChunkStorage::DenseRows => 1,
            ChunkStorage::Merged => 2,
            ChunkStorage::F16 => 3,
            ChunkStorage::Int8 => 4,
        }
    }

    /// Inverse of [`ChunkStorage::index`] (envelope deserialization).
    pub fn from_index(i: usize) -> Option<ChunkStorage> {
        match i {
            0 => Some(ChunkStorage::Csc),
            1 => Some(ChunkStorage::DenseRows),
            2 => Some(ChunkStorage::Merged),
            3 => Some(ChunkStorage::F16),
            4 => Some(ChunkStorage::Int8),
            _ => None,
        }
    }

    /// Whether this layout stores rounded values ([`ChunkStorage::F16`] /
    /// [`ChunkStorage::Int8`]) instead of the exact f32 payload.
    #[inline]
    pub fn is_quantized(&self) -> bool {
        matches!(self, ChunkStorage::F16 | ChunkStorage::Int8)
    }

    /// Compact name for layout histograms.
    pub fn short(&self) -> &'static str {
        match self {
            ChunkStorage::Csc => "csc",
            ChunkStorage::DenseRows => "dense-rows",
            ChunkStorage::Merged => "merged",
            ChunkStorage::F16 => "f16",
            ChunkStorage::Int8 => "int8",
        }
    }
}

// =====================================================================
// Backing storage: owned or memory-mapped
// =====================================================================

/// A weight array that is either heap-owned or a borrowed slice of a
/// leaked, read-only, process-lifetime memory mapping (the `MSCMXMR4`
/// mmap loader — see [`crate::shard::MmapModel`]). Kernels read through
/// `Deref<Target = [T]>` and never see the difference.
///
/// `Mapped` pointers come exclusively from `PROT_READ`/`MAP_PRIVATE`
/// mappings that are intentionally never unmapped, so sharing them
/// across threads and cloning them by pointer copy is sound.
pub enum Arr<T: 'static> {
    /// Heap-owned values (built models, legacy-envelope loads).
    Owned(Vec<T>),
    /// Borrowed from a leaked read-only mapping.
    Mapped {
        /// First element (alignment-checked by the mmap loader).
        ptr: *const T,
        /// Element count.
        len: usize,
    },
}

// Safety: `Mapped` pointers reference immutable, process-lifetime,
// read-only mappings (never unmapped, never written); `Owned` is a Vec.
unsafe impl<T: Send + Sync> Send for Arr<T> {}
unsafe impl<T: Send + Sync> Sync for Arr<T> {}

impl<T> std::ops::Deref for Arr<T> {
    type Target = [T];
    #[inline(always)]
    fn deref(&self) -> &[T] {
        match self {
            Arr::Owned(v) => v,
            Arr::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
        }
    }
}

impl<T> Default for Arr<T> {
    fn default() -> Self {
        Arr::Owned(Vec::new())
    }
}

impl<T> From<Vec<T>> for Arr<T> {
    fn from(v: Vec<T>) -> Self {
        Arr::Owned(v)
    }
}

impl<T: Clone> Clone for Arr<T> {
    fn clone(&self) -> Self {
        match self {
            Arr::Owned(v) => Arr::Owned(v.clone()),
            // The mapping outlives the process: a pointer copy is a
            // correct, zero-cost clone.
            Arr::Mapped { ptr, len } => Arr::Mapped { ptr: *ptr, len: *len },
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Arr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&self[..], f)
    }
}

impl<T: PartialEq> PartialEq for Arr<T> {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for Arr<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T> Arr<T> {
    /// The owned `Vec` for in-place mutation (layout application, store
    /// coalescing — build-time paths only).
    ///
    /// # Panics
    /// On a `Mapped` array: mmap-served weights are immutable by
    /// construction, and every mutating path runs on owned models.
    pub fn vec_mut(&mut self) -> &mut Vec<T> {
        match self {
            Arr::Owned(v) => v,
            Arr::Mapped { .. } => panic!("cannot mutate a memory-mapped weight array"),
        }
    }
}

// =====================================================================
// Half-precision codec (hand-rolled: no half/f16 dependency)
// =====================================================================

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even (overflow → ±inf,
/// underflow → subnormals → ±0).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (keep NaN signalled via a set mantissa bit).
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00;
    }
    if e <= 0 {
        // Subnormal half (or zero): shift the full 24-bit significand
        // down past the exponent deficit, rounding to nearest even.
        if e < -10 {
            return sign;
        }
        let full = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && (half & 1) == 1) {
            half + 1
        } else {
            half
        };
        return sign | rounded as u16;
    }
    let half = (man >> 13) as u16;
    let rem = man & 0x1fff;
    let out = sign | ((e as u16) << 10) | half;
    if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) {
        // The carry may overflow the mantissa into the exponent — that
        // is the correct rounding (including up to infinity).
        out + 1
    } else {
        out
    }
}

/// IEEE 754 binary16 bits → f32 (exact: every f16 value is an f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: value = man * 2^-24; normalize into f32.
            let b = 31 - man.leading_zeros();
            sign | ((103 + b) << 23) | ((man ^ (1 << b)) << (23 - b))
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Sentinel for [`Chunk::merged_slot`] on non-merged chunks.
const NO_SLOT: u32 = u32::MAX;

/// One chunk `K^(i) ∈ R^{d x B}`: the block of sibling columns under one
/// parent node. Field meaning depends on [`Chunk::storage`]; kernels go
/// through [`ChunkedMatrix::view`] instead of reading fields directly.
#[derive(Clone, Debug)]
pub struct Chunk {
    /// Number of columns `B` in this chunk (children of the parent).
    pub ncols: u32,
    /// Physical layout of this chunk's arrays.
    pub storage: ChunkStorage,
    /// `Csc`/`F16`/`Int8`: sorted ids of nonzero rows (the set `S(K)`).
    /// Empty for the other layouts.
    pub row_indices: Arr<u32>,
    /// `Csc`/`F16`/`Int8`: offsets into `col_idx`/values per stored row,
    /// length `row_indices.len() + 1`. `DenseRows`: offsets indexed
    /// directly by row id, length `d + 1`. `Merged`: empty (lives in the
    /// store).
    pub row_ptr: Arr<u32>,
    /// Within-chunk column of each entry (`0..ncols`); empty for `Merged`.
    pub col_idx: Arr<u16>,
    /// Entry values, co-indexed with `col_idx`; empty for `Merged` and
    /// the quantized layouts.
    pub values: Arr<f32>,
    /// Quantized value payload (`F16`: packed little-endian f16 pairs,
    /// `2 * nnz` bytes; `Int8`: one `i8`-as-`u8` per entry). Empty for
    /// the exact layouts.
    pub qvalues: Arr<u8>,
    /// Dequantization scale (`Int8`: `max |v| / 127`, or `1.0` for an
    /// all-zero chunk; `1.0` otherwise).
    pub scale: f32,
    /// Optional row-id → row-position map for the hash iteration method
    /// (only ever present on `Csc`-structured chunks — `Csc` itself and
    /// the quantized layouts; `DenseRows`/`Merged` don't need one).
    pub row_map: Option<U32Map>,
    /// Span slot in the matrix's [`MergedStore`] (`Merged` only).
    pub merged_slot: u32,
}

/// Cheap structural statistics of one chunk — the kernel planner's
/// inputs ([`crate::inference::plan`]). All fields are O(1) reads off the
/// build-time layout (O(d) for `DenseRows`, which only exists after
/// planning); nothing is recomputed per query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkStats {
    /// Chunk width `B` (sibling columns).
    pub width: usize,
    /// Total stored entries.
    pub nnz: usize,
    /// Rows touched `|S(K)|`.
    pub rows: usize,
    /// Mean stored entries per touched row (`nnz / rows`, 0 when empty).
    pub avg_row_len: f64,
}

impl ChunkStats {
    fn new(width: usize, nnz: usize, rows: usize) -> Self {
        ChunkStats {
            width,
            nnz,
            rows,
            avg_row_len: if rows == 0 {
                0.0
            } else {
                nnz as f64 / rows as f64
            },
        }
    }
}

/// Shared physical storage of a layer's [`ChunkStorage::Merged`] chunks:
/// the tiny chunks the plan coalesces live contiguously in four shared
/// arrays instead of four `Vec`s each. `spans[slot]` locates one
/// sub-chunk; its `row_ptr` offsets are *global* into the store's
/// `col_idx`/`values`, so a sub-chunk view is pure slicing.
#[derive(Clone, Debug, Default)]
pub struct MergedStore {
    spans: Vec<MergedSpan>,
    row_indices: Arr<u32>,
    /// Per sub-chunk: `rows + 1` offsets (global into `col_idx`/`values`).
    row_ptr: Arr<u32>,
    col_idx: Arr<u16>,
    values: Arr<f32>,
}

#[derive(Clone, Copy, Debug)]
struct MergedSpan {
    /// Start of the sub-chunk's rows in `row_indices`.
    rows_start: u32,
    /// Stored rows of the sub-chunk.
    rows: u32,
    /// Start of the sub-chunk's `rows + 1` entries in `row_ptr`.
    ptr_start: u32,
}

impl MergedStore {
    /// Appends one CSC-laid-out chunk's arrays; returns its span slot.
    fn push(&mut self, chunk: &Chunk) -> u32 {
        debug_assert_eq!(chunk.storage, ChunkStorage::Csc);
        let slot = self.spans.len() as u32;
        let base = self.col_idx.len() as u32;
        self.spans.push(MergedSpan {
            rows_start: self.row_indices.len() as u32,
            rows: chunk.row_indices.len() as u32,
            ptr_start: self.row_ptr.len() as u32,
        });
        self.row_indices.vec_mut().extend_from_slice(&chunk.row_indices);
        self.row_ptr
            .vec_mut()
            .extend(chunk.row_ptr.iter().map(|&p| p + base));
        self.col_idx.vec_mut().extend_from_slice(&chunk.col_idx);
        self.values.vec_mut().extend_from_slice(&chunk.values);
        slot
    }

    /// Span table as parallel `(rows_start, rows, ptr_start)` columns —
    /// the `MSCMXMR4` serialization of the store's topology.
    pub(crate) fn span_columns(&self) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let rs = self.spans.iter().map(|s| s.rows_start).collect();
        let r = self.spans.iter().map(|s| s.rows).collect();
        let ps = self.spans.iter().map(|s| s.ptr_start).collect();
        (rs, r, ps)
    }

    /// The four shared weight arrays, for serialization.
    pub(crate) fn raw_arrays(&self) -> (&[u32], &[u32], &[u16], &[f32]) {
        (&self.row_indices, &self.row_ptr, &self.col_idx, &self.values)
    }

    /// Rebuilds a store from its serialized parts (`MSCMXMR4` loaders;
    /// the arrays may be heap copies or mmap borrows).
    pub(crate) fn from_raw(
        spans: Vec<(u32, u32, u32)>,
        row_indices: Arr<u32>,
        row_ptr: Arr<u32>,
        col_idx: Arr<u16>,
        values: Arr<f32>,
    ) -> Self {
        MergedStore {
            spans: spans
                .into_iter()
                .map(|(rows_start, rows, ptr_start)| MergedSpan {
                    rows_start,
                    rows,
                    ptr_start,
                })
                .collect(),
            row_indices,
            row_ptr,
            col_idx,
            values,
        }
    }

    /// Number of coalesced sub-chunks.
    pub(crate) fn num_spans(&self) -> usize {
        self.spans.len()
    }

    /// The layout-resolved view of sub-chunk `slot`.
    #[inline]
    fn view(&self, slot: usize, ncols: u32) -> ChunkView<'_> {
        let s = self.spans[slot];
        let (r0, r1) = (s.rows_start as usize, (s.rows_start + s.rows) as usize);
        let (p0, p1) = (s.ptr_start as usize, (s.ptr_start + s.rows + 1) as usize);
        ChunkView {
            ncols,
            storage: ChunkStorage::Merged,
            row_indices: &self.row_indices[r0..r1],
            row_ptr: &self.row_ptr[p0..p1],
            col_idx: &self.col_idx,
            values: &self.values,
            row_map: None,
        }
    }

    /// Stats of sub-chunk `slot` (O(1)).
    fn stats(&self, slot: usize, ncols: u32) -> ChunkStats {
        let s = self.spans[slot];
        let p0 = s.ptr_start as usize;
        let nnz =
            (self.row_ptr[p0 + s.rows as usize] - self.row_ptr[p0]) as usize;
        ChunkStats::new(ncols as usize, nnz, s.rows as usize)
    }

    /// Weight bytes attributable to sub-chunk `slot` (span row included).
    fn slot_weight_bytes(&self, slot: usize) -> usize {
        let s = self.spans[slot];
        let p0 = s.ptr_start as usize;
        let nnz =
            (self.row_ptr[p0 + s.rows as usize] - self.row_ptr[p0]) as usize;
        std::mem::size_of::<MergedSpan>() + (s.rows as usize) * 8 + 4 + nnz * 6
    }

    /// Approximate resident bytes of the whole store.
    pub fn memory_bytes(&self) -> usize {
        self.spans.len() * std::mem::size_of::<MergedSpan>()
            + self.row_indices.len() * 4
            + self.row_ptr.len() * 4
            + self.col_idx.len() * 2
            + self.values.len() * 4
    }
}

/// A borrowed, layout-resolved view of one logical chunk — the interface
/// every kernel consumes ([`crate::sparse::iterators`]).
///
/// `row_ptr` semantics follow `storage`: for `Csc`/`Merged` it has one
/// entry per stored row plus one (positions co-indexed with
/// `row_indices`); for `DenseRows` it is indexed directly by row id
/// (length `d + 1`) and `row_indices` is empty. Offsets always index
/// `col_idx`/`values` as exposed here, so [`ChunkView::row_entries`]
/// works uniformly.
#[derive(Clone, Copy, Debug)]
pub struct ChunkView<'a> {
    /// Number of columns `B` of the logical chunk.
    pub ncols: u32,
    /// The layout this view resolves.
    pub storage: ChunkStorage,
    /// Sorted stored-row ids (`Csc`/`Merged`; empty for `DenseRows`).
    pub row_indices: &'a [u32],
    /// Row offsets (see the type docs for per-layout semantics).
    pub row_ptr: &'a [u32],
    /// Within-chunk column of each entry.
    pub col_idx: &'a [u16],
    /// Entry values, co-indexed with `col_idx`.
    pub values: &'a [f32],
    /// The hash row map, when the chunk carries one (`Csc` only).
    pub row_map: Option<&'a U32Map>,
}

impl<'a> ChunkView<'a> {
    /// Entries `(within-chunk col, value)` at row-ptr position `pos`
    /// (a stored-row position for `Csc`/`Merged`, a row id for
    /// `DenseRows`).
    #[inline(always)]
    pub fn row_entries(&self, pos: usize) -> (&'a [u16], &'a [f32]) {
        let (s, e) = (self.row_ptr[pos] as usize, self.row_ptr[pos + 1] as usize);
        (&self.col_idx[s..e], &self.values[s..e])
    }

    /// Calls `f(row id, cols, values)` for every stored row, ascending —
    /// the layout-agnostic iteration used by [`ChunkedMatrix::to_csc`]
    /// and the exactness tests.
    pub fn for_each_row(&self, mut f: impl FnMut(u32, &[u16], &[f32])) {
        match self.storage {
            ChunkStorage::DenseRows => {
                for r in 0..self.row_ptr.len().saturating_sub(1) {
                    let (cs, vs) = self.row_entries(r);
                    if !cs.is_empty() {
                        f(r as u32, cs, vs);
                    }
                }
            }
            _ => {
                for (pos, &r) in self.row_indices.iter().enumerate() {
                    let (cs, vs) = self.row_entries(pos);
                    f(r, cs, vs);
                }
            }
        }
    }

    /// Structural statistics of the viewed chunk (O(d) for `DenseRows`).
    pub fn stats(&self) -> ChunkStats {
        match self.storage {
            ChunkStorage::DenseRows => {
                let rows = (0..self.row_ptr.len().saturating_sub(1))
                    .filter(|&r| self.row_ptr[r] < self.row_ptr[r + 1])
                    .count();
                ChunkStats::new(self.ncols as usize, self.values.len(), rows)
            }
            ChunkStorage::Csc => ChunkStats::new(
                self.ncols as usize,
                self.values.len(),
                self.row_indices.len(),
            ),
            ChunkStorage::Merged => {
                let rows = self.row_indices.len();
                let nnz = (self.row_ptr[rows] - self.row_ptr[0]) as usize;
                ChunkStats::new(self.ncols as usize, nnz, rows)
            }
        }
    }
}

impl Chunk {
    /// Number of stored nonzero rows `|S(K)|`.
    ///
    /// Meaningful for `Csc` chunks (the layout models are built in);
    /// layout-aware callers go through [`ChunkedMatrix::chunk_stats`].
    #[inline]
    pub fn nnz_rows(&self) -> usize {
        self.row_indices.len()
    }

    /// Total entries stored in this chunk's own arrays (0 for `Merged` —
    /// the store holds them). `col_idx` is co-indexed with the value
    /// payload under every layout, exact or quantized, so it is the one
    /// layout-independent entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Structural statistics. Valid for every layout but `Merged`, whose
    /// chunks must be read via [`ChunkedMatrix::chunk_stats`].
    ///
    /// # Panics
    /// On a `Merged` chunk — its arrays live in the store, so answering
    /// from the husk would silently report an empty chunk.
    #[inline]
    pub fn stats(&self) -> ChunkStats {
        assert!(
            self.storage != ChunkStorage::Merged,
            "merged chunk stats live in the store (use ChunkedMatrix::chunk_stats)"
        );
        if self.storage.is_quantized() {
            // Quantized chunks keep the exact `Csc` structure; only the
            // value payload is rounded, so stats are purely structural.
            return ChunkStats::new(self.ncols as usize, self.col_idx.len(), self.row_indices.len());
        }
        self.view().stats()
    }

    /// Entries `(within-chunk col, value)` of the stored row at position
    /// `pos` in `row_indices` (`Csc` layout).
    #[inline(always)]
    pub fn row_entries(&self, pos: usize) -> (&[u16], &[f32]) {
        let (s, e) = (self.row_ptr[pos] as usize, self.row_ptr[pos + 1] as usize);
        (&self.col_idx[s..e], &self.values[s..e])
    }

    /// The layout-resolved view of a non-merged, non-quantized chunk
    /// (merged chunks need the owning matrix, quantized chunks need a
    /// dequantization arena — use [`ChunkedMatrix::view`] /
    /// [`Chunk::dequantize_into`]).
    ///
    /// # Panics
    /// On a `Merged` or quantized chunk, in release builds too — an
    /// empty-values view would be a silent wrong answer, and every hot
    /// path goes through [`ChunkedMatrix::view`], which resolves the
    /// store first.
    #[inline]
    pub fn view(&self) -> ChunkView<'_> {
        assert!(
            self.storage != ChunkStorage::Merged,
            "merged chunks are viewed through ChunkedMatrix::view"
        );
        assert!(
            !self.storage.is_quantized(),
            "quantized chunks are dequantized into the workspace, not viewed directly"
        );
        ChunkView {
            ncols: self.ncols,
            storage: self.storage,
            row_indices: &self.row_indices,
            row_ptr: &self.row_ptr,
            col_idx: &self.col_idx,
            values: &self.values,
            row_map: self.row_map.as_ref(),
        }
    }

    /// Builds (or rebuilds) the hash index used by the hash iterator.
    /// Only `Csc`-structured chunks carry one (`Csc` itself and the
    /// quantized layouts, whose row structure is identical): `DenseRows`
    /// probes `row_ptr` directly and `Merged` chunks fall back to binary
    /// search, so for those layouts this is a no-op.
    pub fn build_row_map(&mut self) {
        if !matches!(
            self.storage,
            ChunkStorage::Csc | ChunkStorage::F16 | ChunkStorage::Int8
        ) {
            return;
        }
        self.row_map = Some(U32Map::from_pairs(
            self.row_indices
                .iter()
                .enumerate()
                .map(|(p, &r)| (r, p as u32)),
        ));
    }

    /// Reconstructs this quantized chunk's f32 values into `out`
    /// (cleared first), co-indexed with `col_idx` — the kernel-facing
    /// bridge: the caller wraps `out` in a `Csc`-shaped [`ChunkView`]
    /// and runs the ordinary kernels over it.
    ///
    /// # Panics
    /// On a non-quantized chunk (exact layouts are viewed directly).
    pub fn dequantize_into(&self, out: &mut Vec<f32>) {
        out.clear();
        match self.storage {
            ChunkStorage::F16 => {
                out.reserve(self.qvalues.len() / 2);
                out.extend(
                    self.qvalues
                        .chunks_exact(2)
                        .map(|p| f16_to_f32(u16::from_le_bytes([p[0], p[1]]))),
                );
            }
            ChunkStorage::Int8 => {
                out.reserve(self.qvalues.len());
                let s = self.scale;
                out.extend(self.qvalues.iter().map(|&b| (b as i8) as f32 * s));
            }
            _ => panic!("dequantize_into on a non-quantized chunk"),
        }
    }

    /// Quantizes an exact `Csc` chunk in place to `target` (`F16` or
    /// `Int8`): the structure arrays are untouched, `values` moves into
    /// the packed `qvalues` payload, and `scale` is set (`Int8`:
    /// `max |v| / 127`, `1.0` for an all-zero chunk).
    fn quantize(&mut self, target: ChunkStorage) {
        debug_assert_eq!(self.storage, ChunkStorage::Csc);
        match target {
            ChunkStorage::F16 => {
                let mut q = Vec::with_capacity(self.values.len() * 2);
                for &v in self.values.iter() {
                    q.extend_from_slice(&f32_to_f16(v).to_le_bytes());
                }
                self.qvalues = q.into();
                self.scale = 1.0;
            }
            ChunkStorage::Int8 => {
                let max = self.values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = if max > 0.0 { max / 127.0 } else { 1.0 };
                let inv = 1.0 / scale;
                let q: Vec<u8> = self
                    .values
                    .iter()
                    .map(|&v| ((v * inv).round().clamp(-127.0, 127.0) as i8) as u8)
                    .collect();
                self.qvalues = q.into();
                self.scale = scale;
            }
            _ => unreachable!("quantize targets are F16/Int8 only"),
        }
        self.values = Arr::default();
        self.storage = target;
    }

    /// Bytes of the weight payload under this chunk's layout (row map
    /// excluded — that is side-index memory; quantized chunks count
    /// their 4-byte scale). `Merged` chunks report 0 here; their share
    /// lives in the store ([`ChunkedMatrix::chunk_weight_bytes`]
    /// accounts it).
    pub fn weight_bytes(&self) -> usize {
        self.row_indices.len() * 4
            + self.row_ptr.len() * 4
            + self.col_idx.len() * 2
            + self.values.len() * 4
            + self.qvalues.len()
            + self.storage.is_quantized() as usize * 4
    }

    /// Approximate resident bytes (hash index included if built).
    pub fn memory_bytes(&self) -> usize {
        self.weight_bytes() + self.row_map.as_ref().map_or(0, |m| m.memory_bytes())
    }

    /// Converts a `Csc` chunk to the `DenseRows` layout over feature
    /// dimension `d`: `row_ptr` becomes directly row-id-indexed, and
    /// `row_indices` + the row map are dropped. Entry order is preserved
    /// verbatim, so results stay bitwise identical.
    fn to_dense_rows(&mut self, d: usize) {
        debug_assert_eq!(self.storage, ChunkStorage::Csc);
        let mut ptr = Vec::with_capacity(d + 1);
        ptr.push(0u32);
        let mut pos = 0usize;
        for r in 0..d as u32 {
            if pos < self.row_indices.len() && self.row_indices[pos] == r {
                pos += 1;
            }
            ptr.push(self.row_ptr[pos]);
        }
        self.row_ptr = ptr.into();
        self.row_indices = Arr::default();
        self.row_map = None;
        self.storage = ChunkStorage::DenseRows;
    }
}

/// A weight matrix stored as per-parent chunks (eq. 7).
///
/// `chunk_offsets` records which contiguous column range each chunk covers:
/// chunk `c` holds columns `chunk_offsets[c] .. chunk_offsets[c+1]`. Because
/// chunks coincide with sibling groups, this array *is* the tree topology —
/// it plays the role of the cluster indicator matrix `C^(l)` (eq. 4).
/// The logical chunk structure is layout-independent: `Merged` only
/// changes where a chunk's *arrays* live, never its column range.
#[derive(Clone, Debug)]
pub struct ChunkedMatrix {
    /// Number of rows (feature dimension `d`).
    pub rows: usize,
    /// Number of columns (`L_l`).
    pub cols: usize,
    /// Column offset of each chunk; length `chunks.len() + 1`.
    pub chunk_offsets: Vec<u32>,
    /// The chunks, in column order (merged ones are span slots into
    /// `merged`).
    pub chunks: Vec<Chunk>,
    /// Shared storage of the `Merged` chunks (present only when some
    /// chunk uses that layout).
    pub merged: Option<Box<MergedStore>>,
}

impl ChunkedMatrix {
    /// Converts a CSC weight matrix into chunked form (all chunks in the
    /// seed `Csc` layout; [`ChunkedMatrix::apply_layout`] re-lays them).
    ///
    /// `chunk_offsets` partitions `0..csc.cols` into contiguous sibling
    /// groups (strictly increasing, first element 0, last `csc.cols`).
    /// When `with_row_maps` is set, each chunk also gets the hash index
    /// required by [`crate::inference::IterationMethod::Hash`].
    pub fn from_csc(csc: &CscMatrix, chunk_offsets: &[u32], with_row_maps: bool) -> Self {
        assert!(!chunk_offsets.is_empty(), "need at least one chunk offset");
        assert_eq!(chunk_offsets[0], 0, "chunk offsets must start at 0");
        assert_eq!(
            *chunk_offsets.last().unwrap() as usize,
            csc.cols,
            "chunk offsets must end at the column count"
        );
        let mut chunks = Vec::with_capacity(chunk_offsets.len() - 1);
        for w in chunk_offsets.windows(2) {
            let (c0, c1) = (w[0] as usize, w[1] as usize);
            assert!(c1 > c0, "chunks must be non-empty column ranges");
            assert!(
                c1 - c0 <= u16::MAX as usize + 1,
                "branching factor exceeds u16 within-chunk column index"
            );
            // Gather (row, col-in-chunk, value) triples and sort by row
            // then column — this produces the row-sparse layout directly.
            let mut triples: Vec<(u32, u16, f32)> = Vec::new();
            for j in c0..c1 {
                let col = csc.col(j);
                let cj = (j - c0) as u16;
                for (&r, &v) in col.indices.iter().zip(col.values) {
                    triples.push((r, cj, v));
                }
            }
            triples.sort_unstable_by_key(|&(r, c, _)| (r, c));
            let mut row_indices = Vec::new();
            let mut row_ptr = vec![0u32];
            let mut col_idx = Vec::with_capacity(triples.len());
            let mut values = Vec::with_capacity(triples.len());
            for (r, c, v) in triples {
                if row_indices.last() != Some(&r) {
                    if !row_indices.is_empty() {
                        row_ptr.push(col_idx.len() as u32);
                    }
                    row_indices.push(r);
                }
                col_idx.push(c);
                values.push(v);
            }
            row_ptr.push(col_idx.len() as u32);
            if row_indices.is_empty() {
                row_ptr = vec![0]; // length invariant: nnz_rows + 1
            }
            let mut chunk = Chunk {
                ncols: (c1 - c0) as u32,
                storage: ChunkStorage::Csc,
                row_indices: row_indices.into(),
                row_ptr: row_ptr.into(),
                col_idx: col_idx.into(),
                values: values.into(),
                qvalues: Arr::default(),
                scale: 1.0,
                row_map: None,
                merged_slot: NO_SLOT,
            };
            if with_row_maps {
                chunk.build_row_map();
            }
            chunks.push(chunk);
        }
        Self {
            rows: csc.rows,
            cols: csc.cols,
            chunk_offsets: chunk_offsets.to_vec(),
            chunks,
            merged: None,
        }
    }

    /// Re-lays every chunk's storage to `layout` (one entry per chunk).
    /// The matrix must be all-`Csc` (models are built that way; layouts
    /// are applied exactly once, at engine construction) — re-applying
    /// the layout the matrix already has is a no-op.
    pub fn apply_layout(&mut self, layout: &[ChunkStorage]) {
        assert_eq!(layout.len(), self.num_chunks(), "layout length mismatch");
        if self
            .chunks
            .iter()
            .zip(layout)
            .all(|(c, &s)| c.storage == s)
        {
            return;
        }
        assert!(
            self.merged.is_none() && self.chunks.iter().all(|c| c.storage == ChunkStorage::Csc),
            "chunk layouts can only be applied to an all-Csc matrix"
        );
        let d = self.rows;
        let mut store = MergedStore::default();
        for (chunk, &target) in self.chunks.iter_mut().zip(layout) {
            match target {
                ChunkStorage::Csc => {}
                ChunkStorage::DenseRows => chunk.to_dense_rows(d),
                ChunkStorage::Merged => {
                    let slot = store.push(chunk);
                    chunk.storage = ChunkStorage::Merged;
                    chunk.merged_slot = slot;
                    chunk.row_indices = Arr::default();
                    chunk.row_ptr = Arr::default();
                    chunk.col_idx = Arr::default();
                    chunk.values = Arr::default();
                    chunk.row_map = None;
                }
                ChunkStorage::F16 | ChunkStorage::Int8 => chunk.quantize(target),
            }
        }
        if !store.spans.is_empty() {
            self.merged = Some(Box::new(store));
        }
    }

    /// The layout-resolved view of chunk `c` — the hot-loop accessor
    /// every kernel dispatch goes through.
    ///
    /// # Panics
    /// On a quantized chunk: its f32 values do not exist until
    /// [`Chunk::dequantize_into`] reconstructs them into a workspace
    /// arena, so there is no borrowable view to hand out.
    #[inline]
    pub fn view(&self, c: usize) -> ChunkView<'_> {
        let chunk = &self.chunks[c];
        match chunk.storage {
            ChunkStorage::Merged => self
                .merged
                .as_ref()
                .expect("merged chunk without a store")
                .view(chunk.merged_slot as usize, chunk.ncols),
            _ => chunk.view(),
        }
    }

    /// Number of chunks (= number of parent nodes).
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// First column covered by chunk `c`.
    #[inline]
    pub fn chunk_start(&self, c: usize) -> usize {
        self.chunk_offsets[c] as usize
    }

    /// Number of columns of chunk `c`.
    #[inline]
    pub fn chunk_width(&self, c: usize) -> usize {
        (self.chunk_offsets[c + 1] - self.chunk_offsets[c]) as usize
    }

    /// Total stored entries (all layouts).
    pub fn nnz(&self) -> usize {
        self.chunks.iter().map(|c| c.nnz()).sum::<usize>()
            + self.merged.as_ref().map_or(0, |m| m.values.len())
    }

    /// Reconstructs the CSC representation (inverse of [`Self::from_csc`]
    /// under any exact layout; quantized chunks reconstruct their
    /// *rounded* values — the approximation the planner opted into);
    /// used by round-trip tests, the model converter, and
    /// baseline-on-`MSCMXMR4` hydration.
    pub fn to_csc(&self) -> CscMatrix {
        let mut cols: Vec<SparseVec> = vec![SparseVec::new(); self.cols];
        let mut dequant = Vec::new();
        for c in 0..self.num_chunks() {
            let base = self.chunk_start(c);
            let mut emit = |r: u32, cs: &[u16], vs: &[f32]| {
                for (&cj, &v) in cs.iter().zip(vs) {
                    let col = &mut cols[base + cj as usize];
                    col.indices.push(r);
                    col.values.push(v);
                }
            };
            let chunk = &self.chunks[c];
            if chunk.storage.is_quantized() {
                chunk.dequantize_into(&mut dequant);
                ChunkView {
                    ncols: chunk.ncols,
                    storage: ChunkStorage::Csc,
                    row_indices: &chunk.row_indices,
                    row_ptr: &chunk.row_ptr,
                    col_idx: &chunk.col_idx,
                    values: &dequant,
                    row_map: None,
                }
                .for_each_row(&mut emit);
            } else {
                self.view(c).for_each_row(&mut emit);
            }
        }
        // Entries were appended in ascending row order per column already.
        CscMatrix::from_cols(cols, self.rows)
    }

    /// Approximate resident bytes (merged store and hash maps included).
    pub fn memory_bytes(&self) -> usize {
        self.chunk_offsets.len() * 4
            + self.chunks.iter().map(|c| c.memory_bytes()).sum::<usize>()
            + self.merged.as_ref().map_or(0, |m| m.memory_bytes())
    }

    /// Bytes of the weight payload under the current layout — row maps
    /// and every other side index excluded (those are
    /// [`crate::inference::InferenceEngine::side_index_bytes`]'s to
    /// count).
    pub fn weight_bytes(&self) -> usize {
        self.chunk_offsets.len() * 4
            + self.chunks.iter().map(|c| c.weight_bytes()).sum::<usize>()
            + self.merged.as_ref().map_or(0, |m| m.memory_bytes())
    }

    /// Weight bytes attributable to chunk `c` under its current layout
    /// (for `Merged`: its store share, span row included).
    pub fn chunk_weight_bytes(&self, c: usize) -> usize {
        let chunk = &self.chunks[c];
        match chunk.storage {
            ChunkStorage::Merged => self
                .merged
                .as_ref()
                .expect("merged chunk without a store")
                .slot_weight_bytes(chunk.merged_slot as usize),
            _ => chunk.weight_bytes(),
        }
    }

    /// Structural statistics of chunk `c` (planner inputs), layout-aware.
    #[inline]
    pub fn chunk_stats(&self, c: usize) -> ChunkStats {
        let chunk = &self.chunks[c];
        match chunk.storage {
            ChunkStorage::Merged => self
                .merged
                .as_ref()
                .expect("merged chunk without a store")
                .stats(chunk.merged_slot as usize, chunk.ncols),
            ChunkStorage::F16 | ChunkStorage::Int8 => ChunkStats::new(
                chunk.ncols as usize,
                chunk.col_idx.len(),
                chunk.row_indices.len(),
            ),
            _ => chunk.view().stats(),
        }
    }

    /// Builds hash indices on all chunks that use one (`Csc` layout).
    pub fn build_row_maps(&mut self) {
        for c in &mut self.chunks {
            c.build_row_map();
        }
    }

    /// Drops hash indices from all chunks (reclaims the ~40% overhead).
    pub fn drop_row_maps(&mut self) {
        for c in &mut self.chunks {
            c.row_map = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 6x4 matrix, chunks of width 2; sibling columns share support.
    fn sample_csc() -> CscMatrix {
        CscMatrix::from_cols(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (3, 2.0)]),
                SparseVec::from_pairs(vec![(0, -1.0), (3, 0.5), (5, 1.0)]),
                SparseVec::from_pairs(vec![(2, 4.0)]),
                SparseVec::from_pairs(vec![(2, 3.0), (4, 1.0)]),
            ],
            6,
        )
    }

    #[test]
    fn from_csc_layout() {
        let m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], false);
        assert_eq!(m.num_chunks(), 2);
        let k0 = &m.chunks[0];
        assert_eq!(k0.storage, ChunkStorage::Csc);
        assert_eq!(k0.row_indices, vec![0, 3, 5]);
        // row 0 holds cols {0: 1.0, 1: -1.0}
        let (cs, vs) = k0.row_entries(0);
        assert_eq!(cs, &[0, 1]);
        assert_eq!(vs, &[1.0, -1.0]);
        // row 5 holds col {1: 1.0}
        let (cs, vs) = k0.row_entries(2);
        assert_eq!(cs, &[1]);
        assert_eq!(vs, &[1.0]);
        let k1 = &m.chunks[1];
        assert_eq!(k1.row_indices, vec![2, 4]);
    }

    #[test]
    fn round_trip_csc() {
        let csc = sample_csc();
        let m = ChunkedMatrix::from_csc(&csc, &[0, 2, 4], false);
        assert_eq!(m.to_csc(), csc);
    }

    #[test]
    fn round_trip_uneven_chunks() {
        let csc = sample_csc();
        let m = ChunkedMatrix::from_csc(&csc, &[0, 1, 4], true);
        assert_eq!(m.to_csc(), csc);
        assert_eq!(m.chunk_width(0), 1);
        assert_eq!(m.chunk_width(1), 3);
    }

    #[test]
    fn chunk_stats_reflect_layout() {
        let m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], false);
        let s0 = m.chunk_stats(0);
        assert_eq!(s0.width, 2);
        assert_eq!(s0.nnz, 5);
        assert_eq!(s0.rows, 3);
        assert!((s0.avg_row_len - 5.0 / 3.0).abs() < 1e-12);
        let empty = ChunkedMatrix::from_csc(
            &CscMatrix::from_cols(vec![SparseVec::new()], 4),
            &[0, 1],
            false,
        );
        let se = empty.chunk_stats(0);
        assert_eq!((se.rows, se.nnz), (0, 0));
        assert_eq!(se.avg_row_len, 0.0);
    }

    #[test]
    fn row_maps_resolve_positions() {
        let m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], true);
        let k0 = &m.chunks[0];
        let map = k0.row_map.as_ref().unwrap();
        for (p, &r) in k0.row_indices.iter().enumerate() {
            assert_eq!(map.get(r), Some(p as u32));
        }
        assert_eq!(map.get(1), None);
    }

    #[test]
    fn empty_chunk_is_representable() {
        let csc = CscMatrix::from_cols(vec![SparseVec::new(), SparseVec::new()], 4);
        let m = ChunkedMatrix::from_csc(&csc, &[0, 2], false);
        assert_eq!(m.chunks[0].nnz_rows(), 0);
        assert_eq!(m.to_csc(), csc);
    }

    #[test]
    #[should_panic(expected = "chunk offsets must end")]
    fn bad_offsets_panic() {
        ChunkedMatrix::from_csc(&sample_csc(), &[0, 2], false);
    }

    #[test]
    fn dense_rows_layout_round_trips_and_shrinks() {
        let csc = sample_csc();
        let mut m = ChunkedMatrix::from_csc(&csc, &[0, 2, 4], true);
        let csc_bytes = m.chunk_weight_bytes(0);
        m.apply_layout(&[ChunkStorage::DenseRows, ChunkStorage::Csc]);
        let k0 = &m.chunks[0];
        assert_eq!(k0.storage, ChunkStorage::DenseRows);
        assert!(k0.row_indices.is_empty());
        assert!(k0.row_map.is_none(), "DenseRows drops the row map");
        assert_eq!(k0.row_ptr.len(), 6 + 1);
        // row 3 holds cols {0: 2.0, 1: 0.5}
        let v = m.view(0);
        let (cs, vs) = v.row_entries(3);
        assert_eq!(cs, &[0, 1]);
        assert_eq!(vs, &[2.0, 0.5]);
        // untouched rows are empty ranges
        let (cs, _) = v.row_entries(1);
        assert!(cs.is_empty());
        // stats and payload are preserved
        let s = m.chunk_stats(0);
        assert_eq!((s.rows, s.nnz), (3, 5));
        assert_eq!(m.to_csc(), csc);
        // d = 6 here, rows = 3: 4*(6+1) + 4 < 8*3 + 8 fails numerically —
        // what the planner gates on; the structural claim stays: the
        // row-index array is gone and only ptr bytes differ.
        let dr_bytes = m.chunk_weight_bytes(0);
        assert_eq!(dr_bytes, csc_bytes - (3 * 4 + 4 * 4) + 7 * 4);
    }

    #[test]
    fn merged_layout_round_trips_and_views_match() {
        let csc = sample_csc();
        let plain = ChunkedMatrix::from_csc(&csc, &[0, 2, 4], false);
        let mut m = ChunkedMatrix::from_csc(&csc, &[0, 2, 4], true);
        m.apply_layout(&[ChunkStorage::Merged, ChunkStorage::Merged]);
        assert!(m.merged.is_some());
        for c in 0..2 {
            assert_eq!(m.chunks[c].storage, ChunkStorage::Merged);
            assert!(m.chunks[c].values.is_empty());
            let (want, got) = (plain.view(c), m.view(c));
            assert_eq!(want.row_indices, got.row_indices, "chunk {c}");
            for (pos, _) in want.row_indices.iter().enumerate() {
                assert_eq!(want.row_entries(pos), got.row_entries(pos), "chunk {c}");
            }
            assert_eq!(m.chunk_stats(c), plain.chunk_stats(c));
        }
        assert_eq!(m.to_csc(), csc);
        assert_eq!(m.nnz(), plain.nnz());
    }

    #[test]
    fn mixed_layout_with_empty_merged_chunk() {
        // An all-empty chunk merges into a zero-length span.
        let csc = CscMatrix::from_cols(
            vec![
                SparseVec::from_pairs(vec![(1, 2.0)]),
                SparseVec::new(),
                SparseVec::from_pairs(vec![(0, 1.0), (3, -1.0)]),
            ],
            4,
        );
        let mut m = ChunkedMatrix::from_csc(&csc, &[0, 1, 2, 3], false);
        m.apply_layout(&[
            ChunkStorage::Merged,
            ChunkStorage::Merged,
            ChunkStorage::DenseRows,
        ]);
        assert_eq!(m.chunk_stats(1).nnz, 0);
        assert_eq!(m.chunk_stats(2).rows, 2);
        assert_eq!(m.to_csc(), csc);
        // idempotent re-application is a no-op
        let bytes = m.weight_bytes();
        m.apply_layout(&[
            ChunkStorage::Merged,
            ChunkStorage::Merged,
            ChunkStorage::DenseRows,
        ]);
        assert_eq!(m.weight_bytes(), bytes);
    }

    #[test]
    #[should_panic(expected = "all-Csc")]
    fn relayout_of_laid_out_matrix_panics() {
        let mut m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], false);
        m.apply_layout(&[ChunkStorage::DenseRows, ChunkStorage::Csc]);
        m.apply_layout(&[ChunkStorage::Csc, ChunkStorage::Merged]);
    }

    #[test]
    fn storage_index_round_trips() {
        for (i, s) in ChunkStorage::EVERY.into_iter().enumerate() {
            assert_eq!(s.index(), i);
            assert_eq!(ChunkStorage::from_index(i), Some(s));
        }
        assert_eq!(ChunkStorage::from_index(5), None);
        // ALL stays the exact-layout prefix every kernel-class invariant
        // iterates.
        assert_eq!(&ChunkStorage::EVERY[..3], &ChunkStorage::ALL[..]);
        assert!(ChunkStorage::ALL.iter().all(|s| !s.is_quantized()));
        assert!(ChunkStorage::F16.is_quantized() && ChunkStorage::Int8.is_quantized());
    }

    #[test]
    fn f16_codec_round_trips_and_bounds_error() {
        // Exactly representable values survive bit for bit.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5, -3.25, 65504.0, 6.1035156e-5] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "{v} must be exact in f16");
        }
        // Specials.
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(1.0e6)), f32::INFINITY, "overflow goes to inf");
        assert_eq!(f16_to_f32(f32_to_f16(1.0e-12)), 0.0, "deep underflow flushes to zero");
        // Round-to-nearest-even at the half-ulp: 1 + 2^-11 is exactly
        // between 1.0 and the next f16 (1 + 2^-10); even mantissa wins.
        assert_eq!(f16_to_f32(f32_to_f16(1.0 + 2f32.powi(-11))), 1.0);
        assert_eq!(
            f16_to_f32(f32_to_f16(1.0 + 3.0 * 2f32.powi(-11))),
            1.0 + 2.0 * 2f32.powi(-10),
            "odd half-ulp rounds up to the even neighbor"
        );
        // Relative error bound 2^-11 over a deterministic value sweep
        // (normals) and absolute bound 2^-25 in the subnormal range.
        let mut x = 1.37e-3f32;
        for _ in 0..200 {
            let r = f16_to_f32(f32_to_f16(x));
            assert!(
                (r - x).abs() <= x.abs() * 2f32.powi(-11) + 2f32.powi(-25),
                "f16 error out of bounds at {x}: {r}"
            );
            x *= -1.171;
        }
    }

    #[test]
    fn quantized_layouts_preserve_structure_and_bound_values() {
        let csc = sample_csc();
        let plain = ChunkedMatrix::from_csc(&csc, &[0, 2, 4], false);
        let mut m = ChunkedMatrix::from_csc(&csc, &[0, 2, 4], true);
        m.apply_layout(&[ChunkStorage::F16, ChunkStorage::Int8]);

        let k0 = &m.chunks[0];
        assert_eq!(k0.storage, ChunkStorage::F16);
        assert!(k0.values.is_empty(), "exact payload must be dropped");
        assert_eq!(k0.qvalues.len(), 2 * k0.nnz());
        assert_eq!(k0.scale, 1.0);
        let k1 = &m.chunks[1];
        assert_eq!(k1.storage, ChunkStorage::Int8);
        assert_eq!(k1.qvalues.len(), k1.nnz());
        assert_eq!(k1.scale, 4.0 / 127.0, "scale is max |v| / 127");

        // Structure (and therefore stats and nnz) is untouched.
        assert_eq!(m.nnz(), plain.nnz());
        for c in 0..2 {
            assert_eq!(m.chunk_stats(c), plain.chunk_stats(c), "chunk {c}");
            assert_eq!(m.chunks[c].row_indices, plain.chunks[c].row_indices);
            assert_eq!(m.chunks[c].col_idx, plain.chunks[c].col_idx);
        }
        // Quantized chunks keep their hash index (same row structure).
        assert!(m.chunks[0].row_map.is_some());

        // Dequantization: f16 is exact on these values; int8 is within
        // half a quantization step per entry.
        let mut dq = Vec::new();
        m.chunks[0].dequantize_into(&mut dq);
        assert_eq!(dq, vec![1.0, -1.0, 2.0, 0.5, 1.0]);
        m.chunks[1].dequantize_into(&mut dq);
        let exact = [4.0f32, 3.0, 1.0];
        assert_eq!(dq.len(), exact.len());
        for (got, want) in dq.iter().zip(exact) {
            assert!(
                (got - want).abs() <= k1.scale / 2.0 + 1e-7,
                "int8 entry {want} off by more than half a step: {got}"
            );
        }
        // to_csc reconstructs the rounded values (the served weights).
        let rt = m.to_csc();
        assert_eq!(rt.col(0).values, &[1.0f32, 2.0]);
        assert!((rt.col(2).values[0] - 4.0).abs() <= k1.scale / 2.0 + 1e-7);

        // Byte accounting: f16 halves and int8 quarters the value
        // payload relative to the exact chunk (+4 bytes of scale each).
        let (p0, p1) = (plain.chunk_weight_bytes(0), plain.chunk_weight_bytes(1));
        assert_eq!(m.chunk_weight_bytes(0), p0 - 4 * 5 + 2 * 5 + 4);
        assert_eq!(m.chunk_weight_bytes(1), p1 - 4 * 3 + 3 + 4);
    }

    #[test]
    fn all_zero_chunk_quantizes_with_unit_scale() {
        let csc = CscMatrix::from_cols(
            vec![SparseVec::from_pairs(vec![(1, 0.0)]), SparseVec::new()],
            4,
        );
        let mut m = ChunkedMatrix::from_csc(&csc, &[0, 2], false);
        m.apply_layout(&[ChunkStorage::Int8]);
        assert_eq!(m.chunks[0].scale, 1.0);
        let mut dq = Vec::new();
        m.chunks[0].dequantize_into(&mut dq);
        assert_eq!(dq, vec![0.0]);
    }

    #[test]
    #[should_panic(expected = "dequantized into the workspace")]
    fn quantized_chunks_cannot_be_viewed() {
        let mut m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], false);
        m.apply_layout(&[ChunkStorage::F16, ChunkStorage::Csc]);
        let _ = m.view(0);
    }

    #[test]
    fn mapped_arr_reads_like_a_slice() {
        // Simulate a mapping with a leaked, immutable heap array — the
        // same lifetime contract the mmap loader establishes.
        let leaked: &'static [u32] = Vec::from([7u32, 9, 11]).leak();
        let a = Arr::Mapped {
            ptr: leaked.as_ptr(),
            len: leaked.len(),
        };
        assert_eq!(a, vec![7u32, 9, 11]);
        assert_eq!(a.clone()[1], 9);
        let owned: Arr<u32> = vec![7u32, 9, 11].into();
        assert_eq!(owned, a);
    }
}
