//! The **column-chunked matrix** — the paper's central data structure
//! (eq. 7–8).
//!
//! A layer weight matrix `W ∈ R^{d x L}` is stored as a horizontal array of
//! chunks `K^(i)`, one per *parent node* of the tree layer: the chunk's
//! columns are exactly the sibling nodes sharing that parent. Each chunk is
//! a vertical sparse array of sparse *row* vectors (eq. 8): only nonzero
//! rows are stored, and each stored row holds its within-chunk column ids
//! and values contiguously.
//!
//! Two structural facts make this fast (paper §4 items 1–2): the beam mask
//! activates whole chunks at a time, and sibling columns share similar row
//! support — so the support intersection `S(x) ∩ S(K)` is walked **once per
//! chunk** instead of once per column, over memory that is contiguous.

use super::csc::CscMatrix;
use super::hashmap::U32Map;
use super::vec::SparseVec;

/// One chunk `K^(i) ∈ R^{d x B}`: the block of sibling columns under one
/// parent node, stored row-sparse.
#[derive(Clone, Debug)]
pub struct Chunk {
    /// Number of columns `B` in this chunk (children of the parent).
    pub ncols: u32,
    /// Sorted ids of nonzero rows (the set `S(K)`).
    pub row_indices: Vec<u32>,
    /// Offsets into `col_idx`/`values` per stored row; length
    /// `row_indices.len() + 1`.
    pub row_ptr: Vec<u32>,
    /// Within-chunk column of each entry (`0..ncols`).
    pub col_idx: Vec<u16>,
    /// Entry values, co-indexed with `col_idx`.
    pub values: Vec<f32>,
    /// Optional row-id → row-position map for the hash iteration method.
    pub row_map: Option<U32Map>,
}

/// Cheap structural statistics of one chunk — the kernel planner's
/// inputs ([`crate::inference::plan`]). All fields are O(1) reads off the
/// build-time layout; nothing is recomputed per query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkStats {
    /// Chunk width `B` (sibling columns).
    pub width: usize,
    /// Total stored entries.
    pub nnz: usize,
    /// Rows touched `|S(K)|`.
    pub rows: usize,
    /// Mean stored entries per touched row (`nnz / rows`, 0 when empty).
    pub avg_row_len: f64,
}

impl Chunk {
    /// Number of stored nonzero rows `|S(K)|`.
    #[inline]
    pub fn nnz_rows(&self) -> usize {
        self.row_indices.len()
    }

    /// Total stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Structural statistics (planner inputs).
    #[inline]
    pub fn stats(&self) -> ChunkStats {
        let rows = self.nnz_rows();
        ChunkStats {
            width: self.ncols as usize,
            nnz: self.nnz(),
            rows,
            avg_row_len: if rows == 0 {
                0.0
            } else {
                self.nnz() as f64 / rows as f64
            },
        }
    }

    /// Entries `(within-chunk col, value)` of the stored row at position
    /// `pos` in `row_indices`.
    #[inline(always)]
    pub fn row_entries(&self, pos: usize) -> (&[u16], &[f32]) {
        let (s, e) = (self.row_ptr[pos] as usize, self.row_ptr[pos + 1] as usize);
        (&self.col_idx[s..e], &self.values[s..e])
    }

    /// Builds (or rebuilds) the hash index used by the hash iterator.
    /// The pair iterator is exact-size straight off `row_indices`, so the
    /// map is pre-sized from `row_indices.len()` with no intermediate
    /// collection.
    pub fn build_row_map(&mut self) {
        self.row_map = Some(U32Map::from_pairs(
            self.row_indices
                .iter()
                .enumerate()
                .map(|(p, &r)| (r, p as u32)),
        ));
    }

    /// Approximate resident bytes (hash index included if built).
    pub fn memory_bytes(&self) -> usize {
        self.row_indices.len() * 4
            + self.row_ptr.len() * 4
            + self.col_idx.len() * 2
            + self.values.len() * 4
            + self.row_map.as_ref().map_or(0, |m| m.memory_bytes())
    }
}

/// A weight matrix stored as per-parent chunks (eq. 7).
///
/// `chunk_offsets` records which contiguous column range each chunk covers:
/// chunk `c` holds columns `chunk_offsets[c] .. chunk_offsets[c+1]`. Because
/// chunks coincide with sibling groups, this array *is* the tree topology —
/// it plays the role of the cluster indicator matrix `C^(l)` (eq. 4).
#[derive(Clone, Debug)]
pub struct ChunkedMatrix {
    /// Number of rows (feature dimension `d`).
    pub rows: usize,
    /// Number of columns (`L_l`).
    pub cols: usize,
    /// Column offset of each chunk; length `chunks.len() + 1`.
    pub chunk_offsets: Vec<u32>,
    /// The chunks, in column order.
    pub chunks: Vec<Chunk>,
}

impl ChunkedMatrix {
    /// Converts a CSC weight matrix into chunked form.
    ///
    /// `chunk_offsets` partitions `0..csc.cols` into contiguous sibling
    /// groups (strictly increasing, first element 0, last `csc.cols`).
    /// When `with_row_maps` is set, each chunk also gets the hash index
    /// required by [`crate::inference::IterationMethod::Hash`].
    pub fn from_csc(csc: &CscMatrix, chunk_offsets: &[u32], with_row_maps: bool) -> Self {
        assert!(!chunk_offsets.is_empty(), "need at least one chunk offset");
        assert_eq!(chunk_offsets[0], 0, "chunk offsets must start at 0");
        assert_eq!(
            *chunk_offsets.last().unwrap() as usize,
            csc.cols,
            "chunk offsets must end at the column count"
        );
        let mut chunks = Vec::with_capacity(chunk_offsets.len() - 1);
        for w in chunk_offsets.windows(2) {
            let (c0, c1) = (w[0] as usize, w[1] as usize);
            assert!(c1 > c0, "chunks must be non-empty column ranges");
            assert!(
                c1 - c0 <= u16::MAX as usize + 1,
                "branching factor exceeds u16 within-chunk column index"
            );
            // Gather (row, col-in-chunk, value) triples and sort by row
            // then column — this produces the row-sparse layout directly.
            let mut triples: Vec<(u32, u16, f32)> = Vec::new();
            for j in c0..c1 {
                let col = csc.col(j);
                let cj = (j - c0) as u16;
                for (&r, &v) in col.indices.iter().zip(col.values) {
                    triples.push((r, cj, v));
                }
            }
            triples.sort_unstable_by_key(|&(r, c, _)| (r, c));
            let mut row_indices = Vec::new();
            let mut row_ptr = vec![0u32];
            let mut col_idx = Vec::with_capacity(triples.len());
            let mut values = Vec::with_capacity(triples.len());
            for (r, c, v) in triples {
                if row_indices.last() != Some(&r) {
                    if !row_indices.is_empty() {
                        row_ptr.push(col_idx.len() as u32);
                    }
                    row_indices.push(r);
                }
                col_idx.push(c);
                values.push(v);
            }
            row_ptr.push(col_idx.len() as u32);
            if row_indices.is_empty() {
                row_ptr = vec![0]; // length invariant: nnz_rows + 1
            }
            let mut chunk = Chunk {
                ncols: (c1 - c0) as u32,
                row_indices,
                row_ptr,
                col_idx,
                values,
                row_map: None,
            };
            if with_row_maps {
                chunk.build_row_map();
            }
            chunks.push(chunk);
        }
        Self {
            rows: csc.rows,
            cols: csc.cols,
            chunk_offsets: chunk_offsets.to_vec(),
            chunks,
        }
    }

    /// Number of chunks (= number of parent nodes).
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// First column covered by chunk `c`.
    #[inline]
    pub fn chunk_start(&self, c: usize) -> usize {
        self.chunk_offsets[c] as usize
    }

    /// Number of columns of chunk `c`.
    #[inline]
    pub fn chunk_width(&self, c: usize) -> usize {
        (self.chunk_offsets[c + 1] - self.chunk_offsets[c]) as usize
    }

    /// Total stored entries.
    pub fn nnz(&self) -> usize {
        self.chunks.iter().map(|c| c.nnz()).sum()
    }

    /// Reconstructs the CSC representation (inverse of [`Self::from_csc`]);
    /// used by round-trip tests and the model converter.
    pub fn to_csc(&self) -> CscMatrix {
        let mut cols: Vec<SparseVec> = vec![SparseVec::new(); self.cols];
        for (c, chunk) in self.chunks.iter().enumerate() {
            let base = self.chunk_start(c);
            for pos in 0..chunk.nnz_rows() {
                let r = chunk.row_indices[pos];
                let (cs, vs) = chunk.row_entries(pos);
                for (&cj, &v) in cs.iter().zip(vs) {
                    let col = &mut cols[base + cj as usize];
                    col.indices.push(r);
                    col.values.push(v);
                }
            }
        }
        // Entries were appended in ascending row order per column already.
        CscMatrix::from_cols(cols, self.rows)
    }

    /// Approximate resident bytes.
    pub fn memory_bytes(&self) -> usize {
        self.chunk_offsets.len() * 4 + self.chunks.iter().map(|c| c.memory_bytes()).sum::<usize>()
    }

    /// Structural statistics of chunk `c` (planner inputs).
    #[inline]
    pub fn chunk_stats(&self, c: usize) -> ChunkStats {
        self.chunks[c].stats()
    }

    /// Builds hash indices on all chunks.
    pub fn build_row_maps(&mut self) {
        for c in &mut self.chunks {
            c.build_row_map();
        }
    }

    /// Drops hash indices from all chunks (reclaims the ~40% overhead).
    pub fn drop_row_maps(&mut self) {
        for c in &mut self.chunks {
            c.row_map = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 6x4 matrix, chunks of width 2; sibling columns share support.
    fn sample_csc() -> CscMatrix {
        CscMatrix::from_cols(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (3, 2.0)]),
                SparseVec::from_pairs(vec![(0, -1.0), (3, 0.5), (5, 1.0)]),
                SparseVec::from_pairs(vec![(2, 4.0)]),
                SparseVec::from_pairs(vec![(2, 3.0), (4, 1.0)]),
            ],
            6,
        )
    }

    #[test]
    fn from_csc_layout() {
        let m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], false);
        assert_eq!(m.num_chunks(), 2);
        let k0 = &m.chunks[0];
        assert_eq!(k0.row_indices, vec![0, 3, 5]);
        // row 0 holds cols {0: 1.0, 1: -1.0}
        let (cs, vs) = k0.row_entries(0);
        assert_eq!(cs, &[0, 1]);
        assert_eq!(vs, &[1.0, -1.0]);
        // row 5 holds col {1: 1.0}
        let (cs, vs) = k0.row_entries(2);
        assert_eq!(cs, &[1]);
        assert_eq!(vs, &[1.0]);
        let k1 = &m.chunks[1];
        assert_eq!(k1.row_indices, vec![2, 4]);
    }

    #[test]
    fn round_trip_csc() {
        let csc = sample_csc();
        let m = ChunkedMatrix::from_csc(&csc, &[0, 2, 4], false);
        assert_eq!(m.to_csc(), csc);
    }

    #[test]
    fn round_trip_uneven_chunks() {
        let csc = sample_csc();
        let m = ChunkedMatrix::from_csc(&csc, &[0, 1, 4], true);
        assert_eq!(m.to_csc(), csc);
        assert_eq!(m.chunk_width(0), 1);
        assert_eq!(m.chunk_width(1), 3);
    }

    #[test]
    fn chunk_stats_reflect_layout() {
        let m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], false);
        let s0 = m.chunk_stats(0);
        assert_eq!(s0.width, 2);
        assert_eq!(s0.nnz, 5);
        assert_eq!(s0.rows, 3);
        assert!((s0.avg_row_len - 5.0 / 3.0).abs() < 1e-12);
        let empty = ChunkedMatrix::from_csc(
            &CscMatrix::from_cols(vec![SparseVec::new()], 4),
            &[0, 1],
            false,
        );
        let se = empty.chunk_stats(0);
        assert_eq!((se.rows, se.nnz), (0, 0));
        assert_eq!(se.avg_row_len, 0.0);
    }

    #[test]
    fn row_maps_resolve_positions() {
        let m = ChunkedMatrix::from_csc(&sample_csc(), &[0, 2, 4], true);
        let k0 = &m.chunks[0];
        let map = k0.row_map.as_ref().unwrap();
        for (p, &r) in k0.row_indices.iter().enumerate() {
            assert_eq!(map.get(r), Some(p as u32));
        }
        assert_eq!(map.get(1), None);
    }

    #[test]
    fn empty_chunk_is_representable() {
        let csc = CscMatrix::from_cols(vec![SparseVec::new(), SparseVec::new()], 4);
        let m = ChunkedMatrix::from_csc(&csc, &[0, 2], false);
        assert_eq!(m.chunks[0].nnz_rows(), 0);
        assert_eq!(m.to_csc(), csc);
    }

    #[test]
    #[should_panic(expected = "chunk offsets must end")]
    fn bad_offsets_panic() {
        ChunkedMatrix::from_csc(&sample_csc(), &[0, 2], false);
    }
}
