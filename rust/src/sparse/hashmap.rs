//! A compact open-addressing `u32 -> u32` hash map.
//!
//! The hash-map iteration method (paper §4 item 3) performs one lookup per
//! query nonzero on the hot path, so lookup latency dominates. `std`'s
//! `HashMap` with SipHash is far too slow and too large; this map uses a
//! power-of-two table, a multiplicative (Fibonacci) hash and linear
//! probing. Key and value are packed into a single `u64` slot so a hit
//! costs one cache line, not two (§Perf). Memory overhead is
//! `capacity * 8` bytes ≈ the "~40% additional memory" the paper reports
//! for its hash-map variant.

/// Sentinel key marking an empty slot (feature ids never reach u32::MAX).
const EMPTY: u32 = u32::MAX;

/// Open-addressing `u32 -> u32` map with linear probing and packed slots.
#[derive(Clone, Debug)]
pub struct U32Map {
    /// Packed slots: high 32 bits = key, low 32 bits = value.
    slots: Vec<u64>,
    mask: u32,
    len: usize,
}

#[inline(always)]
fn fib_hash(key: u32, mask: u32) -> u32 {
    // Knuth's multiplicative hashing; entropy lands in the high bits, so
    // fold them down before masking.
    let h = key.wrapping_mul(2654435769);
    (h ^ (h >> 16)) & mask
}

#[inline(always)]
fn pack(key: u32, val: u32) -> u64 {
    ((key as u64) << 32) | val as u64
}

impl U32Map {
    /// Creates a map sized for `n` entries at ~50% max load.
    pub fn with_capacity(n: usize) -> Self {
        let cap = (2 * n.max(2)).next_power_of_two();
        Self {
            slots: vec![pack(EMPTY, 0); cap],
            mask: (cap - 1) as u32,
            len: 0,
        }
    }

    /// A one-slot always-empty map (8 bytes): the placeholder the
    /// plan-driven baseline hash index uses for columns whose chunk is
    /// not hash-planned. Lookups return `None`; inserting trips the
    /// overfull debug assert — use [`U32Map::with_capacity`] for live
    /// maps.
    pub fn empty() -> Self {
        Self {
            slots: vec![pack(EMPTY, 0)],
            mask: 0,
            len: 0,
        }
    }

    /// Bytes a map sized for `n` entries occupies ([`U32Map::with_capacity`]
    /// sizing) — lets the planner price the fixed-hash side index
    /// analytically, without constructing a single map.
    pub fn capacity_bytes_for(n: usize) -> usize {
        (2 * n.max(2)).next_power_of_two() * 8
    }

    /// Builds a map from `(key, value)` pairs.
    pub fn from_pairs(pairs: impl ExactSizeIterator<Item = (u32, u32)>) -> Self {
        let mut m = Self::with_capacity(pairs.len());
        for (k, v) in pairs {
            m.insert(k, v);
        }
        m
    }

    /// Number of stored entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no entries are stored.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Inserts or overwrites `key -> val`. Keys must not be `u32::MAX`.
    pub fn insert(&mut self, key: u32, val: u32) {
        debug_assert_ne!(key, EMPTY);
        let mut slot = fib_hash(key, self.mask) as usize;
        loop {
            let k = (self.slots[slot] >> 32) as u32;
            if k == EMPTY || k == key {
                if k == EMPTY {
                    // <= 50% load after a *new* insert (overwrites are
                    // always fine) — also rejects inserting into a
                    // one-slot `empty()` placeholder, whose probe ring
                    // could otherwise never terminate on a miss.
                    debug_assert!(
                        (self.len + 1) * 2 <= self.slots.len(),
                        "U32Map overfull (placeholder maps reject inserts)"
                    );
                    self.len += 1;
                }
                self.slots[slot] = pack(key, val);
                return;
            }
            slot = (slot + 1) & self.mask as usize;
        }
    }

    /// Looks up `key`, returning its value if present.
    #[inline(always)]
    pub fn get(&self, key: u32) -> Option<u32> {
        let mut slot = fib_hash(key, self.mask) as usize;
        loop {
            let s = self.slots[slot];
            let k = (s >> 32) as u32;
            if k == key {
                return Some(s as u32);
            }
            if k == EMPTY {
                return None;
            }
            slot = (slot + 1) & self.mask as usize;
        }
    }

    /// Approximate resident bytes (the paper's Table 6 `O(c * nnz_K)`
    /// overhead term is measured with this).
    pub fn memory_bytes(&self) -> usize {
        self.slots.len() * 8
    }

    /// Iterates stored `(key, value)` pairs in table order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.slots
            .iter()
            .filter(|&&s| (s >> 32) as u32 != EMPTY)
            .map(|&s| ((s >> 32) as u32, s as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_round_trip() {
        let mut m = U32Map::with_capacity(10);
        for i in 0..10u32 {
            m.insert(i * 7 + 1, i);
        }
        assert_eq!(m.len(), 10);
        for i in 0..10u32 {
            assert_eq!(m.get(i * 7 + 1), Some(i));
        }
        assert_eq!(m.get(3), None);
    }

    #[test]
    fn overwrite_keeps_len() {
        let mut m = U32Map::with_capacity(4);
        m.insert(5, 1);
        m.insert(5, 2);
        assert_eq!(m.len(), 1);
        assert_eq!(m.get(5), Some(2));
    }

    #[test]
    fn overwrite_at_full_load_is_legal() {
        // with_capacity(2) -> 4 slots; two inserts reach the 50% cap.
        // Overwriting must not trip the new-insert load assert.
        let mut m = U32Map::with_capacity(2);
        m.insert(1, 10);
        m.insert(2, 20);
        m.insert(1, 11);
        m.insert(2, 21);
        assert_eq!(m.len(), 2);
        assert_eq!(m.get(1), Some(11));
        assert_eq!(m.get(2), Some(21));
    }

    #[test]
    fn from_pairs_and_iter() {
        let m = U32Map::from_pairs(vec![(1, 10), (2, 20), (9, 90)].into_iter());
        let mut got: Vec<_> = m.iter().collect();
        got.sort_unstable();
        assert_eq!(got, vec![(1, 10), (2, 20), (9, 90)]);
    }

    #[test]
    fn collision_heavy_keys() {
        // Keys that collide under the masked hash must still resolve.
        let mut m = U32Map::with_capacity(64);
        let keys: Vec<u32> = (0..64u32).map(|i| i << 16).collect();
        for (v, &k) in keys.iter().enumerate() {
            m.insert(k, v as u32);
        }
        for (v, &k) in keys.iter().enumerate() {
            assert_eq!(m.get(k), Some(v as u32));
        }
    }

    #[test]
    fn zero_capacity_works() {
        let m = U32Map::with_capacity(0);
        assert_eq!(m.get(1), None);
        assert!(m.is_empty());
    }

    #[test]
    fn empty_placeholder_is_tiny_and_inert() {
        let m = U32Map::empty();
        assert_eq!(m.memory_bytes(), 8);
        assert!(m.is_empty());
        for k in [0u32, 1, 7, u32::MAX - 1] {
            assert_eq!(m.get(k), None);
        }
        assert_eq!(m.iter().count(), 0);
    }

    #[test]
    #[should_panic(expected = "overfull")]
    #[cfg(debug_assertions)]
    fn empty_placeholder_rejects_insert() {
        U32Map::empty().insert(5, 1);
    }

    #[test]
    fn capacity_bytes_match_built_maps() {
        for n in [0usize, 1, 2, 3, 7, 8, 60, 1000] {
            let m = U32Map::from_pairs((0..n as u32).map(|i| (i * 3 + 1, i)));
            assert_eq!(m.memory_bytes(), U32Map::capacity_bytes_for(n), "n={n}");
        }
    }

    #[test]
    fn value_zero_and_large_keys() {
        let mut m = U32Map::with_capacity(4);
        m.insert(u32::MAX - 1, 0);
        m.insert(0, u32::MAX);
        assert_eq!(m.get(u32::MAX - 1), Some(0));
        assert_eq!(m.get(0), Some(u32::MAX));
    }
}
