//! The four ways to walk the support intersection `S(x) ∩ S(K)` when
//! computing a sparse-vector × chunk product (paper §4, items 1–4, and
//! Algorithm 2), plus the direct-probe kernel of the
//! [`ChunkStorage::DenseRows`] layout.
//!
//! Every function here computes `z = x K` for one query row `x` and one
//! chunk *view* `K` ([`ChunkView`] — the layout-resolved interface of
//! [`crate::sparse::ChunkedMatrix::view`]), accumulating into a
//! caller-provided dense output of length `K.ncols` (the caller zeroes
//! it). All produce *identical* results — they differ only in how the
//! common nonzero rows are found:
//!
//! | method             | per-query complexity                      | extra memory |
//! |--------------------|-------------------------------------------|--------------|
//! | marching pointers  | `O(nnz_x + nnz_K)`                        | none         |
//! | binary search      | `O(min·log(max))`                         | none         |
//! | hash-map           | `O(h · nnz_x)`                            | `O(c·nnz_K)` |
//! | dense lookup       | `O(nnz_x + nnz_K / n)` (fill amortized)   | `O(d)`       |
//! | dense-rows probe   | `O(nnz_x)`                                | none (layout)|
//!
//! (Table 6 of the paper; the last row is the layout-level variant where
//! the `O(d)` position array is baked into the chunk's own `row_ptr`, so
//! no scratch, no load and no clear exist at all.)
//!
//! The marching/binary/hash kernels require a layout with stored
//! `row_indices` (`Csc` or `Merged`); `DenseRows` chunks are always
//! evaluated by [`vec_chunk_dense_rows`], whatever method the plan named
//! — the probe *is* that layout's hash/dense/marching walk, and all
//! kernels are bitwise identical anyway.
//!
//! # SIMD tier
//!
//! Each kernel additionally has a `_simd` variant taking the
//! [`SimdLevel`] detected at engine construction. They compute the
//! *bitwise identical* result by vectorizing only across independent
//! output rows ([`crate::sparse::simd`] has the full argument):
//!
//! - the emit loop runs lane-parallel over runs of consecutive output
//!   columns (non-fused mul+add; [`simd::axpy_emit`]) at every level;
//! - on AVX2, [`vec_chunk_dense_rows_simd`] gathers 8 `row_ptr` probes
//!   per step and [`vec_chunk_dense_simd`] gathers 8 scratch probes per
//!   step, emitting hit lanes in ascending (scalar) order;
//! - at [`SimdLevel::None`] every `_simd` variant *is* its scalar
//!   oracle, which is how SIMD-planned shards serve on plain hardware.

use super::chunked::{ChunkStorage, ChunkView};
use super::simd::{self, SimdLevel};
use super::vec::{lower_bound, SparseVecView};

/// Accumulate `x_val * K[row at pos]` into `out`.
#[inline(always)]
fn emit(chunk: &ChunkView<'_>, pos: usize, x_val: f32, out: &mut [f32]) {
    let (cols, vals) = chunk.row_entries(pos);
    for (&c, &v) in cols.iter().zip(vals) {
        // `c < chunk.ncols == out.len()` by construction; an unchecked
        // variant was tried in the §Perf pass and showed no measurable
        // gain (the loop is memory-bound), so safe indexing stays.
        out[c as usize] += x_val * v;
    }
}

/// Item 1 — **marching pointers**: advance two sorted cursors one step at
/// a time.
pub fn vec_chunk_marching(x: SparseVecView<'_>, chunk: ChunkView<'_>, out: &mut [f32]) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert!(chunk.storage != ChunkStorage::DenseRows);
    let rows = chunk.row_indices;
    let (mut a, mut b) = (0usize, 0usize);
    while a < x.indices.len() && b < rows.len() {
        let (ia, ib) = (x.indices[a], rows[b]);
        if ia == ib {
            emit(&chunk, b, x.values[a], out);
            a += 1;
            b += 1;
        } else if ia < ib {
            a += 1;
        } else {
            b += 1;
        }
    }
}

/// Item 2 — **binary search**: marching pointers, but the lagging cursor
/// jumps via `LowerBound` (mirrors baseline Alg. 4).
pub fn vec_chunk_binary(x: SparseVecView<'_>, chunk: ChunkView<'_>, out: &mut [f32]) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert!(chunk.storage != ChunkStorage::DenseRows);
    let rows = chunk.row_indices;
    let (mut a, mut b) = (0usize, 0usize);
    while a < x.indices.len() && b < rows.len() {
        let (ia, ib) = (x.indices[a], rows[b]);
        if ia == ib {
            emit(&chunk, b, x.values[a], out);
            a += 1;
            b += 1;
        } else if ia < ib {
            a += lower_bound(&x.indices[a..], ib);
        } else {
            b += lower_bound(&rows[b..], ia);
        }
    }
}

/// Item 3 — **hash-map**: iterate the query nonzeros and look each row up
/// in the chunk's prebuilt row map (one map per chunk — NapkinXC keeps one
/// per *column*, which is the overhead MSCM removes).
///
/// # Panics
/// If the chunk carries no row map (only `Csc` chunks can).
pub fn vec_chunk_hash(x: SparseVecView<'_>, chunk: ChunkView<'_>, out: &mut [f32]) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    let map = chunk
        .row_map
        .expect("hash iteration requires chunk row maps (build_row_maps)");
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        if let Some(pos) = map.get(i) {
            emit(&chunk, pos as usize, xv, out);
        }
    }
}

/// Reusable `O(d)` scratch for the dense-lookup method: `pos[row]` holds
/// `row position + 1` within the currently-loaded chunk, 0 meaning absent.
/// One instance is recycled across the whole run (per thread) and cleared
/// by re-walking the chunk's nonzero rows — never by an `O(d)` memset.
///
/// Only `Csc`/`Merged` chunks are ever loaded: a `DenseRows` chunk *is*
/// its own position array ([`vec_chunk_dense_rows`]).
#[derive(Debug)]
pub struct DenseScratch {
    pos: Vec<u32>,
    loaded: bool,
}

impl DenseScratch {
    /// Scratch for feature dimension `d`.
    pub fn new(d: usize) -> Self {
        Self {
            pos: vec![0; d],
            loaded: false,
        }
    }

    /// Feature dimension this scratch serves.
    pub fn dim(&self) -> usize {
        self.pos.len()
    }

    /// Loads a chunk's nonzero-row positions (cost `O(nnz_K)` — amortized
    /// across all queries that hit this chunk when blocks are evaluated in
    /// chunk order, Alg. 3 line 7).
    pub fn load(&mut self, chunk: ChunkView<'_>) {
        debug_assert!(!self.loaded, "DenseScratch::load without clear");
        for (p, &r) in chunk.row_indices.iter().enumerate() {
            self.pos[r as usize] = p as u32 + 1;
        }
        self.loaded = true;
    }

    /// Clears the previously-loaded chunk.
    pub fn clear(&mut self, chunk: ChunkView<'_>) {
        for &r in chunk.row_indices {
            self.pos[r as usize] = 0;
        }
        self.loaded = false;
    }

    /// Approximate resident bytes (`O(d)` — Table 6).
    pub fn memory_bytes(&self) -> usize {
        self.pos.len() * 4
    }
}

/// Item 4 — **dense lookup**: like hash, but row positions come from the
/// dense scratch that [`DenseScratch::load`] filled for this chunk.
pub fn vec_chunk_dense(
    x: SparseVecView<'_>,
    chunk: ChunkView<'_>,
    scratch: &DenseScratch,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert!(scratch.loaded, "DenseScratch must be loaded with this chunk");
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        let p = scratch.pos[i as usize];
        if p != 0 {
            emit(&chunk, (p - 1) as usize, xv, out);
        }
    }
}

/// The [`ChunkStorage::DenseRows`] kernel: the chunk's `row_ptr` is
/// indexed directly by row id, so each query nonzero is one probe —
/// no scratch, no load, no clear. Per output entry the accumulation
/// order is ascending row id, exactly as in every other kernel, so the
/// result is bitwise identical.
pub fn vec_chunk_dense_rows(x: SparseVecView<'_>, chunk: ChunkView<'_>, out: &mut [f32]) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert_eq!(chunk.storage, ChunkStorage::DenseRows);
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        emit(&chunk, i as usize, xv, out);
    }
}

/// [`emit`] with the run-vectorized accumulate loop
/// ([`simd::axpy_emit`]) — bitwise identical at every level.
#[inline(always)]
fn emit_tiered(chunk: &ChunkView<'_>, pos: usize, x_val: f32, out: &mut [f32], level: SimdLevel) {
    let (cols, vals) = chunk.row_entries(pos);
    simd::axpy_emit(cols, vals, x_val, out, level);
}

/// SIMD tier of [`vec_chunk_marching`]: the intersection walk is
/// inherently serial, but every matched row's emit vectorizes over
/// consecutive-column runs.
pub fn vec_chunk_marching_simd(
    x: SparseVecView<'_>,
    chunk: ChunkView<'_>,
    out: &mut [f32],
    level: SimdLevel,
) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert!(chunk.storage != ChunkStorage::DenseRows);
    let rows = chunk.row_indices;
    let (mut a, mut b) = (0usize, 0usize);
    while a < x.indices.len() && b < rows.len() {
        let (ia, ib) = (x.indices[a], rows[b]);
        if ia == ib {
            emit_tiered(&chunk, b, x.values[a], out, level);
            a += 1;
            b += 1;
        } else if ia < ib {
            a += 1;
        } else {
            b += 1;
        }
    }
}

/// SIMD tier of [`vec_chunk_binary`]: `LowerBound` jumps unchanged,
/// vectorized emit.
pub fn vec_chunk_binary_simd(
    x: SparseVecView<'_>,
    chunk: ChunkView<'_>,
    out: &mut [f32],
    level: SimdLevel,
) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert!(chunk.storage != ChunkStorage::DenseRows);
    let rows = chunk.row_indices;
    let (mut a, mut b) = (0usize, 0usize);
    while a < x.indices.len() && b < rows.len() {
        let (ia, ib) = (x.indices[a], rows[b]);
        if ia == ib {
            emit_tiered(&chunk, b, x.values[a], out, level);
            a += 1;
            b += 1;
        } else if ia < ib {
            a += lower_bound(&x.indices[a..], ib);
        } else {
            b += lower_bound(&rows[b..], ia);
        }
    }
}

/// SIMD tier of [`vec_chunk_hash`]: scalar map probes (the probe is
/// latency-bound; vectorizing it would buy nothing), vectorized emit.
///
/// # Panics
/// If the chunk carries no row map (only `Csc` chunks can).
pub fn vec_chunk_hash_simd(
    x: SparseVecView<'_>,
    chunk: ChunkView<'_>,
    out: &mut [f32],
    level: SimdLevel,
) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    let map = chunk
        .row_map
        .expect("hash iteration requires chunk row maps (build_row_maps)");
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        if let Some(pos) = map.get(i) {
            emit_tiered(&chunk, pos as usize, xv, out, level);
        }
    }
}

/// SIMD tier of [`vec_chunk_dense`]: on AVX2 the scratch probe gathers
/// 8 query rows per step and emits hit lanes in ascending lane order —
/// exactly the scalar probe order; elsewhere scalar probes with
/// vectorized emit.
pub fn vec_chunk_dense_simd(
    x: SparseVecView<'_>,
    chunk: ChunkView<'_>,
    scratch: &DenseScratch,
    out: &mut [f32],
    level: SimdLevel,
) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert!(scratch.loaded, "DenseScratch must be loaded with this chunk");
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2 && x.indices.len() >= 8 {
        let (ids, vals) = (x.indices, x.values);
        let mut k = 0;
        while k + 8 <= ids.len() {
            let mut m = simd::nonzero_mask8(&scratch.pos, &ids[k..k + 8]);
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                m &= m - 1;
                let p = scratch.pos[ids[k + lane] as usize];
                emit_tiered(&chunk, (p - 1) as usize, vals[k + lane], out, level);
            }
            k += 8;
        }
        for (&i, &xv) in ids[k..].iter().zip(&vals[k..]) {
            let p = scratch.pos[i as usize];
            if p != 0 {
                emit_tiered(&chunk, (p - 1) as usize, xv, out, level);
            }
        }
        return;
    }
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        let p = scratch.pos[i as usize];
        if p != 0 {
            emit_tiered(&chunk, (p - 1) as usize, xv, out, level);
        }
    }
}

/// SIMD tier of [`vec_chunk_dense_rows`]: on AVX2 the `row_ptr` probe
/// gathers spans for 8 query nonzeros per step (start and end pointers,
/// two gathers) and emits the non-empty lanes in ascending lane order;
/// elsewhere scalar probes with vectorized emit.
pub fn vec_chunk_dense_rows_simd(
    x: SparseVecView<'_>,
    chunk: ChunkView<'_>,
    out: &mut [f32],
    level: SimdLevel,
) {
    debug_assert_eq!(out.len(), chunk.ncols as usize);
    debug_assert_eq!(chunk.storage, ChunkStorage::DenseRows);
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2 && x.indices.len() >= 8 {
        let (ids, vals) = (x.indices, x.values);
        let mut k = 0;
        while k + 8 <= ids.len() {
            let mut m = simd::row_span_mask8(chunk.row_ptr, &ids[k..k + 8]);
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                m &= m - 1;
                emit_tiered(&chunk, ids[k + lane] as usize, vals[k + lane], out, level);
            }
            k += 8;
        }
        for (&i, &xv) in ids[k..].iter().zip(&vals[k..]) {
            emit_tiered(&chunk, i as usize, xv, out, level);
        }
        return;
    }
    for (&i, &xv) in x.indices.iter().zip(x.values) {
        emit_tiered(&chunk, i as usize, xv, out, level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{ChunkedMatrix, CscMatrix, SparseVec};

    fn chunk_and_query() -> (ChunkedMatrix, SparseVec) {
        let csc = CscMatrix::from_cols(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (3, 2.0), (7, 1.0)]),
                SparseVec::from_pairs(vec![(0, -1.0), (3, 0.5)]),
                SparseVec::from_pairs(vec![(5, 4.0)]),
            ],
            8,
        );
        let m = ChunkedMatrix::from_csc(&csc, &[0, 3], true);
        let x = SparseVec::from_pairs(vec![(0, 2.0), (3, 1.0), (5, -1.0), (6, 9.0)]);
        (m, x)
    }

    /// Dense reference: z = x^T K.
    fn reference(m: &ChunkedMatrix, x: &SparseVec) -> Vec<f32> {
        let csc = m.to_csc();
        (0..csc.cols)
            .map(|j| x.view().dot_marching(csc.col(j)))
            .collect()
    }

    #[test]
    fn all_methods_match_reference() {
        let (m, x) = chunk_and_query();
        let chunk = m.view(0);
        let expect = reference(&m, &x);

        let mut out = vec![0.0; 3];
        vec_chunk_marching(x.view(), chunk, &mut out);
        assert_eq!(out, expect);

        out.fill(0.0);
        vec_chunk_binary(x.view(), chunk, &mut out);
        assert_eq!(out, expect);

        out.fill(0.0);
        vec_chunk_hash(x.view(), chunk, &mut out);
        assert_eq!(out, expect);

        let mut scratch = DenseScratch::new(8);
        scratch.load(chunk);
        out.fill(0.0);
        vec_chunk_dense(x.view(), chunk, &scratch, &mut out);
        assert_eq!(out, expect);
        scratch.clear(chunk);
        assert!(scratch.pos.iter().all(|&p| p == 0));
    }

    #[test]
    fn dense_rows_and_merged_layouts_match_reference() {
        use crate::sparse::ChunkStorage;
        let (m, x) = chunk_and_query();
        let expect = reference(&m, &x);

        let mut dr = m.clone();
        dr.apply_layout(&[ChunkStorage::DenseRows]);
        let mut out = vec![0.0; 3];
        vec_chunk_dense_rows(x.view(), dr.view(0), &mut out);
        assert_eq!(out, expect);

        let mut mg = m.clone();
        mg.apply_layout(&[ChunkStorage::Merged]);
        let v = mg.view(0);
        out.fill(0.0);
        vec_chunk_marching(x.view(), v, &mut out);
        assert_eq!(out, expect);
        out.fill(0.0);
        vec_chunk_binary(x.view(), v, &mut out);
        assert_eq!(out, expect);
        let mut scratch = DenseScratch::new(8);
        scratch.load(v);
        out.fill(0.0);
        vec_chunk_dense(x.view(), v, &scratch, &mut out);
        assert_eq!(out, expect);
        scratch.clear(v);
    }

    #[test]
    fn empty_query_yields_zeros() {
        let (m, _) = chunk_and_query();
        let chunk = m.view(0);
        let x = SparseVec::new();
        let mut out = vec![0.0; 3];
        vec_chunk_marching(x.view(), chunk, &mut out);
        vec_chunk_binary(x.view(), chunk, &mut out);
        vec_chunk_hash(x.view(), chunk, &mut out);
        assert_eq!(out, vec![0.0; 3]);
    }

    #[test]
    fn simd_variants_match_scalar_oracles() {
        use crate::sparse::ChunkStorage;
        // A query wide enough (>= 8 nnz) to engage the gather paths.
        let csc = CscMatrix::from_cols(
            (0..12)
                .map(|j| {
                    SparseVec::from_pairs(
                        (0..10).map(|r| ((r * 2 + j % 3) as u32, 0.3 * j as f32 - r as f32 * 0.11)).collect(),
                    )
                })
                .collect(),
            24,
        );
        let m = ChunkedMatrix::from_csc(&csc, &[0, 12], true);
        let x = SparseVec::from_pairs((0..11).map(|i| ((i * 2) as u32, 1.0 + 0.2 * i as f32)).collect());
        let chunk = m.view(0);
        let width = chunk.ncols as usize;
        for level in [SimdLevel::None, SimdLevel::detect()] {
            let mut expect = vec![0.0f32; width];
            vec_chunk_marching(x.view(), chunk, &mut expect);
            let bitwise =
                |o: &[f32]| o.iter().zip(&expect).all(|(a, b)| a.to_bits() == b.to_bits());

            let mut out = vec![0.0f32; width];
            vec_chunk_marching_simd(x.view(), chunk, &mut out, level);
            assert!(bitwise(&out), "marching_simd at {level:?}");
            out.fill(0.0);
            vec_chunk_binary_simd(x.view(), chunk, &mut out, level);
            assert!(bitwise(&out), "binary_simd at {level:?}");
            out.fill(0.0);
            vec_chunk_hash_simd(x.view(), chunk, &mut out, level);
            assert!(bitwise(&out), "hash_simd at {level:?}");
            let mut scratch = DenseScratch::new(24);
            scratch.load(chunk);
            out.fill(0.0);
            vec_chunk_dense_simd(x.view(), chunk, &scratch, &mut out, level);
            assert!(bitwise(&out), "dense_simd at {level:?}");
            scratch.clear(chunk);

            let mut dr = m.clone();
            dr.apply_layout(&[ChunkStorage::DenseRows]);
            out.fill(0.0);
            vec_chunk_dense_rows_simd(x.view(), dr.view(0), &mut out, level);
            assert!(bitwise(&out), "dense_rows_simd at {level:?}");
        }
    }

    #[test]
    fn scratch_reload_cycle() {
        let (m, x) = chunk_and_query();
        let chunk = m.view(0);
        let mut scratch = DenseScratch::new(8);
        for _ in 0..3 {
            scratch.load(chunk);
            let mut out = vec![0.0; 3];
            vec_chunk_dense(x.view(), chunk, &scratch, &mut out);
            assert_eq!(out, reference(&m, &x));
            scratch.clear(chunk);
        }
    }
}
