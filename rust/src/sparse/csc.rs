//! Compressed sparse column matrices — the vanilla storage for ranker
//! weight matrices `W^(l)` (one column per tree node) and the baseline
//! format the paper's MSCM is benchmarked against.

use super::vec::{SparseVec, SparseVecView};

/// CSC matrix with `u32` row indices and `f32` values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CscMatrix {
    /// Number of rows (feature dimension `d` for weight matrices).
    pub rows: usize,
    /// Number of columns (clusters/labels `L_l`).
    pub cols: usize,
    /// Column pointer array, length `cols + 1`.
    pub indptr: Vec<usize>,
    /// Row indices, sorted ascending within each column.
    pub indices: Vec<u32>,
    /// Values co-indexed with `indices`.
    pub values: Vec<f32>,
}

impl CscMatrix {
    /// An empty `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            indptr: vec![0; cols + 1],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Builds from per-column sparse vectors.
    pub fn from_cols(cols: Vec<SparseVec>, rows: usize) -> Self {
        let n = cols.len();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let nnz: usize = cols.iter().map(|c| c.nnz()).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for c in &cols {
            debug_assert!(c.indices.iter().all(|&i| (i as usize) < rows));
            indices.extend_from_slice(&c.indices);
            values.extend_from_slice(&c.values);
            indptr.push(indices.len());
        }
        Self {
            rows,
            cols: n,
            indptr,
            indices,
            values,
        }
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Borrowed view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> SparseVecView<'_> {
        let (s, e) = (self.indptr[j], self.indptr[j + 1]);
        SparseVecView {
            indices: &self.indices[s..e],
            values: &self.values[s..e],
        }
    }

    /// Owned copy of column `j`.
    pub fn col_owned(&self, j: usize) -> SparseVec {
        let v = self.col(j);
        SparseVec {
            indices: v.indices.to_vec(),
            values: v.values.to_vec(),
        }
    }

    /// Extracts the contiguous column range `c0..c1` as a standalone
    /// matrix (row dimension unchanged, entries copied verbatim — column
    /// contents are bitwise identical to the source, which is what keeps
    /// sharded inference exact; see [`crate::shard`]).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> CscMatrix {
        assert!(c0 <= c1 && c1 <= self.cols, "column slice out of range");
        let (s, e) = (self.indptr[c0], self.indptr[c1]);
        CscMatrix {
            rows: self.rows,
            cols: c1 - c0,
            indptr: self.indptr[c0..=c1].iter().map(|&p| p - s).collect(),
            indices: self.indices[s..e].to_vec(),
            values: self.values[s..e].to_vec(),
        }
    }

    /// Average nonzeros per column.
    pub fn avg_col_nnz(&self) -> f64 {
        if self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.cols as f64
        }
    }

    /// Approximate resident bytes of the structure.
    pub fn memory_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // columns: [ (0,1.0),(2,3.0) ], [ (1,4.0) ], []
        CscMatrix::from_cols(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0), (2, 3.0)]),
                SparseVec::from_pairs(vec![(1, 4.0)]),
                SparseVec::new(),
            ],
            3,
        )
    }

    #[test]
    fn col_views() {
        let m = sample();
        assert_eq!(m.col(0).indices, &[0, 2]);
        assert_eq!(m.col(1).values, &[4.0]);
        assert!(m.col(2).is_empty());
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn avg_col_nnz_counts() {
        let m = sample();
        assert!((m.avg_col_nnz() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn memory_accounting_positive() {
        assert!(sample().memory_bytes() > 0);
    }

    #[test]
    fn slice_cols_copies_ranges_verbatim() {
        let m = sample();
        let s = m.slice_cols(1, 3);
        assert_eq!(s.rows, m.rows);
        assert_eq!(s.cols, 2);
        assert_eq!(s.col(0).indices, m.col(1).indices);
        assert_eq!(s.col(0).values, m.col(1).values);
        assert!(s.col(1).is_empty());
        // degenerate slices
        assert_eq!(m.slice_cols(0, 3).indptr, m.indptr);
        assert_eq!(m.slice_cols(2, 2).nnz(), 0);
    }
}
