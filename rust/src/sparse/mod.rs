//! Sparse-matrix substrate.
//!
//! The paper's models are backed by three matrix representations:
//!
//! - [`CsrMatrix`] — compressed sparse row; used for the query matrix `X`
//!   (row-major access to individual queries).
//! - [`CscMatrix`] — compressed sparse column; the *vanilla* storage for
//!   ranker weight matrices `W^(l)` (column-major access to rankers) and
//!   the baseline the paper compares against.
//! - [`ChunkedMatrix`] — the paper's contribution: `W^(l)` stored as a
//!   horizontal array of per-parent **chunks** (eq. 7–8), each chunk a
//!   vertical sparse array of sparse row vectors over the sibling columns.
//!
//! [`iterators`] implements the four ways of walking the support
//! intersection `S(x) ∩ S(K)` (marching pointers, binary search, hash-map,
//! dense lookup) shared by the baseline and MSCM kernels.

pub mod chunked;
pub mod csc;
pub mod csr;
pub mod hashmap;
pub mod iterators;
pub mod vec;

pub use chunked::{Chunk, ChunkStats, ChunkedMatrix};
pub use csc::CscMatrix;
pub use csr::CsrMatrix;
pub use hashmap::U32Map;
pub use vec::{SparseVec, SparseVecView};
