//! Sparse-matrix substrate.
//!
//! The paper's models are backed by three matrix representations:
//!
//! - [`CsrMatrix`] — compressed sparse row; used for the query matrix `X`
//!   (row-major access to individual queries).
//! - [`CscMatrix`] — compressed sparse column; the *vanilla* storage for
//!   ranker weight matrices `W^(l)` (column-major access to rankers) and
//!   the baseline the paper compares against.
//! - [`ChunkedMatrix`] — the paper's contribution: `W^(l)` stored as a
//!   horizontal array of per-parent **chunks** (eq. 7–8), each chunk a
//!   vertical sparse array of sparse row vectors over the sibling columns.
//!
//! # Kernels and the SIMD tier
//!
//! [`iterators`] implements the four ways of walking the support
//! intersection `S(x) ∩ S(K)` (marching pointers, binary search, hash-map,
//! dense lookup) shared by the baseline and MSCM kernels — each in two
//! **tiers**: the portable scalar loop and a runtime-dispatched SIMD
//! variant (`vec_chunk_*_simd`, backed by [`simd`]). The SIMD tier
//! vectorizes only across *independent* output rows — 8-lane AVX2
//! gathers of `row_ptr`/scratch probes whose hits are emitted in scalar
//! lane order, and non-fused lane-parallel `mul`+`add` over runs of
//! consecutive output columns — so every output entry accumulates the
//! exact same values in the exact same order as the scalar tier, and the
//! two tiers are **bitwise identical** (pinned by `rust/tests/simd.rs`).
//! [`simd::SimdLevel::detect`] resolves the hardware once per process
//! (AVX2 on `x86_64`, NEON on `aarch64`, scalar otherwise or under
//! `MSCM_FORCE_SCALAR=1`); the scalar tier is both the universal
//! fallback and the exactness oracle.
//!
//! # Per-chunk weight layouts ([`ChunkStorage`])
//!
//! Each chunk of a [`ChunkedMatrix`] additionally carries one of five
//! physical *storage layouts*, chosen by the kernel planner
//! ([`crate::inference::plan`]) from the same per-chunk cost model that
//! picks the kernels (extended with per-layout byte + probe-time terms,
//! timing-calibration aware). Three are **exact** — always eligible:
//!
//! - **`Csc`** — the seed row-sparse layout: sorted `row_indices` plus a
//!   `row_ptr` slice per stored row. Always valid; the only layout that
//!   can carry a hash row map. Picked whenever nothing cheaper applies.
//! - **`DenseRows`** — `row_ptr` indexed directly by row id (`d + 1`
//!   entries): `row_indices`, the hash row map and the `O(d)` dense
//!   scratch all disappear, and a support probe is a single array read.
//!   Picked for chunks whose stored rows cover more than half the
//!   feature dimension (the byte crossover) when the probe is no slower
//!   than the planned kernel — dense top-of-tree chunks.
//! - **`Merged`** — runs of ≥ 2 adjacent tiny sibling chunks coalesce
//!   their arrays into the layer's shared
//!   [`MergedStore`](chunked::MergedStore) with a sub-chunk span table,
//!   shrinking per-chunk `Vec` overhead and putting chunks that are
//!   beam-activated together contiguous in memory. Picked for
//!   marching/binary-planned chunks below the tiny-chunk thresholds.
//!
//! Every exact layout stores the exact same entries in the exact same
//! per-row order, so all three are **bitwise identical** to `Csc` under
//! every kernel and algorithm — enforced by the seeded property harness
//! in `rust/tests/layout.rs`. Kernels consume layout-resolved
//! [`ChunkView`]s; engines apply a plan's layout at construction via
//! [`ChunkedMatrix::apply_layout`] (models are always *built* all-`Csc`).
//!
//! Two more layouts are **approximate** — strictly opt-in behind the
//! planner's `approx` flag (the `--approx` CLI switch), never chosen for
//! an exact deployment:
//!
//! - **`F16`** — `Csc` structure with the value payload packed as IEEE
//!   754 binary16 ([`f32_to_f16`] / [`f16_to_f32`], hand-rolled — no
//!   `half` dependency): 4 → 2 bytes per stored weight at ≤ 2⁻¹¹
//!   relative error.
//! - **`Int8`** — `Csc` structure with values stored as symmetric
//!   per-chunk linear-quantized bytes (`scale = max |v| / 127`): 4 → 1
//!   bytes per weight at ≤ `scale / 2` absolute error.
//!
//! Quantized chunks keep their structure arrays bitwise-intact — only
//! the payload is packed — and serve through the ordinary `Csc` kernels
//! after a workspace-resident dequantization
//! ([`chunked::Chunk::dequantize_into`]); the top-k damage is gated by
//! the precision@k regression suite in `rust/tests/quant.rs`.

pub mod chunked;
pub mod csc;
pub mod csr;
pub mod hashmap;
pub mod iterators;
pub mod simd;
pub mod vec;

pub use chunked::{
    f16_to_f32, f32_to_f16, Arr, Chunk, ChunkStats, ChunkStorage, ChunkView, ChunkedMatrix,
    MergedStore,
};
pub use csc::CscMatrix;
pub use csr::CsrMatrix;
pub use hashmap::U32Map;
pub use simd::SimdLevel;
pub use vec::{SparseVec, SparseVecView};
