//! Runtime SIMD capability detection and the lane-level primitives
//! behind the vectorized kernel tier
//! ([`crate::inference::KernelTier::Simd`]).
//!
//! # Bitwise-exactness contract
//!
//! Every primitive here vectorizes **across independent output lanes
//! only**; no operation ever changes the value or the order of the
//! floating-point work a single output entry receives:
//!
//! - [`axpy_emit`] performs `out[c] += x * v` per entry with a separate
//!   vector multiply and vector add — **never FMA**, whose fused single
//!   rounding would differ from the scalar `mul` + `add` — and only over
//!   runs of *consecutive, distinct* output columns, so each lane gets
//!   exactly the one multiply-add the scalar loop would give it, in the
//!   same order.
//! - The gather probes ([`row_span_mask8`], [`nonzero_mask8`]) read
//!   integers only; hit lanes are consumed in ascending lane order, which
//!   is exactly the scalar probe order.
//!
//! Hence the SIMD tier is bit-for-bit the scalar tier on every input —
//! property-pinned by `rust/tests/simd.rs` over the seeded model
//! generator, remainder lanes (`nnz % 8 != 0`, run breaks) included.
//!
//! # Dispatch
//!
//! [`SimdLevel::detect`] runs once per process (cached): AVX2 via CPUID
//! on `x86_64`, NEON unconditionally on `aarch64` (baseline mandatory
//! there), [`SimdLevel::None`] elsewhere — or everywhere when
//! `MSCM_FORCE_SCALAR=1` is set, which is how CI exercises the scalar
//! fallback arm on SIMD hardware. Engines snapshot the level at
//! construction; a plan's SIMD entries simply degrade to the scalar
//! kernels when the level is `None`, so shard files planned on one
//! machine serve identically on any other.

use std::sync::OnceLock;

/// The vector instruction set available to the SIMD kernel tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimdLevel {
    /// No usable vector unit (or `MSCM_FORCE_SCALAR=1`): the SIMD tier
    /// degrades to the scalar kernels.
    None,
    /// 256-bit AVX2: 8-lane f32 axpy and 8-lane `i32` gather probes.
    Avx2,
    /// 128-bit NEON: 4-lane f32 axpy (no gather — probes stay scalar).
    Neon,
}

impl SimdLevel {
    /// The process-wide detected level, computed once and cached.
    ///
    /// `MSCM_FORCE_SCALAR=1` overrides detection to [`SimdLevel::None`]
    /// (read at first call only, like the detection itself).
    pub fn detect() -> SimdLevel {
        static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
        *LEVEL.get_or_init(|| {
            if matches!(std::env::var("MSCM_FORCE_SCALAR").as_deref(), Ok("1")) {
                return SimdLevel::None;
            }
            detect_raw()
        })
    }

    /// True when vector kernels exist at this level.
    pub fn is_vector(&self) -> bool {
        *self != SimdLevel::None
    }

    /// f32 lanes per vector step (1 when scalar).
    pub fn lanes(&self) -> usize {
        match self {
            SimdLevel::None => 1,
            SimdLevel::Avx2 => 8,
            SimdLevel::Neon => 4,
        }
    }

    /// Human-readable name.
    pub fn label(&self) -> &'static str {
        match self {
            SimdLevel::None => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_raw() -> SimdLevel {
    if is_x86_feature_detected!("avx2") {
        SimdLevel::Avx2
    } else {
        SimdLevel::None
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_raw() -> SimdLevel {
    // NEON is a mandatory part of the aarch64 baseline ISA.
    SimdLevel::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_raw() -> SimdLevel {
    SimdLevel::None
}

/// `out[cols[k]] += x * vals[k]` for every `k` in ascending order —
/// the emit loop of every kernel — vectorizing runs of consecutive
/// output columns at the given level. Bitwise identical to the scalar
/// loop (see the module docs); with [`SimdLevel::None`] it *is* the
/// scalar loop.
///
/// `cols` must be strictly increasing (distinct output columns of one
/// stored row — guaranteed by chunk construction) with every value
/// `< out.len()`.
#[inline]
pub(crate) fn axpy_emit(cols: &[u16], vals: &[f32], x: f32, out: &mut [f32], level: SimdLevel) {
    debug_assert_eq!(cols.len(), vals.len());
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2 && cols.len() >= 8 {
        let n = cols.len();
        let mut k = 0;
        while k + 8 <= n {
            let c0 = cols[k] as usize;
            if cols[k + 7] as usize == c0 + 7 {
                // 8 consecutive distinct columns: one non-fused
                // mul + add per lane — the scalar step, lane-parallel.
                debug_assert!(c0 + 8 <= out.len());
                unsafe { x86::axpy8(out.as_mut_ptr().add(c0), vals.as_ptr().add(k), x) };
                k += 8;
            } else {
                out[c0] += x * vals[k];
                k += 1;
            }
        }
        for (&c, &v) in cols[k..].iter().zip(&vals[k..]) {
            out[c as usize] += x * v;
        }
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if level == SimdLevel::Neon && cols.len() >= 4 {
        let n = cols.len();
        let mut k = 0;
        while k + 4 <= n {
            let c0 = cols[k] as usize;
            if cols[k + 3] as usize == c0 + 3 {
                debug_assert!(c0 + 4 <= out.len());
                unsafe { arm::axpy4(out.as_mut_ptr().add(c0), vals.as_ptr().add(k), x) };
                k += 4;
            } else {
                out[c0] += x * vals[k];
                k += 1;
            }
        }
        for (&c, &v) in cols[k..].iter().zip(&vals[k..]) {
            out[c as usize] += x * v;
        }
        return;
    }
    let _ = level;
    for (&c, &v) in cols.iter().zip(vals) {
        out[c as usize] += x * v;
    }
}

/// AVX2 8-lane row-span probe: bit `j` of the result is set iff
/// `row_ptr[ids[j]] != row_ptr[ids[j] + 1]` (a non-empty `DenseRows`
/// row). Lane order is query order, so consuming set bits from the
/// lowest up replays the scalar probe order exactly.
///
/// Requires `ids.len() == 8`, every `id + 1 < row_ptr.len()`, and an
/// AVX2-verified level (callers dispatch on [`SimdLevel::Avx2`], which
/// only [`SimdLevel::detect`] hands out).
#[cfg(target_arch = "x86_64")]
#[inline]
pub(crate) fn row_span_mask8(row_ptr: &[u32], ids: &[u32]) -> u32 {
    debug_assert_eq!(ids.len(), 8);
    debug_assert!(ids.iter().all(|&i| (i as usize) + 1 < row_ptr.len()));
    debug_assert!(is_x86_feature_detected!("avx2"));
    unsafe { x86::row_span_mask8(row_ptr.as_ptr(), ids.as_ptr()) }
}

/// AVX2 8-lane scratch probe: bit `j` set iff `pos[ids[j]] != 0` (the
/// dense-lookup "row present" sentinel). Same lane-order contract as
/// [`row_span_mask8`].
///
/// Requires `ids.len() == 8` and every `id < pos.len()`.
#[cfg(target_arch = "x86_64")]
#[inline]
pub(crate) fn nonzero_mask8(pos: &[u32], ids: &[u32]) -> u32 {
    debug_assert_eq!(ids.len(), 8);
    debug_assert!(ids.iter().all(|&i| (i as usize) < pos.len()));
    debug_assert!(is_x86_feature_detected!("avx2"));
    unsafe { x86::nonzero_mask8(pos.as_ptr(), ids.as_ptr()) }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// `dst[l] += x * vals[l]` for lanes `l` in `0..8`, as a separate
    /// vector multiply and vector add (never `vfmadd`: fusing would
    /// round once where the scalar code rounds twice).
    ///
    /// # Safety
    /// AVX2 must be available and both pointers must be readable
    /// (and `dst` writable) for 8 `f32`s.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy8(dst: *mut f32, vals: *const f32, x: f32) {
        let xv = _mm256_set1_ps(x);
        let v = _mm256_loadu_ps(vals);
        let d = _mm256_loadu_ps(dst);
        _mm256_storeu_ps(dst, _mm256_add_ps(d, _mm256_mul_ps(xv, v)));
    }

    /// # Safety
    /// AVX2 must be available; `ids` must point at 8 `u32`s, each of
    /// which (and its successor index) must be in bounds of `ptr`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn row_span_mask8(ptr: *const u32, ids: *const u32) -> u32 {
        let idx = _mm256_loadu_si256(ids as *const __m256i);
        let starts = _mm256_i32gather_epi32::<4>(ptr as *const i32, idx);
        let next = _mm256_add_epi32(idx, _mm256_set1_epi32(1));
        let ends = _mm256_i32gather_epi32::<4>(ptr as *const i32, next);
        let empty = _mm256_cmpeq_epi32(starts, ends);
        !(_mm256_movemask_ps(_mm256_castsi256_ps(empty)) as u32) & 0xFF
    }

    /// # Safety
    /// AVX2 must be available; `ids` must point at 8 `u32`s, each in
    /// bounds of `pos`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn nonzero_mask8(pos: *const u32, ids: *const u32) -> u32 {
        let idx = _mm256_loadu_si256(ids as *const __m256i);
        let p = _mm256_i32gather_epi32::<4>(pos as *const i32, idx);
        let zero = _mm256_cmpeq_epi32(p, _mm256_setzero_si256());
        !(_mm256_movemask_ps(_mm256_castsi256_ps(zero)) as u32) & 0xFF
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    /// `dst[l] += x * vals[l]` for lanes `l` in `0..4` — `fmul` + `fadd`,
    /// never the fused `fmla` (single rounding would diverge from the
    /// scalar two-rounding result).
    ///
    /// # Safety
    /// Both pointers must be readable (and `dst` writable) for 4 `f32`s.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy4(dst: *mut f32, vals: *const f32, x: f32) {
        let xv = vdupq_n_f32(x);
        let v = vld1q_f32(vals);
        let d = vld1q_f32(dst);
        vst1q_f32(dst, vaddq_f32(d, vmulq_f32(xv, v)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_is_stable_and_consistent() {
        let a = SimdLevel::detect();
        let b = SimdLevel::detect();
        assert_eq!(a, b);
        assert_eq!(a.is_vector(), a.lanes() > 1);
        assert!(!a.label().is_empty());
    }

    #[test]
    fn axpy_emit_matches_scalar_on_all_levels() {
        // Mixed consecutive runs and breaks, plus a remainder tail.
        let cols: Vec<u16> = vec![0, 1, 2, 3, 4, 5, 6, 7, 9, 10, 11, 12, 13, 14, 15, 16, 20];
        let vals: Vec<f32> = (0..cols.len()).map(|k| 0.37 * k as f32 - 1.5).collect();
        let x = 1.217f32;
        let mut expect = vec![0.25f32; 24];
        for (&c, &v) in cols.iter().zip(&vals) {
            expect[c as usize] += x * v;
        }
        for level in [SimdLevel::None, SimdLevel::detect()] {
            let mut out = vec![0.25f32; 24];
            axpy_emit(&cols, &vals, x, &mut out, level);
            let same = out.iter().zip(&expect).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "axpy_emit diverged at level {:?}", level);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn gather_masks_match_scalar_probes() {
        if SimdLevel::detect() != SimdLevel::Avx2 {
            return; // no AVX2 (or MSCM_FORCE_SCALAR): nothing to check
        }
        let row_ptr: Vec<u32> = vec![0, 2, 2, 5, 5, 5, 9, 9, 10, 12];
        let ids: Vec<u32> = vec![0, 1, 2, 3, 4, 5, 6, 8];
        let m = row_span_mask8(&row_ptr, &ids);
        for (lane, &i) in ids.iter().enumerate() {
            let hit = row_ptr[i as usize] != row_ptr[i as usize + 1];
            assert_eq!((m >> lane) & 1 == 1, hit, "lane {lane}");
        }
        let pos: Vec<u32> = vec![0, 3, 0, 1, 0, 0, 7, 0, 2];
        let ids: Vec<u32> = vec![0, 1, 2, 3, 4, 5, 6, 8];
        let m = nonzero_mask8(&pos, &ids);
        for (lane, &i) in ids.iter().enumerate() {
            assert_eq!((m >> lane) & 1 == 1, pos[i as usize] != 0, "lane {lane}");
        }
    }
}
