//! Sparse vectors: owned ([`SparseVec`]) and borrowed ([`SparseVecView`]).
//!
//! Indices are `u32` (the paper's feature dimensions top out at d = 4M)
//! and are kept sorted ascending; values are `f32` to match the memory
//! budget of enterprise-scale models.

/// An owned sparse vector with sorted, unique indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// Nonzero coordinates, strictly ascending.
    pub indices: Vec<u32>,
    /// Values co-indexed with `indices`.
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Creates an empty vector.
    pub fn new() -> Self {
        Self::default()
    }

    /// Builds from parallel index/value arrays, sorting by index and
    /// summing duplicates.
    pub fn from_pairs(mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|&(i, _)| i);
        let mut indices = Vec::with_capacity(pairs.len());
        let mut values: Vec<f32> = Vec::with_capacity(pairs.len());
        for (i, v) in pairs {
            if let Some(&last) = indices.last() {
                if last == i {
                    *values.last_mut().unwrap() += v;
                    continue;
                }
            }
            indices.push(i);
            values.push(v);
        }
        Self { indices, values }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Borrowed view.
    pub fn view(&self) -> SparseVecView<'_> {
        SparseVecView {
            indices: &self.indices,
            values: &self.values,
        }
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.values.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Scales all values in place.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    /// Normalizes to unit L2 norm (no-op on the zero vector).
    pub fn normalize(&mut self) {
        let n = self.norm();
        if n > 0.0 {
            self.scale(1.0 / n);
        }
    }

    /// Drops entries with `|value| <= threshold` (model pruning).
    pub fn prune(&mut self, threshold: f32) {
        let mut w = 0;
        for r in 0..self.indices.len() {
            if self.values[r].abs() > threshold {
                self.indices[w] = self.indices[r];
                self.values[w] = self.values[r];
                w += 1;
            }
        }
        self.indices.truncate(w);
        self.values.truncate(w);
    }

    /// `self += alpha * other`, merging supports.
    pub fn axpy(&mut self, alpha: f32, other: SparseVecView<'_>) {
        let mut out_i = Vec::with_capacity(self.nnz() + other.nnz());
        let mut out_v = Vec::with_capacity(self.nnz() + other.nnz());
        let (mut a, mut b) = (0, 0);
        while a < self.indices.len() || b < other.indices.len() {
            let ia = self.indices.get(a).copied().unwrap_or(u32::MAX);
            let ib = other.indices.get(b).copied().unwrap_or(u32::MAX);
            if ia == ib {
                out_i.push(ia);
                out_v.push(self.values[a] + alpha * other.values[b]);
                a += 1;
                b += 1;
            } else if ia < ib {
                out_i.push(ia);
                out_v.push(self.values[a]);
                a += 1;
            } else {
                out_i.push(ib);
                out_v.push(alpha * other.values[b]);
                b += 1;
            }
        }
        self.indices = out_i;
        self.values = out_v;
    }
}

/// A borrowed sparse vector (e.g. one CSR row or CSC column).
#[derive(Clone, Copy, Debug)]
pub struct SparseVecView<'a> {
    /// Nonzero coordinates, strictly ascending.
    pub indices: &'a [u32],
    /// Values co-indexed with `indices`.
    pub values: &'a [f32],
}

impl<'a> SparseVecView<'a> {
    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// True when there are no stored entries.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Dot product via marching pointers (Alg. 4's simplest variant).
    pub fn dot_marching(&self, other: SparseVecView<'_>) -> f32 {
        let mut z = 0.0f32;
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.indices.len() && b < other.indices.len() {
            let (ia, ib) = (self.indices[a], other.indices[b]);
            if ia == ib {
                z += self.values[a] * other.values[b];
                a += 1;
                b += 1;
            } else if ia < ib {
                a += 1;
            } else {
                b += 1;
            }
        }
        z
    }

    /// Dot product via progressive binary search (paper Alg. 4):
    /// on a mismatch, `LowerBound` jumps the lagging cursor forward.
    pub fn dot_binary_search(&self, other: SparseVecView<'_>) -> f32 {
        let mut z = 0.0f32;
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.indices.len() && b < other.indices.len() {
            let (ia, ib) = (self.indices[a], other.indices[b]);
            if ia == ib {
                z += self.values[a] * other.values[b];
                a += 1;
                b += 1;
            } else if ia < ib {
                a += lower_bound(&self.indices[a..], ib);
            } else {
                b += lower_bound(&other.indices[b..], ia);
            }
        }
        z
    }

    /// Materializes to a dense vector of length `d` (test helper).
    pub fn to_dense(&self, d: usize) -> Vec<f32> {
        let mut out = vec![0.0; d];
        for (&i, &v) in self.indices.iter().zip(self.values) {
            out[i as usize] = v;
        }
        out
    }
}

/// Index of the first element of `sorted` not less than `key`
/// (paper's `LowerBound`).
///
/// Galloping variant: probe 1, 2, 4, … then binary-search the final
/// window. In progressive intersection walks the next hit is usually
/// close to the cursor, so this beats a full `partition_point` over the
/// remaining slice (§Perf: ~1.5x on the binary-search iterators).
#[inline]
pub fn lower_bound(sorted: &[u32], key: u32) -> usize {
    let n = sorted.len();
    let mut hi = 1usize;
    let mut lo = 0usize;
    while hi < n && sorted[hi] < key {
        lo = hi;
        hi <<= 1;
    }
    let end = hi.min(n);
    lo + sorted[lo..end].partition_point(|&x| x < key)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(pairs: &[(u32, f32)]) -> SparseVec {
        SparseVec::from_pairs(pairs.to_vec())
    }

    #[test]
    fn from_pairs_sorts_and_merges() {
        let v = sv(&[(5, 1.0), (2, 2.0), (5, 3.0)]);
        assert_eq!(v.indices, vec![2, 5]);
        assert_eq!(v.values, vec![2.0, 4.0]);
    }

    #[test]
    fn dot_variants_agree() {
        let a = sv(&[(0, 1.0), (3, 2.0), (7, -1.5), (9, 4.0)]);
        let b = sv(&[(1, 5.0), (3, 0.5), (9, 2.0), (12, 8.0)]);
        let expect = 2.0 * 0.5 + 4.0 * 2.0;
        assert_eq!(a.view().dot_marching(b.view()), expect);
        assert_eq!(a.view().dot_binary_search(b.view()), expect);
        assert_eq!(b.view().dot_binary_search(a.view()), expect);
    }

    #[test]
    fn dot_empty_is_zero() {
        let a = sv(&[(0, 1.0)]);
        let e = SparseVec::new();
        assert_eq!(a.view().dot_marching(e.view()), 0.0);
        assert_eq!(a.view().dot_binary_search(e.view()), 0.0);
    }

    #[test]
    fn dot_disjoint_is_zero() {
        let a = sv(&[(0, 1.0), (2, 1.0)]);
        let b = sv(&[(1, 1.0), (3, 1.0)]);
        assert_eq!(a.view().dot_marching(b.view()), 0.0);
        assert_eq!(a.view().dot_binary_search(b.view()), 0.0);
    }

    #[test]
    fn axpy_merges_supports() {
        let mut a = sv(&[(1, 1.0), (4, 2.0)]);
        let b = sv(&[(0, 3.0), (4, 1.0)]);
        a.axpy(2.0, b.view());
        assert_eq!(a.indices, vec![0, 1, 4]);
        assert_eq!(a.values, vec![6.0, 1.0, 4.0]);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut a = sv(&[(0, 3.0), (1, 4.0)]);
        a.normalize();
        assert!((a.norm() - 1.0).abs() < 1e-6);
        let mut z = SparseVec::new();
        z.normalize(); // must not panic
    }

    #[test]
    fn prune_drops_small() {
        let mut a = sv(&[(0, 0.01), (1, -0.5), (2, 0.2)]);
        a.prune(0.1);
        assert_eq!(a.indices, vec![1, 2]);
    }

    #[test]
    fn lower_bound_matches_partition() {
        let xs = [2u32, 4, 4, 8];
        assert_eq!(lower_bound(&xs, 0), 0);
        assert_eq!(lower_bound(&xs, 4), 1);
        assert_eq!(lower_bound(&xs, 5), 3);
        assert_eq!(lower_bound(&xs, 9), 4);
    }
}
