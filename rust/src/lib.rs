//! # mscm-xmr — Masked Sparse Chunk Multiplication for XMR tree inference
//!
//! Reproduction of *"Enterprise-Scale Search: Accelerating Inference for
//! Sparse Extreme Multi-Label Ranking Trees"* (Etter, Zhong, Yu, Ying,
//! Dhillon — WWW 2022).
//!
//! The library is organised bottom-up:
//!
//! - [`sparse`] — sparse-matrix substrate: sparse vectors, CSR/CSC, the
//!   paper's **column-chunked** weight format (eq. 7–8), the four
//!   support-intersection iteration methods (§4 items 1–4), and a compact
//!   open-addressing `u32 -> u32` map used by the hash iterators.
//! - [`tree`] — the linear XMR tree model (§3): layers of sparse ranker
//!   weight matrices, tree topology, binary model serialization.
//! - [`train`] — everything needed to *produce* models: TFIDF featurizer,
//!   PIFA label embeddings, hierarchical balanced k-means clustering and
//!   one-vs-rest logistic ranker training.
//! - [`data`] — dataset substrate: SVMLight-style loaders, synthetic
//!   dataset generators with the structural statistics of the paper's six
//!   public benchmarks (Table 5), and the enterprise-scale model
//!   synthesizer (§6).
//! - [`inference`] — Algorithms 1–4: beam-search inference with the
//!   masked matrix product evaluated by the vanilla per-column baseline or
//!   by MSCM, each under all four iteration methods; multi-threaded batch
//!   inference (§6.1); a NapkinXC-style per-column hash comparator (§5.2).
//! - [`metrics`] — streaming latency histograms (avg / P50 / P95 / P99).
//! - [`coordinator`] — the L3 serving system: request router, dynamic
//!   batcher, worker pool, backpressure.
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   layer step (`artifacts/*.hlo.txt`).
//!
//! The masked product `A = M ⊙ (X W)` (eq. 6) is exact under every engine
//! configuration: MSCM returns bit-identical scores to the baseline — this
//! is enforced by property tests.

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod inference;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod sparse;
pub mod train;
pub mod tree;
pub mod util;

pub use inference::{InferenceEngine, IterationMethod, MatmulAlgo};
pub use tree::XmrModel;
