//! # mscm-xmr — Masked Sparse Chunk Multiplication for XMR tree inference
//!
//! Reproduction of *"Enterprise-Scale Search: Accelerating Inference for
//! Sparse Extreme Multi-Label Ranking Trees"* (Etter, Zhong, Yu, Ying,
//! Dhillon — WWW 2022).
//!
//! The library is organised bottom-up:
//!
//! - [`sparse`] — sparse-matrix substrate: sparse vectors, CSR/CSC, the
//!   paper's **column-chunked** weight format (eq. 7–8), the four
//!   support-intersection iteration methods (§4 items 1–4) with their
//!   runtime-dispatched **SIMD tier** ([`sparse::simd`]: AVX2/NEON,
//!   detected once, bitwise identical to the scalar kernels), and a
//!   compact open-addressing `u32 -> u32` map used by the hash iterators.
//! - [`tree`] — the linear XMR tree model (§3): layers of sparse ranker
//!   weight matrices, tree topology, binary model serialization.
//! - [`train`] — everything needed to *produce* models: TFIDF featurizer,
//!   PIFA label embeddings, hierarchical balanced k-means clustering and
//!   one-vs-rest logistic ranker training.
//! - [`data`] — dataset substrate: SVMLight-style loaders, synthetic
//!   dataset generators with the structural statistics of the paper's six
//!   public benchmarks (Table 5), and the enterprise-scale model
//!   synthesizer (§6).
//! - [`inference`] — Algorithms 1–4: beam-search inference with the
//!   masked matrix product evaluated by the vanilla per-column baseline or
//!   by MSCM, each under all four iteration methods — or under the
//!   per-chunk cost-model **kernel planner** (`IterationMethod::Auto`,
//!   [`inference::plan`]), which picks the best method — and kernel
//!   tier, scalar vs SIMD ([`inference::KernelTier`]) — chunk by chunk
//!   with bitwise-identical output and plan-driven side indexes;
//!   multi-threaded batch inference (§6.1); a NapkinXC-style per-column
//!   hash comparator (§5.2).
//! - [`metrics`] — the observability layer: a registry of named
//!   lock-free counters / gauges / streaming latency histograms (avg /
//!   P50 / P95 / P99) with diffable point-in-time [`metrics::Snapshot`]s
//!   (text / Prometheus / JSON rendering), per-layer per-chunk-class
//!   engine telemetry joined against the kernel planner's cost model
//!   ([`metrics::PlanDrift`]), and opt-in per-query traces
//!   ([`metrics::QueryTrace`]). Snapshots travel across processes in the
//!   shard protocol's `Stats` frame and feed the `metrics` CLI.
//! - [`coordinator`] — the L3 serving system: request router, dynamic
//!   batcher, worker pool, backpressure.
//! - [`shard`] — label-space sharding: partitions a model into root-
//!   subtree shards, persists them in a versioned shard format, and
//!   serves them through an **exact** scatter-gather coordinator (per-
//!   shard worker pools driven layer-by-layer by a gather stage that
//!   owns the global beam — bit-identical to unsharded search). The
//!   [`shard::wire`] / [`shard::remote`] pair carries the same protocol
//!   across processes: TCP shard hosts, replicated with mid-query
//!   failover, driven by a remote gather stage whose speculative
//!   expansion halves the RTT × depth cost. The transport is
//!   chaos-hardened: per-replica health with a half-open circuit
//!   breaker (healthy → suspect → ejected → probation), round-robin
//!   replica rotation, per-batch deadline budgets, observed-p99 hedged
//!   retries, and an opt-in degraded mode (`--allow-partial`) that
//!   serves live shards with an explicit `degraded` response flag when
//!   a shard is fully down — all under seeded, replayable fault
//!   injection ([`shard::fault`], `rust/tests/chaos.rs`).
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   layer step (`artifacts/*.hlo.txt`).
//!
//! The masked product `A = M ⊙ (X W)` (eq. 6) is exact under every engine
//! configuration: MSCM returns bit-identical scores to the baseline — and
//! the sharded scatter-gather returns bit-identical top-k to the single
//! engine — both enforced by property tests.

// Stylistic lints the hot-path code intentionally trips: index loops keep
// the kernels shaped like the paper's pseudocode, and the engine entry
// points take the full (query range, beam, topk, workspace, out) surface.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::type_complexity)]

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod inference;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod shard;
pub mod sparse;
pub mod train;
pub mod tree;
pub mod util;

pub use inference::{InferenceEngine, IterationMethod, MatmulAlgo};
pub use shard::{RemoteShardedCoordinator, ShardHost, ShardedCoordinator, ShardedEngine};
pub use tree::XmrModel;
