//! Latency metrics: streaming histograms with avg / P50 / P95 / P99,
//! matching the quantities reported in the paper's Table 4 and §6, plus
//! the per-shard scatter-round telemetry ([`ScatterMetrics`]) both
//! sharded gather stages feed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// A latency histogram with logarithmic microsecond buckets plus exact
/// sum/count, cheap enough for the serving hot path.
///
/// Buckets cover 1 µs … ~17 s in 4 sub-buckets per octave; quantile error
/// is bounded by the bucket width (≤ ~19%), and `avg` is exact.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const SUB: u64 = 4; // sub-buckets per octave
const OCTAVES: u64 = 24; // 2^24 µs ≈ 16.7 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        let n = (OCTAVES * SUB) as usize;
        Self {
            buckets: (0..n).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_index(us: u64) -> usize {
        if us < 1 {
            return 0;
        }
        let oct = 63 - us.leading_zeros() as u64; // floor(log2)
        let oct = oct.min(OCTAVES - 1);
        let frac = if oct == 0 {
            0
        } else {
            ((us >> (oct.saturating_sub(2))) & (SUB - 1)).min(SUB - 1)
        };
        (oct * SUB + frac) as usize
    }

    /// Upper bound (µs) of a bucket, used when reading quantiles.
    fn bucket_upper(idx: usize) -> u64 {
        let oct = (idx as u64) / SUB;
        let frac = (idx as u64) % SUB;
        if oct == 0 {
            return frac + 1;
        }
        let base = 1u64 << oct;
        base + ((frac + 1) * base) / SUB
    }

    /// Records one observation.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e3
        }
    }

    /// Approximate quantile (0..1) in milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        let target = ((c as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_upper(i) as f64 / 1e3;
            }
        }
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Maximum observed, in milliseconds.
    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// One-line summary matching Table 4's columns.
    pub fn summary(&self) -> String {
        format!(
            "n={} avg={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
            self.count(),
            self.mean_ms(),
            self.quantile_ms(0.50),
            self.quantile_ms(0.95),
            self.quantile_ms(0.99),
            self.max_ms()
        )
    }
}

/// Per-round scatter-gather telemetry: one latency histogram per shard
/// plus a **join-wait** histogram — how long the gather join idles
/// between the first and the last shard reply of a layer round. The
/// layer-synchronized protocol advances at the pace of the slowest
/// shard, so the join wait is exactly the latency the ROADMAP's
/// "gather join waits for the slowest shard" item wants shaved (and the
/// per-shard histograms show *which* shard to rebalance or re-plan —
/// the planner feedback loop's serving-side signal).
///
/// Recording is lock-free atomic adds, cheap enough for every round of
/// both the in-process and the remote gather stages.
#[derive(Debug)]
pub struct ScatterMetrics {
    per_shard: Vec<LatencyHistogram>,
    /// Idle time between the first and last shard reply per round.
    pub join_wait: LatencyHistogram,
    /// Completed scatter rounds.
    pub rounds: AtomicU64,
}

impl ScatterMetrics {
    /// Empty telemetry for `num_shards` shards.
    pub fn new(num_shards: usize) -> Self {
        Self {
            per_shard: (0..num_shards).map(|_| LatencyHistogram::new()).collect(),
            join_wait: LatencyHistogram::new(),
            rounds: AtomicU64::new(0),
        }
    }

    /// Number of shards tracked.
    pub fn num_shards(&self) -> usize {
        self.per_shard.len()
    }

    /// Records shard `s`'s reply latency for one round (dispatch → reply
    /// joined).
    pub fn record_round(&self, s: usize, d: Duration) {
        self.per_shard[s].record(d);
    }

    /// Records one completed round's join wait (last reply − first
    /// reply).
    pub fn record_join_wait(&self, d: Duration) {
        self.join_wait.record(d);
        self.rounds.fetch_add(1, Ordering::Relaxed);
    }

    /// Shard `s`'s round-latency histogram.
    pub fn shard(&self, s: usize) -> &LatencyHistogram {
        &self.per_shard[s]
    }

    /// Multi-line summary: one row per shard plus the join wait.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (s, h) in self.per_shard.iter().enumerate() {
            out.push_str(&format!("shard {s} rounds: {}\n", h.summary()));
        }
        out.push_str(&format!("join wait:      {}", self.join_wait.summary()));
        out
    }
}

/// Exact latency recorder (stores all samples) for offline benchmarks
/// where Table-4-grade precision matters more than memory.
#[derive(Debug, Default)]
pub struct ExactLatencies {
    samples_us: Mutex<Vec<u64>>,
}

impl ExactLatencies {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Records one observation.
    pub fn record(&self, d: Duration) {
        self.samples_us.lock().unwrap().push(d.as_micros() as u64);
    }

    /// (mean, p50, p95, p99) in milliseconds.
    pub fn stats_ms(&self) -> (f64, f64, f64, f64) {
        let mut s = self.samples_us.lock().unwrap().clone();
        if s.is_empty() {
            return (0.0, 0.0, 0.0, 0.0);
        }
        s.sort_unstable();
        let n = s.len();
        let pct = |q: f64| s[(((n as f64) * q) as usize).min(n - 1)] as f64 / 1e3;
        let mean = s.iter().sum::<u64>() as f64 / n as f64 / 1e3;
        (mean, pct(0.50), pct(0.95), pct(0.99))
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples_us.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_exact() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 2, 3] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_ms() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_bracket() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.quantile_ms(0.5) * 1e3; // back to µs
        assert!((400.0..700.0).contains(&p50), "p50 {p50}");
        let p99 = h.quantile_ms(0.99) * 1e3;
        assert!((900.0..1300.0).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn bucket_monotonicity() {
        let mut last = 0;
        for us in [0u64, 1, 2, 3, 5, 9, 17, 100, 1000, 1_000_000] {
            let b = LatencyHistogram::bucket_index(us);
            assert!(b >= last, "bucket({us}) = {b} < {last}");
            last = b;
        }
    }

    #[test]
    fn exact_latencies_stats() {
        let e = ExactLatencies::new();
        for i in 1..=100u64 {
            e.record(Duration::from_millis(i));
        }
        let (mean, p50, p95, p99) = e.stats_ms();
        assert!((mean - 50.5).abs() < 1e-6);
        assert_eq!(p50, 51.0);
        assert_eq!(p95, 96.0);
        assert_eq!(p99, 100.0);
    }

    #[test]
    fn scatter_metrics_track_per_shard_rounds() {
        let m = ScatterMetrics::new(3);
        assert_eq!(m.num_shards(), 3);
        m.record_round(0, Duration::from_micros(100));
        m.record_round(1, Duration::from_micros(300));
        m.record_round(2, Duration::from_micros(900));
        m.record_join_wait(Duration::from_micros(800));
        assert_eq!(m.rounds.load(Ordering::Relaxed), 1);
        assert_eq!(m.shard(1).count(), 1);
        assert_eq!(m.join_wait.count(), 1);
        assert!(m.shard(2).mean_ms() > m.shard(0).mean_ms());
        let s = m.summary();
        assert!(s.contains("shard 2") && s.contains("join wait"), "{s}");
    }

    #[test]
    fn empty_histograms_are_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
        let e = ExactLatencies::new();
        assert_eq!(e.stats_ms(), (0.0, 0.0, 0.0, 0.0));
    }
}
