//! Serving observability: streaming latency histograms, a named-metric
//! [`Registry`] with point-in-time [`Snapshot`]s, and the engine-level
//! plan-drift telemetry that closes the planner loop.
//!
//! # Histograms
//!
//! [`LatencyHistogram`] is the shared recording primitive: logarithmic
//! microsecond buckets (4 sub-buckets per octave, 1 µs … ~16.7 s) plus
//! exact count/sum/max — the quantities of the paper's Table 4 —
//! recorded with lock-free atomic adds cheap enough for every request.
//! [`ScatterMetrics`] layers per-shard round latencies and the gather
//! **join wait** on top; both sharded gather stages (in-process and
//! remote) feed it.
//!
//! # Registry, snapshots, diffing
//!
//! A [`Registry`] names lock-free counters, gauges and histograms.
//! Handles ([`Counter`], [`Gauge`], `Arc<LatencyHistogram>`) are
//! resolved once — registration takes a lock and may allocate; recording
//! through a handle is a plain atomic op, so hot paths stay
//! allocation-free (pinned by `rust/tests/alloc.rs`). [`Registry::snapshot`]
//! captures a point-in-time [`Snapshot`]; [`Snapshot::diff`] subtracts an
//! earlier one for *windowed* stats (`serve --stats-interval` prints
//! these), so a long-running server is observable without restart-to-
//! reset. Snapshots render as human text ([`Snapshot::render_text`]),
//! Prometheus-style exposition ([`Snapshot::render_prometheus`], served
//! by `serve --metrics-addr`) and JSON ([`Snapshot::to_json`] /
//! [`Snapshot::from_json`]), and travel between processes in the shard
//! wire protocol's `Stats` frame (see [`crate::shard`] docs).
//!
//! # Engine telemetry and plan drift
//!
//! [`EngineMetrics`] times every layer expansion with a single `Instant`
//! pair per layer slice and attributes the touched blocks to their
//! `(IterationMethod, ChunkStorage)` chunk class, accumulating alongside
//! the **predicted** cost of the same blocks under the engine's
//! [`crate::inference::CostModel`]. [`PlanDrift`] joins the two: per
//! layer and per chunk class, measured ns vs predicted ns. The
//! measured/predicted ratio is exactly the scale factor ROADMAP item 5's
//! online recalibration needs — a drift ratio far from 1.0 on some class
//! means the cost constants `k` mispredict that kernel on this machine
//! and the planner should recalibrate ([`CostModel::calibrate`]) or
//! re-plan. See [`EngineMetrics`] for the recording contract.
//!
//! # Query traces and the distributed trace tree
//!
//! [`QueryTrace`] (emitted by `infer --trace out.json`, sampled by
//! `serve --trace-sample N`) is the opt-in per-query view: beam width,
//! chunks touched, kernel/storage mix and expand/select ns per layer,
//! plus ranking time. The JSON schema is documented on [`QueryTrace`].
//!
//! [`TraceRecord`] is the **cross-process** view: per-batch trace trees
//! over the scatter-gather serving path — per-shard per-round
//! [`RoundSpan`]s carrying client tx/round/join-wait times, the
//! host-side [`HostSpan`] piggybacked on wire v3 `Cands` replies, and
//! `EV_*` event annotations (hedges, failovers, ejections, degraded
//! rounds, speculation hits/misses). The [`FlightRecorder`] retains the
//! last N of them with tail-based sampling — traces above the live p99
//! are pinned, the rest 1-in-N sampled — exported via the `Traces` wire
//! poll, `metrics --traces` and `serve --flight-recorder`. See the
//! trace module docs for the retention and hot-path contracts.
//!
//! [`CostModel::calibrate`]: crate::inference::CostModel::calibrate

mod drift;
mod trace;

pub use drift::{DriftCell, DriftLayer, EngineMetrics, PlanDrift};
pub use trace::{
    event_names, FlightRecorder, FlightRecorderConfig, HostSpan, LayerTrace, QueryTrace,
    RoundSpan, TraceRecord, EV_DEAD, EV_DEGRADED, EV_EJECTION, EV_FAILOVER, EV_HEDGE,
    EV_SPEC_HIT, EV_SPEC_MISS, MAX_TRACE_SPANS,
};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::Json;

/// A latency histogram with logarithmic microsecond buckets plus exact
/// sum/count, cheap enough for the serving hot path.
///
/// Buckets cover 1 µs … ~17 s in 4 sub-buckets per octave; quantile error
/// is bounded by the bucket width (≤ ~19%), and `avg` is exact.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const SUB: u64 = 4; // sub-buckets per octave
const OCTAVES: u64 = 24; // 2^24 µs ≈ 16.7 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        let n = (OCTAVES * SUB) as usize;
        Self {
            buckets: (0..n).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Maps a µs value to its bucket index.
    ///
    /// The low octaves are intentionally **uneven**: sub-bucket
    /// resolution only exists once an octave spans at least `SUB`
    /// integer values. Octave 0 (`us ∈ {0, 1}`) collapses to index 0,
    /// and octave 1 (`us ∈ {2, 3}`) carries a single fractional bit so
    /// only its upper two sub-buckets (indices 6–7) are reachable —
    /// indices 1–5 are never produced. Rather than special-casing these
    /// octaves, the consistency contract is pinned by the
    /// `bucket_bounds_bracket_every_value` property test below: indices
    /// are monotone in `us`, `bucket_upper(bucket_index(us)) >= us`, and
    /// each bucket's value range is contiguous. Values at or above the
    /// 2^24 µs ceiling all fold into the single last bucket (keeping the
    /// index monotone through the boundary); count/sum/max stay exact
    /// there and quantiles past the ceiling fall back to `max_us`.
    fn bucket_index(us: u64) -> usize {
        if us < 1 {
            return 0;
        }
        let oct = 63 - us.leading_zeros() as u64; // floor(log2)
        if oct >= OCTAVES {
            // At or past the 2^24 µs ceiling everything folds into the
            // single last bucket. The old low-bits fold could map a
            // ceiling value *below* smaller ones (bucket_index(2^24)
            // landed at sub-bucket 0 of the top octave, under
            // bucket_index(2^24 - 1)), breaking monotonicity and the
            // bracketing contract at the boundary.
            return (OCTAVES * SUB - 1) as usize;
        }
        let frac = if oct == 0 {
            0
        } else {
            ((us >> (oct.saturating_sub(2))) & (SUB - 1)).min(SUB - 1)
        };
        (oct * SUB + frac) as usize
    }

    /// Upper bound (µs) of a bucket, used when reading quantiles.
    fn bucket_upper(idx: usize) -> u64 {
        let oct = (idx as u64) / SUB;
        let frac = (idx as u64) % SUB;
        if oct == 0 {
            return frac + 1;
        }
        let base = 1u64 << oct;
        base + ((frac + 1) * base) / SUB
    }

    /// Records one observation.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e3
        }
    }

    /// Approximate quantile (0..1) in milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        let target = ((c as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_upper(i) as f64 / 1e3;
            }
        }
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// [`LatencyHistogram::quantile_ms`] gated on a minimum sample
    /// count: `None` until the histogram holds `min_count` observations.
    /// Consumers that turn a quantile into a decision threshold (the
    /// remote transport's hedged reads) use this so a cold histogram
    /// can't produce a garbage cutoff.
    pub fn quantile_ms_if(&self, q: f64, min_count: u64) -> Option<f64> {
        (self.count() >= min_count).then(|| self.quantile_ms(q))
    }

    /// Maximum observed, in milliseconds.
    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// One-line summary matching Table 4's columns (plus the p999 the
    /// under-load story tracks — see ROADMAP item 2 / `benches/load.rs`).
    pub fn summary(&self) -> String {
        format!(
            "n={} avg={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms p999={:.3}ms max={:.3}ms",
            self.count(),
            self.mean_ms(),
            self.quantile_ms(0.50),
            self.quantile_ms(0.95),
            self.quantile_ms(0.99),
            self.quantile_ms(0.999),
            self.max_ms()
        )
    }

    /// Point-in-time copy of every bucket plus the exact count/sum/max.
    /// Snapshots are plain data: diffable, serializable, and readable
    /// with the same mean/quantile math as the live histogram.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of one [`LatencyHistogram`]: the full bucket
/// vector plus exact count/sum/max. Two snapshots of the same histogram
/// subtract ([`HistogramSnapshot::diff`]) into the window between them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket observation counts (`OCTAVES * SUB` entries).
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Exact sum of all observations, µs.
    pub sum_us: u64,
    /// Maximum observation, µs.
    pub max_us: u64,
}

impl HistogramSnapshot {
    /// The window between `earlier` and `self`: per-bucket and
    /// count/sum subtraction (saturating, so a reset or mismatched pair
    /// degrades to zeros instead of wrapping). The *exact* windowed max
    /// is not recoverable from two cumulative snapshots, so `max_us` is
    /// derived from the diffed buckets: the upper bound of the highest
    /// nonempty bucket, tightened by the lifetime max — an **upper
    /// estimate** within one bucket's resolution, and `0` for an empty
    /// window. (Carrying the lifetime `max_us` here, as earlier versions
    /// did, made `metrics --interval` windows and the quantile
    /// past-ceiling fallback report stale pre-window tails forever.)
    pub fn diff(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .enumerate()
            .map(|(i, &b)| b.saturating_sub(earlier.buckets.get(i).copied().unwrap_or(0)))
            .collect();
        let max_us = buckets
            .iter()
            .rposition(|&b| b != 0)
            .map_or(0, |i| LatencyHistogram::bucket_upper(i).min(self.max_us));
        HistogramSnapshot {
            buckets,
            count: self.count.saturating_sub(earlier.count),
            sum_us: self.sum_us.saturating_sub(earlier.sum_us),
            max_us,
        }
    }

    /// Exact mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64 / 1e3
        }
    }

    /// Approximate quantile (0..1) in milliseconds — the same walk as
    /// [`LatencyHistogram::quantile_ms`].
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return LatencyHistogram::bucket_upper(i) as f64 / 1e3;
            }
        }
        self.max_us as f64 / 1e3
    }

    /// Maximum observed, in milliseconds.
    pub fn max_ms(&self) -> f64 {
        self.max_us as f64 / 1e3
    }

    /// One-line summary matching [`LatencyHistogram::summary`].
    pub fn summary(&self) -> String {
        format!(
            "n={} avg={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms p999={:.3}ms max={:.3}ms",
            self.count,
            self.mean_ms(),
            self.quantile_ms(0.50),
            self.quantile_ms(0.95),
            self.quantile_ms(0.99),
            self.quantile_ms(0.999),
            self.max_ms()
        )
    }
}

/// A named monotone counter handle. Cloning shares the underlying
/// atomic; recording is a single relaxed `fetch_add` — no lock, no
/// allocation.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Adds one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Adds `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A named gauge handle (an `f64` stored as bits in one atomic).
/// Cloning shares the underlying atomic; `set` is a single relaxed
/// store.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Replaces the value.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A registry of named lock-free metrics.
///
/// Registration (`counter` / `gauge` / `histogram`) is get-or-create:
/// it takes the registry lock and may allocate, so resolve handles once
/// at setup. Recording through a resolved handle never touches the
/// registry again. [`Registry::snapshot`] walks the name table under the
/// lock and copies every value into a [`Snapshot`].
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicU64>>,
    histograms: BTreeMap<String, Arc<LatencyHistogram>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The counter named `name`, created at zero on first use.
    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = self.inner.lock().unwrap();
        let a = inner
            .counters
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)));
        Counter(Arc::clone(a))
    }

    /// The gauge named `name`, created at 0.0 on first use.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = self.inner.lock().unwrap();
        let a = inner
            .gauges
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0f64.to_bits())));
        Gauge(Arc::clone(a))
    }

    /// The histogram named `name`, created empty on first use.
    pub fn histogram(&self, name: &str) -> Arc<LatencyHistogram> {
        let mut inner = self.inner.lock().unwrap();
        let h = inner
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(LatencyHistogram::new()));
        Arc::clone(h)
    }

    /// Adopts an externally owned histogram under `name`, so structures
    /// that already record into their own `Arc<LatencyHistogram>` export
    /// through the registry without double recording.
    pub fn register_histogram(&self, name: &str, h: Arc<LatencyHistogram>) {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .insert(name.to_string(), h);
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> Snapshot {
        let inner = self.inner.lock().unwrap();
        Snapshot {
            counters: inner
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            gauges: inner
                .gauges
                .iter()
                .map(|(k, v)| (k.clone(), f64::from_bits(v.load(Ordering::Relaxed))))
                .collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// A point-in-time copy of a [`Registry`] (or any composed stats
/// source): named counter values, gauge values and histogram snapshots.
/// Plain data — diffable, renderable, JSON round-trippable, and carried
/// across processes by the shard wire protocol's `Stats` frame.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// Monotone counters by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauges by name.
    pub gauges: BTreeMap<String, f64>,
    /// Histograms by name.
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl Snapshot {
    /// The window between `earlier` and `self`: counters and histograms
    /// subtract (saturating); gauges keep their latest value (a gauge is
    /// a level, not a flow). Names present only in `self` pass through —
    /// a metric registered mid-window diffs against zero.
    pub fn diff(&self, earlier: &Snapshot) -> Snapshot {
        Snapshot {
            counters: self
                .counters
                .iter()
                .map(|(k, &v)| {
                    (
                        k.clone(),
                        v.saturating_sub(earlier.counters.get(k).copied().unwrap_or(0)),
                    )
                })
                .collect(),
            gauges: self.gauges.clone(),
            histograms: self
                .histograms
                .iter()
                .map(|(k, v)| {
                    let base = earlier.histograms.get(k);
                    let d = match base {
                        Some(b) => v.diff(b),
                        None => v.clone(),
                    };
                    (k.clone(), d)
                })
                .collect(),
        }
    }

    /// Human-readable multi-line rendering: one `name = value` line per
    /// counter/gauge, one summary line per histogram.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k} = {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k} = {v:.3}\n"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!("{k}: {}\n", h.summary()));
        }
        out
    }

    /// Prometheus-style text exposition: `mscm_<name> <value>` lines,
    /// with histogram count/sum/max/quantiles flattened to suffixed
    /// series. Metric names are sanitized to `[a-zA-Z0-9_]`.
    pub fn render_prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            name.chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect()
        }
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("mscm_{} {v}\n", sanitize(k)));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("mscm_{} {v}\n", sanitize(k)));
        }
        for (k, h) in &self.histograms {
            let k = sanitize(k);
            out.push_str(&format!("mscm_{k}_count {}\n", h.count));
            out.push_str(&format!("mscm_{k}_sum_us {}\n", h.sum_us));
            out.push_str(&format!("mscm_{k}_max_us {}\n", h.max_us));
            out.push_str(&format!("mscm_{k}_p50_ms {}\n", h.quantile_ms(0.50)));
            out.push_str(&format!("mscm_{k}_p95_ms {}\n", h.quantile_ms(0.95)));
            out.push_str(&format!("mscm_{k}_p99_ms {}\n", h.quantile_ms(0.99)));
            out.push_str(&format!("mscm_{k}_p999_ms {}\n", h.quantile_ms(0.999)));
        }
        out
    }

    /// JSON encoding (counters, gauges, histograms with their raw
    /// buckets). Counter values ride as JSON numbers, exact below 2^53 —
    /// far beyond any real counter here.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect(),
        );
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count as f64)),
                            ("sum_us", Json::Num(h.sum_us as f64)),
                            ("max_us", Json::Num(h.max_us as f64)),
                            (
                                "buckets",
                                Json::Arr(
                                    h.buckets.iter().map(|&b| Json::Num(b as f64)).collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }

    /// Inverse of [`Snapshot::to_json`].
    pub fn from_json(v: &Json) -> Result<Snapshot, String> {
        fn num(v: &Json, what: &str) -> Result<f64, String> {
            v.as_f64().ok_or_else(|| format!("{what} is not a number"))
        }
        fn obj<'a>(
            v: &'a Json,
            key: &str,
        ) -> Result<&'a BTreeMap<String, Json>, String> {
            match v.get(key) {
                Some(Json::Obj(m)) => Ok(m),
                _ => Err(format!("missing object field '{key}'")),
            }
        }
        let mut snap = Snapshot::default();
        for (k, v) in obj(v, "counters")? {
            snap.counters.insert(k.clone(), num(v, k)? as u64);
        }
        for (k, v) in obj(v, "gauges")? {
            snap.gauges.insert(k.clone(), num(v, k)?);
        }
        for (k, v) in obj(v, "histograms")? {
            let buckets = v
                .get("buckets")
                .and_then(|b| b.as_arr())
                .ok_or_else(|| format!("histogram '{k}' missing buckets"))?
                .iter()
                .map(|b| num(b, "bucket").map(|f| f as u64))
                .collect::<Result<Vec<u64>, String>>()?;
            snap.histograms.insert(
                k.clone(),
                HistogramSnapshot {
                    buckets,
                    count: num(v.get("count").ok_or("histogram missing count")?, "count")?
                        as u64,
                    sum_us: num(
                        v.get("sum_us").ok_or("histogram missing sum_us")?,
                        "sum_us",
                    )? as u64,
                    max_us: num(
                        v.get("max_us").ok_or("histogram missing max_us")?,
                        "max_us",
                    )? as u64,
                },
            );
        }
        Ok(snap)
    }
}

/// Per-round scatter-gather telemetry: one latency histogram per shard
/// plus a **join-wait** histogram — how long the gather join idles
/// between the first and the last shard reply of a layer round. The
/// layer-synchronized protocol advances at the pace of the slowest
/// shard, so the join wait is exactly the latency the ROADMAP's
/// "gather join waits for the slowest shard" item wants shaved (and the
/// per-shard histograms show *which* shard to rebalance or re-plan —
/// the planner feedback loop's serving-side signal).
///
/// Recording is lock-free atomic adds, cheap enough for every round of
/// both the in-process and the remote gather stages.
#[derive(Debug)]
pub struct ScatterMetrics {
    per_shard: Vec<LatencyHistogram>,
    /// Idle time between the first and last shard reply per round.
    pub join_wait: LatencyHistogram,
    /// Completed scatter rounds.
    pub rounds: AtomicU64,
}

impl ScatterMetrics {
    /// Empty telemetry for `num_shards` shards.
    pub fn new(num_shards: usize) -> Self {
        Self {
            per_shard: (0..num_shards).map(|_| LatencyHistogram::new()).collect(),
            join_wait: LatencyHistogram::new(),
            rounds: AtomicU64::new(0),
        }
    }

    /// Number of shards tracked.
    pub fn num_shards(&self) -> usize {
        self.per_shard.len()
    }

    /// Records shard `s`'s reply latency for one round (dispatch → reply
    /// joined).
    pub fn record_round(&self, s: usize, d: Duration) {
        self.per_shard[s].record(d);
    }

    /// Records one completed round's join wait (last reply − first
    /// reply).
    pub fn record_join_wait(&self, d: Duration) {
        self.join_wait.record(d);
        self.rounds.fetch_add(1, Ordering::Relaxed);
    }

    /// Shard `s`'s round-latency histogram.
    pub fn shard(&self, s: usize) -> &LatencyHistogram {
        &self.per_shard[s]
    }

    /// Multi-line summary: one row per shard plus the join wait.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (s, h) in self.per_shard.iter().enumerate() {
            out.push_str(&format!("shard {s} rounds: {}\n", h.summary()));
        }
        out.push_str(&format!("join wait:      {}", self.join_wait.summary()));
        out
    }

    /// Copies this telemetry into `snap` under `prefix`: a
    /// `{prefix}.rounds` counter, one `{prefix}.shard{s}.round`
    /// histogram per shard, and `{prefix}.join_wait` — the bridge from
    /// the accumulate-forever recorders into the snapshot/diff
    /// machinery.
    pub fn snapshot_into(&self, snap: &mut Snapshot, prefix: &str) {
        snap.counters.insert(
            format!("{prefix}.rounds"),
            self.rounds.load(Ordering::Relaxed),
        );
        for (s, h) in self.per_shard.iter().enumerate() {
            snap.histograms
                .insert(format!("{prefix}.shard{s}.round"), h.snapshot());
        }
        snap.histograms
            .insert(format!("{prefix}.join_wait"), self.join_wait.snapshot());
    }
}

/// Exact latency recorder (stores all samples) for offline benchmarks
/// where Table-4-grade precision matters more than memory.
#[derive(Debug, Default)]
pub struct ExactLatencies {
    samples_us: Mutex<Vec<u64>>,
}

impl ExactLatencies {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Records one observation.
    pub fn record(&self, d: Duration) {
        self.samples_us.lock().unwrap().push(d.as_micros() as u64);
    }

    /// (mean, p50, p95, p99) in milliseconds.
    pub fn stats_ms(&self) -> (f64, f64, f64, f64) {
        let mut s = self.samples_us.lock().unwrap().clone();
        if s.is_empty() {
            return (0.0, 0.0, 0.0, 0.0);
        }
        s.sort_unstable();
        let n = s.len();
        let pct = |q: f64| s[(((n as f64) * q) as usize).min(n - 1)] as f64 / 1e3;
        let mean = s.iter().sum::<u64>() as f64 / n as f64 / 1e3;
        (mean, pct(0.50), pct(0.95), pct(0.99))
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples_us.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_exact() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 2, 3] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_ms() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_bracket() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.quantile_ms(0.5) * 1e3; // back to µs
        assert!((400.0..700.0).contains(&p50), "p50 {p50}");
        let p99 = h.quantile_ms(0.99) * 1e3;
        assert!((900.0..1300.0).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn bucket_monotonicity() {
        let mut last = 0;
        for us in [0u64, 1, 2, 3, 5, 9, 17, 100, 1000, 1_000_000] {
            let b = LatencyHistogram::bucket_index(us);
            assert!(b >= last, "bucket({us}) = {b} < {last}");
            last = b;
        }
    }

    #[test]
    fn exact_latencies_stats() {
        let e = ExactLatencies::new();
        for i in 1..=100u64 {
            e.record(Duration::from_millis(i));
        }
        let (mean, p50, p95, p99) = e.stats_ms();
        assert!((mean - 50.5).abs() < 1e-6);
        assert_eq!(p50, 51.0);
        assert_eq!(p95, 96.0);
        assert_eq!(p99, 100.0);
    }

    #[test]
    fn scatter_metrics_track_per_shard_rounds() {
        let m = ScatterMetrics::new(3);
        assert_eq!(m.num_shards(), 3);
        m.record_round(0, Duration::from_micros(100));
        m.record_round(1, Duration::from_micros(300));
        m.record_round(2, Duration::from_micros(900));
        m.record_join_wait(Duration::from_micros(800));
        assert_eq!(m.rounds.load(Ordering::Relaxed), 1);
        assert_eq!(m.shard(1).count(), 1);
        assert_eq!(m.join_wait.count(), 1);
        assert!(m.shard(2).mean_ms() > m.shard(0).mean_ms());
        let s = m.summary();
        assert!(s.contains("shard 2") && s.contains("join wait"), "{s}");
    }

    #[test]
    fn empty_histograms_are_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
        let e = ExactLatencies::new();
        assert_eq!(e.stats_ms(), (0.0, 0.0, 0.0, 0.0));
    }

    /// Satellite property: for every value below the 2^24 µs ceiling the
    /// bucket mapping is monotone, the bucket's upper bound covers the
    /// value, and each bucket's value range is contiguous (so the
    /// bucket's own minimum is the implied lower bound, `<= us` by
    /// construction). Exhaustive over the uneven low octaves, octave
    /// boundaries and a seeded log-uniform sweep above.
    #[test]
    fn bucket_bounds_bracket_every_value() {
        let ceiling = 1u64 << OCTAVES; // 2^24 µs
        let check = |us: u64, last: &mut usize| {
            let i = LatencyHistogram::bucket_index(us);
            assert!(i >= *last, "bucket_index({us}) = {i} < {last}");
            *last = i;
            assert!(
                LatencyHistogram::bucket_upper(i) >= us,
                "bucket_upper({i}) = {} < us {us}",
                LatencyHistogram::bucket_upper(i)
            );
            i
        };
        // Exhaustive low range: covers octave 0/1's dead sub-buckets.
        let mut last = 0usize;
        let mut min_of_bucket = vec![u64::MAX; (OCTAVES * SUB) as usize];
        let mut max_of_bucket = vec![0u64; (OCTAVES * SUB) as usize];
        for us in 0..=65_536u64 {
            let i = check(us, &mut last);
            min_of_bucket[i] = min_of_bucket[i].min(us);
            max_of_bucket[i] = max_of_bucket[i].max(us);
        }
        // Contiguity: monotone mapping means a bucket's [min, max] range
        // has no holes; the bucket's own minimum is its implied lower
        // bound and is <= every value the bucket received.
        for i in 0..min_of_bucket.len() {
            if min_of_bucket[i] == u64::MAX {
                continue;
            }
            for j in i + 1..min_of_bucket.len() {
                if min_of_bucket[j] != u64::MAX {
                    assert!(
                        max_of_bucket[i] < min_of_bucket[j],
                        "buckets {i} and {j} overlap"
                    );
                    break;
                }
            }
        }
        // Every octave boundary ±1, the 2^24 µs ceiling included. Up to
        // and at the ceiling the full bracket holds (bucket_upper of the
        // last bucket is exactly 2^24); past it only monotonicity can —
        // values above the ceiling fold into the last bucket, whose
        // upper bound they exceed (the documented max_us fallback).
        let mut last = 0usize;
        let mut prev = 0u64;
        for oct in 1..=OCTAVES {
            for us in [(1u64 << oct) - 1, 1u64 << oct, (1u64 << oct) + 1] {
                if us < prev {
                    continue;
                }
                prev = us;
                if us <= ceiling {
                    check(us, &mut last);
                } else {
                    let i = LatencyHistogram::bucket_index(us);
                    assert!(i >= last, "bucket_index({us}) = {i} < {last}");
                    assert_eq!(i, (OCTAVES * SUB - 1) as usize, "past-ceiling fold");
                    last = i;
                }
            }
        }
        // The boundary regression pinned: the ceiling maps to the last
        // bucket, never below its predecessor.
        assert_eq!(
            LatencyHistogram::bucket_index(ceiling),
            LatencyHistogram::bucket_index(ceiling - 1),
        );
        // Seeded log-uniform sweep: random pairs stay ordered.
        let mut rng = crate::util::Rng::seed_from_u64(0xB0C4E7);
        for _ in 0..5_000 {
            let ea = rng.gen_range(0..24) as u64;
            let eb = rng.gen_range(0..24) as u64;
            let a = ((1u64 << ea) + rng.gen_range(0..(1usize << ea)) as u64).min(ceiling - 1);
            let b = ((1u64 << eb) + rng.gen_range(0..(1usize << eb)) as u64).min(ceiling - 1);
            let (lo, hi) = (a.min(b), a.max(b));
            let mut last = LatencyHistogram::bucket_index(lo);
            check(hi, &mut last);
        }
    }

    #[test]
    fn snapshot_diff_is_the_window() {
        let h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        let s1 = h.snapshot();
        assert_eq!(s1.count, 100);
        assert_eq!(s1.summary(), h.summary());
        for i in 1..=50u64 {
            h.record(Duration::from_millis(i));
        }
        let s2 = h.snapshot();
        let w = s2.diff(&s1);
        // The window holds exactly the 50 millisecond-scale records.
        assert_eq!(w.count, 50);
        assert_eq!(w.sum_us, (1..=50u64).map(|i| i * 1000).sum::<u64>());
        assert!(w.mean_ms() > 10.0, "window mean {}", w.mean_ms());
        assert!(w.quantile_ms(0.5) > 1.0);
        // Empty window: diff against itself.
        let z = s2.diff(&s2);
        assert_eq!(z.count, 0);
        assert!(z.buckets.iter().all(|&b| b == 0));
        assert_eq!(z.max_us, 0, "an empty window has no maximum");
    }

    /// Regression: the windowed max must come from the window, not the
    /// lifetime. Pre-fix, `diff` carried the all-time `max_us` into
    /// every window, so a single old spike polluted `metrics --interval`
    /// summaries (and the quantile past-ceiling fallback) forever.
    #[test]
    fn diff_windowed_max_tracks_the_window() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_millis(100)); // lifetime spike: 100_000 µs
        let s1 = h.snapshot();
        h.record(Duration::from_millis(1)); // the window: one 1_000 µs obs
        let s2 = h.snapshot();
        let w = s2.diff(&s1);
        assert_eq!(w.count, 1);
        assert!(
            w.max_us < 100_000,
            "window max {} leaked the pre-window lifetime spike",
            w.max_us
        );
        // Upper-estimate contract: covers the true windowed max within
        // one bucket's resolution.
        assert!(w.max_us >= 1_000, "window max {} under the true max", w.max_us);
        assert!(
            w.max_us as f64 <= 1_000.0 * 1.5,
            "window max {} looser than one bucket",
            w.max_us
        );
        // A window holding the lifetime max keeps reporting it exactly
        // (the bucket-upper estimate is tightened by the lifetime max).
        let all = s2.diff(&HistogramSnapshot::default());
        assert_eq!(all.count, 2);
        assert_eq!(all.max_us, 100_000);
    }

    #[test]
    fn registry_snapshot_diff_and_render() {
        let reg = Registry::new();
        let c = reg.counter("served");
        let g = reg.gauge("queue_depth");
        let h = reg.histogram("latency");
        c.add(5);
        g.set(2.5);
        h.record(Duration::from_micros(300));
        let s1 = reg.snapshot();
        assert_eq!(s1.counters["served"], 5);
        assert_eq!(s1.gauges["queue_depth"], 2.5);
        assert_eq!(s1.histograms["latency"].count, 1);
        // Handles are shared: a second lookup sees the same atomic.
        reg.counter("served").add(2);
        assert_eq!(c.get(), 7);
        g.set(1.0);
        h.record(Duration::from_micros(700));
        let s2 = reg.snapshot();
        let w = s2.diff(&s1);
        assert_eq!(w.counters["served"], 2);
        assert_eq!(w.gauges["queue_depth"], 1.0); // gauges keep latest
        assert_eq!(w.histograms["latency"].count, 1);
        let text = s2.render_text();
        assert!(text.contains("served = 7"), "{text}");
        assert!(text.contains("latency: n=2"), "{text}");
        let prom = s2.render_prometheus();
        assert!(prom.contains("mscm_served 7"), "{prom}");
        assert!(prom.contains("mscm_latency_count 2"), "{prom}");
        assert!(prom.contains("mscm_queue_depth 1"), "{prom}");
    }

    #[test]
    fn snapshot_json_round_trips() {
        let reg = Registry::new();
        reg.counter("a.b").add(42);
        reg.gauge("g").set(-1.25);
        reg.histogram("h").record(Duration::from_micros(123));
        let snap = reg.snapshot();
        let j = snap.to_json();
        let back = Snapshot::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, snap);
        // Structural violations are rejected, not defaulted.
        assert!(Snapshot::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(Snapshot::from_json(
            &Json::parse(r#"{"counters":{},"gauges":{},"histograms":{"x":{"count":1}}}"#)
                .unwrap()
        )
        .is_err());
    }

    #[test]
    fn scatter_metrics_snapshot_into_registry_namespace() {
        let m = ScatterMetrics::new(2);
        m.record_round(0, Duration::from_micros(100));
        m.record_round(1, Duration::from_micros(200));
        m.record_join_wait(Duration::from_micros(100));
        let mut snap = Snapshot::default();
        m.snapshot_into(&mut snap, "scatter");
        assert_eq!(snap.counters["scatter.rounds"], 1);
        assert_eq!(snap.histograms["scatter.shard0.round"].count, 1);
        assert_eq!(snap.histograms["scatter.shard1.round"].count, 1);
        assert_eq!(snap.histograms["scatter.join_wait"].count, 1);
    }
}
