//! Engine-level timing telemetry and the plan-drift join.
//!
//! [`EngineMetrics`] is the serving-side half of the planner feedback
//! loop (ROADMAP item 5): it measures what the layer kernels actually
//! cost and accumulates, side by side, what the engine's resolved
//! [`KernelPlan`] *predicted* those same blocks would cost under the
//! [`CostModel`]. [`PlanDrift`] joins the two into per-layer and
//! per-chunk-class rows whose measured/predicted ratio is the
//! recalibration signal: a class drifting far from 1.0 means the cost
//! constants `k` mispredict that kernel on this machine.
//!
//! # Recording contract
//!
//! The hot path pays exactly one `Instant` pair per layer slice (one
//! call to [`crate::inference::InferenceEngine::expand_layer`]) plus a
//! walk over the already-resident beam parents accumulating into two
//! stack arrays, flushed as at most `4 × 3 × 2` relaxed atomic adds. No
//! locks, no allocations — `rust/tests/alloc.rs` pins the zero-alloc
//! invariant with metrics enabled on the online, batch and sharded
//! paths. Block attribution is exact, not sampled: every beamed parent
//! is one block of its chunk's `(method, storage, tier)` class, and the
//! predicted cost of *those* chunks (precomputed per chunk at enable
//! time) is what accumulates, so the join compares identical workloads.
//! The tier half of the class is the **effective** tier — the plan's
//! tier gated by the engine's detected SIMD level — so a SIMD-planned
//! chunk running on scalar hardware is attributed (and cost-predicted)
//! as the scalar kernel it actually executed.
//!
//! Layer wall time is measured once per slice rather than per class;
//! [`DriftLayer`] therefore carries the measured ns exactly, while
//! [`DriftCell`] rows carry exact block counts and predicted ns per
//! class. On mixed-class layers the per-class measured share is not
//! directly observable without per-chunk timers (which would break the
//! single-Instant-pair budget); the layer-level ratio plus the class
//! composition is what the recalibration loop consumes.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::inference::{
    CostModel, IterationMethod, KernelPlan, KernelTier, MatmulAlgo, PlannerConfig,
};
use crate::sparse::{ChunkStorage, SimdLevel};
use crate::tree::XmrModel;
use crate::util::Json;

use super::Snapshot;

/// Chunk classes: 4 concrete methods × 3 storage layouts × 2 kernel
/// tiers (scalar classes occupy the low half so tier-free readers keep
/// their indices). The quantized layouts (`F16`/`Int8`) execute the
/// CSC-shaped kernels after an arena dequantize, so they attribute to
/// the `Csc` class rather than widening the table.
const CLASSES: usize = 24;

#[inline]
fn class_of(method: IterationMethod, storage: ChunkStorage, tier: KernelTier) -> usize {
    let storage = if storage.is_quantized() {
        ChunkStorage::Csc
    } else {
        storage
    };
    tier.index() * 12 + method.index() * 3 + storage.index()
}

fn class_parts(class: usize) -> (IterationMethod, ChunkStorage, KernelTier) {
    (
        IterationMethod::from_index(class / 3 % 4).expect("class method in range"),
        ChunkStorage::from_index(class % 3).expect("class storage in range"),
        KernelTier::from_index(class / 12).expect("class tier in range"),
    )
}

/// Per-layer accumulators plus the immutable per-chunk attribution
/// tables built once at enable time.
struct LayerMetrics {
    /// Measured wall time of every slice of this layer, ns.
    ns: AtomicU64,
    /// Layer slices expanded (one per `expand_layer` call).
    calls: AtomicU64,
    /// Blocks expanded per chunk class.
    blocks: [AtomicU64; CLASSES],
    /// Predicted ns accumulated per chunk class (the cost model's
    /// per-block prediction summed over the actual blocks touched).
    pred_ns: [AtomicU64; CLASSES],
    /// Chunk id → chunk class, from the resolved plan.
    chunk_class: Vec<u8>,
    /// Chunk id → predicted ns per block, scaled to integer ns.
    chunk_pred_ns: Vec<u64>,
}

/// Lock-free per-engine timing telemetry, attached with
/// [`crate::inference::InferenceEngine::with_metrics`]. See the module
/// docs for the recording contract and [`EngineMetrics::plan_drift`] for
/// the join.
pub struct EngineMetrics {
    layers: Vec<LayerMetrics>,
}

impl EngineMetrics {
    /// Builds the attribution tables for `model` under its resolved
    /// `plan`: each chunk's class and its predicted per-block cost under
    /// `cost`/`pc` — the prediction side of the drift join, frozen at
    /// enable time so the hot path only indexes.
    pub(crate) fn for_plan(
        model: &XmrModel,
        algo: MatmulAlgo,
        plan: &KernelPlan,
        level: SimdLevel,
        cost: &CostModel,
        pc: &PlannerConfig,
    ) -> Self {
        let layers = model
            .layers
            .iter()
            .enumerate()
            .map(|(li, layer)| {
                let methods = plan.layer_methods(li);
                let storage = plan.layer_storage(li);
                let tiers = plan.layer_tiers(li);
                let nc = layer.chunked.num_chunks();
                let mut chunk_class = Vec::with_capacity(nc);
                let mut chunk_pred_ns = Vec::with_capacity(nc);
                for c in 0..nc {
                    let stats = layer.chunked.chunk_stats(c);
                    // Attribute (and price) what actually runs: SIMD-planned
                    // chunks degrade to scalar on non-vector hardware.
                    let tier = if level.is_vector() {
                        tiers[c]
                    } else {
                        KernelTier::Scalar
                    };
                    chunk_class.push(class_of(methods[c], storage[c], tier) as u8);
                    let pred =
                        cost.planned_block_cost(algo, methods[c], storage[c], tier, &stats, pc);
                    chunk_pred_ns.push(pred.max(0.0).round() as u64);
                }
                LayerMetrics {
                    ns: AtomicU64::new(0),
                    calls: AtomicU64::new(0),
                    blocks: std::array::from_fn(|_| AtomicU64::new(0)),
                    pred_ns: std::array::from_fn(|_| AtomicU64::new(0)),
                    chunk_class,
                    chunk_pred_ns,
                }
            })
            .collect();
        Self { layers }
    }

    /// Hot-path record: one completed slice of layer `li` that took `ns`
    /// and expanded the beam parents in `parents` (flat `(chunk id,
    /// score)` entries across the slice's queries). Stack accumulation,
    /// then at most `2 × CLASSES` relaxed atomic adds.
    #[inline]
    pub(crate) fn record_layer(&self, li: usize, ns: u64, parents: &[(u32, f32)]) {
        let lm = &self.layers[li];
        lm.ns.fetch_add(ns, Ordering::Relaxed);
        lm.calls.fetch_add(1, Ordering::Relaxed);
        let mut blocks = [0u64; CLASSES];
        let mut pred = [0u64; CLASSES];
        for &(p, _) in parents {
            let c = lm.chunk_class[p as usize] as usize;
            blocks[c] += 1;
            pred[c] += lm.chunk_pred_ns[p as usize];
        }
        for c in 0..CLASSES {
            if blocks[c] != 0 {
                lm.blocks[c].fetch_add(blocks[c], Ordering::Relaxed);
                lm.pred_ns[c].fetch_add(pred[c], Ordering::Relaxed);
            }
        }
    }

    /// Number of layers instrumented.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Total measured expansion time across all layers, ns.
    pub fn total_ns(&self) -> u64 {
        self.layers.iter().map(|l| l.ns.load(Ordering::Relaxed)).sum()
    }

    /// Bitmask of the *effective* kernel tiers that have executed blocks
    /// in layer `li` so far (bit position = [`KernelTier::index`]). The
    /// distributed trace spans stamp this on every round so a trace tree
    /// shows which tier actually ran each layer on each host — a
    /// SIMD-planned shard degraded to scalar hardware is visible per
    /// span, not just in the aggregate drift join. Lock-free reads; no
    /// allocation.
    pub fn layer_tier_mask(&self, li: usize) -> u32 {
        let mut mask = 0u32;
        if let Some(lm) = self.layers.get(li) {
            for class in 0..CLASSES {
                if lm.blocks[class].load(Ordering::Relaxed) != 0 {
                    mask |= 1 << (class / 12);
                }
            }
        }
        mask
    }

    /// Joins the measurements against the plan's predictions — the
    /// [`PlanDrift`] report ROADMAP item 5's recalibration consumes.
    pub fn plan_drift(&self) -> PlanDrift {
        let mut layers = Vec::with_capacity(self.layers.len());
        let mut cells = Vec::new();
        for (li, lm) in self.layers.iter().enumerate() {
            let mut predicted_ns = 0u64;
            for class in 0..CLASSES {
                let blocks = lm.blocks[class].load(Ordering::Relaxed);
                if blocks == 0 {
                    continue;
                }
                let pred = lm.pred_ns[class].load(Ordering::Relaxed);
                predicted_ns += pred;
                let (method, storage, tier) = class_parts(class);
                cells.push(DriftCell {
                    layer: li,
                    method,
                    storage,
                    tier,
                    blocks,
                    predicted_ns: pred,
                });
            }
            layers.push(DriftLayer {
                layer: li,
                calls: lm.calls.load(Ordering::Relaxed),
                measured_ns: lm.ns.load(Ordering::Relaxed),
                predicted_ns,
            });
        }
        PlanDrift { layers, cells }
    }

    /// Copies the raw accumulators into `snap` under `prefix` (e.g.
    /// `engine.`): `{prefix}layer{li}.ns` / `.calls` per layer and
    /// `{prefix}layer{li}.{method}.{storage}.blocks` / `.pred_ns` per
    /// touched chunk class — the form the `Stats` wire frame exports.
    /// SIMD-tier classes add a `.simd` component before `.blocks` /
    /// `.pred_ns`; scalar classes keep the historical key shape.
    pub fn export_into(&self, snap: &mut Snapshot, prefix: &str) {
        for (li, lm) in self.layers.iter().enumerate() {
            snap.counters.insert(
                format!("{prefix}layer{li}.ns"),
                lm.ns.load(Ordering::Relaxed),
            );
            snap.counters.insert(
                format!("{prefix}layer{li}.calls"),
                lm.calls.load(Ordering::Relaxed),
            );
            for class in 0..CLASSES {
                let blocks = lm.blocks[class].load(Ordering::Relaxed);
                if blocks == 0 {
                    continue;
                }
                let (method, storage, tier) = class_parts(class);
                let mut key = format!("{prefix}layer{li}.{}.{}", method.short(), storage.short());
                if tier == KernelTier::Simd {
                    key.push_str(".simd");
                }
                snap.counters.insert(format!("{key}.blocks"), blocks);
                snap.counters.insert(
                    format!("{key}.pred_ns"),
                    lm.pred_ns[class].load(Ordering::Relaxed),
                );
            }
        }
    }
}

/// One layer's row of the drift join: measured wall time vs the cost
/// model's prediction for the same blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DriftLayer {
    /// Layer index.
    pub layer: usize,
    /// Layer slices expanded.
    pub calls: u64,
    /// Measured expansion wall time, ns.
    pub measured_ns: u64,
    /// Cost-model prediction for the same blocks, ns.
    pub predicted_ns: u64,
}

impl DriftLayer {
    /// Measured / predicted; 0.0 when nothing was predicted.
    pub fn ratio(&self) -> f64 {
        if self.predicted_ns == 0 {
            0.0
        } else {
            self.measured_ns as f64 / self.predicted_ns as f64
        }
    }
}

/// One chunk-class row of the drift join: how many blocks of a
/// `(layer, method, storage, tier)` class ran and what the cost model
/// said they would cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DriftCell {
    /// Layer index.
    pub layer: usize,
    /// Planned iteration method of the class.
    pub method: IterationMethod,
    /// Planned storage layout of the class.
    pub storage: ChunkStorage,
    /// Effective kernel tier of the class (plan ∧ detected hardware).
    pub tier: KernelTier,
    /// Blocks expanded.
    pub blocks: u64,
    /// Cost-model prediction for those blocks, ns.
    pub predicted_ns: u64,
}

/// The measured-vs-predicted join ([`EngineMetrics::plan_drift`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanDrift {
    /// Per-layer measured/predicted rows.
    pub layers: Vec<DriftLayer>,
    /// Per-chunk-class composition rows (zero-block classes omitted).
    pub cells: Vec<DriftCell>,
}

impl PlanDrift {
    /// Total measured ns across layers.
    pub fn total_measured_ns(&self) -> u64 {
        self.layers.iter().map(|l| l.measured_ns).sum()
    }

    /// Total predicted ns across layers.
    pub fn total_predicted_ns(&self) -> u64 {
        self.layers.iter().map(|l| l.predicted_ns).sum()
    }

    /// Overall measured / predicted ratio — the global recalibration
    /// scale; 0.0 when nothing was recorded.
    pub fn ratio(&self) -> f64 {
        let p = self.total_predicted_ns();
        if p == 0 {
            0.0
        } else {
            self.total_measured_ns() as f64 / p as f64
        }
    }

    /// Human-readable report: one row per layer with its ratio, then
    /// the class composition.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "plan drift: measured {} ns vs predicted {} ns (ratio {:.3})\n",
            self.total_measured_ns(),
            self.total_predicted_ns(),
            self.ratio()
        ));
        for l in &self.layers {
            out.push_str(&format!(
                "  layer {}: calls={} measured={}ns predicted={}ns ratio={:.3}\n",
                l.layer, l.calls, l.measured_ns, l.predicted_ns, l.ratio()
            ));
        }
        for c in &self.cells {
            out.push_str(&format!(
                "    layer {} {}/{}/{}: blocks={} predicted={}ns\n",
                c.layer,
                c.method.short(),
                c.storage.short(),
                c.tier.short(),
                c.blocks,
                c.predicted_ns
            ));
        }
        out
    }

    /// JSON encoding: `{"layers": [...], "cells": [...]}` with the
    /// field names of [`DriftLayer`] / [`DriftCell`] plus per-row
    /// ratios.
    pub fn to_json(&self) -> Json {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("layer", Json::Num(l.layer as f64)),
                    ("calls", Json::Num(l.calls as f64)),
                    ("measured_ns", Json::Num(l.measured_ns as f64)),
                    ("predicted_ns", Json::Num(l.predicted_ns as f64)),
                    ("ratio", Json::Num(l.ratio())),
                ])
            })
            .collect();
        let cells = self
            .cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("layer", Json::Num(c.layer as f64)),
                    ("method", Json::Str(c.method.short().to_string())),
                    ("storage", Json::Str(c.storage.short().to_string())),
                    ("tier", Json::Str(c.tier.short().to_string())),
                    ("blocks", Json::Num(c.blocks as f64)),
                    ("predicted_ns", Json::Num(c.predicted_ns as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("measured_ns", Json::Num(self.total_measured_ns() as f64)),
            ("predicted_ns", Json::Num(self.total_predicted_ns() as f64)),
            ("ratio", Json::Num(self.ratio())),
            ("layers", Json::Arr(layers)),
            ("cells", Json::Arr(cells)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_round_trips() {
        let mut seen = std::collections::HashSet::new();
        for t in KernelTier::ALL {
            for m in IterationMethod::ALL {
                for s in ChunkStorage::ALL {
                    let c = class_of(m, s, t);
                    assert!(c < CLASSES);
                    assert!(seen.insert(c), "class {c} collides");
                    assert_eq!(class_parts(c), (m, s, t));
                }
            }
        }
        assert_eq!(seen.len(), CLASSES);
        // Scalar classes occupy the low half — existing tier-free
        // consumers of the class indices keep their meaning.
        for m in IterationMethod::ALL {
            for s in ChunkStorage::ALL {
                assert!(class_of(m, s, KernelTier::Scalar) < 12);
            }
        }
        // Quantized layouts run the CSC kernels and share its class.
        for t in KernelTier::ALL {
            for m in IterationMethod::ALL {
                for s in [ChunkStorage::F16, ChunkStorage::Int8] {
                    assert_eq!(class_of(m, s, t), class_of(m, ChunkStorage::Csc, t));
                }
            }
        }
    }

    #[test]
    fn drift_ratio_math() {
        let d = PlanDrift {
            layers: vec![
                DriftLayer {
                    layer: 0,
                    calls: 2,
                    measured_ns: 300,
                    predicted_ns: 100,
                },
                DriftLayer {
                    layer: 1,
                    calls: 2,
                    measured_ns: 100,
                    predicted_ns: 100,
                },
            ],
            cells: vec![],
        };
        assert_eq!(d.total_measured_ns(), 400);
        assert_eq!(d.total_predicted_ns(), 200);
        assert!((d.ratio() - 2.0).abs() < 1e-12);
        assert!((d.layers[0].ratio() - 3.0).abs() < 1e-12);
        let j = d.to_json();
        assert_eq!(j.get("ratio").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("layers").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(PlanDrift::default().ratio(), 0.0);
    }
}
