//! Opt-in per-query tracing — the structured record behind
//! `infer --trace out.json` and the sampled `serve --trace-sample N`.
//!
//! A [`QueryTrace`] is produced by
//! [`crate::inference::InferenceEngine::predict_traced`], a separate
//! cold path that steps the beam search layer by layer with extra
//! timers and bookkeeping. The hot paths carry **no** tracing hooks at
//! all, so the disabled path costs nothing (pinned by
//! `rust/tests/alloc.rs`).
//!
//! # JSON schema
//!
//! ```text
//! {
//!   "query_nnz": int,        // nonzeros of the query vector
//!   "beam": int, "topk": int,
//!   "total_ns": int,         // whole search, expand + select + rank
//!   "rank_ns": int,          // final top-k ranking
//!   "layers": [{
//!     "layer": int,
//!     "beam_width": int,     // surviving parents expanded (= chunks touched)
//!     "candidates": int,     // children generated before the beam cut
//!     "expand_ns": int,      // masked-matmul expansion of this layer
//!     "select_ns": int,      // global beam selection
//!     "methods": {"marching"|"binary"|"hash"|"dense": blocks, ...},
//!     "storages": {"csc"|"dense-rows"|"merged": blocks, ...},
//!     "tiers": {"scalar"|"simd": blocks, ...}  // effective (hardware-gated)
//!   }, ...]
//! }
//! ```
//!
//! On the sharded serving paths, `serve --trace-sample N` wraps sampled
//! requests in an outer object carrying queue/total ns and batch size
//! plus a windowed stats diff (gather/wire/join live in the
//! `scatter.*` / `remote.scatter.*` histograms there) — see the serve
//! command docs in `main.rs`.

use crate::util::Json;

/// One layer's slice of a traced query.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerTrace {
    /// Layer index.
    pub layer: usize,
    /// Surviving parents expanded — each is one sibling chunk touched.
    pub beam_width: usize,
    /// Children generated before the beam cut.
    pub candidates: usize,
    /// Expansion wall time, ns.
    pub expand_ns: u64,
    /// Beam-selection wall time, ns.
    pub select_ns: u64,
    /// Blocks per iteration method, indexed by
    /// [`crate::inference::IterationMethod::index`].
    pub method_blocks: [u64; 4],
    /// Blocks per storage layout, indexed by
    /// [`crate::sparse::ChunkStorage::index`].
    pub storage_blocks: [u64; 3],
    /// Blocks per *effective* kernel tier (the plan's tier gated by the
    /// engine's detected SIMD level), indexed by
    /// [`crate::inference::KernelTier::index`].
    pub tier_blocks: [u64; 2],
}

/// A full per-query trace ([`crate::inference::InferenceEngine::predict_traced`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QueryTrace {
    /// Nonzeros of the query vector.
    pub query_nnz: usize,
    /// Beam width searched.
    pub beam: usize,
    /// Ranking depth requested.
    pub topk: usize,
    /// Whole-search wall time, ns.
    pub total_ns: u64,
    /// Final ranking wall time, ns.
    pub rank_ns: u64,
    /// Per-layer slices.
    pub layers: Vec<LayerTrace>,
}

impl QueryTrace {
    /// JSON encoding (schema in the module docs). Zero-block method /
    /// storage entries are omitted.
    pub fn to_json(&self) -> Json {
        use crate::inference::{IterationMethod, KernelTier};
        use crate::sparse::ChunkStorage;
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let methods = Json::Obj(
                    IterationMethod::ALL
                        .iter()
                        .filter(|m| l.method_blocks[m.index()] != 0)
                        .map(|m| {
                            (
                                m.short().to_string(),
                                Json::Num(l.method_blocks[m.index()] as f64),
                            )
                        })
                        .collect(),
                );
                let storages = Json::Obj(
                    ChunkStorage::ALL
                        .iter()
                        .filter(|s| l.storage_blocks[s.index()] != 0)
                        .map(|s| {
                            (
                                s.short().to_string(),
                                Json::Num(l.storage_blocks[s.index()] as f64),
                            )
                        })
                        .collect(),
                );
                let tiers = Json::Obj(
                    KernelTier::ALL
                        .iter()
                        .filter(|t| l.tier_blocks[t.index()] != 0)
                        .map(|t| {
                            (
                                t.short().to_string(),
                                Json::Num(l.tier_blocks[t.index()] as f64),
                            )
                        })
                        .collect(),
                );
                Json::obj(vec![
                    ("layer", Json::Num(l.layer as f64)),
                    ("beam_width", Json::Num(l.beam_width as f64)),
                    ("candidates", Json::Num(l.candidates as f64)),
                    ("expand_ns", Json::Num(l.expand_ns as f64)),
                    ("select_ns", Json::Num(l.select_ns as f64)),
                    ("methods", methods),
                    ("storages", storages),
                    ("tiers", tiers),
                ])
            })
            .collect();
        Json::obj(vec![
            ("query_nnz", Json::Num(self.query_nnz as f64)),
            ("beam", Json::Num(self.beam as f64)),
            ("topk", Json::Num(self.topk as f64)),
            ("total_ns", Json::Num(self.total_ns as f64)),
            ("rank_ns", Json::Num(self.rank_ns as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_json_has_schema_fields() {
        let t = QueryTrace {
            query_nnz: 8,
            beam: 10,
            topk: 5,
            total_ns: 1000,
            rank_ns: 50,
            layers: vec![LayerTrace {
                layer: 0,
                beam_width: 1,
                candidates: 4,
                expand_ns: 700,
                select_ns: 20,
                method_blocks: [0, 0, 1, 0],
                storage_blocks: [1, 0, 0],
                tier_blocks: [1, 0],
            }],
        };
        let j = t.to_json();
        assert_eq!(j.get("beam").unwrap().as_f64(), Some(10.0));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 1);
        let l0 = &layers[0];
        assert_eq!(l0.get("beam_width").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            l0.get("methods").unwrap().get("hash").unwrap().as_f64(),
            Some(1.0)
        );
        assert!(l0.get("methods").unwrap().get("dense").is_none());
        assert_eq!(
            l0.get("storages").unwrap().get("csc").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            l0.get("tiers").unwrap().get("scalar").unwrap().as_f64(),
            Some(1.0)
        );
        assert!(l0.get("tiers").unwrap().get("simd").is_none());
        // Round-trips through the strict parser.
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
