//! Query tracing — the in-process [`QueryTrace`] behind
//! `infer --trace out.json`, and the **distributed** trace tree +
//! tail-sampling [`FlightRecorder`] behind the sharded serving path.
//!
//! # Distributed traces
//!
//! A [`TraceRecord`] is one batch's walk through the scatter-gather
//! protocol: per shard, per layer round, a [`RoundSpan`] carrying the
//! client-side timings (`tx_ns` encode+send, `round_ns` scatter → reply
//! decoded, `wait_ns` join-wait share past the round's first reply) and
//! the host-side [`HostSpan`] piggybacked on the wire v3 `Cands` reply
//! (`decode_ns` / `expand_ns` / `encode_ns` on the host's own clock,
//! plus the effective kernel-tier bitmask of the expanded layer). The
//! `events` bit set annotates what the serving layer did to the round:
//! hedges, failovers, ejections, dead shards / degraded rounds, and
//! speculation hits/misses ([`EV_HEDGE`] … [`EV_SPEC_MISS`]). A host
//! span is a genuine sub-interval of the client's batch window (the
//! host may start decoding while the client is still scattering to its
//! peers, so only the batch-level bound `host.total_ns() <= total_ns`
//! is guaranteed span by span), and `round_ns − host.total_ns()`
//! estimates the wire + queue share — the decomposition ROADMAP items
//! 2/5 consume (adaptive batch delay, online recalibration) attributed
//! to *real* queries, not averages.
//!
//! # The flight recorder
//!
//! [`FlightRecorder`] is an always-on, fixed-capacity ring of the last
//! N [`TraceRecord`]s with **tail-based retention**: every record is
//! observed into an internal [`LatencyHistogram`](super::LatencyHistogram),
//! and a trace whose total latency exceeds the live p99 (once a sample
//! floor is met) is *pinned* — it always claims a slot, and sampled
//! writes cannot evict it until the ring has lapped it. Everything else
//! is 1-in-N sampled. The slow queries a probability sampler
//! statistically misses are exactly the ones retained.
//!
//! Hot-path contract (pinned by `rust/tests/alloc.rs` and
//! `rust/tests/tracing.rs`): recording is allocation-free — every
//! slot's span vector is pre-sized at construction and refilled in
//! place — and never blocks: slots are claimed with a `try_lock`, so a
//! contended slot drops the sample (counted) instead of waiting.
//! Tracing never changes results (traced serving is bitwise identical
//! to untraced), and with the recorder disabled the serving paths carry
//! no tracing hooks at all.
//!
//! # Distributed trace JSON schema
//!
//! [`TraceRecord::to_json`] (exported by `metrics --traces` and the
//! `Traces` wire poll — see [`crate::shard::wire`]):
//!
//! ```text
//! {
//!   "trace_id": int,          // batch span id, carried on wire v3 Expand
//!   "batch": int, "beam": int,
//!   "total_ns": int,          // whole batch, scatter rounds + ranking
//!   "pinned": bool,           // true: retained as a tail (> live p99) trace
//!   "events": ["hedge"|"failover"|"ejection"|"dead-shard"|"degraded"
//!              |"spec-hit"|"spec-miss", ...],   // union over spans
//!   "truncated_spans": int,   // spans dropped past MAX_TRACE_SPANS
//!   "spans": [{
//!     "shard": int, "layer": int,
//!     "tx_ns": int,           // client: encode + send of the Expand
//!     "round_ns": int,        // client: scatter done -> reply decoded
//!     "wait_ns": int,         // client: this reply - first reply of round
//!     "host_decode_ns": int,  // host: Expand decode
//!     "host_expand_ns": int,  // host: expand + speculation
//!     "host_encode_ns": int,  // host: Cands encode
//!     "tiers": ["scalar"|"simd", ...],  // effective tiers run on the host
//!     "events": [...]         // this round's annotations
//!   }, ...]
//! }
//! ```
//!
//! # Per-query traces
//!
//! [`QueryTrace`] is produced by
//! [`crate::inference::InferenceEngine::predict_traced`], a separate
//! cold path that steps the beam search layer by layer with extra
//! timers and bookkeeping (schema documented on [`QueryTrace`]). On the
//! sharded serving paths, `serve --trace-sample N` wraps sampled
//! requests in an outer object carrying queue/total ns and batch size
//! plus a windowed stats diff — see the serve command docs in
//! `main.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::Json;

/// One layer's slice of a traced query.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerTrace {
    /// Layer index.
    pub layer: usize,
    /// Surviving parents expanded — each is one sibling chunk touched.
    pub beam_width: usize,
    /// Children generated before the beam cut.
    pub candidates: usize,
    /// Expansion wall time, ns.
    pub expand_ns: u64,
    /// Beam-selection wall time, ns.
    pub select_ns: u64,
    /// Blocks per iteration method, indexed by
    /// [`crate::inference::IterationMethod::index`].
    pub method_blocks: [u64; 4],
    /// Blocks per storage layout, indexed by
    /// [`crate::sparse::ChunkStorage::index`] over
    /// [`crate::sparse::ChunkStorage::EVERY`] (trailing slots: the
    /// approximate `F16`/`Int8` layouts).
    pub storage_blocks: [u64; 5],
    /// Blocks per *effective* kernel tier (the plan's tier gated by the
    /// engine's detected SIMD level), indexed by
    /// [`crate::inference::KernelTier::index`].
    pub tier_blocks: [u64; 2],
}

/// A full per-query trace ([`crate::inference::InferenceEngine::predict_traced`]).
///
/// JSON schema ([`QueryTrace::to_json`]):
///
/// ```text
/// {
///   "query_nnz": int,        // nonzeros of the query vector
///   "beam": int, "topk": int,
///   "total_ns": int,         // whole search, expand + select + rank
///   "rank_ns": int,          // final top-k ranking
///   "layers": [{
///     "layer": int,
///     "beam_width": int,     // surviving parents expanded (= chunks touched)
///     "candidates": int,     // children generated before the beam cut
///     "expand_ns": int,      // masked-matmul expansion of this layer
///     "select_ns": int,      // global beam selection
///     "methods": {"marching"|"binary"|"hash"|"dense": blocks, ...},
///     "storages": {"csc"|"dense-rows"|"merged"|"f16"|"int8": blocks, ...},
///     "tiers": {"scalar"|"simd": blocks, ...}  // effective (hardware-gated)
///   }, ...]
/// }
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QueryTrace {
    /// Nonzeros of the query vector.
    pub query_nnz: usize,
    /// Beam width searched.
    pub beam: usize,
    /// Ranking depth requested.
    pub topk: usize,
    /// Whole-search wall time, ns.
    pub total_ns: u64,
    /// Final ranking wall time, ns.
    pub rank_ns: u64,
    /// Per-layer slices.
    pub layers: Vec<LayerTrace>,
}

impl QueryTrace {
    /// JSON encoding (schema on [`QueryTrace`]). Zero-block method /
    /// storage entries are omitted.
    pub fn to_json(&self) -> Json {
        use crate::inference::{IterationMethod, KernelTier};
        use crate::sparse::ChunkStorage;
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let methods = Json::Obj(
                    IterationMethod::ALL
                        .iter()
                        .filter(|m| l.method_blocks[m.index()] != 0)
                        .map(|m| {
                            (
                                m.short().to_string(),
                                Json::Num(l.method_blocks[m.index()] as f64),
                            )
                        })
                        .collect(),
                );
                let storages = Json::Obj(
                    ChunkStorage::EVERY
                        .iter()
                        .filter(|s| l.storage_blocks[s.index()] != 0)
                        .map(|s| {
                            (
                                s.short().to_string(),
                                Json::Num(l.storage_blocks[s.index()] as f64),
                            )
                        })
                        .collect(),
                );
                let tiers = Json::Obj(
                    KernelTier::ALL
                        .iter()
                        .filter(|t| l.tier_blocks[t.index()] != 0)
                        .map(|t| {
                            (
                                t.short().to_string(),
                                Json::Num(l.tier_blocks[t.index()] as f64),
                            )
                        })
                        .collect(),
                );
                Json::obj(vec![
                    ("layer", Json::Num(l.layer as f64)),
                    ("beam_width", Json::Num(l.beam_width as f64)),
                    ("candidates", Json::Num(l.candidates as f64)),
                    ("expand_ns", Json::Num(l.expand_ns as f64)),
                    ("select_ns", Json::Num(l.select_ns as f64)),
                    ("methods", methods),
                    ("storages", storages),
                    ("tiers", tiers),
                ])
            })
            .collect();
        Json::obj(vec![
            ("query_nnz", Json::Num(self.query_nnz as f64)),
            ("beam", Json::Num(self.beam as f64)),
            ("topk", Json::Num(self.topk as f64)),
            ("total_ns", Json::Num(self.total_ns as f64)),
            ("rank_ns", Json::Num(self.rank_ns as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Distributed traces: spans, events, records, and the flight recorder.
// ---------------------------------------------------------------------------

/// A hedged retry fired on this round (the first read hit the p99 bound
/// and the round was re-issued on the next replica).
pub const EV_HEDGE: u32 = 1 << 0;
/// The round failed over to another replica (io error / timeout on the
/// active connection).
pub const EV_FAILOVER: u32 = 1 << 1;
/// A replica's circuit breaker opened during this round.
pub const EV_EJECTION: u32 = 1 << 2;
/// This shard was marked dead for the batch (all replicas down under
/// `--allow-partial`); the span carries no reply timings.
pub const EV_DEAD: u32 = 1 << 3;
/// The round completed with at least one dead shard — the batch is
/// serving degraded results over the live shards' label subspace.
pub const EV_DEGRADED: u32 = 1 << 4;
/// The speculative next-layer hint covered the whole global beam: the
/// next layer was assembled locally and its network round skipped.
pub const EV_SPEC_HIT: u32 = 1 << 5;
/// A speculative hint was requested but did not cover the beam; the
/// next layer paid a full network round.
pub const EV_SPEC_MISS: u32 = 1 << 6;

const EVENT_NAMES: [(u32, &str); 7] = [
    (EV_HEDGE, "hedge"),
    (EV_FAILOVER, "failover"),
    (EV_EJECTION, "ejection"),
    (EV_DEAD, "dead-shard"),
    (EV_DEGRADED, "degraded"),
    (EV_SPEC_HIT, "spec-hit"),
    (EV_SPEC_MISS, "spec-miss"),
];

/// The names of the set bits in an `EV_*` event mask (cold path:
/// allocates the vector).
pub fn event_names(events: u32) -> Vec<&'static str> {
    EVENT_NAMES
        .iter()
        .filter(|(bit, _)| events & bit != 0)
        .map(|&(_, name)| name)
        .collect()
}

/// Host-side timings of one layer round, measured inside the shard host
/// around the `Expand → Cands` handling and piggybacked on the wire v3
/// `Cands` reply. All times are on the host's own monotonic clock but
/// are pure durations, so they compose with the client-side batch
/// window that strictly contains them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HostSpan {
    /// `Expand` frame decode (query rows + beam slice), ns.
    pub decode_ns: u64,
    /// Layer expansion plus speculative next-layer expansion, ns.
    pub expand_ns: u64,
    /// `Cands` reply encode, ns (backpatched into the frame after the
    /// encode completes).
    pub encode_ns: u64,
    /// Effective kernel tiers that have executed blocks in the expanded
    /// layer (bit = [`crate::inference::KernelTier::index`]); 0 when the
    /// host serves without engine telemetry.
    pub tiers: u32,
}

impl HostSpan {
    /// Total host-side time of the round, ns.
    pub fn total_ns(&self) -> u64 {
        self.decode_ns + self.expand_ns + self.encode_ns
    }
}

/// One shard's slice of one layer round in a distributed trace tree.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundSpan {
    /// Shard id.
    pub shard: u32,
    /// Layer expanded this round.
    pub layer: u32,
    /// Client: encode + send of the `Expand` frame, ns.
    pub tx_ns: u64,
    /// Client: scatter complete → this shard's reply decoded, ns.
    pub round_ns: u64,
    /// Client: this shard's reply − the round's first reply, ns — the
    /// join-wait share this shard charged the gather (0 for the round's
    /// fastest shard).
    pub wait_ns: u64,
    /// Host-side decode/expand/encode (zeros for an in-process round's
    /// decode/encode, or when the host replied without a span).
    pub host: HostSpan,
    /// `EV_*` annotations for this round.
    pub events: u32,
}

/// Spans kept per [`TraceRecord`]; rounds past the cap are dropped and
/// counted in [`TraceRecord::truncated`]. Sized for deep trees × wide
/// partitions (e.g. 16 shards × 8 layers) without unbounded growth.
pub const MAX_TRACE_SPANS: usize = 128;

/// One batch's distributed trace: identity, totals, and the per-shard
/// per-round spans. Slot-pooled inside the [`FlightRecorder`] — the
/// span vector is pre-sized at construction and refilled in place, so
/// steady-state recording never allocates.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceRecord {
    /// Batch span id, carried to hosts in the v3 `Expand` trace section.
    pub trace_id: u64,
    /// Queries in the traced batch.
    pub batch: u32,
    /// Beam width served.
    pub beam: u32,
    /// Whole-batch wall time (scatter rounds + ranking), ns.
    pub total_ns: u64,
    /// Union of every span's `EV_*` bits plus batch-level annotations.
    pub events: u32,
    /// True when retained as a tail trace (total latency above the live
    /// p99 at record time) rather than a 1-in-N sample.
    pub pinned: bool,
    /// Spans dropped past [`MAX_TRACE_SPANS`].
    pub truncated: u32,
    /// Per-shard per-round spans, in join order.
    pub spans: Vec<RoundSpan>,
}

impl TraceRecord {
    /// An empty record whose span vector holds [`MAX_TRACE_SPANS`]
    /// capacity up front (the allocation happens here, never in
    /// [`TraceRecord::push_span`]).
    pub fn with_capacity() -> Self {
        TraceRecord {
            spans: Vec::with_capacity(MAX_TRACE_SPANS),
            ..TraceRecord::default()
        }
    }

    /// Resets every field, keeping the span vector's capacity.
    pub fn clear(&mut self) {
        self.trace_id = 0;
        self.batch = 0;
        self.beam = 0;
        self.total_ns = 0;
        self.events = 0;
        self.pinned = false;
        self.truncated = 0;
        self.spans.clear();
    }

    /// Appends a span, folding its events into the record's union;
    /// spans past [`MAX_TRACE_SPANS`] are counted as truncated instead
    /// of growing the vector.
    pub fn push_span(&mut self, span: RoundSpan) {
        self.events |= span.events;
        if self.spans.len() < MAX_TRACE_SPANS {
            self.spans.push(span);
        } else {
            self.truncated += 1;
        }
    }

    /// JSON encoding (schema in the module docs). Cold path.
    pub fn to_json(&self) -> Json {
        use crate::inference::KernelTier;
        let names = |events: u32| {
            Json::Arr(
                event_names(events)
                    .into_iter()
                    .map(|n| Json::Str(n.to_string()))
                    .collect(),
            )
        };
        let tiers = |mask: u32| {
            Json::Arr(
                KernelTier::ALL
                    .iter()
                    .filter(|t| mask & (1 << t.index()) != 0)
                    .map(|t| Json::Str(t.short().to_string()))
                    .collect(),
            )
        };
        let spans = self
            .spans
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("shard", Json::Num(s.shard as f64)),
                    ("layer", Json::Num(s.layer as f64)),
                    ("tx_ns", Json::Num(s.tx_ns as f64)),
                    ("round_ns", Json::Num(s.round_ns as f64)),
                    ("wait_ns", Json::Num(s.wait_ns as f64)),
                    ("host_decode_ns", Json::Num(s.host.decode_ns as f64)),
                    ("host_expand_ns", Json::Num(s.host.expand_ns as f64)),
                    ("host_encode_ns", Json::Num(s.host.encode_ns as f64)),
                    ("tiers", tiers(s.host.tiers)),
                    ("events", names(s.events)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("trace_id", Json::Num(self.trace_id as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("beam", Json::Num(self.beam as f64)),
            ("total_ns", Json::Num(self.total_ns as f64)),
            ("pinned", Json::Bool(self.pinned)),
            ("events", names(self.events)),
            ("truncated_spans", Json::Num(self.truncated as f64)),
            ("spans", Json::Arr(spans)),
        ])
    }

    /// One-line human rendering for `metrics --traces` text output.
    pub fn summary(&self) -> String {
        let ev = event_names(self.events).join(",");
        format!(
            "trace {} batch={} beam={} total={:.3}ms spans={}{} {}{}",
            self.trace_id,
            self.batch,
            self.beam,
            self.total_ns as f64 / 1e6,
            self.spans.len(),
            if self.truncated > 0 {
                format!("(+{} truncated)", self.truncated)
            } else {
                String::new()
            },
            if self.pinned { "PINNED" } else { "sampled" },
            if ev.is_empty() {
                String::new()
            } else {
                format!(" [{ev}]")
            },
        )
    }
}

/// Tuning knobs for a [`FlightRecorder`].
#[derive(Clone, Copy, Debug)]
pub struct FlightRecorderConfig {
    /// Ring capacity in records; 0 disables the recorder entirely.
    pub capacity: usize,
    /// Non-tail traces are kept 1 in `sample_every` (≥ 1).
    pub sample_every: u64,
    /// Quantile of the internal latency histogram above which a trace is
    /// pinned.
    pub pin_quantile: f64,
    /// Observations the internal histogram needs before the pin
    /// threshold is live — below the floor everything is sampled, never
    /// pinned (a cold histogram cannot produce a sane p99).
    pub min_samples: u64,
}

impl Default for FlightRecorderConfig {
    fn default() -> Self {
        FlightRecorderConfig {
            capacity: 256,
            sample_every: 8,
            pin_quantile: 0.99,
            min_samples: 64,
        }
    }
}

/// One ring slot: the pooled record plus a packed publish word —
/// bit 63 = pinned, low bits = the write sequence (0 = never written).
struct Slot {
    meta: AtomicU64,
    rec: Mutex<TraceRecord>,
}

const SLOT_PINNED: u64 = 1 << 63;
const SLOT_SEQ: u64 = SLOT_PINNED - 1;

/// A fixed-capacity ring of the last N [`TraceRecord`]s with tail-based
/// retention (module docs). Shared by every serving thread of a
/// coordinator or host; recording is allocation-free and never blocks
/// (per-slot `try_lock`, contended slots drop the sample and count it).
pub struct FlightRecorder {
    slots: Vec<Slot>,
    /// Monotone write-attempt sequence; `seq % capacity` picks the slot.
    head: AtomicU64,
    /// 1-in-N sampling tick for non-pinned traces.
    tick: AtomicU64,
    /// Trace-id sequence ([`FlightRecorder::next_trace_id`]) — one
    /// stream per recorder, so every serving thread sharing it mints
    /// unique ids.
    ids: AtomicU64,
    /// Every observed total feeds this histogram; its live
    /// `pin_quantile` is the pin threshold.
    totals: super::LatencyHistogram,
    recorded: AtomicU64,
    pinned: AtomicU64,
    dropped: AtomicU64,
    cfg: FlightRecorderConfig,
}

impl FlightRecorder {
    /// A recorder with `cfg.capacity` pre-sized slots (every record's
    /// span vector is allocated here, once).
    pub fn new(cfg: FlightRecorderConfig) -> Self {
        let cfg = FlightRecorderConfig {
            sample_every: cfg.sample_every.max(1),
            ..cfg
        };
        FlightRecorder {
            slots: (0..cfg.capacity)
                .map(|_| Slot {
                    meta: AtomicU64::new(0),
                    rec: Mutex::new(TraceRecord::with_capacity()),
                })
                .collect(),
            head: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            ids: AtomicU64::new(0),
            totals: super::LatencyHistogram::new(),
            recorded: AtomicU64::new(0),
            pinned: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            cfg,
        }
    }

    /// Ring capacity in records.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Totals observed so far (every call to [`FlightRecorder::record`],
    /// retained or not).
    pub fn observed(&self) -> u64 {
        self.totals.count()
    }

    /// Mints the next trace id (1-based; serving threads sharing one
    /// recorder share the sequence, so ids never collide).
    pub fn next_trace_id(&self) -> u64 {
        self.ids.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Records retained into the ring so far.
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Records retained as pinned tail traces.
    pub fn pinned(&self) -> u64 {
        self.pinned.load(Ordering::Relaxed)
    }

    /// Retention candidates dropped (slot contention or a protected
    /// pinned occupant) — distinct from traces the 1-in-N sampler never
    /// selected.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The live pin threshold in ms, once the sample floor is met.
    pub fn pin_threshold_ms(&self) -> Option<f64> {
        self.totals
            .quantile_ms_if(self.cfg.pin_quantile, self.cfg.min_samples)
    }

    /// Observes one batch's `total` latency and, if retained (tail-
    /// pinned or 1-in-N sampled), claims a slot and hands its pooled
    /// record to `fill` (already cleared; `total_ns` and `pinned` are
    /// stamped by the recorder). Returns whether the trace was retained.
    ///
    /// Never blocks and never allocates: see the module docs.
    pub fn record(&self, total: Duration, fill: impl FnOnce(&mut TraceRecord)) -> bool {
        if self.slots.is_empty() {
            return false;
        }
        // The pin threshold is computed over *prior* traffic before the
        // current total is folded in — a lone outlier must not raise the
        // quantile it is being compared against.
        let pin = self
            .pin_threshold_ms()
            .is_some_and(|p99| total.as_secs_f64() * 1e3 > p99);
        self.totals.record(total);
        if !pin && self.tick.fetch_add(1, Ordering::Relaxed) % self.cfg.sample_every != 0 {
            return false;
        }
        let seq = self.head.fetch_add(1, Ordering::Relaxed) + 1;
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        // Tail retention: a sampled write never evicts a pinned record
        // until the ring has lapped it twice (age in retained writes).
        let meta = slot.meta.load(Ordering::Acquire);
        if !pin
            && meta & SLOT_PINNED != 0
            && seq.saturating_sub(meta & SLOT_SEQ) <= 2 * self.slots.len() as u64
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let Ok(mut rec) = slot.rec.try_lock() else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        };
        rec.clear();
        fill(&mut rec);
        rec.total_ns = total.as_nanos() as u64;
        rec.pinned = pin;
        drop(rec);
        slot.meta
            .store(if pin { SLOT_PINNED | seq } else { seq }, Ordering::Release);
        self.recorded.fetch_add(1, Ordering::Relaxed);
        if pin {
            self.pinned.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    /// Newest-first copy of the retained records (cold path: allocates,
    /// and skips any slot a writer holds at the instant of the copy).
    pub fn export(&self) -> Vec<TraceRecord> {
        let mut out: Vec<(u64, TraceRecord)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            if slot.meta.load(Ordering::Acquire) & SLOT_SEQ == 0 {
                continue;
            }
            let Ok(rec) = slot.rec.try_lock() else {
                continue;
            };
            // Re-read the sequence under the lock so record + meta agree.
            let seq = slot.meta.load(Ordering::Acquire) & SLOT_SEQ;
            if seq != 0 {
                out.push((seq, rec.clone()));
            }
        }
        out.sort_by(|a, b| b.0.cmp(&a.0));
        out.into_iter().map(|(_, r)| r).collect()
    }

    /// One-line status for stats output.
    pub fn status_line(&self) -> String {
        format!(
            "flight recorder: cap={} observed={} recorded={} pinned={} dropped={} pin_threshold={}",
            self.capacity(),
            self.observed(),
            self.recorded(),
            self.pinned(),
            self.dropped(),
            match self.pin_threshold_ms() {
                Some(ms) => format!("{ms:.3}ms"),
                None => "warming".to_string(),
            }
        )
    }
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("capacity", &self.capacity())
            .field("recorded", &self.recorded())
            .field("pinned", &self.pinned())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_json_has_schema_fields() {
        let t = QueryTrace {
            query_nnz: 8,
            beam: 10,
            topk: 5,
            total_ns: 1000,
            rank_ns: 50,
            layers: vec![LayerTrace {
                layer: 0,
                beam_width: 1,
                candidates: 4,
                expand_ns: 700,
                select_ns: 20,
                method_blocks: [0, 0, 1, 0],
                storage_blocks: [1, 0, 0, 0, 0],
                tier_blocks: [1, 0],
            }],
        };
        let j = t.to_json();
        assert_eq!(j.get("beam").unwrap().as_f64(), Some(10.0));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 1);
        let l0 = &layers[0];
        assert_eq!(l0.get("beam_width").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            l0.get("methods").unwrap().get("hash").unwrap().as_f64(),
            Some(1.0)
        );
        assert!(l0.get("methods").unwrap().get("dense").is_none());
        assert_eq!(
            l0.get("storages").unwrap().get("csc").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            l0.get("tiers").unwrap().get("scalar").unwrap().as_f64(),
            Some(1.0)
        );
        assert!(l0.get("tiers").unwrap().get("simd").is_none());
        // Round-trips through the strict parser.
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    fn span(shard: u32, layer: u32, events: u32) -> RoundSpan {
        RoundSpan {
            shard,
            layer,
            tx_ns: 10,
            round_ns: 1000,
            wait_ns: 5,
            host: HostSpan {
                decode_ns: 100,
                expand_ns: 200,
                encode_ns: 50,
                tiers: 0b01,
            },
            events,
        }
    }

    #[test]
    fn trace_record_json_and_events() {
        let mut rec = TraceRecord::with_capacity();
        rec.trace_id = 7;
        rec.batch = 4;
        rec.beam = 10;
        rec.total_ns = 5000;
        rec.push_span(span(0, 0, EV_FAILOVER));
        rec.push_span(span(1, 0, EV_SPEC_HIT));
        assert_eq!(rec.events, EV_FAILOVER | EV_SPEC_HIT);
        let j = rec.to_json();
        assert_eq!(j.get("trace_id").unwrap().as_f64(), Some(7.0));
        let spans = j.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(
            spans[0].get("host_expand_ns").unwrap().as_f64(),
            Some(200.0)
        );
        let evs = spans[0].get("events").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert!(Json::parse(&j.to_string()).is_ok());
        assert!(rec.summary().contains("trace 7"), "{}", rec.summary());
        // Span cap: overflow counts, never grows.
        for _ in 0..2 * MAX_TRACE_SPANS {
            rec.push_span(span(2, 1, 0));
        }
        assert_eq!(rec.spans.len(), MAX_TRACE_SPANS);
        assert!(rec.truncated > 0);
        assert!(rec.spans.capacity() >= MAX_TRACE_SPANS);
    }

    #[test]
    fn flight_recorder_ring_wraps_and_samples() {
        let rec = FlightRecorder::new(FlightRecorderConfig {
            capacity: 8,
            sample_every: 1,
            ..Default::default()
        });
        for i in 0..100u64 {
            rec.record(Duration::from_micros(500), |r| {
                r.trace_id = i;
                r.push_span(span(0, 0, 0));
            });
        }
        let out = rec.export();
        assert_eq!(out.len(), 8, "ring holds exactly its capacity");
        // Newest first, and the newest writes survived the wrap.
        assert_eq!(out[0].trace_id, 99);
        assert!(out.iter().all(|r| r.trace_id >= 92), "{out:?}");
        assert_eq!(rec.recorded(), 100);
        // 1-in-N sampling actually thins.
        let sparse = FlightRecorder::new(FlightRecorderConfig {
            capacity: 8,
            sample_every: 10,
            ..Default::default()
        });
        for i in 0..100u64 {
            sparse.record(Duration::from_micros(500), |r| r.trace_id = i);
        }
        assert_eq!(sparse.recorded(), 10);
        assert_eq!(sparse.observed(), 100);
    }

    #[test]
    fn flight_recorder_pins_tail_traces() {
        let cfg = FlightRecorderConfig {
            capacity: 16,
            sample_every: 1000, // sampling alone would keep almost nothing
            ..Default::default()
        };
        let rec = FlightRecorder::new(cfg);
        // Warm past the sample floor with fast traces. The threshold is
        // computed over prior traffic, but already-pinned slow traces do
        // land in the histogram — warm enough that four 80 ms outliers
        // cannot drag the p99 rank into their own bucket.
        for i in 0..400u64 {
            rec.record(Duration::from_micros(900 + i % 50), |r| r.trace_id = i);
        }
        assert!(rec.pin_threshold_ms().is_some());
        // Every injected-slow trace must be pinned and retained.
        for i in 0..4u64 {
            let kept = rec.record(Duration::from_millis(80), |r| {
                r.trace_id = 10_000 + i;
            });
            assert!(kept, "slow trace {i} not retained");
        }
        let out = rec.export();
        for i in 0..4u64 {
            let r = out
                .iter()
                .find(|r| r.trace_id == 10_000 + i)
                .unwrap_or_else(|| panic!("slow trace {i} missing from export"));
            assert!(r.pinned, "slow trace {i} retained but not pinned");
        }
        // Fast follow-up samples cannot evict the pinned tails.
        let fast = FlightRecorderConfig {
            capacity: 16,
            sample_every: 1,
            ..Default::default()
        };
        let rec = FlightRecorder::new(fast);
        for i in 0..200u64 {
            rec.record(Duration::from_micros(900), |r| r.trace_id = i);
        }
        assert!(rec.record(Duration::from_millis(80), |r| r.trace_id = 777));
        // Enough sampled writes to lap back onto the pinned slot (but
        // under the two-lap protection window).
        for i in 0..20u64 {
            rec.record(Duration::from_micros(900), |r| r.trace_id = 300 + i);
        }
        assert!(
            rec.export().iter().any(|r| r.trace_id == 777 && r.pinned),
            "pinned tail evicted by sampled writes within one lap"
        );
        assert!(rec.dropped() > 0, "eviction protection never engaged");
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = FlightRecorder::new(FlightRecorderConfig {
            capacity: 0,
            ..Default::default()
        });
        assert!(!rec.record(Duration::from_millis(1), |_| {}));
        assert!(rec.export().is_empty());
        assert_eq!(rec.observed(), 0);
    }
}
