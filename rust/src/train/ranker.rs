//! One-vs-rest logistic ranker training over the label tree (the
//! "logistic-like" rankers of paper eq. 1).
//!
//! For node `Y_i^(l)` the positives are training instances carrying any
//! label under the node; the negatives are instances under the *parent*
//! that are not positives (teacher-forced hard negatives, as in
//! Parabel/PECOS). Rankers are trained by SGD on the logistic loss with
//! L2 regularization applied lazily to touched coordinates, then pruned
//! to sparsity — pruning is what creates the sparse weight matrices MSCM
//! exploits.

use super::cluster::ClusterTree;
use crate::inference::sigmoid;
use crate::sparse::{CscMatrix, CsrMatrix, SparseVec};
use crate::tree::{Layer, XmrModel};
use crate::util::Rng;

/// Ranker-training hyperparameters.
#[derive(Clone, Debug)]
pub struct RankerParams {
    /// SGD epochs per node.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
    /// L2 regularization strength.
    pub l2: f32,
    /// Magnitude threshold below which weights are pruned.
    pub prune_threshold: f32,
    /// Hard cap on nonzeros per column (0 = no cap).
    pub max_col_nnz: usize,
}

impl Default for RankerParams {
    fn default() -> Self {
        Self {
            epochs: 6,
            lr: 0.5,
            l2: 1e-4,
            prune_threshold: 0.01,
            max_col_nnz: 0,
        }
    }
}

/// Trains every layer's rankers and assembles the model.
pub fn train_rankers(
    features: &CsrMatrix,
    labels: &[Vec<u32>],
    tree: &ClusterTree,
    params: &RankerParams,
    seed: u64,
) -> XmrModel {
    let dim = features.cols;
    let n_docs = features.rows;
    // invert: label -> docs
    let num_labels = tree.label_perm.len();
    let mut label_docs: Vec<Vec<u32>> = vec![Vec::new(); num_labels];
    for (doc, ls) in labels.iter().enumerate() {
        for &l in ls {
            if (l as usize) < num_labels {
                label_docs[l as usize].push(doc as u32);
            }
        }
    }

    let mut rng = Rng::seed_from_u64(seed);
    let mut layers: Vec<Layer> = Vec::with_capacity(tree.depth());
    // docs under each node of the previous layer; root = all docs
    let mut parent_docs: Vec<Vec<u32>> = vec![(0..n_docs as u32).collect()];
    for l in 0..tree.depth() {
        let nodes = &tree.node_labels[l];
        let offsets = &tree.layer_offsets[l];
        let mut this_docs: Vec<Vec<u32>> = Vec::with_capacity(nodes.len());
        // gather positives per node
        for node in nodes {
            let mut docs: Vec<u32> = node
                .iter()
                .flat_map(|&lab| label_docs[lab as usize].iter().copied())
                .collect();
            docs.sort_unstable();
            docs.dedup();
            this_docs.push(docs);
        }
        // train one column per node
        let mut cols: Vec<SparseVec> = Vec::with_capacity(nodes.len());
        for p in 0..parent_docs.len() {
            let (c0, c1) = (offsets[p] as usize, offsets[p + 1] as usize);
            for j in c0..c1 {
                let col = train_node(
                    features,
                    &this_docs[j],
                    &parent_docs[p],
                    dim,
                    params,
                    &mut rng,
                );
                cols.push(col);
            }
        }
        layers.push(Layer::new(CscMatrix::from_cols(cols, dim), offsets, true));
        parent_docs = this_docs;
    }
    XmrModel::new(dim, layers)
}

/// Trains one node's logistic ranker.
fn train_node(
    features: &CsrMatrix,
    positives: &[u32],
    parent_pool: &[u32],
    dim: usize,
    params: &RankerParams,
    rng: &mut Rng,
) -> SparseVec {
    // samples: (doc, y)
    let pos_set: std::collections::HashSet<u32> = positives.iter().copied().collect();
    let mut samples: Vec<(u32, f32)> = Vec::with_capacity(parent_pool.len());
    for &d in parent_pool {
        samples.push((d, if pos_set.contains(&d) { 1.0 } else { 0.0 }));
    }
    if samples.is_empty() {
        return SparseVec::new();
    }
    let mut w = vec![0.0f32; dim];
    let mut touched: Vec<u32> = Vec::new();
    let mut is_touched = vec![false; dim];
    for _ in 0..params.epochs {
        rng.shuffle(&mut samples);
        for &(d, y) in &samples {
            let x = features.row(d as usize);
            let mut a = 0.0f32;
            for (&i, &v) in x.indices.iter().zip(x.values) {
                a += w[i as usize] * v;
            }
            let g = sigmoid(a) - y;
            for (&i, &v) in x.indices.iter().zip(x.values) {
                let iu = i as usize;
                w[iu] -= params.lr * (g * v + params.l2 * w[iu]);
                if !is_touched[iu] {
                    is_touched[iu] = true;
                    touched.push(i);
                }
            }
        }
    }
    let mut pairs: Vec<(u32, f32)> = touched
        .into_iter()
        .filter(|&i| w[i as usize].abs() > params.prune_threshold)
        .map(|i| (i, w[i as usize]))
        .collect();
    if params.max_col_nnz > 0 && pairs.len() > params.max_col_nnz {
        pairs.sort_unstable_by(|a, b| b.1.abs().total_cmp(&a.1.abs()));
        pairs.truncate(params.max_col_nnz);
    }
    SparseVec::from_pairs(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::cluster::hierarchical_kmeans;
    use crate::train::pifa::pifa_embeddings;

    /// Two separable classes on features {0} vs {1}.
    fn toy() -> (CsrMatrix, Vec<Vec<u32>>) {
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for i in 0..40 {
            if i % 2 == 0 {
                rows.push(SparseVec::from_pairs(vec![(0, 1.0), (2, 0.3)]));
                labels.push(vec![0u32]);
            } else {
                rows.push(SparseVec::from_pairs(vec![(1, 1.0), (3, 0.3)]));
                labels.push(vec![1u32]);
            }
        }
        (CsrMatrix::from_rows(rows, 4), labels)
    }

    #[test]
    fn learns_separable_rankers() {
        let (x, labels) = toy();
        let emb = pifa_embeddings(&x, &labels, 2);
        let tree = hierarchical_kmeans(&emb, 2, 0);
        let model = train_rankers(&x, &labels, &tree, &RankerParams::default(), 1);
        assert_eq!(model.num_labels(), 2);
        // the column for the node containing label 0 must weight
        // feature 0 positively and feature 1 negatively (or absent)
        let bottom = model.layers.last().unwrap();
        let pos0 = tree.label_perm.iter().position(|&l| l == 0).unwrap();
        let col = bottom.csc.col_owned(pos0);
        let w0 = col
            .indices
            .iter()
            .position(|&i| i == 0)
            .map(|p| col.values[p])
            .unwrap_or(0.0);
        let w1 = col
            .indices
            .iter()
            .position(|&i| i == 1)
            .map(|p| col.values[p])
            .unwrap_or(0.0);
        assert!(w0 > 0.2, "w0 = {w0}");
        assert!(w1 <= 0.0, "w1 = {w1}");
    }

    #[test]
    fn pruning_caps_nnz() {
        let (x, labels) = toy();
        let emb = pifa_embeddings(&x, &labels, 2);
        let tree = hierarchical_kmeans(&emb, 2, 0);
        let params = RankerParams {
            max_col_nnz: 1,
            ..Default::default()
        };
        let model = train_rankers(&x, &labels, &tree, &params, 1);
        for layer in &model.layers {
            for j in 0..layer.csc.cols {
                assert!(layer.csc.col(j).nnz() <= 1);
            }
        }
    }
}
