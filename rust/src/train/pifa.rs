//! PIFA — Positive Instance Feature Aggregation label embeddings
//! (paper §5's label representation; see PECOS).
//!
//! The embedding of label `l` is the L2-normalized sum of the feature
//! vectors of all instances positive for `l`.

use crate::sparse::{CsrMatrix, SparseVec};

/// Computes PIFA embeddings: one sparse row per label.
pub fn pifa_embeddings(
    features: &CsrMatrix,
    labels: &[Vec<u32>],
    num_labels: usize,
) -> Vec<SparseVec> {
    // Accumulate per-label via pair collection (sparse, cache-friendly
    // for the modest corpora the trainer targets).
    let mut acc: Vec<Vec<(u32, f32)>> = vec![Vec::new(); num_labels];
    for (i, ls) in labels.iter().enumerate() {
        let row = features.row(i);
        for &l in ls {
            let a = &mut acc[l as usize];
            a.extend(row.indices.iter().zip(row.values).map(|(&f, &v)| (f, v)));
        }
    }
    acc.into_iter()
        .map(|pairs| {
            let mut v = SparseVec::from_pairs(pairs);
            v.normalize();
            v
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_positive_instances() {
        let x = CsrMatrix::from_rows(
            vec![
                SparseVec::from_pairs(vec![(0, 1.0)]),
                SparseVec::from_pairs(vec![(1, 1.0)]),
                SparseVec::from_pairs(vec![(0, 1.0), (1, 1.0)]),
            ],
            3,
        );
        let labels = vec![vec![0], vec![1], vec![0, 1]];
        let e = pifa_embeddings(&x, &labels, 3);
        // label 0: docs 0,2 → features {0: 2.0, 1: 1.0} normalized
        assert_eq!(e[0].indices, vec![0, 1]);
        assert!(e[0].values[0] > e[0].values[1]);
        assert!((e[0].norm() - 1.0).abs() < 1e-6);
        // label 2 has no positives → zero vector
        assert_eq!(e[2].nnz(), 0);
    }
}
