//! Hierarchical balanced spherical k-means over PIFA label embeddings —
//! builds the label tree (the clustering `Y_i^(l)` of paper §3.1).

use crate::sparse::SparseVec;
use crate::util::Rng;

/// The label tree produced by clustering.
///
/// Layers are top-down; bottom-layer nodes are singleton labels in
/// clustered order, with `label_perm[j]` giving the original label id of
/// bottom column `j`.
#[derive(Clone, Debug)]
pub struct ClusterTree {
    /// Per layer: chunk offsets partitioning that layer's nodes by parent
    /// (layer 0 has a single chunk under the implicit root).
    pub layer_offsets: Vec<Vec<u32>>,
    /// Per layer, per node: sorted original label ids under the node.
    pub node_labels: Vec<Vec<Vec<u32>>>,
    /// Bottom-layer column → original label id.
    pub label_perm: Vec<u32>,
}

impl ClusterTree {
    /// Number of layers (= model depth).
    pub fn depth(&self) -> usize {
        self.layer_offsets.len()
    }

    /// Number of nodes in layer `l`.
    pub fn layer_size(&self, l: usize) -> usize {
        self.node_labels[l].len()
    }
}

/// Splits `members` (label ids) into `k` balanced clusters by spherical
/// k-means with greedy balanced assignment; returns the clusters in a
/// deterministic order.
fn balanced_kmeans(
    emb: &[SparseVec],
    members: &[u32],
    k: usize,
    dim: usize,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    let n = members.len();
    debug_assert!(k >= 2 && n >= k);
    let cap = n.div_ceil(k);
    // init: k distinct random members as centroids
    let picks = rng.sample_distinct(n, k);
    let mut centroids: Vec<Vec<f32>> = picks
        .iter()
        .map(|&p| emb[members[p as usize] as usize].view().to_dense(dim))
        .collect();
    let mut assign = vec![0usize; n];
    for _round in 0..6 {
        // score all (member, centroid) pairs
        let mut scored: Vec<(f32, u32, u16)> = Vec::with_capacity(n * k);
        for (mi, &m) in members.iter().enumerate() {
            let e = emb[m as usize].view();
            for (ci, c) in centroids.iter().enumerate() {
                let mut s = 0.0f32;
                for (&i, &v) in e.indices.iter().zip(e.values) {
                    s += v * c[i as usize];
                }
                scored.push((s, mi as u32, ci as u16));
            }
        }
        // greedy balanced assignment: best similarities first
        scored.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut counts = vec![0usize; k];
        let mut done = vec![false; n];
        let mut assigned = 0;
        for &(_, mi, ci) in &scored {
            let (mi, ci) = (mi as usize, ci as usize);
            if !done[mi] && counts[ci] < cap {
                done[mi] = true;
                counts[ci] += 1;
                assign[mi] = ci;
                assigned += 1;
                if assigned == n {
                    break;
                }
            }
        }
        // recompute centroids (normalized mean of members)
        for c in &mut centroids {
            c.iter_mut().for_each(|v| *v = 0.0);
        }
        for (mi, &m) in members.iter().enumerate() {
            let c = &mut centroids[assign[mi]];
            let e = emb[m as usize].view();
            for (&i, &v) in e.indices.iter().zip(e.values) {
                c[i as usize] += v;
            }
        }
        for c in &mut centroids {
            let norm: f32 = c.iter().map(|v| v * v).sum::<f32>().sqrt();
            if norm > 0.0 {
                c.iter_mut().for_each(|v| *v /= norm);
            }
        }
    }
    let mut out = vec![Vec::new(); k];
    for (mi, &m) in members.iter().enumerate() {
        out[assign[mi]].push(m);
    }
    out.iter_mut().for_each(|g| g.sort_unstable());
    out
}

/// Builds the hierarchical clustering: every group is recursively split
/// into at most `branching` balanced clusters until all groups are
/// singletons. Balanced splits keep group sizes within one of each other,
/// so all leaves land on the same layer (the model's uniform-depth
/// requirement).
pub fn hierarchical_kmeans(emb: &[SparseVec], branching: usize, seed: u64) -> ClusterTree {
    assert!(branching >= 2);
    let num_labels = emb.len();
    assert!(num_labels >= 1);
    let dim = emb
        .iter()
        .flat_map(|e| e.indices.iter().map(|&i| i as usize + 1))
        .max()
        .unwrap_or(1);
    let mut rng = Rng::seed_from_u64(seed);

    let mut layer_offsets: Vec<Vec<u32>> = Vec::new();
    let mut node_labels: Vec<Vec<Vec<u32>>> = Vec::new();
    // current groups, each = (labels under a node of the previous layer)
    let mut current: Vec<Vec<u32>> = vec![(0..num_labels as u32).collect()];
    loop {
        // split each parent group
        let mut offsets: Vec<u32> = vec![0];
        let mut next: Vec<Vec<u32>> = Vec::new();
        for group in &current {
            let children: Vec<Vec<u32>> = if group.len() == 1 {
                vec![group.clone()]
            } else {
                let k = branching.min(group.len());
                balanced_kmeans(emb, group, k, dim, &mut rng)
                    .into_iter()
                    .filter(|g| !g.is_empty())
                    .collect()
            };
            for ch in children {
                next.push(ch);
            }
            offsets.push(next.len() as u32);
        }
        layer_offsets.push(offsets);
        node_labels.push(next.clone());
        let all_single = next.iter().all(|g| g.len() == 1);
        current = next;
        if all_single {
            break;
        }
    }
    let label_perm: Vec<u32> = current.iter().map(|g| g[0]).collect();
    ClusterTree {
        layer_offsets,
        node_labels,
        label_perm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clustered_embeddings(groups: usize, per: usize, dim: usize) -> Vec<SparseVec> {
        // group g occupies features [g*8, g*8+4)
        let mut out = Vec::new();
        for g in 0..groups {
            for i in 0..per {
                let mut v = SparseVec::from_pairs(vec![
                    ((g * 8) as u32, 1.0),
                    ((g * 8 + 1 + i % 3) as u32, 0.5),
                ]);
                v.normalize();
                assert!(((g * 8 + 4) as usize) < dim);
                out.push(v);
            }
        }
        out
    }

    #[test]
    fn tree_structure_invariants() {
        let emb = clustered_embeddings(8, 4, 80);
        let t = hierarchical_kmeans(&emb, 4, 1);
        // bottom layer: singletons, a permutation of labels
        let mut perm = t.label_perm.clone();
        perm.sort_unstable();
        assert_eq!(perm, (0..32).collect::<Vec<u32>>());
        // offsets chain: layer l offsets has layer_size(l-1)+1 entries
        for l in 1..t.depth() {
            assert_eq!(t.layer_offsets[l].len(), t.layer_size(l - 1) + 1);
            assert_eq!(
                *t.layer_offsets[l].last().unwrap() as usize,
                t.layer_size(l)
            );
        }
        // node labels of a parent = union of its children's
        for l in 1..t.depth() {
            for p in 0..t.layer_size(l - 1) {
                let (c0, c1) = (
                    t.layer_offsets[l][p] as usize,
                    t.layer_offsets[l][p + 1] as usize,
                );
                let mut union: Vec<u32> = (c0..c1)
                    .flat_map(|c| t.node_labels[l][c].iter().copied())
                    .collect();
                union.sort_unstable();
                assert_eq!(union, t.node_labels[l - 1][p]);
            }
        }
    }

    #[test]
    fn balanced_sizes() {
        let emb = clustered_embeddings(4, 8, 40);
        let t = hierarchical_kmeans(&emb, 2, 3);
        // top layer: two groups of 16
        assert_eq!(t.layer_size(0), 2);
        for g in &t.node_labels[0] {
            assert_eq!(g.len(), 16);
        }
    }

    #[test]
    fn recovers_planted_clusters() {
        let emb = clustered_embeddings(4, 4, 40);
        let t = hierarchical_kmeans(&emb, 4, 7);
        // the 4 top-layer clusters should be exactly the planted groups
        let mut found = 0;
        for g in &t.node_labels[0] {
            let planted: Vec<Vec<u32>> = (0..4)
                .map(|k| (k * 4..(k + 1) * 4).map(|v| v as u32).collect())
                .collect();
            if planted.contains(g) {
                found += 1;
            }
        }
        assert!(found >= 3, "recovered only {found}/4 planted clusters");
    }

    #[test]
    fn single_label_tree() {
        let emb = vec![SparseVec::from_pairs(vec![(0, 1.0)])];
        let t = hierarchical_kmeans(&emb, 4, 0);
        assert_eq!(t.depth(), 1);
        assert_eq!(t.label_perm, vec![0]);
    }
}
