//! TFIDF featurization of token documents (paper §5's word embedding).

use crate::sparse::{CsrMatrix, SparseVec};

/// A fitted TFIDF vocabulary: smoothed idf per token.
#[derive(Clone, Debug)]
pub struct Tfidf {
    /// Smoothed inverse document frequency per token id.
    pub idf: Vec<f32>,
}

impl Tfidf {
    /// Fits idf over a token-bag corpus with vocabulary size `vocab`.
    /// Uses the standard smoothed formulation `ln((1+n)/(1+df)) + 1`.
    pub fn fit(docs: &[Vec<u32>], vocab: usize) -> Self {
        let mut df = vec![0u32; vocab];
        let mut seen = vec![u32::MAX; vocab];
        for (i, doc) in docs.iter().enumerate() {
            for &t in doc {
                let t = t as usize;
                if seen[t] != i as u32 {
                    seen[t] = i as u32;
                    df[t] += 1;
                }
            }
        }
        let n = docs.len() as f32;
        let idf = df
            .iter()
            .map(|&d| ((1.0 + n) / (1.0 + d as f32)).ln() + 1.0)
            .collect();
        Self { idf }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.idf.len()
    }

    /// Transforms one document into an L2-normalized tf·idf vector.
    /// Tokens outside the fitted vocabulary are ignored (a real query
    /// stream contains unseen terms).
    pub fn transform_doc(&self, doc: &[u32]) -> SparseVec {
        let vocab = self.vocab() as u32;
        let mut pairs: Vec<(u32, f32)> = doc
            .iter()
            .filter(|&&t| t < vocab)
            .map(|&t| (t, 1.0f32))
            .collect();
        let mut v = SparseVec::from_pairs(pairs.drain(..).collect());
        for (i, val) in v.indices.iter().zip(v.values.iter_mut()) {
            *val *= self.idf[*i as usize];
        }
        v.normalize();
        v
    }

    /// Transforms a corpus into a CSR feature matrix.
    pub fn transform(&self, docs: &[Vec<u32>]) -> CsrMatrix {
        let rows = docs.iter().map(|d| self.transform_doc(d)).collect();
        CsrMatrix::from_rows(rows, self.vocab())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idf_down_weights_common_tokens() {
        // token 0 in every doc, token 3 in one doc
        let docs = vec![vec![0, 1], vec![0, 2], vec![0, 3]];
        let t = Tfidf::fit(&docs, 5);
        assert!(t.idf[3] > t.idf[0]);
        // unseen token has the highest idf
        assert!(t.idf[4] >= t.idf[3]);
    }

    #[test]
    fn transform_counts_and_normalizes() {
        let docs = vec![vec![1, 1, 2]];
        let t = Tfidf::fit(&docs, 4);
        let v = t.transform_doc(&docs[0]);
        assert_eq!(v.indices, vec![1, 2]);
        // tf(1) = 2 > tf(2) = 1, same idf → larger weight
        assert!(v.values[0] > v.values[1]);
        assert!((v.norm() - 1.0).abs() < 1e-6);
        let m = t.transform(&docs);
        assert_eq!(m.rows, 1);
        assert_eq!(m.cols, 4);
    }

    #[test]
    fn empty_doc_is_zero_row() {
        let t = Tfidf::fit(&[vec![0]], 2);
        let v = t.transform_doc(&[]);
        assert_eq!(v.nnz(), 0);
    }

    #[test]
    fn out_of_vocabulary_tokens_ignored() {
        let t = Tfidf::fit(&[vec![0, 1]], 2);
        let v = t.transform_doc(&[0, 5, 99]);
        assert_eq!(v.indices, vec![0]);
        assert!((v.norm() - 1.0).abs() < 1e-6);
    }
}
