//! The model-production pipeline (the substrate the paper assumes):
//! TFIDF featurization, PIFA label embeddings, hierarchical balanced
//! k-means clustering, and one-vs-rest logistic ranker training —
//! the same recipe as PECOS (paper §5: "TFIDF word embedding and
//! positive instance feature aggregation (PIFA) for label
//! representations").

pub mod cluster;
pub mod pifa;
pub mod ranker;
pub mod tfidf;

pub use cluster::{hierarchical_kmeans, ClusterTree};
pub use pifa::pifa_embeddings;
pub use ranker::RankerParams;
pub use tfidf::Tfidf;

use crate::sparse::CsrMatrix;
use crate::tree::XmrModel;

/// A trained model plus the clustered-order → original label mapping.
///
/// Tree training reorders labels so that siblings are contiguous columns
/// (which is what makes chunking possible); `label_perm[j]` is the
/// original label id of bottom-layer column `j`.
pub struct TrainedModel {
    /// The XMR tree model (bottom columns in clustered order).
    pub model: XmrModel,
    /// Bottom column → original label id.
    pub label_perm: Vec<u32>,
}

impl TrainedModel {
    /// Maps an engine prediction (bottom column id) to the original label.
    pub fn original_label(&self, column: u32) -> u32 {
        self.label_perm[column as usize]
    }
}

/// Trains a full XMR tree model from features + multi-label annotations.
///
/// 1. PIFA label embeddings from positive instances;
/// 2. hierarchical balanced k-means over label embeddings → tree;
/// 3. per-layer one-vs-rest logistic rankers (positives = instances
///    having a label under the node; negatives = instances under the
///    parent but not the node), pruned to sparsity.
pub fn train_model(
    features: &CsrMatrix,
    labels: &[Vec<u32>],
    num_labels: usize,
    branching: usize,
    params: &RankerParams,
    seed: u64,
) -> TrainedModel {
    assert_eq!(features.rows, labels.len());
    let emb = pifa_embeddings(features, labels, num_labels);
    let tree = hierarchical_kmeans(&emb, branching, seed);
    let model = ranker::train_rankers(features, labels, &tree, params, seed);
    TrainedModel {
        model,
        label_perm: tree.label_perm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusSpec};
    use crate::inference::{EngineConfig, InferenceEngine, IterationMethod, MatmulAlgo};

    /// End-to-end smoke: corpus → TFIDF → trained tree → inference must
    /// rank the true topic highly for held-out documents.
    #[test]
    fn trained_model_ranks_true_labels() {
        let c = Corpus::generate(CorpusSpec {
            docs: 600,
            topics: 16,
            vocab: 2_000,
            max_labels: 1,
            seed: 11,
            ..Default::default()
        });
        let tfidf = Tfidf::fit(&c.docs, 2_000);
        let x = tfidf.transform(&c.docs);
        let (train_n, test_n) = (500, 100);
        let xtrain = x.select_rows(&(0..train_n).collect::<Vec<_>>());
        let trained = train_model(
            &xtrain,
            &c.labels[..train_n],
            16,
            4,
            &RankerParams::default(),
            5,
        );
        assert_eq!(trained.model.num_labels(), 16);
        let perm = trained.label_perm.clone();
        let engine = InferenceEngine::new(
            trained.model,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        );
        let mut hits_at_3 = 0;
        for i in train_n..train_n + test_n {
            let preds = engine.predict(&x.row_owned(i), 4, 3);
            let truth = c.labels[i][0];
            if preds.iter().any(|p| perm[p.label as usize] == truth) {
                hits_at_3 += 1;
            }
        }
        // Topic structure is strong; require well-above-chance ranking
        // (chance P@3 with 16 labels ≈ 19%).
        assert!(
            hits_at_3 > test_n / 2,
            "precision@3 too low: {hits_at_3}/{test_n}"
        );
    }
}
