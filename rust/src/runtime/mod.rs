//! PJRT runtime: loads the AOT-compiled JAX/Pallas layer step
//! (`artifacts/*.hlo.txt`, produced by `python/compile/aot.py`) and
//! executes it from rust.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! Python never runs at serving time: `make artifacts` is a build step,
//! and this module is plain `dlopen`-free rust over the PJRT C API via
//! the `xla` crate.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client plus the executables loaded into it.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

/// One compiled computation ready to execute.
pub struct LoadedComputation {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact path it was loaded from (for logs).
    pub source: String,
}

/// A dense f32 tensor crossing the rust↔XLA boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl Tensor {
    /// Creates a tensor, checking volume.
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>(), "shape mismatch");
        Self { data, dims }
    }

    /// 1-D tensor.
    pub fn vec1(data: Vec<f32>) -> Self {
        let n = data.len();
        Self::new(data, vec![n])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

impl XlaRuntime {
    /// Creates a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Platform string (e.g. "cpu"), for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Loads HLO text from `path` and compiles it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<LoadedComputation> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(LoadedComputation {
            exe,
            source: path.display().to_string(),
        })
    }
}

impl LoadedComputation {
    /// Executes with dense f32 inputs; returns the flattened tuple of
    /// f32 outputs (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = result.to_tuple().context("decompose result tuple")?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.shape()?;
                let dims: Vec<usize> = match &shape {
                    xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                    _ => anyhow::bail!("nested tuple output unsupported"),
                };
                let data = lit.to_vec::<f32>()?;
                Ok(Tensor::new(data, dims))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.dims, vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn tensor_volume_mismatch_panics() {
        Tensor::new(vec![1.0], vec![2, 2]);
    }

    // PJRT-backed tests live in rust/tests/runtime_artifacts.rs — they
    // need `make artifacts` to have produced the HLO files first.
}
