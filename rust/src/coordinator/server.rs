//! Coordinator implementation: router queue, dynamic batcher thread,
//! inference worker pool.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::{CoordinatorConfig, Request, Response, SubmitError};
use crate::inference::InferenceEngine;
use crate::metrics::LatencyHistogram;
use crate::sparse::{CsrMatrix, SparseVec};

/// Aggregated serving statistics.
#[derive(Debug, Default)]
pub struct CoordinatorStats {
    /// Completed requests.
    pub completed: AtomicU64,
    /// Requests shed due to a full queue.
    pub shed: AtomicU64,
    /// Dispatched batches.
    pub batches: AtomicU64,
    /// Sum of batch sizes (mean batch = this / batches).
    pub batched_queries: AtomicU64,
    /// End-to-end latency histogram.
    pub latency: LatencyHistogram,
    /// Queue-wait histogram.
    pub queue_wait: LatencyHistogram,
}

impl CoordinatorStats {
    /// Mean batch size so far.
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// A running serving system (see module docs for the topology).
pub struct Coordinator {
    inner: Arc<Inner>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

struct Inner {
    engine: Arc<InferenceEngine>,
    config: CoordinatorConfig,
    stats: CoordinatorStats,
    queue: Mutex<mpsc::Sender<Request>>,
    queue_len: AtomicU64,
    next_id: AtomicU64,
    shutdown: AtomicBool,
}

impl Coordinator {
    /// Starts the batcher and worker threads.
    pub fn start(engine: Arc<InferenceEngine>, config: CoordinatorConfig) -> Self {
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let inner = Arc::new(Inner {
            engine,
            config: config.clone(),
            stats: CoordinatorStats::default(),
            queue: Mutex::new(req_tx),
            queue_len: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });

        let batcher = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("mscm-batcher".into())
                .spawn(move || batcher_loop(&inner, req_rx, batch_tx))
                .expect("spawn batcher")
        };
        let workers = (0..config.workers.max(1))
            .map(|w| {
                let inner = Arc::clone(&inner);
                let rx = Arc::clone(&batch_rx);
                std::thread::Builder::new()
                    .name(format!("mscm-worker-{w}"))
                    .spawn(move || worker_loop(&inner, &rx))
                    .expect("spawn worker")
            })
            .collect();
        Self {
            inner,
            batcher: Some(batcher),
            workers,
        }
    }

    /// Submits a query; the reply arrives on the returned channel.
    /// Fails fast when the router queue is at capacity (backpressure).
    pub fn submit(&self, query: SparseVec) -> Result<(u64, mpsc::Receiver<Response>), SubmitError> {
        if self.inner.shutdown.load(Ordering::Acquire) {
            return Err(SubmitError::Shutdown);
        }
        if self.inner.queue_len.load(Ordering::Relaxed) >= self.inner.config.queue_capacity as u64 {
            self.inner.stats.shed.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Overloaded);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id,
            query,
            submitted: Instant::now(),
            reply: tx,
        };
        self.inner.queue_len.fetch_add(1, Ordering::Relaxed);
        self.inner
            .queue
            .lock()
            .unwrap()
            .send(req)
            .map_err(|_| SubmitError::Shutdown)?;
        Ok((id, rx))
    }

    /// Convenience: submit and block for the response.
    pub fn query_blocking(&self, query: SparseVec) -> Result<Response, SubmitError> {
        let (_, rx) = self.submit(query)?;
        rx.recv().map_err(|_| SubmitError::Shutdown)
    }

    /// Serving statistics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.inner.stats
    }

    /// Stops accepting work, drains in-flight batches, joins all threads.
    pub fn shutdown(mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        // Dropping the sender wakes the batcher's recv with Err.
        {
            let (dead_tx, _) = mpsc::channel();
            *self.inner.queue.lock().unwrap() = dead_tx;
        }
        if let Some(b) = self.batcher.take() {
            b.join().ok();
        }
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// Dynamic batching: block for the first request, then fill the batch
/// until `max_batch` or `max_batch_delay` since the first arrival.
fn batcher_loop(inner: &Inner, rx: mpsc::Receiver<Request>, tx: mpsc::Sender<Vec<Request>>) {
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped → shutdown
        };
        let deadline = Instant::now() + inner.config.max_batch_delay;
        let mut batch = vec![first];
        while batch.len() < inner.config.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    dispatch(inner, &tx, batch);
                    return;
                }
            }
        }
        dispatch(inner, &tx, batch);
    }
}

fn dispatch(inner: &Inner, tx: &mpsc::Sender<Vec<Request>>, batch: Vec<Request>) {
    inner.stats.batches.fetch_add(1, Ordering::Relaxed);
    inner
        .stats
        .batched_queries
        .fetch_add(batch.len() as u64, Ordering::Relaxed);
    // If workers are gone (shutdown), drop the batch.
    let _ = tx.send(batch);
}

/// Inference worker: pull a batch, run the engine, reply per request.
fn worker_loop(inner: &Inner, rx: &Arc<Mutex<mpsc::Receiver<Vec<Request>>>>) {
    let mut ws = inner.engine.workspace();
    let dim = inner.engine.model().dim;
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return,
            }
        };
        let n = batch.len();
        let dispatch_time = Instant::now();
        let rows: Vec<SparseVec> = batch.iter().map(|r| r.query.clone()).collect();
        let x = CsrMatrix::from_rows(rows, dim);
        let mut out: Vec<Vec<crate::inference::Prediction>> = vec![Vec::new(); n];
        inner.engine.predict_range(
            &x,
            0,
            n,
            inner.config.beam,
            inner.config.topk,
            &mut ws,
            &mut out,
        );
        for (req, preds) in batch.into_iter().zip(out) {
            let queue_time = dispatch_time.duration_since(req.submitted);
            let total_time = req.submitted.elapsed();
            inner.stats.queue_wait.record(queue_time);
            inner.stats.latency.record(total_time);
            inner.stats.completed.fetch_add(1, Ordering::Relaxed);
            inner.queue_len.fetch_sub(1, Ordering::Relaxed);
            // Receiver may have gone away (client timeout) — fine.
            let _ = req.reply.send(Response {
                id: req.id,
                predictions: preds,
                queue_time,
                total_time,
                batch_size: n,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::{EngineConfig, IterationMethod, MatmulAlgo};
    use crate::util::Rng;
    use std::time::Duration;

    fn test_engine() -> Arc<InferenceEngine> {
        let model = crate::tree::test_util::tiny_model(32, 4, 3, 77);
        Arc::new(InferenceEngine::new(
            model,
            EngineConfig {
                algo: MatmulAlgo::Mscm,
                iter: IterationMethod::Hash,
            },
        ))
    }

    fn rand_query(rng: &mut Rng) -> SparseVec {
        SparseVec::from_pairs(
            (0..rng.gen_range(1..12))
                .map(|_| (rng.gen_range(0..32) as u32, rng.gen_f32(-1.0, 1.0)))
                .collect(),
        )
    }

    #[test]
    fn every_request_gets_matching_reply() {
        let engine = test_engine();
        let coord = Coordinator::start(
            Arc::clone(&engine),
            CoordinatorConfig {
                workers: 3,
                max_batch: 8,
                max_batch_delay: Duration::from_micros(200),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(1);
        let mut pending = Vec::new();
        let mut queries = Vec::new();
        for _ in 0..200 {
            let q = rand_query(&mut rng);
            let (id, rx) = coord.submit(q.clone()).unwrap();
            pending.push((id, rx));
            queries.push(q);
        }
        for (i, (id, rx)) in pending.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
            assert_eq!(resp.id, id);
            // result must equal a direct engine call (bitwise)
            let direct = engine.predict(&queries[i], 10, 10);
            assert_eq!(resp.predictions, direct);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
        assert_eq!(coord.stats().completed.load(Ordering::Relaxed), 200);
        assert!(coord.stats().mean_batch() >= 1.0);
        coord.shutdown();
    }

    #[test]
    fn backpressure_sheds_when_full() {
        let engine = test_engine();
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig {
                workers: 1,
                max_batch: 4,
                queue_capacity: 8,
                // long delay so the queue backs up
                max_batch_delay: Duration::from_millis(50),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(2);
        let mut ok = 0;
        let mut shed = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match coord.submit(rand_query(&mut rng)) {
                Ok((_, rx)) => {
                    ok += 1;
                    rxs.push(rx);
                }
                Err(SubmitError::Overloaded) => shed += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(ok > 0);
        assert!(shed > 0, "expected shedding with tiny queue");
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).expect("reply");
        }
        coord.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let engine = test_engine();
        let coord = Coordinator::start(engine, CoordinatorConfig::default());
        let mut rng = Rng::seed_from_u64(3);
        coord.query_blocking(rand_query(&mut rng)).unwrap();
        let stats_completed = coord.stats().completed.load(Ordering::Relaxed);
        assert_eq!(stats_completed, 1);
        coord.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let engine = test_engine();
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig {
                workers: 1,
                max_batch: 32,
                max_batch_delay: Duration::from_millis(20),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(4);
        let rxs: Vec<_> = (0..32)
            .map(|_| coord.submit(rand_query(&mut rng)).unwrap().1)
            .collect();
        let mut max_batch = 0;
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            max_batch = max_batch.max(r.batch_size);
        }
        assert!(max_batch > 1, "no batching happened");
        coord.shutdown();
    }
}
