//! Coordinator implementation: router queue, dynamic batcher thread,
//! inference worker pool — wired together from the generic pieces in
//! [`super::batcher`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{spawn_batcher, WorkerPool};
use super::{CoordinatorConfig, Request, Response, SubmitError};
use crate::inference::InferenceEngine;
use crate::metrics::{LatencyHistogram, ScatterMetrics, Snapshot};
use crate::sparse::{CsrMatrix, SparseVec};

/// Aggregated serving statistics.
#[derive(Debug, Default)]
pub struct CoordinatorStats {
    /// Completed requests.
    pub completed: AtomicU64,
    /// Requests shed due to a full queue.
    pub shed: AtomicU64,
    /// Dispatched batches.
    pub batches: AtomicU64,
    /// Sum of batch sizes (mean batch = this / batches).
    pub batched_queries: AtomicU64,
    /// End-to-end latency histogram.
    pub latency: LatencyHistogram,
    /// Queue-wait histogram.
    pub queue_wait: LatencyHistogram,
    /// Per-shard scatter-round telemetry — `Some` on the sharded
    /// coordinators (one histogram per shard plus the gather join wait),
    /// `None` on the single-engine coordinator, which has no rounds.
    pub scatter: Option<ScatterMetrics>,
}

impl CoordinatorStats {
    /// Stats for a sharded serving stack: scatter-round telemetry over
    /// `num_shards` shards enabled.
    pub fn with_scatter(num_shards: usize) -> Self {
        Self {
            scatter: Some(ScatterMetrics::new(num_shards)),
            ..Default::default()
        }
    }

    /// Mean batch size so far.
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Adds the front-door counters and histograms to `snap` under the
    /// `coordinator.` namespace (scatter telemetry under `scatter.` when
    /// present). Diff two snapshots for windowed serving stats.
    pub fn snapshot_into(&self, snap: &mut Snapshot) {
        let counters = [
            ("coordinator.completed", &self.completed),
            ("coordinator.shed", &self.shed),
            ("coordinator.batches", &self.batches),
            ("coordinator.batched_queries", &self.batched_queries),
        ];
        for (name, c) in counters {
            snap.counters.insert(name.to_string(), c.load(Ordering::Relaxed));
        }
        snap.gauges.insert("coordinator.mean_batch".to_string(), self.mean_batch());
        snap.histograms
            .insert("coordinator.latency".to_string(), self.latency.snapshot());
        snap.histograms
            .insert("coordinator.queue_wait".to_string(), self.queue_wait.snapshot());
        if let Some(sc) = &self.scatter {
            sc.snapshot_into(snap, "scatter");
        }
    }

    /// Point-in-time [`Snapshot`] of the serving statistics.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        self.snapshot_into(&mut snap);
        snap
    }
}

/// The submit-side front door shared by both coordinators: a bounded
/// in-flight counter over an mpsc sender, with shed accounting.
pub(crate) struct Router {
    queue: Mutex<mpsc::Sender<Request>>,
    queue_len: AtomicU64,
    capacity: u64,
    next_id: AtomicU64,
    shutdown: AtomicBool,
}

impl Router {
    pub(crate) fn new(tx: mpsc::Sender<Request>, capacity: usize) -> Self {
        Self {
            queue: Mutex::new(tx),
            queue_len: AtomicU64::new(0),
            capacity: capacity as u64,
            next_id: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Admits a query or fails fast; `stats` records sheds.
    pub(crate) fn submit(
        &self,
        query: SparseVec,
        stats: &CoordinatorStats,
    ) -> Result<(u64, mpsc::Receiver<Response>), SubmitError> {
        if self.shutdown.load(Ordering::Acquire) {
            return Err(SubmitError::Shutdown);
        }
        if self.queue_len.load(Ordering::Relaxed) >= self.capacity {
            stats.shed.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Overloaded);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id,
            query,
            submitted: Instant::now(),
            reply: tx,
        };
        self.queue_len.fetch_add(1, Ordering::Relaxed);
        self.queue
            .lock()
            .unwrap()
            .send(req)
            .map_err(|_| SubmitError::Shutdown)?;
        Ok((id, rx))
    }

    /// One in-flight request finished.
    pub(crate) fn mark_done(&self) {
        self.queue_len.fetch_sub(1, Ordering::Relaxed);
    }

    /// Stops admitting work and disconnects the batcher's input (the
    /// dangling sender swap wakes its `recv` with `Err`).
    pub(crate) fn close(&self) {
        self.shutdown.store(true, Ordering::Release);
        let (dead_tx, _) = mpsc::channel();
        *self.queue.lock().unwrap() = dead_tx;
    }
}

/// A running serving system (see module docs for the topology).
pub struct Coordinator {
    inner: Arc<Inner>,
    batcher: Option<JoinHandle<()>>,
    workers: Option<WorkerPool>,
}

struct Inner {
    engine: Arc<InferenceEngine>,
    config: CoordinatorConfig,
    stats: CoordinatorStats,
    router: Router,
}

impl Coordinator {
    /// Starts the batcher and worker threads.
    pub fn start(engine: Arc<InferenceEngine>, config: CoordinatorConfig) -> Self {
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let inner = Arc::new(Inner {
            engine,
            config: config.clone(),
            stats: CoordinatorStats::default(),
            router: Router::new(req_tx, config.queue_capacity),
        });

        let batcher = {
            let inner = Arc::clone(&inner);
            spawn_batcher(
                "mscm-batcher".into(),
                req_rx,
                batch_tx,
                config.max_batch,
                config.max_batch_delay,
                move |n| {
                    inner.stats.batches.fetch_add(1, Ordering::Relaxed);
                    inner.stats.batched_queries.fetch_add(n as u64, Ordering::Relaxed);
                },
            )
        };
        let workers = {
            let inner = Arc::clone(&inner);
            let engine = Arc::clone(&inner.engine);
            WorkerPool::spawn(
                "mscm-worker",
                config.workers,
                batch_rx,
                move |_w| WorkerState {
                    ws: engine.workspace(),
                    x: CsrMatrix::default(),
                    out: Vec::new(),
                },
                move |state, batch: Vec<Request>| run_batch(&inner, state, batch),
            )
        };
        Self {
            inner,
            batcher: Some(batcher),
            workers: Some(workers),
        }
    }

    /// Submits a query; the reply arrives on the returned channel.
    /// Fails fast when the router queue is at capacity (backpressure).
    pub fn submit(&self, query: SparseVec) -> Result<(u64, mpsc::Receiver<Response>), SubmitError> {
        self.inner.router.submit(query, &self.inner.stats)
    }

    /// Convenience: submit and block for the response.
    pub fn query_blocking(&self, query: SparseVec) -> Result<Response, SubmitError> {
        let (_, rx) = self.submit(query)?;
        rx.recv().map_err(|_| SubmitError::Shutdown)
    }

    /// Serving statistics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.inner.stats
    }

    /// Point-in-time [`Snapshot`] of the serving stats plus, when the
    /// engine was built [`InferenceEngine::with_metrics`], its per-layer
    /// telemetry under the `engine.` prefix.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = self.inner.stats.snapshot();
        if let Some(m) = self.inner.engine.metrics() {
            m.export_into(&mut snap, "engine.");
        }
        snap
    }

    /// Stops accepting new work without joining the pipeline: subsequent
    /// [`Coordinator::submit`] calls fail with [`SubmitError::Shutdown`];
    /// in-flight batches still complete. Call [`Coordinator::shutdown`]
    /// to drain and join.
    pub fn stop(&self) {
        self.inner.router.close();
    }

    /// Stops accepting work, drains in-flight batches, joins all threads.
    pub fn shutdown(mut self) {
        self.stop();
        if let Some(b) = self.batcher.take() {
            b.join().ok();
        }
        if let Some(w) = self.workers.take() {
            w.join();
        }
    }
}

/// Per-worker pooled state: the inference workspace plus batch-lifetime
/// buffers (query matrix, result rows) that recycle across batches so
/// the worker's hot path allocates only what each client must own.
struct WorkerState {
    ws: crate::inference::Workspace,
    x: CsrMatrix,
    out: Vec<Vec<crate::inference::Prediction>>,
}

/// Inference worker body: run the engine over a batch, reply per request.
fn run_batch(inner: &Inner, state: &mut WorkerState, batch: Vec<Request>) {
    let n = batch.len();
    let dispatch_time = Instant::now();
    // Rebuild the pooled query matrix in place — no per-batch row vector
    // or query clones.
    state
        .x
        .assign_rows(inner.engine.model().dim, batch.iter().map(|req| req.query.view()));
    if state.out.len() < n {
        state.out.resize_with(n, Vec::new);
    }
    inner.engine.predict_range(
        &state.x,
        0,
        n,
        inner.config.beam,
        inner.config.topk,
        &mut state.ws,
        &mut state.out,
    );
    for (q, req) in batch.into_iter().enumerate() {
        let queue_time = dispatch_time.duration_since(req.submitted);
        let total_time = req.submitted.elapsed();
        inner.stats.queue_wait.record(queue_time);
        inner.stats.latency.record(total_time);
        inner.stats.completed.fetch_add(1, Ordering::Relaxed);
        inner.router.mark_done();
        // The one unavoidable per-request allocation: the client owns its
        // ranking, so the taken slot starts empty (capacity 0) and
        // predict_range refills it fresh next batch.
        // Receiver may have gone away (client timeout) — fine.
        let _ = req.reply.send(Response {
            id: req.id,
            predictions: std::mem::take(&mut state.out[q]),
            queue_time,
            total_time,
            batch_size: n,
            degraded: false,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::{EngineConfig, IterationMethod, MatmulAlgo};
    use crate::util::Rng;
    use std::time::Duration;

    fn test_engine() -> Arc<InferenceEngine> {
        let model = crate::tree::test_util::tiny_model(32, 4, 3, 77);
        Arc::new(InferenceEngine::new(
            model,
            EngineConfig::new(MatmulAlgo::Mscm, IterationMethod::Hash),
        ))
    }

    fn rand_query(rng: &mut Rng) -> SparseVec {
        SparseVec::from_pairs(
            (0..rng.gen_range(1..12))
                .map(|_| (rng.gen_range(0..32) as u32, rng.gen_f32(-1.0, 1.0)))
                .collect(),
        )
    }

    #[test]
    fn every_request_gets_matching_reply() {
        let engine = test_engine();
        let coord = Coordinator::start(
            Arc::clone(&engine),
            CoordinatorConfig {
                workers: 3,
                max_batch: 8,
                max_batch_delay: Duration::from_micros(200),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(1);
        let mut pending = Vec::new();
        let mut queries = Vec::new();
        for _ in 0..200 {
            let q = rand_query(&mut rng);
            let (id, rx) = coord.submit(q.clone()).unwrap();
            pending.push((id, rx));
            queries.push(q);
        }
        for (i, (id, rx)) in pending.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
            assert_eq!(resp.id, id);
            // result must equal a direct engine call (bitwise)
            let direct = engine.predict(&queries[i], 10, 10);
            assert_eq!(resp.predictions, direct);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
        assert_eq!(coord.stats().completed.load(Ordering::Relaxed), 200);
        assert!(coord.stats().mean_batch() >= 1.0);
        coord.shutdown();
    }

    #[test]
    fn backpressure_sheds_when_full() {
        let engine = test_engine();
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig {
                workers: 1,
                max_batch: 4,
                queue_capacity: 8,
                // long delay so the queue backs up
                max_batch_delay: Duration::from_millis(50),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(2);
        let mut ok = 0;
        let mut shed = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match coord.submit(rand_query(&mut rng)) {
                Ok((_, rx)) => {
                    ok += 1;
                    rxs.push(rx);
                }
                Err(SubmitError::Overloaded) => shed += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(ok > 0);
        assert!(shed > 0, "expected shedding with tiny queue");
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).expect("reply");
        }
        coord.shutdown();
    }

    #[test]
    fn overload_is_deterministic_while_batcher_stalls() {
        // A batcher holding its first request for a long max_batch_delay
        // (and a max_batch it can never reach) keeps every admitted
        // request in flight, so exactly `queue_capacity` submissions are
        // admitted and the next one must shed — no timing dependence.
        let engine = test_engine();
        let cap = 6usize;
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig {
                workers: 1,
                max_batch: cap + 10,
                queue_capacity: cap,
                max_batch_delay: Duration::from_secs(30),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(7);
        let mut rxs = Vec::new();
        for i in 0..cap {
            let (_, rx) = coord
                .submit(rand_query(&mut rng))
                .unwrap_or_else(|e| panic!("submit {i} under capacity failed: {e}"));
            rxs.push(rx);
        }
        match coord.submit(rand_query(&mut rng)) {
            Err(SubmitError::Overloaded) => {}
            other => panic!("expected Overloaded at capacity, got {other:?}"),
        }
        assert_eq!(coord.stats().shed.load(Ordering::Relaxed), 1);
        // Shutdown flushes the batcher's partial batch; every admitted
        // request still gets its reply.
        coord.stop();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).expect("reply after stop");
        }
        coord.shutdown();
    }

    #[test]
    fn stop_rejects_new_work_with_shutdown_error() {
        let engine = test_engine();
        let coord = Coordinator::start(engine, CoordinatorConfig::default());
        let mut rng = Rng::seed_from_u64(3);
        coord.query_blocking(rand_query(&mut rng)).unwrap();
        coord.stop();
        match coord.submit(rand_query(&mut rng)) {
            Err(SubmitError::Shutdown) => {}
            other => panic!("expected Shutdown after stop, got {other:?}"),
        }
        assert_eq!(coord.stats().completed.load(Ordering::Relaxed), 1);
        coord.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let engine = test_engine();
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig {
                workers: 1,
                max_batch: 32,
                max_batch_delay: Duration::from_millis(20),
                ..Default::default()
            },
        );
        let mut rng = Rng::seed_from_u64(4);
        let rxs: Vec<_> = (0..32)
            .map(|_| coord.submit(rand_query(&mut rng)).unwrap().1)
            .collect();
        let mut max_batch = 0;
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            max_batch = max_batch.max(r.batch_size);
        }
        assert!(max_batch > 1, "no batching happened");
        coord.shutdown();
    }
}
