//! Reusable serving-stack building blocks: the dynamic batcher thread and
//! a generic worker pool.
//!
//! Extracted from the single-engine [`super::Coordinator`] so the sharded
//! scatter-gather coordinator ([`crate::shard::ShardedCoordinator`]) can
//! reuse the exact same machinery — per-shard fan-out queues, gather
//! workers and the front batcher are all instances of these two pieces
//! rather than re-implementations.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Spawns the dynamic-batching thread: blocks for the first item, then
/// fills the batch until `max_batch` items or `max_delay` since the first
/// arrival, then forwards the batch. `on_dispatch` observes every batch
/// size (stats hook). Exits when all senders of `rx` are gone, flushing
/// any partial batch first.
pub(crate) fn spawn_batcher<T, F>(
    name: String,
    rx: mpsc::Receiver<T>,
    tx: mpsc::Sender<Vec<T>>,
    max_batch: usize,
    max_delay: Duration,
    on_dispatch: F,
) -> JoinHandle<()>
where
    T: Send + 'static,
    F: Fn(usize) + Send + 'static,
{
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let dispatch = |batch: Vec<T>| {
                on_dispatch(batch.len());
                // Receivers may be gone during shutdown — drop the batch.
                let _ = tx.send(batch);
            };
            loop {
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => return, // all senders dropped → shutdown
                };
                let deadline = Instant::now() + max_delay;
                let mut batch = vec![first];
                while batch.len() < max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            dispatch(batch);
                            return;
                        }
                    }
                }
                dispatch(batch);
            }
        })
        .expect("spawn batcher")
}

/// A pool of worker threads pulling jobs off a shared channel.
///
/// Each worker owns private state built by `init` inside the thread (an
/// inference [`crate::inference::Workspace`] in every current use), so the
/// hot path never locks anything but the shared receiver.
pub(crate) struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawns `workers` threads (at least one). Workers exit when every
    /// sender of the shared channel has been dropped.
    pub(crate) fn spawn<B, S, I, F>(
        name: &str,
        workers: usize,
        rx: Arc<Mutex<mpsc::Receiver<B>>>,
        init: I,
        handler: F,
    ) -> Self
    where
        B: Send + 'static,
        I: Fn(usize) -> S + Send + Sync + 'static,
        F: Fn(&mut S, B) + Send + Sync + 'static,
    {
        let init = Arc::new(init);
        let handler = Arc::new(handler);
        let handles = (0..workers.max(1))
            .map(|w| {
                let rx = Arc::clone(&rx);
                let init = Arc::clone(&init);
                let handler = Arc::clone(&handler);
                std::thread::Builder::new()
                    .name(format!("{name}-{w}"))
                    .spawn(move || {
                        let mut state = init(w);
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                match guard.recv() {
                                    Ok(b) => b,
                                    Err(_) => return,
                                }
                            };
                            handler(&mut state, job);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { handles }
    }

    /// Joins every worker (callers drop the senders first).
    pub(crate) fn join(self) {
        for h in self.handles {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn batcher_groups_and_flushes_on_disconnect() {
        let (tx_in, rx_in) = mpsc::channel::<u32>();
        let (tx_out, rx_out) = mpsc::channel::<Vec<u32>>();
        let sizes = Arc::new(AtomicUsize::new(0));
        let sizes2 = Arc::clone(&sizes);
        let h = spawn_batcher(
            "test-batcher".into(),
            rx_in,
            tx_out,
            8,
            Duration::from_millis(20),
            move |n| {
                sizes2.fetch_add(n, Ordering::Relaxed);
            },
        );
        for i in 0..20 {
            tx_in.send(i).unwrap();
        }
        drop(tx_in);
        h.join().unwrap();
        let mut seen = Vec::new();
        while let Ok(batch) = rx_out.recv() {
            assert!(!batch.is_empty() && batch.len() <= 8);
            seen.extend(batch);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
        assert_eq!(sizes.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn worker_pool_drains_and_joins() {
        let (tx, rx) = mpsc::channel::<u32>();
        let rx = Arc::new(Mutex::new(rx));
        let sum = Arc::new(AtomicUsize::new(0));
        let sum2 = Arc::clone(&sum);
        let pool = WorkerPool::spawn(
            "test-worker",
            3,
            rx,
            |w| w, // per-worker state: its own index
            move |_state, job: u32| {
                sum2.fetch_add(job as usize, Ordering::Relaxed);
            },
        );
        for i in 1..=100u32 {
            tx.send(i).unwrap();
        }
        drop(tx);
        pool.join();
        assert_eq!(sum.load(Ordering::Relaxed), 5050);
    }
}
