//! The L3 serving coordinator: request router, dynamic batcher and worker
//! pool in front of an [`crate::inference::InferenceEngine`].
//!
//! This is the system layer the paper's §6 production deployment implies:
//! queries arrive one at a time (online) but the engine is fastest in
//! batch mode (dense-lookup MSCM amortizes chunk loads across queries —
//! Alg. 3 line 7), so a dynamic batcher groups requests up to a maximum
//! batch size or age before dispatching them to inference workers.
//!
//! Design (std threads; the offline build has no async runtime — and none
//! is needed, the hot path is CPU-bound):
//!
//! ```text
//! clients ──submit──► router queue ──batcher──► batch queue ──► worker 0..W
//!    ▲                                                             │
//!    └───────────────── per-request reply channel ◄────────────────┘
//! ```
//!
//! Backpressure: the router queue is bounded; `submit` fails fast with
//! [`SubmitError::Overloaded`] when the system is saturated rather than
//! queueing unboundedly (availability over latency collapse).

pub(crate) mod batcher;
mod server;

pub use server::{Coordinator, CoordinatorStats};
pub(crate) use server::Router;

use crate::inference::Prediction;
use crate::sparse::SparseVec;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Maximum queries per dispatched batch.
    pub max_batch: usize,
    /// Maximum time the batcher waits to fill a batch once it holds at
    /// least one request.
    pub max_batch_delay: Duration,
    /// Number of inference worker threads.
    pub workers: usize,
    /// Beam width used for every query.
    pub beam: usize,
    /// Labels returned per query.
    pub topk: usize,
    /// Router queue capacity (backpressure bound).
    pub queue_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_batch_delay: Duration::from_micros(500),
            workers: 2,
            beam: 10,
            topk: 10,
            queue_capacity: 4096,
        }
    }
}

/// A query submitted to the coordinator.
#[derive(Debug)]
pub(crate) struct Request {
    pub id: u64,
    pub query: SparseVec,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// A completed query.
#[derive(Clone, Debug)]
pub struct Response {
    /// Request id (as returned by `submit`).
    pub id: u64,
    /// Ranked predictions.
    pub predictions: Vec<Prediction>,
    /// Time spent queued before batch dispatch.
    pub queue_time: Duration,
    /// End-to-end latency (submit → reply send).
    pub total_time: Duration,
    /// Size of the batch this query rode in.
    pub batch_size: usize,
    /// Served from a degraded (partial-shard) remote partition: the
    /// ranking covers only the live shards' label ranges. Always `false`
    /// on in-process coordinators and in the default exact-or-fail
    /// remote mode; only `--allow-partial` serving can set it.
    pub degraded: bool,
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded router queue is full — shed load.
    Overloaded,
    /// The coordinator has shut down.
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "coordinator overloaded (queue full)"),
            SubmitError::Shutdown => write!(f, "coordinator is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}
