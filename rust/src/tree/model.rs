//! Model structures and invariants.

use crate::sparse::{ChunkedMatrix, CscMatrix};

/// One tree layer: the ranker weight matrix `W^(l) ∈ R^{d x L_l}` in both
/// storage formats, plus the per-parent chunk partition.
#[derive(Clone, Debug)]
pub struct Layer {
    /// Vanilla CSC storage (the paper's baseline).
    pub csc: CscMatrix,
    /// The MSCM chunked storage of the same matrix.
    pub chunked: ChunkedMatrix,
}

impl Layer {
    /// Number of clusters `L_l` in this layer.
    pub fn num_nodes(&self) -> usize {
        self.csc.cols
    }

    /// Builds a layer from CSC weights and the sibling-group partition.
    pub fn new(csc: CscMatrix, chunk_offsets: &[u32], with_row_maps: bool) -> Self {
        let chunked = ChunkedMatrix::from_csc(&csc, chunk_offsets, with_row_maps);
        Self { csc, chunked }
    }

    /// Assembles a layer from already-built parts — the `MSCMXMR4`
    /// loaders, whose chunked side comes off the file layout-resolved.
    /// `csc` may be the empty placeholder of an mmap-served layer (see
    /// [`Layer::csc_is_stub`]); real columns are only rebuilt when the
    /// baseline algo actually needs them.
    pub(crate) fn from_parts(csc: CscMatrix, chunked: ChunkedMatrix) -> Self {
        Self { csc, chunked }
    }

    /// Whether `csc` is the empty placeholder of a layout-resolved
    /// (`MSCMXMR4`-mmap) load rather than real baseline columns: right
    /// shape, zero entries, while the chunked side holds the weights.
    pub fn csc_is_stub(&self) -> bool {
        self.csc.nnz() == 0 && self.chunked.nnz() != 0
    }

    /// Column range (child nodes) of parent `j` in this layer.
    #[inline]
    pub fn children_of(&self, j: usize) -> std::ops::Range<usize> {
        self.chunked.chunk_start(j)..self.chunked.chunk_start(j) + self.chunked.chunk_width(j)
    }
}

/// A trained linear XMR tree model.
///
/// `layers[0]` is the top layer (children of the implicit root, a single
/// chunk); `layers.last()` has one column per label.
#[derive(Clone, Debug)]
pub struct XmrModel {
    /// Feature dimension `d`.
    pub dim: usize,
    /// Layers from top (below root) to bottom (labels).
    pub layers: Vec<Layer>,
}

impl XmrModel {
    /// Builds a model, checking structural invariants:
    /// - layer 0 has exactly one chunk (the root's children);
    /// - layer `l` has one chunk per node of layer `l-1`;
    /// - all weight matrices share the feature dimension `d`.
    pub fn new(dim: usize, layers: Vec<Layer>) -> Self {
        assert!(!layers.is_empty(), "model needs at least one layer");
        assert_eq!(
            layers[0].chunked.num_chunks(),
            1,
            "top layer must be a single chunk under the root"
        );
        for l in 1..layers.len() {
            assert_eq!(
                layers[l].chunked.num_chunks(),
                layers[l - 1].num_nodes(),
                "layer {l} must have one chunk per parent node"
            );
        }
        for (l, layer) in layers.iter().enumerate() {
            assert_eq!(layer.csc.rows, dim, "layer {l} dim mismatch");
        }
        Self { dim, layers }
    }

    /// Number of labels (leaves).
    pub fn num_labels(&self) -> usize {
        self.layers.last().unwrap().num_nodes()
    }

    /// Tree depth in ranker layers (paper's `depth - 1`: the root carries
    /// no ranker).
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Builds (or rebuilds) hash row maps on every layer — required before
    /// using the hash iteration method.
    pub fn build_row_maps(&mut self) {
        for l in &mut self.layers {
            l.chunked.build_row_maps();
        }
    }

    /// Drops hash row maps from every layer.
    pub fn drop_row_maps(&mut self) {
        for l in &mut self.layers {
            l.chunked.drop_row_maps();
        }
    }

    /// Structural statistics (Table 5 analogue + memory accounting).
    /// Counted off the chunked side, which always holds the weights —
    /// `csc` may be an empty stub on mmap-served models.
    pub fn stats(&self) -> ModelStats {
        let last = self.layers.last().unwrap();
        let total_nnz: usize = self.layers.iter().map(|l| l.chunked.nnz()).sum();
        let max_branching = self
            .layers
            .iter()
            .flat_map(|l| (0..l.chunked.num_chunks()).map(|c| l.chunked.chunk_width(c)))
            .max()
            .unwrap_or(0);
        ModelStats {
            dim: self.dim,
            num_labels: last.num_nodes(),
            depth: self.depth(),
            total_nnz,
            avg_label_col_nnz: if last.num_nodes() == 0 {
                0.0
            } else {
                last.chunked.nnz() as f64 / last.num_nodes() as f64
            },
            max_branching,
            csc_bytes: self.layers.iter().map(|l| l.csc.memory_bytes()).sum(),
            chunked_bytes: self.layers.iter().map(|l| l.chunked.memory_bytes()).sum(),
        }
    }
}

/// Summary statistics of a model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelStats {
    /// Feature dimension `d`.
    pub dim: usize,
    /// Number of labels `L`.
    pub num_labels: usize,
    /// Ranker layers.
    pub depth: usize,
    /// Stored weight nonzeros across all layers.
    pub total_nnz: usize,
    /// Average nonzeros per label column (bottom layer).
    pub avg_label_col_nnz: f64,
    /// Largest sibling-group width.
    pub max_branching: usize,
    /// Bytes of the CSC representation.
    pub csc_bytes: usize,
    /// Bytes of the chunked representation (incl. hash maps if built).
    pub chunked_bytes: usize,
}

impl std::fmt::Display for ModelStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "d={} L={} depth={} nnz={} avg_col_nnz={:.1} max_B={} csc={}B chunked={}B",
            self.dim,
            self.num_labels,
            self.depth,
            self.total_nnz,
            self.avg_label_col_nnz,
            self.max_branching,
            self.csc_bytes,
            self.chunked_bytes
        )
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;
    use crate::sparse::SparseVec;
    use crate::util::Rng;

    /// A small random model: depth layers, branching B, dense-ish columns.
    pub fn tiny_model(dim: usize, branching: usize, depth: usize, seed: u64) -> XmrModel {
        let mut rng = Rng::seed_from_u64(seed);
        let mut layers = Vec::new();
        let mut parents = 1usize;
        for _ in 0..depth {
            let cols = parents * branching;
            let mut colvecs = Vec::with_capacity(cols);
            for _ in 0..cols {
                let nnz = rng.gen_range(1..(dim / 2).max(3));
                let mut pairs = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    pairs.push((rng.gen_range(0..dim) as u32, rng.gen_f32(-1.0, 1.0)));
                }
                colvecs.push(SparseVec::from_pairs(pairs));
            }
            let csc = crate::sparse::CscMatrix::from_cols(colvecs, dim);
            let offsets: Vec<u32> = (0..=parents).map(|p| (p * branching) as u32).collect();
            layers.push(Layer::new(csc, &offsets, true));
            parents = cols;
        }
        XmrModel::new(dim, layers)
    }
}

#[cfg(test)]
mod tests {
    use super::test_util::tiny_model;
    use super::*;
    use crate::sparse::{CscMatrix, SparseVec};

    #[test]
    fn tiny_model_invariants() {
        let m = tiny_model(32, 3, 3, 7);
        assert_eq!(m.num_labels(), 27);
        assert_eq!(m.depth(), 3);
        assert_eq!(m.layers[1].chunked.num_chunks(), 3);
        assert_eq!(m.layers[2].chunked.num_chunks(), 9);
        let s = m.stats();
        assert_eq!(s.num_labels, 27);
        assert_eq!(s.max_branching, 3);
        assert!(s.chunked_bytes > 0 && s.csc_bytes > 0);
    }

    #[test]
    fn children_ranges_partition_layer() {
        let m = tiny_model(16, 4, 2, 1);
        let l1 = &m.layers[1];
        let mut covered = 0;
        for p in 0..m.layers[0].num_nodes() {
            let r = l1.children_of(p);
            assert_eq!(r.start, covered);
            covered = r.end;
        }
        assert_eq!(covered, l1.num_nodes());
    }

    #[test]
    #[should_panic(expected = "one chunk per parent")]
    fn mismatched_layers_panic() {
        let dim = 4;
        let col = || SparseVec::from_pairs(vec![(0, 1.0)]);
        let l0 = Layer::new(CscMatrix::from_cols(vec![col(), col()], dim), &[0, 2], false);
        // layer 1 with 3 chunks but layer 0 has 2 nodes
        let l1 = Layer::new(
            CscMatrix::from_cols(vec![col(), col(), col()], dim),
            &[0, 1, 2, 3],
            false,
        );
        XmrModel::new(dim, vec![l0, l1]);
    }
}
