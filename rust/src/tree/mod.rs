//! The linear XMR tree model (paper §3).
//!
//! A model is a stack of layers; layer `l` holds one sparse ranker column
//! per cluster `Y_i^(l)`, stored both as CSC (the vanilla baseline format)
//! and as the chunked MSCM format. The chunk boundaries of layer `l+1`
//! encode the cluster indicator matrix `C^(l)` (eq. 4): the children of
//! node `j` of layer `l` are exactly the columns of chunk `j` of layer
//! `l+1`.

mod io;
mod model;

pub use io::{load_model, save_model};
pub(crate) use io::{
    read_f32s, read_model_body, read_u16s, read_u32s, read_u64, read_u64s, write_f32s,
    write_model_body, write_u16s, write_u32s, write_u64, write_u64s,
};
pub use model::{Layer, ModelStats, XmrModel};

#[cfg(test)]
pub(crate) use model::test_util;
