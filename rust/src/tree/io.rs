//! Binary model serialization.
//!
//! Format (little-endian):
//! ```text
//! magic  u64  = 0x4d53_434d_584d_5231 ("MSCMXMR1")
//! dim    u64
//! layers u64
//! per layer:
//!   cols        u64
//!   num_chunks  u64
//!   chunk_offsets: (num_chunks+1) x u32
//!   nnz         u64
//!   indptr:     (cols+1) x u64
//!   indices:    nnz x u32
//!   values:     nnz x f32
//! ```
//! Only the CSC payload is stored; the chunked representation (and
//! optional hash maps) is rebuilt at load time.
//!
//! The header-less model body (everything after `magic`) is exposed
//! crate-internally as [`write_model_body`] / [`read_model_body`] so that
//! versioned envelope formats — currently the shard format of
//! [`crate::shard`] — can embed a model payload without re-implementing
//! the layer codec.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::model::{Layer, XmrModel};
use crate::sparse::CscMatrix;

const MAGIC: u64 = 0x4d53_434d_584d_5231;

pub(crate) fn write_u64(w: &mut impl Write, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub(crate) fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Serialization buffer size: arrays are staged through a bounded scratch
/// so huge layers never materialize a second full-size byte copy.
const IO_CHUNK_BYTES: usize = 64 * 1024;

/// A fixed-width scalar with a little-endian byte encoding — the one
/// place the array codec knows about element types.
trait LeScalar: Copy {
    const WIDTH: usize;
    fn put(self, buf: &mut Vec<u8>);
    fn take(bytes: &[u8]) -> Self;
}

impl LeScalar for u32 {
    const WIDTH: usize = 4;
    fn put(self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&self.to_le_bytes());
    }
    fn take(bytes: &[u8]) -> Self {
        u32::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl LeScalar for u16 {
    const WIDTH: usize = 2;
    fn put(self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&self.to_le_bytes());
    }
    fn take(bytes: &[u8]) -> Self {
        u16::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl LeScalar for f32 {
    const WIDTH: usize = 4;
    fn put(self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&self.to_le_bytes());
    }
    fn take(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().unwrap())
    }
}

/// `usize` values travel as `u64` on the wire (the CSC `indptr`).
impl LeScalar for usize {
    const WIDTH: usize = 8;
    fn put(self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&(self as u64).to_le_bytes());
    }
    fn take(bytes: &[u8]) -> Self {
        u64::from_le_bytes(bytes.try_into().unwrap()) as usize
    }
}

/// Writes a scalar slice as one little-endian byte stream, staging
/// through a 64 KiB buffer (one `write_all` per buffer fill, not per
/// element).
fn write_scalars<T: LeScalar>(w: &mut impl Write, vs: &[T]) -> io::Result<()> {
    let mut buf = Vec::with_capacity(IO_CHUNK_BYTES.min(vs.len() * T::WIDTH));
    for chunk in vs.chunks(IO_CHUNK_BYTES / T::WIDTH) {
        buf.clear();
        for &v in chunk {
            v.put(&mut buf);
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Reads `n` scalars written by [`write_scalars`], staging through the
/// same bounded buffer.
fn read_scalars<T: LeScalar>(r: &mut impl Read, n: usize) -> io::Result<Vec<T>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; IO_CHUNK_BYTES.min(n.max(1) * T::WIDTH)];
    let mut left = n;
    while left > 0 {
        let take = left.min(buf.len() / T::WIDTH);
        let bytes = &mut buf[..take * T::WIDTH];
        r.read_exact(bytes)?;
        out.extend(bytes.chunks_exact(T::WIDTH).map(T::take));
        left -= take;
    }
    Ok(out)
}

pub(crate) fn write_u32s(w: &mut impl Write, vs: &[u32]) -> io::Result<()> {
    write_scalars(w, vs)
}

pub(crate) fn read_u32s(r: &mut impl Read, n: usize) -> io::Result<Vec<u32>> {
    read_scalars(r, n)
}

pub(crate) fn write_u16s(w: &mut impl Write, vs: &[u16]) -> io::Result<()> {
    write_scalars(w, vs)
}

pub(crate) fn read_u16s(r: &mut impl Read, n: usize) -> io::Result<Vec<u16>> {
    read_scalars(r, n)
}

pub(crate) fn write_f32s(w: &mut impl Write, vs: &[f32]) -> io::Result<()> {
    write_scalars(w, vs)
}

pub(crate) fn read_f32s(r: &mut impl Read, n: usize) -> io::Result<Vec<f32>> {
    read_scalars(r, n)
}

pub(crate) fn write_u64s(w: &mut impl Write, vs: &[usize]) -> io::Result<()> {
    write_scalars(w, vs)
}

pub(crate) fn read_u64s(r: &mut impl Read, n: usize) -> io::Result<Vec<usize>> {
    read_scalars(r, n)
}

/// Writes the header-less model payload (`dim` onward).
pub(crate) fn write_model_body(w: &mut impl Write, model: &XmrModel) -> io::Result<()> {
    write_u64(w, model.dim as u64)?;
    write_u64(w, model.layers.len() as u64)?;
    for layer in &model.layers {
        let csc = &layer.csc;
        write_u64(w, csc.cols as u64)?;
        write_u64(w, layer.chunked.num_chunks() as u64)?;
        write_u32s(w, &layer.chunked.chunk_offsets)?;
        write_u64(w, csc.nnz() as u64)?;
        write_u64s(w, &csc.indptr)?;
        write_u32s(w, &csc.indices)?;
        write_f32s(w, &csc.values)?;
    }
    Ok(())
}

/// Reads the header-less model payload written by [`write_model_body`],
/// rebuilding the chunked representation (with hash row maps when
/// `with_row_maps`).
pub(crate) fn read_model_body(r: &mut impl Read, with_row_maps: bool) -> io::Result<XmrModel> {
    let dim = read_u64(r)? as usize;
    let nlayers = read_u64(r)? as usize;
    let mut layers = Vec::with_capacity(nlayers);
    for _ in 0..nlayers {
        let cols = read_u64(r)? as usize;
        let num_chunks = read_u64(r)? as usize;
        let chunk_offsets = read_u32s(r, num_chunks + 1)?;
        let nnz = read_u64(r)? as usize;
        let indptr = read_u64s(r, cols + 1)?;
        let indices = read_u32s(r, nnz)?;
        let values = read_f32s(r, nnz)?;
        let csc = CscMatrix {
            rows: dim,
            cols,
            indptr,
            indices,
            values,
        };
        layers.push(Layer::new(csc, &chunk_offsets, with_row_maps));
    }
    Ok(XmrModel::new(dim, layers))
}

/// Saves a model to `path`.
pub fn save_model(model: &XmrModel, path: impl AsRef<Path>) -> io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    write_u64(&mut w, MAGIC)?;
    write_model_body(&mut w, model)?;
    w.flush()
}

/// Loads a model from `path`, rebuilding the chunked representation
/// (with hash row maps when `with_row_maps`).
pub fn load_model(path: impl AsRef<Path>, with_row_maps: bool) -> io::Result<XmrModel> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    if read_u64(&mut r)? != MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "not an MSCM-XMR model file",
        ));
    }
    read_model_body(&mut r, with_row_maps)
}

#[cfg(test)]
mod tests {
    use super::super::model::test_util::tiny_model;
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let m = tiny_model(24, 4, 3, 42);
        let dir = crate::util::temp_dir("model-io");
        let path = dir.join("model.bin");
        save_model(&m, &path).unwrap();
        let m2 = load_model(&path, true).unwrap();
        assert_eq!(m2.dim, m.dim);
        assert_eq!(m2.depth(), m.depth());
        for (a, b) in m.layers.iter().zip(&m2.layers) {
            assert_eq!(a.csc, b.csc);
            assert_eq!(a.chunked.chunk_offsets, b.chunked.chunk_offsets);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn reject_garbage_file() {
        let dir = crate::util::temp_dir("model-io");
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"not a model at all............").unwrap();
        assert!(load_model(&path, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn scalar_arrays_round_trip_buffered() {
        // Exercise the chunked staging paths with sizes straddling the
        // 64 KiB buffer boundary.
        for n in [0usize, 1, 7, 16 * 1024, 16 * 1024 + 3, 40_000] {
            let us: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
            let fs: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 7.0).collect();
            let ps: Vec<usize> = (0..n).map(|i| i * 3).collect();
            let mut buf = Vec::new();
            write_u32s(&mut buf, &us).unwrap();
            write_f32s(&mut buf, &fs).unwrap();
            write_u64s(&mut buf, &ps).unwrap();
            let mut r = std::io::Cursor::new(buf);
            assert_eq!(read_u32s(&mut r, n).unwrap(), us, "n={n}");
            assert_eq!(read_f32s(&mut r, n).unwrap(), fs, "n={n}");
            assert_eq!(read_u64s(&mut r, n).unwrap(), ps, "n={n}");
        }
    }
}
