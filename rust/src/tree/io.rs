//! Binary model serialization.
//!
//! Format (little-endian):
//! ```text
//! magic  u64  = 0x4d53_434d_584d_5231 ("MSCMXMR1")
//! dim    u64
//! layers u64
//! per layer:
//!   cols        u64
//!   num_chunks  u64
//!   chunk_offsets: (num_chunks+1) x u32
//!   nnz         u64
//!   indptr:     (cols+1) x u64
//!   indices:    nnz x u32
//!   values:     nnz x f32
//! ```
//! Only the CSC payload is stored; the chunked representation (and
//! optional hash maps) is rebuilt at load time.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::model::{Layer, XmrModel};
use crate::sparse::CscMatrix;

const MAGIC: u64 = 0x4d53_434d_584d_5231;

fn write_u64(w: &mut impl Write, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_u32s(w: &mut impl Write, vs: &[u32]) -> io::Result<()> {
    for v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32s(r: &mut impl Read, n: usize) -> io::Result<Vec<u32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn write_f32s(w: &mut impl Write, vs: &[f32]) -> io::Result<()> {
    for v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Saves a model to `path`.
pub fn save_model(model: &XmrModel, path: impl AsRef<Path>) -> io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    write_u64(&mut w, MAGIC)?;
    write_u64(&mut w, model.dim as u64)?;
    write_u64(&mut w, model.layers.len() as u64)?;
    for layer in &model.layers {
        let csc = &layer.csc;
        write_u64(&mut w, csc.cols as u64)?;
        write_u64(&mut w, layer.chunked.num_chunks() as u64)?;
        write_u32s(&mut w, &layer.chunked.chunk_offsets)?;
        write_u64(&mut w, csc.nnz() as u64)?;
        for &p in &csc.indptr {
            write_u64(&mut w, p as u64)?;
        }
        write_u32s(&mut w, &csc.indices)?;
        write_f32s(&mut w, &csc.values)?;
    }
    w.flush()
}

/// Loads a model from `path`, rebuilding the chunked representation
/// (with hash row maps when `with_row_maps`).
pub fn load_model(path: impl AsRef<Path>, with_row_maps: bool) -> io::Result<XmrModel> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    if read_u64(&mut r)? != MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "not an MSCM-XMR model file",
        ));
    }
    let dim = read_u64(&mut r)? as usize;
    let nlayers = read_u64(&mut r)? as usize;
    let mut layers = Vec::with_capacity(nlayers);
    for _ in 0..nlayers {
        let cols = read_u64(&mut r)? as usize;
        let num_chunks = read_u64(&mut r)? as usize;
        let chunk_offsets = read_u32s(&mut r, num_chunks + 1)?;
        let nnz = read_u64(&mut r)? as usize;
        let mut indptr = Vec::with_capacity(cols + 1);
        for _ in 0..=cols {
            indptr.push(read_u64(&mut r)? as usize);
        }
        let indices = read_u32s(&mut r, nnz)?;
        let values = read_f32s(&mut r, nnz)?;
        let csc = CscMatrix {
            rows: dim,
            cols,
            indptr,
            indices,
            values,
        };
        layers.push(Layer::new(csc, &chunk_offsets, with_row_maps));
    }
    Ok(XmrModel::new(dim, layers))
}

#[cfg(test)]
mod tests {
    use super::super::model::test_util::tiny_model;
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let m = tiny_model(24, 4, 3, 42);
        let dir = crate::util::temp_dir("model-io");
        let path = dir.join("model.bin");
        save_model(&m, &path).unwrap();
        let m2 = load_model(&path, true).unwrap();
        assert_eq!(m2.dim, m.dim);
        assert_eq!(m2.depth(), m.depth());
        for (a, b) in m.layers.iter().zip(&m2.layers) {
            assert_eq!(a.csc, b.csc);
            assert_eq!(a.chunked.chunk_offsets, b.chunked.chunk_offsets);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn reject_garbage_file() {
        let dir = crate::util::temp_dir("model-io");
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"not a model at all............").unwrap();
        assert!(load_model(&path, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
