//! Topic-model corpus generator — the labeled-text substrate for the
//! training pipeline (TFIDF → PIFA → clustering → ranker fitting).
//!
//! Documents are bags of token ids drawn from a mixture of their topics'
//! token distributions and a background Zipf distribution; each document
//! is labeled with the topics that generated it. Topics with nearby ids
//! share tokens, so hierarchical clustering has real structure to find —
//! this is the synthetic stand-in for the product-title corpora behind
//! the paper's semantic search application.

use crate::util::rng::{Rng, Zipf};

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    /// Vocabulary size (token universe).
    pub vocab: usize,
    /// Number of topics = number of labels.
    pub topics: usize,
    /// Number of documents.
    pub docs: usize,
    /// Mean tokens per document.
    pub doc_len: usize,
    /// Tokens private to each topic's core distribution.
    pub tokens_per_topic: usize,
    /// Probability a token comes from the topic (vs background noise).
    pub topic_affinity: f64,
    /// Labels per document (1..=this).
    pub max_labels: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        Self {
            vocab: 5_000,
            topics: 64,
            docs: 2_000,
            doc_len: 40,
            tokens_per_topic: 30,
            topic_affinity: 0.7,
            max_labels: 2,
            seed: 42,
        }
    }
}

/// A generated corpus: token documents plus label sets.
#[derive(Clone, Debug)]
pub struct Corpus {
    /// The generating spec.
    pub spec: CorpusSpec,
    /// Documents as token-id bags (with repetition).
    pub docs: Vec<Vec<u32>>,
    /// Label (topic) ids per document.
    pub labels: Vec<Vec<u32>>,
}

impl Corpus {
    /// Generates a corpus from `spec`.
    pub fn generate(spec: CorpusSpec) -> Self {
        let mut rng = Rng::seed_from_u64(spec.seed);
        let background = Zipf::new(spec.vocab, 1.0);
        // Topic token pools: contiguous-ish regions with overlap between
        // neighbouring topics (so clustering finds a hierarchy).
        let pools: Vec<Vec<u32>> = (0..spec.topics)
            .map(|t| {
                let stride = spec.vocab / (spec.topics + 1);
                let base = t * stride;
                let mut pool: Vec<u32> = (0..spec.tokens_per_topic)
                    .map(|k| ((base + k * stride / spec.tokens_per_topic.max(1)) % spec.vocab) as u32)
                    .collect();
                // plus a few random tokens to avoid perfect separability
                for _ in 0..spec.tokens_per_topic / 4 {
                    pool.push(rng.gen_range(0..spec.vocab) as u32);
                }
                pool
            })
            .collect();
        let mut docs = Vec::with_capacity(spec.docs);
        let mut labels = Vec::with_capacity(spec.docs);
        for _ in 0..spec.docs {
            let nlabels = rng.gen_range(1..spec.max_labels + 1);
            let mut doc_topics: Vec<u32> = Vec::with_capacity(nlabels);
            // correlated labels: a primary topic plus neighbours
            let primary = rng.gen_range(0..spec.topics);
            doc_topics.push(primary as u32);
            for _ in 1..nlabels {
                let nb = (primary + rng.gen_range(0..3)).min(spec.topics - 1);
                if !doc_topics.contains(&(nb as u32)) {
                    doc_topics.push(nb as u32);
                }
            }
            let len = rng.gen_range(spec.doc_len / 2..spec.doc_len * 3 / 2 + 1);
            let mut doc = Vec::with_capacity(len);
            for _ in 0..len {
                if rng.gen_bool(spec.topic_affinity) {
                    let t = doc_topics[rng.gen_range(0..doc_topics.len())] as usize;
                    doc.push(pools[t][rng.gen_range(0..pools[t].len())]);
                } else {
                    doc.push(background.sample(&mut rng) as u32);
                }
            }
            docs.push(doc);
            labels.push(doc_topics);
        }
        Self { spec, docs, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shape() {
        let c = Corpus::generate(CorpusSpec {
            docs: 100,
            ..Default::default()
        });
        assert_eq!(c.docs.len(), 100);
        assert_eq!(c.labels.len(), 100);
        assert!(c.docs.iter().all(|d| !d.is_empty()));
        assert!(c.labels.iter().all(|l| !l.is_empty()));
        assert!(c
            .docs
            .iter()
            .flatten()
            .all(|&t| (t as usize) < c.spec.vocab));
    }

    #[test]
    fn same_topic_docs_share_tokens() {
        let c = Corpus::generate(CorpusSpec {
            docs: 400,
            topics: 8,
            max_labels: 1,
            seed: 7,
            ..Default::default()
        });
        // average token overlap within topic vs across topics
        let doc_set = |i: usize| -> std::collections::HashSet<u32> {
            c.docs[i].iter().copied().collect()
        };
        let mut within = 0.0;
        let mut across = 0.0;
        let mut wn = 0;
        let mut an = 0;
        for i in 0..100 {
            for j in (i + 1)..100 {
                let inter = doc_set(i).intersection(&doc_set(j)).count() as f64;
                if c.labels[i][0] == c.labels[j][0] {
                    within += inter;
                    wn += 1;
                } else {
                    across += inter;
                    an += 1;
                }
            }
        }
        if wn > 0 && an > 0 {
            assert!(within / wn as f64 > across / an as f64 * 1.5);
        }
    }
}
