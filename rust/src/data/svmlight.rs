//! Loader/saver for the extreme-classification repository's SVMLight-like
//! multi-label format (the format of the paper's six public datasets):
//!
//! ```text
//! <num_points> <num_features> <num_labels>      # optional header
//! l1,l2,...  f1:v1 f2:v2 ...
//! ```
//!
//! With this, the real eurlex/amazoncat/wiki/amazon datasets drop
//! straight into the benchmark harness when available.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::sparse::{CsrMatrix, SparseVec};

/// A loaded multi-label dataset: features plus per-row label sets.
#[derive(Clone, Debug)]
pub struct SvmlightData {
    /// Feature matrix, one row per data point.
    pub features: CsrMatrix,
    /// Labels per data point.
    pub labels: Vec<Vec<u32>>,
    /// Total number of distinct labels (from header or max seen + 1).
    pub num_labels: usize,
}

/// Loads a dataset. A leading `n d L` header line is honoured if present;
/// otherwise dimensions are inferred.
pub fn load_svmlight(path: impl AsRef<Path>) -> std::io::Result<SvmlightData> {
    let f = std::fs::File::open(path)?;
    let mut lines = BufReader::new(f).lines();
    let mut rows: Vec<SparseVec> = Vec::new();
    let mut labels: Vec<Vec<u32>> = Vec::new();
    let mut dim = 0usize;
    let mut num_labels = 0usize;
    let mut header_dim: Option<(usize, usize)> = None;

    let mut first = true;
    while let Some(line) = lines.next() {
        let line = line?;
        let line = line.trim();
        if line.starts_with('#') {
            continue;
        }
        // A blank line is a data point with no labels and no features
        // (that is how `save_svmlight` serializes an empty row).
        if line.is_empty() {
            if !first {
                rows.push(SparseVec::new());
                labels.push(Vec::new());
            }
            continue;
        }
        // Header: exactly three integer tokens, no ':' or ','.
        if first {
            first = false;
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.len() == 3 && !line.contains(':') && !line.contains(',') {
                if let (Ok(_n), Ok(d), Ok(l)) = (
                    toks[0].parse::<usize>(),
                    toks[1].parse::<usize>(),
                    toks[2].parse::<usize>(),
                ) {
                    header_dim = Some((d, l));
                    continue;
                }
            }
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().unwrap_or("");
        let mut row_labels = Vec::new();
        // A first token without ':' is the label list; with ':' the row
        // has no labels and the token is a feature.
        let mut pending_feature: Option<&str> = None;
        if label_tok.contains(':') {
            pending_feature = Some(label_tok);
        } else if !label_tok.is_empty() {
            for l in label_tok.split(',') {
                if let Ok(v) = l.parse::<u32>() {
                    num_labels = num_labels.max(v as usize + 1);
                    row_labels.push(v);
                }
            }
        }
        let mut pairs: Vec<(u32, f32)> = Vec::new();
        let push_feat = |tok: &str, dim: &mut usize, pairs: &mut Vec<(u32, f32)>| {
            if let Some((i, v)) = tok.split_once(':') {
                if let (Ok(i), Ok(v)) = (i.parse::<u32>(), v.parse::<f32>()) {
                    *dim = (*dim).max(i as usize + 1);
                    pairs.push((i, v));
                }
            }
        };
        if let Some(tok) = pending_feature {
            push_feat(tok, &mut dim, &mut pairs);
        }
        for tok in parts {
            push_feat(tok, &mut dim, &mut pairs);
        }
        rows.push(SparseVec::from_pairs(pairs));
        labels.push(row_labels);
    }
    if let Some((d, l)) = header_dim {
        dim = dim.max(d);
        num_labels = num_labels.max(l);
    }
    Ok(SvmlightData {
        features: CsrMatrix::from_rows(rows, dim),
        labels,
        num_labels,
    })
}

/// Saves a dataset with header.
pub fn save_svmlight(data: &SvmlightData, path: impl AsRef<Path>) -> std::io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    writeln!(
        w,
        "{} {} {}",
        data.features.rows, data.features.cols, data.num_labels
    )?;
    for i in 0..data.features.rows {
        let lbls: Vec<String> = data.labels[i].iter().map(|l| l.to_string()).collect();
        write!(w, "{}", lbls.join(","))?;
        let row = data.features.row(i);
        for (&f, &v) in row.indices.iter().zip(row.values) {
            write!(w, " {f}:{v}")?;
        }
        writeln!(w)?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let data = SvmlightData {
            features: CsrMatrix::from_rows(
                vec![
                    SparseVec::from_pairs(vec![(0, 1.5), (7, -2.0)]),
                    SparseVec::from_pairs(vec![(3, 0.25)]),
                    SparseVec::new(),
                ],
                10,
            ),
            labels: vec![vec![1, 4], vec![0], vec![]],
            num_labels: 5,
        };
        let dir = crate::util::temp_dir("svmlight");
        let path = dir.join("data.txt");
        save_svmlight(&data, &path).unwrap();
        let loaded = load_svmlight(&path).unwrap();
        assert_eq!(loaded.features, data.features);
        assert_eq!(loaded.labels, data.labels);
        assert_eq!(loaded.num_labels, 5);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn parses_headerless_and_comments() {
        let dir = crate::util::temp_dir("svmlight");
        let path = dir.join("raw.txt");
        std::fs::write(&path, "# comment\n2,3 1:0.5 4:1.0\n0 2:2.0\n").unwrap();
        let d = load_svmlight(&path).unwrap();
        assert_eq!(d.features.rows, 2);
        assert_eq!(d.features.cols, 5);
        assert_eq!(d.labels[0], vec![2, 3]);
        assert_eq!(d.num_labels, 4);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn parses_unlabeled_rows() {
        let dir = crate::util::temp_dir("svmlight");
        let path = dir.join("u.txt");
        std::fs::write(&path, "1:1.0 2:2.0\n").unwrap();
        let d = load_svmlight(&path).unwrap();
        assert_eq!(d.features.rows, 1);
        assert_eq!(d.features.row(0).indices, &[1, 2]);
        assert!(d.labels[0].is_empty());
        std::fs::remove_dir_all(dir).ok();
    }
}
