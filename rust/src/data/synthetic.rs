//! Synthetic models and query streams with the structural statistics of
//! the paper's six benchmark datasets (Table 5).
//!
//! MSCM's speedup is a function of sparsity *structure*, not semantics:
//! what matters is the feature dimension `d`, label count `L`, nonzeros
//! per query and per weight column, the power-law popularity of features
//! (so query and weight supports actually intersect), the tree branching
//! factor, and — critically for chunking (paper §4 item 2) — how much
//! support sibling columns share. The generator exposes exactly those
//! knobs.
//!
//! Sibling similarity is produced the way tree training produces it: all
//! children of a parent draw most of their support from a common
//! per-parent feature pool (itself seeded by the parent's own support, so
//! the correlation decays up the tree exactly as in PIFA-clustered
//! models).

use crate::sparse::{CscMatrix, CsrMatrix, SparseVec};
use crate::tree::{Layer, XmrModel};
use crate::util::rng::{Rng, Zipf};

/// Structural description of one benchmark dataset / model family.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Dataset name (paper's naming).
    pub name: &'static str,
    /// Feature dimension `d` as used *here* (possibly scaled down).
    pub dim: usize,
    /// Label count `L` as used here.
    pub num_labels: usize,
    /// Paper's original feature dimension (Table 5).
    pub paper_dim: usize,
    /// Paper's original label count (Table 5).
    pub paper_labels: usize,
    /// Mean nonzeros per query (TFIDF document length effect).
    pub query_nnz: usize,
    /// Mean nonzeros per ranker column after pruning.
    pub col_nnz: usize,
    /// Fraction of a child column's support drawn from the shared
    /// per-parent pool (sibling similarity, §4 item 2).
    pub sibling_overlap: f64,
    /// Zipf exponent of feature popularity.
    pub zipf_theta: f64,
}

/// The six-dataset suite of Table 5, scaled to laptop-class memory.
///
/// `scale` divides both `d` and `L` of the larger datasets (1 = paper
/// scale). The default suite used by the benchmarks is `paper_suite(10)`
/// for the three large datasets and full scale for the three small ones;
/// scaling is recorded in the returned specs and in EXPERIMENTS.md.
pub fn paper_suite(scale: usize) -> Vec<DatasetSpec> {
    let s = scale.max(1);
    let sc = |v: usize| (v / s).max(1024);
    vec![
        DatasetSpec {
            name: "eurlex-4k",
            dim: 5_000,
            num_labels: 3_956,
            paper_dim: 5_000,
            paper_labels: 3_956,
            query_nnz: 236,
            col_nnz: 400,
            sibling_overlap: 0.7,
            zipf_theta: 0.9,
        },
        DatasetSpec {
            name: "amazoncat-13k",
            dim: 203_882,
            num_labels: 13_330,
            paper_dim: 203_882,
            paper_labels: 13_330,
            query_nnz: 71,
            col_nnz: 160,
            sibling_overlap: 0.65,
            zipf_theta: 1.0,
        },
        DatasetSpec {
            name: "wiki10-31k",
            dim: 101_938,
            num_labels: 30_938,
            paper_dim: 101_938,
            paper_labels: 30_938,
            query_nnz: 673,
            col_nnz: 110,
            sibling_overlap: 0.6,
            zipf_theta: 1.0,
        },
        DatasetSpec {
            name: "wiki-500k",
            dim: sc(2_381_304),
            num_labels: sc(501_070),
            paper_dim: 2_381_304,
            paper_labels: 501_070,
            query_nnz: 117,
            col_nnz: 140,
            sibling_overlap: 0.6,
            zipf_theta: 1.05,
        },
        DatasetSpec {
            name: "amazon-670k",
            dim: sc(135_909),
            num_labels: sc(670_091),
            paper_dim: 135_909,
            paper_labels: 670_091,
            query_nnz: 75,
            col_nnz: 120,
            sibling_overlap: 0.6,
            zipf_theta: 1.0,
        },
        DatasetSpec {
            name: "amazon-3m",
            dim: sc(337_067),
            num_labels: sc(2_812_281),
            paper_dim: 337_067,
            paper_labels: 2_812_281,
            query_nnz: 36,
            col_nnz: 80,
            sibling_overlap: 0.55,
            zipf_theta: 1.0,
        },
    ]
}

/// A generated model plus matching query stream.
pub struct SyntheticDataset {
    /// The spec this was generated from.
    pub spec: DatasetSpec,
    /// Branching factor used for the tree.
    pub branching: usize,
    /// The model.
    pub model: XmrModel,
    /// Test queries (TFIDF-like, L2-normalized rows).
    pub queries: CsrMatrix,
}

/// Layer sizes bottom-up: `L`, then `ceil(L/B)` repeatedly until one
/// parent group remains, returned top-down (excluding the root).
pub fn layer_sizes(num_labels: usize, branching: usize) -> Vec<usize> {
    assert!(branching >= 2);
    let mut sizes = vec![num_labels];
    while *sizes.last().unwrap() > branching {
        let prev = *sizes.last().unwrap();
        sizes.push(prev.div_ceil(branching));
    }
    sizes.reverse();
    sizes
}

/// Contiguous near-even partition of `n` children among `parents` chunks,
/// as chunk offsets (length `parents + 1`).
pub fn even_offsets(n: usize, parents: usize) -> Vec<u32> {
    let mut offsets = Vec::with_capacity(parents + 1);
    for p in 0..=parents {
        offsets.push(((p * n) / parents) as u32);
    }
    offsets
}

/// Contiguous partition of `n` children among `weights.len()` parents
/// with chunk widths proportional to the weights, every parent getting at
/// least one child (requires `n >= weights.len()`). Returned as chunk
/// offsets (length `weights.len() + 1`).
pub fn weighted_offsets(n: usize, weights: &[f64]) -> Vec<u32> {
    let parents = weights.len();
    assert!(parents >= 1 && n >= parents, "need >= 1 child per parent");
    let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    let mut offsets = Vec::with_capacity(parents + 1);
    offsets.push(0u32);
    let mut acc = 0.0f64;
    for (p, w) in weights.iter().enumerate() {
        acc += w.max(0.0);
        let ideal = if total > 0.0 {
            (acc / total * n as f64).round() as usize
        } else {
            (p + 1) * n / parents
        };
        let prev = *offsets.last().unwrap() as usize;
        // Every parent keeps >= 1 child, and enough children remain for
        // the parents still to be placed.
        let b = ideal.clamp(prev + 1, n - (parents - 1 - p));
        offsets.push(b as u32);
    }
    *offsets.last_mut().unwrap() = n as u32;
    offsets
}

/// Generates a model with the spec's structural statistics.
///
/// Built top-down; each parent's children sample `sibling_overlap` of
/// their support from a shared pool seeded with the parent's own support
/// and refilled from the Zipf feature-popularity law.
pub fn synth_model(spec: &DatasetSpec, branching: usize, seed: u64) -> XmrModel {
    let mut rng = Rng::seed_from_u64(seed);
    let zipf = Zipf::new(spec.dim, spec.zipf_theta);
    let sizes = layer_sizes(spec.num_labels, branching);
    let mut layers: Vec<Layer> = Vec::with_capacity(sizes.len());
    // Support of each node in the previous layer (seeds the child pools).
    let mut parent_supports: Vec<Vec<u32>> = vec![Vec::new()];
    for (li, &nl) in sizes.iter().enumerate() {
        let parents = parent_supports.len();
        let offsets = even_offsets(nl, parents);
        // Upper layers get denser columns (they summarize many labels),
        // bottom layer gets spec.col_nnz — mirroring trained PECOS models.
        let depth_boost = 1 << (sizes.len() - 1 - li).min(3);
        let col_nnz = (spec.col_nnz * depth_boost).min(spec.dim / 2).max(4);
        let mut cols: Vec<SparseVec> = Vec::with_capacity(nl);
        let mut supports: Vec<Vec<u32>> = Vec::with_capacity(nl);
        for p in 0..parents {
            let (c0, c1) = (offsets[p] as usize, offsets[p + 1] as usize);
            let width = c1 - c0;
            if width == 0 {
                continue;
            }
            // Shared per-parent pool: the parent's own support plus fresh
            // Zipf draws, ~2x the column nnz budget.
            let pool_target = (col_nnz * 2).min(spec.dim);
            let mut pool: Vec<u32> = parent_supports[p].clone();
            while pool.len() < pool_target {
                pool.push(zipf.sample(&mut rng) as u32);
            }
            pool.sort_unstable();
            pool.dedup();
            for _ in 0..width {
                let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(col_nnz);
                for _ in 0..col_nnz {
                    let f = if rng.gen_bool(spec.sibling_overlap) && !pool.is_empty() {
                        pool[rng.gen_range(0..pool.len())]
                    } else {
                        zipf.sample(&mut rng) as u32
                    };
                    pairs.push((f, rng.gen_normal() / (col_nnz as f32).sqrt()));
                }
                let col = SparseVec::from_pairs(pairs);
                supports.push(col.indices.clone());
                cols.push(col);
            }
        }
        let csc = CscMatrix::from_cols(cols, spec.dim);
        layers.push(Layer::new(csc, &offsets, true));
        parent_supports = supports;
    }
    XmrModel::new(spec.dim, layers)
}

/// Generates a **deliberately skewed** model: root child `i`'s subtree
/// carries a geometric weight `skew^i` (0 < `skew` <= 1), and both the
/// subtree's share of every deeper layer's nodes *and* its column density
/// scale with that weight — heavy subtrees get wide, dense chunks and
/// many labels; light subtrees get narrow, sparse chunks and few. This is
/// the adversarial shape for (a) count-even shard partitions (residency
/// imbalance) and (b) any single global iteration method (the planner's
/// per-chunk win).
pub fn synth_model_skewed(spec: &DatasetSpec, branching: usize, seed: u64, skew: f64) -> XmrModel {
    assert!(skew > 0.0 && skew <= 1.0, "skew must be in (0, 1]");
    let mut rng = Rng::seed_from_u64(seed);
    let zipf = Zipf::new(spec.dim, spec.zipf_theta);
    let sizes = layer_sizes(spec.num_labels, branching);
    let mut layers: Vec<Layer> = Vec::with_capacity(sizes.len());
    let mut parent_supports: Vec<Vec<u32>> = vec![Vec::new()];
    // Weight of each previous-layer node: the root's children take the
    // geometric profile, every deeper node inherits its subtree's weight.
    let mut parent_weights: Vec<f64> = vec![1.0];
    for (li, &nl) in sizes.iter().enumerate() {
        let parents = parent_supports.len();
        let offsets = weighted_offsets(nl, &parent_weights);
        let depth_boost = 1 << (sizes.len() - 1 - li).min(3);
        let max_w = parent_weights.iter().cloned().fold(f64::MIN, f64::max);
        let mut cols: Vec<SparseVec> = Vec::with_capacity(nl);
        let mut supports: Vec<Vec<u32>> = Vec::with_capacity(nl);
        let mut weights: Vec<f64> = Vec::with_capacity(nl);
        for p in 0..parents {
            let (c0, c1) = (offsets[p] as usize, offsets[p + 1] as usize);
            let wp = parent_weights[p];
            // Column density scales 4x between the lightest and heaviest
            // subtree.
            let density = 0.25 + 0.75 * (wp / max_w);
            let col_nnz = ((spec.col_nnz * depth_boost) as f64 * density) as usize;
            let col_nnz = col_nnz.clamp(2, (spec.dim / 2).max(2));
            let pool_target = (col_nnz * 2).min(spec.dim);
            let mut pool: Vec<u32> = parent_supports[p].clone();
            while pool.len() < pool_target {
                pool.push(zipf.sample(&mut rng) as u32);
            }
            pool.sort_unstable();
            pool.dedup();
            for ci in 0..c1 - c0 {
                let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(col_nnz);
                for _ in 0..col_nnz {
                    let f = if rng.gen_bool(spec.sibling_overlap) && !pool.is_empty() {
                        pool[rng.gen_range(0..pool.len())]
                    } else {
                        zipf.sample(&mut rng) as u32
                    };
                    pairs.push((f, rng.gen_normal() / (col_nnz as f32).sqrt()));
                }
                let col = SparseVec::from_pairs(pairs);
                supports.push(col.indices.clone());
                cols.push(col);
                weights.push(if li == 0 { skew.powi((c0 + ci) as i32) } else { wp });
            }
        }
        let csc = CscMatrix::from_cols(cols, spec.dim);
        layers.push(Layer::new(csc, &offsets, true));
        parent_supports = supports;
        parent_weights = weights;
    }
    XmrModel::new(spec.dim, layers)
}

/// Generates `n` TFIDF-like queries: features drawn from the same Zipf
/// popularity law (so supports overlap with the model's), positive
/// values, rows L2-normalized.
pub fn synth_queries(spec: &DatasetSpec, n: usize, seed: u64) -> CsrMatrix {
    let mut rng = Rng::seed_from_u64(seed ^ 0x9E37_79B9);
    let zipf = Zipf::new(spec.dim, spec.zipf_theta);
    let rows: Vec<SparseVec> = (0..n)
        .map(|_| {
            // Document lengths are roughly log-normal; vary ±50%.
            let lo = (spec.query_nnz / 2).max(1);
            let hi = spec.query_nnz * 3 / 2 + 2;
            let nnz = rng.gen_range(lo..hi).min(spec.dim);
            let mut pairs = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                let f = zipf.sample(&mut rng) as u32;
                // TFIDF values: positive, heavier tail for rare terms.
                pairs.push((f, 0.1 + rng.gen_f64().powi(2) as f32));
            }
            let mut v = SparseVec::from_pairs(pairs);
            v.normalize();
            v
        })
        .collect();
    CsrMatrix::from_rows(rows, spec.dim)
}

/// Generates the full dataset (model + queries).
pub fn synth_dataset(
    spec: &DatasetSpec,
    branching: usize,
    n_queries: usize,
    seed: u64,
) -> SyntheticDataset {
    SyntheticDataset {
        spec: spec.clone(),
        branching,
        model: synth_model(spec, branching, seed),
        queries: synth_queries(spec, n_queries, seed),
    }
}

/// Measures average sibling support overlap (Jaccard over chunk columns) —
/// validates that generated models actually have the §4-item-2 property.
pub fn measured_sibling_overlap(model: &XmrModel) -> f64 {
    let layer = model.layers.last().unwrap();
    let mut total = 0.0f64;
    let mut count = 0usize;
    for c in 0..layer.chunked.num_chunks().min(200) {
        let start = layer.chunked.chunk_start(c);
        let width = layer.chunked.chunk_width(c);
        if width < 2 {
            continue;
        }
        let a = layer.csc.col(start);
        let b = layer.csc.col(start + 1);
        let inter = a
            .indices
            .iter()
            .filter(|i| b.indices.binary_search(i).is_ok())
            .count();
        let union = a.nnz() + b.nnz() - inter;
        if union > 0 {
            total += inter as f64 / union as f64;
            count += 1;
        }
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> DatasetSpec {
        DatasetSpec {
            name: "test-1k",
            dim: 2_000,
            num_labels: 1_000,
            paper_dim: 2_000,
            paper_labels: 1_000,
            query_nnz: 40,
            col_nnz: 20,
            sibling_overlap: 0.7,
            zipf_theta: 1.0,
        }
    }

    #[test]
    fn layer_sizes_shape() {
        assert_eq!(layer_sizes(1000, 10), vec![10, 100, 1000]);
        assert_eq!(layer_sizes(27, 3), vec![3, 9, 27]);
        assert_eq!(layer_sizes(5, 8), vec![5]);
        // uneven
        let s = layer_sizes(1001, 10);
        assert_eq!(*s.last().unwrap(), 1001);
        assert!(s[0] <= 10 && s[0] >= 2);
    }

    #[test]
    fn even_offsets_partition() {
        let o = even_offsets(10, 3);
        assert_eq!(o, vec![0, 3, 6, 10]);
        let o = even_offsets(9, 3);
        assert_eq!(o, vec![0, 3, 6, 9]);
    }

    #[test]
    fn weighted_offsets_follow_weights_and_cover() {
        let o = weighted_offsets(12, &[3.0, 1.0]);
        assert_eq!(o, vec![0, 9, 12]);
        // every parent keeps at least one child under extreme skew
        let o = weighted_offsets(4, &[1000.0, 1.0, 1.0, 1.0]);
        assert_eq!(o, vec![0, 1, 2, 3, 4]);
        // degenerate all-zero weights fall back to an even split
        let o = weighted_offsets(6, &[0.0, 0.0, 0.0]);
        assert_eq!(*o.last().unwrap(), 6);
        assert!(o.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn skewed_model_is_actually_skewed() {
        let spec = small_spec();
        // branching 6 -> layer sizes [5, 28, 167, 1000]: 5 root children
        let m = synth_model_skewed(&spec, 6, 5, 0.5);
        assert_eq!(m.num_labels(), spec.num_labels);
        assert_eq!(m.dim, spec.dim);
        // Per-root-subtree nnz must decay: first subtree much heavier
        // than the last (both wider and denser).
        let r = m.layers[0].num_nodes();
        assert!(r >= 4, "want several root children, got {r}");
        let nnz_of = |root: usize| -> usize {
            let (mut lo, mut hi) = (root, root + 1);
            let mut total = 0usize;
            for (li, layer) in m.layers.iter().enumerate() {
                let (c0, c1) = if li == 0 {
                    (lo, hi)
                } else {
                    let offs = &layer.chunked.chunk_offsets;
                    (offs[lo] as usize, offs[hi] as usize)
                };
                total += layer.csc.indptr[c1] - layer.csc.indptr[c0];
                (lo, hi) = (c0, c1);
            }
            total
        };
        let first = nnz_of(0);
        let last = nnz_of(r - 1);
        assert!(
            first as f64 > 3.0 * last as f64,
            "skew too weak: first={first} last={last}"
        );
        // determinism
        let m2 = synth_model_skewed(&spec, 6, 5, 0.5);
        for (a, b) in m.layers.iter().zip(&m2.layers) {
            assert_eq!(a.csc, b.csc);
        }
    }

    #[test]
    fn synth_model_structure() {
        let spec = small_spec();
        let m = synth_model(&spec, 8, 1);
        assert_eq!(m.num_labels(), 1000);
        assert_eq!(m.dim, 2000);
        let stats = m.stats();
        // bottom-layer columns near the nnz budget (dedup may shave a few)
        assert!(stats.avg_label_col_nnz > spec.col_nnz as f64 * 0.5);
        assert!(stats.avg_label_col_nnz <= spec.col_nnz as f64 + 1.0);
        // branching bounded
        assert!(stats.max_branching <= 9);
    }

    #[test]
    fn synth_model_is_deterministic() {
        let spec = small_spec();
        let a = synth_model(&spec, 4, 7);
        let b = synth_model(&spec, 4, 7);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.csc, y.csc);
        }
    }

    #[test]
    fn sibling_overlap_present() {
        let spec = small_spec();
        let m = synth_model(&spec, 8, 3);
        let overlap = measured_sibling_overlap(&m);
        assert!(overlap > 0.15, "sibling overlap too low: {overlap}");
    }

    #[test]
    fn queries_normalized_and_overlapping() {
        let spec = small_spec();
        let q = synth_queries(&spec, 50, 9);
        assert_eq!(q.rows, 50);
        for i in 0..q.rows {
            let r = q.row(i);
            if !r.is_empty() {
                let n: f32 = r.values.iter().map(|v| v * v).sum();
                assert!((n - 1.0).abs() < 1e-4);
            }
        }
        // queries must intersect model supports for benchmarks to be fair
        let m = synth_model(&spec, 8, 3);
        let layer = m.layers.last().unwrap();
        let mut hits = 0;
        for i in 0..q.rows {
            if q.row(i).dot_marching(layer.csc.col(i % layer.csc.cols)) != 0.0 {
                hits += 1;
            }
        }
        assert!(hits > 10, "queries rarely intersect weights: {hits}/50");
    }

    #[test]
    fn paper_suite_scaling() {
        let full = paper_suite(1);
        assert_eq!(full.len(), 6);
        assert_eq!(full[5].num_labels, 2_812_281);
        let scaled = paper_suite(10);
        assert_eq!(scaled[0].num_labels, 3_956); // small stays full
        assert_eq!(scaled[5].num_labels, 281_228);
        assert!(scaled[3].dim < full[3].dim);
    }
}
