//! Dataset substrate.
//!
//! The paper benchmarks on six public XMC datasets (Table 5) and one
//! proprietary 100M-product semantic search model (§6). Neither is
//! shippable here (multi-GB downloads / proprietary), so this module
//! provides:
//!
//! - [`svmlight`] — a loader/saver for the extreme-classification
//!   repository's SVMLight-like format, so the real datasets drop in when
//!   available;
//! - [`synthetic`] — generators that synthesize models and query streams
//!   with the *structural statistics* that drive MSCM performance
//!   (feature dimension, label count, per-query/per-column nnz, power-law
//!   feature popularity, sibling support overlap) for each of the six
//!   benchmarks, scaled to fit this machine;
//! - [`enterprise`] — the §6 enterprise-scale model synthesizer;
//! - [`corpus`] — a topic-model corpus generator that exercises the full
//!   training pipeline (TFIDF → PIFA → clustering → rankers).
//!
//! DESIGN.md §5 documents why these substitutions preserve the paper's
//! measured behaviour.

pub mod corpus;
pub mod enterprise;
pub mod svmlight;
pub mod synthetic;

pub use corpus::{Corpus, CorpusSpec};
pub use enterprise::EnterpriseSpec;
pub use svmlight::{load_svmlight, save_svmlight, SvmlightData};
pub use synthetic::{paper_suite, DatasetSpec, SyntheticDataset};
