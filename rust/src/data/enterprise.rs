//! Enterprise-scale semantic product search synthesizer (paper §6).
//!
//! The paper's production model has L = 100M products and d = 4M TFIDF
//! features with branching factor 32, evaluated single-threaded in batch
//! mode on an X1 AWS instance (≈2 TB RAM). That model is proprietary and
//! that machine is not this one, so this module synthesizes the same
//! *shape* at a configurable scale factor. Per-query latency under beam
//! search depends on beam width × branching × depth × nnz densities —
//! all preserved — so the MSCM-vs-baseline latency *ratio* (the 8×
//! headline) is testable at any scale; EXPERIMENTS.md records the scale
//! used.

use super::synthetic::{synth_model, synth_queries, DatasetSpec};
use crate::sparse::CsrMatrix;
use crate::tree::XmrModel;

/// Parameters for the enterprise model.
#[derive(Clone, Debug)]
pub struct EnterpriseSpec {
    /// Number of products (labels). Paper: 100M. Default here: 1M
    /// (scale factor 100, recorded in EXPERIMENTS.md).
    pub num_labels: usize,
    /// TFIDF feature dimension. Paper: 4M. Default here: 400K.
    pub dim: usize,
    /// Tree branching factor (paper: 32).
    pub branching: usize,
    /// Nonzeros per ranker column after pruning.
    pub col_nnz: usize,
    /// Nonzeros per query (short search queries, not documents).
    pub query_nnz: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for EnterpriseSpec {
    fn default() -> Self {
        Self {
            num_labels: 1_000_000,
            dim: 400_000,
            branching: 32,
            col_nnz: 24,
            query_nnz: 12,
            seed: 0xE17E_2021,
        }
    }
}

impl EnterpriseSpec {
    /// Scale factor relative to the paper's 100M-label model.
    pub fn scale_factor(&self) -> f64 {
        100_000_000.0 / self.num_labels as f64
    }

    fn dataset_spec(&self) -> DatasetSpec {
        DatasetSpec {
            name: "enterprise-search",
            dim: self.dim,
            num_labels: self.num_labels,
            paper_dim: 4_000_000,
            paper_labels: 100_000_000,
            query_nnz: self.query_nnz,
            col_nnz: self.col_nnz,
            sibling_overlap: 0.6,
            zipf_theta: 1.05,
        }
    }

    /// Synthesizes the model (this is the expensive step; ~1–2 GB at the
    /// default 1M-label scale).
    pub fn build_model(&self) -> XmrModel {
        synth_model(&self.dataset_spec(), self.branching, self.seed)
    }

    /// Synthesizes a query stream (product-search queries are much
    /// shorter than documents).
    pub fn build_queries(&self, n: usize) -> CsrMatrix {
        synth_queries(&self.dataset_spec(), n, self.seed ^ 0x51EA_4C4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_enterprise_model_builds() {
        let spec = EnterpriseSpec {
            num_labels: 20_000,
            dim: 30_000,
            branching: 32,
            col_nnz: 16,
            query_nnz: 8,
            seed: 3,
        };
        let m = spec.build_model();
        assert_eq!(m.num_labels(), 20_000);
        let s = m.stats();
        assert!(s.max_branching <= 32);
        assert!((spec.scale_factor() - 5000.0).abs() < 1.0);
        let q = spec.build_queries(10);
        assert_eq!(q.rows, 10);
    }
}
