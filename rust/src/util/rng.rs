//! A small deterministic PRNG (xoshiro256**) with the sampling helpers the
//! data generators and randomized tests need.

/// Seedable xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeds via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`; `n > 0`. Debiased via rejection.
    #[inline]
    pub fn gen_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `lo..hi` (half-open, `hi > lo`).
    #[inline]
    pub fn gen_range(&mut self, r: std::ops::Range<usize>) -> usize {
        r.start + self.gen_below((r.end - r.start) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn gen_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Samples `k` distinct values from `0..n` (k ≤ n), sorted ascending.
    /// Uses Floyd's algorithm — O(k) memory, no O(n) allocation, which
    /// matters when sampling features from `d` in the millions.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        debug_assert!(k <= n);
        let mut set = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_below(j as u64 + 1) as usize;
            let pick = if set.contains(&t) { j } else { t };
            set.insert(pick);
            out.push(pick as u32);
        }
        out.sort_unstable();
        out
    }
}

/// Zipf(θ) sampler over `{0, …, n-1}` via inverse-CDF on a precomputed
/// table — models the power-law label/feature popularity of XMC datasets.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Builds the sampler (O(n) table; n up to ~10M is fine).
    pub fn new(n: usize, theta: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(theta);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    /// Draws one rank (0 = most popular).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.gen_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..1000 {
            let v = rng.gen_range(3..17);
            assert!((3..17).contains(&v));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut rng = Rng::seed_from_u64(2);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[rng.gen_range(0..8)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn sample_distinct_is_distinct_and_sorted() {
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..50 {
            let s = rng.sample_distinct(100, 30);
            assert_eq!(s.len(), 30);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&v| v < 100));
        }
        // full draw
        let s = rng.sample_distinct(5, 5);
        assert_eq!(s, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(4);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_popular() {
        let mut rng = Rng::seed_from_u64(5);
        let z = Zipf::new(100, 1.0);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from_u64(6);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
