//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Wall-clock measurement with warmup, fixed iteration budget and robust
//! summary statistics; every bench binary and the table/figure
//! reproduction harness is built on this.

use std::time::Instant;

/// Summary of one benchmark: all times in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Number of measured iterations.
    pub iters: usize,
    /// Mean ms per iteration.
    pub mean_ms: f64,
    /// Median ms per iteration.
    pub p50_ms: f64,
    /// 95th-percentile ms.
    pub p95_ms: f64,
    /// 99th-percentile ms.
    pub p99_ms: f64,
    /// Minimum ms.
    pub min_ms: f64,
}

impl BenchStats {
    /// Computes stats from raw per-iteration durations (ms).
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Self {
            iters: n,
            mean_ms: samples.iter().sum::<f64>() / n as f64,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            min_ms: samples[0],
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.3} ms  p50 {:.3}  p95 {:.3}  p99 {:.3}  (n={})",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms, self.iters
        )
    }
}

/// Runs `f` with `warmup` unmeasured iterations, then measures until either
/// `max_iters` iterations or `budget_ms` of wall time (whichever first,
/// with at least one measured iteration).
pub fn bench_ms(warmup: usize, max_iters: usize, budget_ms: f64, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters.min(4096));
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
        if start.elapsed().as_secs_f64() * 1e3 > budget_ms {
            break;
        }
    }
    BenchStats::from_samples(samples)
}

/// Prevents the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = BenchStats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.p50_ms, 51.0);
        assert_eq!(s.p99_ms, 100.0);
        assert_eq!(s.min_ms, 1.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_respects_budget() {
        let mut n = 0u64;
        let s = bench_ms(2, 1_000_000, 20.0, || {
            n += 1;
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(s.iters >= 1);
        assert!(s.mean_ms > 0.0);
        assert!(s.iters < 1_000_000);
    }
}
